//! Engine-equivalence + PJRT round-trip tests (experiment A3's
//! correctness side): the AOT artifacts loaded through the `xla` crate
//! must reproduce the native engine's numbers on every code path the
//! serving stack uses. Skipped (with a note) when artifacts are absent.

use std::path::PathBuf;
use std::sync::Arc;

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::{Engine, Manifest, PjrtProxy};
use slabsvm::solver::smo::SmoParams;
use slabsvm::solver::{SolverKind, Trainer};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn gram_equivalence_across_buckets_and_kernels() {
    let Some(dir) = artifacts() else { return };
    let pjrt = Engine::pjrt(&dir).unwrap();
    for &(m, seed) in &[(100usize, 1u64), (256, 2), (700, 3)] {
        let ds = SlabConfig::default().generate(m, seed);
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.01 }] {
            let kn = Engine::Native.gram(&ds.x, kernel).unwrap();
            let kp = pjrt.gram(&ds.x, kernel).unwrap();
            assert_eq!(kp.rows(), m);
            for i in 0..m {
                for j in 0..m {
                    let (a, b) = (kp.get(i, j), kn.get(i, j));
                    assert!(
                        (a - b).abs() <= 2e-3 * b.abs().max(1.0),
                        "m={m} {kernel:?} ({i},{j}): pjrt {a} vs native {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn predict_equivalence_with_query_chunking() {
    let Some(dir) = artifacts() else { return };
    let pjrt = Engine::pjrt(&dir).unwrap();
    let train = SlabConfig::default().generate(500, 11);
    let model = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .fit(&train.x)
        .unwrap()
        .model;
    let model = Arc::new(model);

    // 700 queries forces chunking over the q=256 bucket
    let eval = SlabConfig::default().generate_eval(350, 350, 12);
    let (sn, ln) = Engine::Native.predict(&model, &eval.x).unwrap();
    let (sp, lp) = pjrt.predict(&model, &eval.x).unwrap();
    assert_eq!(sp.len(), 700);
    let mut flips = 0;
    for i in 0..700 {
        assert!(
            (sp[i] - sn[i]).abs() <= 1e-3 * sn[i].abs().max(1.0),
            "score {i}: {} vs {}",
            sp[i],
            sn[i]
        );
        if lp[i] != ln[i] {
            flips += 1;
        }
    }
    // disagreements can only occur within f32 noise of a plane
    assert!(flips <= 3, "{flips} label flips");
}

#[test]
fn kkt_sweep_artifact_matches_reference() {
    let Some(dir) = artifacts() else { return };
    let proxy = PjrtProxy::start(&dir).unwrap();
    let ds = SlabConfig::default().generate(300, 21);
    let params = SmoParams::default();
    let out = Trainer::from_smo_params(params)
        .kernel(Kernel::Linear)
        .fit(&ds.x)
        .unwrap()
        .dual;
    let k = Kernel::Linear.gram(&ds.x, 4);
    let m = 300f64;
    let (lo, hi) = (-params.eps / (params.nu2 * m), 1.0 / (params.nu1 * m));

    let (viol, fbar) = proxy
        .kkt_sweep(&k, &out.gamma, out.rho1, out.rho2, lo, hi, 1e-6)
        .unwrap()
        .expect("bucket fits");
    assert_eq!(viol.len(), 300);
    // compare against the rust-side case analysis
    for i in 0..300 {
        let want_f = slabsvm::solver::fbar(out.s[i], out.rho1, out.rho2);
        assert!(
            (fbar[i] - want_f).abs() <= 2e-3 * want_f.abs().max(1.0),
            "fbar {i}: {} vs {want_f}",
            fbar[i]
        );
        let want_v = slabsvm::solver::kkt_violation(
            out.gamma[i], out.s[i], out.rho1, out.rho2, lo, hi, 1e-6,
        );
        // f32 + bound-classification noise: compare loosely, and only
        // flag when the artifact reports a large violation the reference
        // calls clean (or vice versa)
        assert!(
            (viol[i] - want_v).abs() <= 0.05 * (1.0 + want_v.abs()),
            "viol {i}: {} vs {want_v} (gamma={}, s={})",
            viol[i],
            out.gamma[i],
            out.s[i]
        );
    }
}

#[test]
fn manifest_buckets_cover_paper_sizes() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(&dir).unwrap();
    // Table-1 max size is 5000 — Gram path falls back to native there,
    // but decision scoring must cover every trained-model size up to the
    // largest bucket:
    assert!(m.max_m().unwrap() >= 2048);
    assert!(m.max_q().unwrap() >= 256);
    // every artifact parses + compiles lazily; spot-check one executes
    let pjrt = Engine::pjrt(&dir).unwrap();
    let ds = SlabConfig::default().generate(64, 31);
    let k = pjrt.gram(&ds.x, Kernel::Linear).unwrap();
    assert_eq!(k.rows(), 64);
}

#[test]
fn oversize_problems_fall_back_to_native() {
    let Some(dir) = artifacts() else { return };
    let pjrt = Engine::pjrt(&dir).unwrap();
    let ds = SlabConfig::default().generate(3000, 41); // > 2048 bucket
    let k = pjrt.gram(&ds.x, Kernel::Linear).unwrap(); // silently native
    assert_eq!(k.rows(), 3000);
    assert_eq!(pjrt.fallbacks(), 1);
}
