//! Property-based tests over the solver invariants (in-tree `testing`
//! harness; see DESIGN.md §6). Each property runs dozens of randomized
//! cases over datasets, kernels and hyper-parameters, training through
//! the unified `Trainer` API.

use slabsvm::data::synthetic::{Noise, SlabConfig};
use slabsvm::kernel::Kernel;
use slabsvm::solver::smo::SmoParams;
use slabsvm::solver::validate::certify;
use slabsvm::solver::{FitReport, Trainer};
use slabsvm::testing::{forall, Gen};

/// Random-but-valid problem instance.
fn gen_problem(g: &mut Gen) -> (slabsvm::data::Dataset, Kernel, SmoParams) {
    let m = g.size(40, 300);
    let cfg = SlabConfig {
        angle: g.f64(0.0, 1.2),
        offset: g.f64(15.0, 30.0),
        half_len: g.f64(1.0, 4.0),
        spread: g.f64(0.1, 0.5),
        noise: *g.choose(&[Noise::Gaussian, Noise::Laplace]),
        contamination: g.f64(0.0, 0.05),
    };
    let ds = cfg.generate(m, g.rng.next_u64());
    let kernel = *g.choose(&[
        Kernel::Linear,
        Kernel::Rbf { g: 0.01 },
        Kernel::Rbf { g: 0.1 },
    ]);
    let params = SmoParams {
        nu1: g.f64(0.15, 0.8),
        nu2: g.f64(0.02, 0.2),
        eps: g.f64(0.2, 0.8),
        ..Default::default()
    };
    (ds, kernel, params)
}

fn fit(
    ds: &slabsvm::data::Dataset,
    kernel: Kernel,
    params: &SmoParams,
) -> Result<FitReport, String> {
    Trainer::from_smo_params(*params)
        .kernel(kernel)
        .fit(&ds.x)
        .map_err(|e| format!("train failed: {e}"))
}

#[test]
fn prop_feasibility_and_certification() {
    forall("feasibility+kkt", 30, |g| {
        let (ds, kernel, params) = gen_problem(g);
        let out = fit(&ds, kernel, &params)?.dual;
        // both sums conserved to fp accuracy
        let sa: f64 = out.alpha.iter().sum();
        let sb: f64 = out.alpha_bar.iter().sum();
        if (sa - 1.0).abs() > 1e-8 {
            return Err(format!("sum(alpha)={sa}"));
        }
        if (sb - params.eps).abs() > 1e-8 {
            return Err(format!("sum(alpha_bar)={sb} want {}", params.eps));
        }
        // box constraints
        let m = out.alpha.len() as f64;
        let cap_a = 1.0 / (params.nu1 * m);
        let cap_b = params.eps / (params.nu2 * m);
        for i in 0..out.alpha.len() {
            if out.alpha[i] < -1e-12 || out.alpha[i] > cap_a + 1e-12 {
                return Err(format!("alpha[{i}]={} outside box", out.alpha[i]));
            }
            if out.alpha_bar[i] < -1e-12 || out.alpha_bar[i] > cap_b + 1e-12 {
                return Err(format!("alpha_bar[{i}] outside box"));
            }
        }
        // independent certification
        let k = kernel.gram(&ds.x, 4);
        let scale = 1.0 + out.rho2.abs().max(out.rho1.abs());
        certify(
            &k, &out.alpha, &out.alpha_bar, out.rho1, out.rho2,
            params.nu1, params.nu2, params.eps, 1e-2 * scale,
        )
        .map_err(|e| format!("certification: {e}"))?;
        Ok(())
    });
}

#[test]
fn prop_margins_match_gamma() {
    forall("margin-consistency", 20, |g| {
        let (ds, kernel, params) = gen_problem(g);
        let out = fit(&ds, kernel, &params)?.dual;
        let k = kernel.gram(&ds.x, 4);
        for i in 0..out.gamma.len() {
            let si: f64 =
                (0..out.gamma.len()).map(|j| out.gamma[j] * k.get(i, j)).sum();
            if (si - out.s[i]).abs() > 1e-6 * (1.0 + si.abs()) {
                return Err(format!("margin drift at {i}: {si} vs {}", out.s[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_slab_ordered_and_nu_bounds() {
    forall("slab-order+nu", 20, |g| {
        let (ds, kernel, params) = gen_problem(g);
        let out = fit(&ds, kernel, &params)?.dual;
        if out.rho1 > out.rho2 + 1e-9 {
            return Err(format!("rho1 {} > rho2 {}", out.rho1, out.rho2));
        }
        // ν-properties (finite-sample slack 8%)
        let m = out.s.len() as f64;
        let below =
            out.s.iter().filter(|&&s| s < out.rho1 - 1e-9).count() as f64 / m;
        let above =
            out.s.iter().filter(|&&s| s > out.rho2 + 1e-9).count() as f64 / m;
        if below > params.nu1 + 0.08 {
            return Err(format!("below={below} > nu1={}", params.nu1));
        }
        if above > params.nu2 + 0.08 {
            return Err(format!("above={above} > nu2={}", params.nu2));
        }
        Ok(())
    });
}

#[test]
fn prop_objective_independent_of_heuristic_and_seed() {
    use slabsvm::solver::Heuristic;
    forall("heuristic-invariance", 12, |g| {
        let (ds, kernel, params) = gen_problem(g);
        let mut objs = Vec::new();
        for h in [
            Heuristic::PaperMaxFbar,
            Heuristic::MaxViolation,
            Heuristic::RandomViolator,
        ] {
            let p = SmoParams { heuristic: h, seed: g.rng.next_u64(), ..params };
            let report = fit(&ds, kernel, &p)
                .map_err(|e| format!("({h:?}) {e}"))?;
            objs.push(report.stats.objective);
        }
        let lo = objs.iter().cloned().fold(f64::MAX, f64::min);
        let hi = objs.iter().cloned().fold(f64::MIN, f64::max);
        if hi - lo > 1e-2 * hi.abs().max(1e-6) {
            return Err(format!("objectives diverge: {objs:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_model_persistence_is_lossless() {
    forall("persistence", 10, |g| {
        let (ds, kernel, params) = gen_problem(g);
        let model = fit(&ds, kernel, &params)?.model;
        let json = model.to_json().to_string();
        let back = slabsvm::solver::ocssvm::SlabModel::from_json(
            &slabsvm::util::json::Json::parse(&json).unwrap(),
        )
        .map_err(|e| format!("reload: {e}"))?;
        for i in 0..ds.len().min(20) {
            let p = ds.x.row(i);
            if (model.score(p) - back.score(p)).abs() > 1e-12 {
                return Err("score drift after JSON round-trip".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scoring_translation_consistency() {
    // decision depends on the margin s(x) only through (s-rho1)(rho2-s):
    // shifting BOTH rhos and scores by the same additive kernel shift
    // preserves labels. We verify label consistency between the model's
    // classify() and an explicitly recomputed decision.
    forall("decision-consistency", 10, |g| {
        let (ds, kernel, params) = gen_problem(g);
        let model = fit(&ds, kernel, &params)?.model;
        for i in 0..ds.len().min(30) {
            let x = ds.x.row(i);
            let s = model.score(x);
            let manual = if (s - model.rho1) * (model.rho2 - s) >= 0.0 { 1 } else { -1 };
            if manual != model.classify(x) {
                return Err(format!("label mismatch at {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_report_certificate_matches_independent_certify() {
    // the FitReport's built-in certificate (margin-based, O(m)) must
    // agree with a from-scratch Gram-based certification
    forall("certificate-consistency", 10, |g| {
        let (ds, kernel, params) = gen_problem(g);
        let report = fit(&ds, kernel, &params)?;
        let k = kernel.gram(&ds.x, 4);
        let m = ds.len() as f64;
        let cls_tol = (1.0 / (params.nu1 * m))
            .min(params.eps / (params.nu2 * m))
            * 1e-6;
        let full = slabsvm::solver::validate::report(
            &k,
            &report.dual.alpha,
            &report.dual.alpha_bar,
            report.dual.rho1,
            report.dual.rho2,
            params.nu1,
            params.nu2,
            params.eps,
            cls_tol,
        );
        let fast = &report.certificate;
        // margins drift by <= ~1e-8, so the two reports agree loosely
        let scale = 1.0 + report.dual.rho2.abs();
        if (full.max_kkt_violation - fast.max_kkt_violation).abs() > 1e-6 * scale {
            return Err(format!(
                "kkt: full {} vs fast {}",
                full.max_kkt_violation, fast.max_kkt_violation
            ));
        }
        if (full.objective - fast.objective).abs()
            > 1e-6 * full.objective.abs().max(1.0)
        {
            return Err(format!(
                "objective: full {} vs fast {}",
                full.objective, fast.objective
            ));
        }
        Ok(())
    });
}
