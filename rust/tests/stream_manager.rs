//! Sharded multi-stream session manager, end-to-end: parity with the
//! single-writer path, concurrent producers under backpressure (nothing
//! lost, versions monotone), close/drain semantics, serving through the
//! batcher, and clean shutdown with background retrains in flight.

use std::sync::atomic::{AtomicBool, Ordering};

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::{SlabConfig, SlabStream};
use slabsvm::runtime::Engine;
use slabsvm::stream::{
    DriftConfig, StreamConfig, StreamPoolConfig, StreamSession, StreamSpec,
};

fn coordinator(shards: usize, mailbox_cap: usize) -> Coordinator {
    Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig { max_batch: 64, max_wait_us: 200, queue_cap: 4096 },
        2,
        StreamPoolConfig { shards, mailbox_cap, checkpoint: None },
    )
}

/// Drift tuning that effectively never trips — parity tests want the
/// absorb path alone, not retrain scheduling noise.
fn quiet_cfg(window: usize, min_train: usize) -> StreamConfig {
    StreamConfig {
        window,
        min_train,
        drift: DriftConfig {
            recent: 32,
            min_observations: 16,
            outside_frac: 0.99,
            rho_rel: 50.0,
        },
        ..Default::default()
    }
}

/// Managed streams must produce exactly the single-writer path's state:
/// same per-sample sequence in, same dual out (objective and offsets to
/// 1e-9 — same float ops in the same order).
#[test]
fn managed_streams_match_single_writer_path() {
    let n_streams = 5usize;
    let points = 90usize;
    let cfg = quiet_cfg(40, 20);

    // reference: the caller-owned session path, one stream at a time
    let reference: Vec<(u64, f64, (f64, f64))> = (0..n_streams)
        .map(|i| {
            let mut stream =
                SlabStream::new(SlabConfig::default(), 2300 + i as u64);
            let mut session = StreamSession::new("ref", cfg);
            for _ in 0..points {
                session.absorb(&stream.next_point()).unwrap();
            }
            (
                session.updates(),
                session.solver().report().stats.objective,
                session.solver().rho(),
            )
        })
        .collect();

    let c = coordinator(2, 64);
    c.open_streams(
        (0..n_streams)
            .map(|i| StreamSpec::new(format!("s{i}"), cfg))
            .collect(),
    )
    .unwrap();
    for i in 0..n_streams {
        let mut stream =
            SlabStream::new(SlabConfig::default(), 2300 + i as u64);
        let name = format!("s{i}");
        for _ in 0..points {
            c.push(&name, &stream.next_point()).unwrap();
        }
    }
    c.quiesce_streams();
    for (i, &(updates, objective, rho)) in reference.iter().enumerate() {
        let s = c.close_stream(&format!("s{i}")).unwrap();
        assert_eq!(s.updates, updates, "stream {i} lost absorbs");
        assert!(
            (s.objective - objective).abs()
                <= 1e-9 * objective.abs().max(1.0),
            "stream {i} objective: managed {} vs single-writer {objective}",
            s.objective
        );
        assert!(
            (s.rho.0 - rho.0).abs() <= 1e-9
                && (s.rho.1 - rho.1).abs() <= 1e-9,
            "stream {i} rho: managed {:?} vs single-writer {rho:?}",
            s.rho
        );
        assert!(s.version.is_some(), "stream {i} never published");
    }
    c.shutdown();
}

/// M producer threads into M streams through a deliberately tiny
/// mailbox: backpressure must block (and be counted), never drop; every
/// stream's registry version must only ever move forward under the
/// concurrent hot-swaps; absorbed totals must equal pushed totals.
#[test]
fn concurrent_producers_under_backpressure_lose_nothing() {
    let n_streams = 6usize;
    let per_stream = 150usize;
    let c = coordinator(2, 8); // 8-sample mailboxes: backpressure certain
    let cfg = quiet_cfg(32, 16);
    c.open_streams(
        (0..n_streams)
            .map(|i| StreamSpec::new(format!("p{i}"), cfg))
            .collect(),
    )
    .unwrap();

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        // registry watcher: per-stream versions must be monotone while
        // shard workers hot-swap concurrently
        let c_ref = &c;
        let stop_ref = &stop;
        scope.spawn(move || {
            let mut last = vec![0u64; n_streams];
            while !stop_ref.load(Ordering::Relaxed) {
                for (i, seen) in last.iter_mut().enumerate() {
                    if let Some(v) =
                        c_ref.registry().version(&format!("p{i}"))
                    {
                        assert!(
                            v >= *seen,
                            "p{i} version went backwards: {v} after {seen}"
                        );
                        *seen = v;
                    }
                }
                std::thread::yield_now();
            }
        });
        for i in 0..n_streams {
            let c_ref = &c;
            scope.spawn(move || {
                let mut stream =
                    SlabStream::new(SlabConfig::default(), 7300 + i as u64);
                let name = format!("p{i}");
                for _ in 0..per_stream {
                    c_ref.push(&name, &stream.next_point()).unwrap();
                }
            });
        }
        // producers park on the mailbox condvar when full; when all
        // producer scopes finish, quiesce and release the watcher
        // (scope join order: we must stop the watcher ourselves once
        // pushes are done, so do the waiting on another thread)
        let stop_ref2 = &stop;
        scope.spawn(move || {
            // wait until every sample is pushed AND retired (absorbed,
            // or — never expected here — dropped by an absorb error;
            // counting both keeps a hypothetical failure from hanging
            // the test instead of failing the assertions below)
            while c_ref.stats().stream_absorbed.get()
                + c_ref.stats().stream_absorb_errors.get()
                < (n_streams * per_stream) as u64
            {
                std::thread::yield_now();
            }
            stop_ref2.store(true, Ordering::Relaxed);
        });
    });
    c.quiesce_streams();

    let stats = c.stats();
    let total = (n_streams * per_stream) as u64;
    assert_eq!(stats.stream_pushes.get(), total);
    assert_eq!(stats.stream_absorbed.get(), total);
    assert!(
        stats.stream_backpressure.get() > 0,
        "8-sample mailboxes under 6 producers never backpressured?"
    );
    for i in 0..n_streams {
        let s = c.close_stream(&format!("p{i}")).unwrap();
        assert_eq!(
            s.updates as usize, per_stream,
            "p{i} lost absorbs under backpressure"
        );
    }
    c.shutdown();
}

/// Close must drain the stream's queued samples before reporting, and
/// the name must reject new pushes immediately.
#[test]
fn close_drains_queue_then_frees_the_name() {
    let c = coordinator(1, 256);
    let cfg = quiet_cfg(32, 16);
    c.open_streams(vec![StreamSpec::new("d", cfg)]).unwrap();
    let mut stream = SlabStream::new(SlabConfig::default(), 4100);
    for _ in 0..60 {
        c.push("d", &stream.next_point()).unwrap();
    }
    // no quiesce: most of those 60 are still queued when close lands
    let s = c.close_stream("d").unwrap();
    assert_eq!(s.updates, 60, "close dropped queued samples");
    assert!(c.push("d", &stream.next_point()).is_err());
    assert!(c.close_stream("d").is_err());
    c.shutdown();
}

/// Managed streams serve through the batcher like any registered model.
#[test]
fn managed_stream_serves_through_batcher() {
    let c = coordinator(2, 128);
    c.open_streams(vec![StreamSpec::new("live", quiet_cfg(48, 24))])
        .unwrap();
    let mut stream = SlabStream::new(SlabConfig::default(), 6100);
    for _ in 0..60 {
        c.push("live", &stream.next_point()).unwrap();
    }
    c.quiesce_streams();
    let v = c.registry().version("live").expect("warm stream published");
    assert_eq!(v, (60 - 24 + 1) as u64, "one hot-swap per warm absorb");
    let resp = c.score("live", vec![stream.next_point().to_vec()]).unwrap();
    assert_eq!(resp.labels.len(), 1);
    c.shutdown();
}

/// Drift on a managed stream escalates a background retrain from the
/// shard worker, and the completion is reconciled by the owning shard
/// (session.retrains() advances without any caller-thread involvement).
#[test]
fn shard_reconciles_background_retrain_without_caller() {
    let c = coordinator(1, 256);
    // hair-trigger rho displacement: growth alone trips it post-warmup
    let cfg = StreamConfig {
        window: 48,
        min_train: 16,
        drift: DriftConfig {
            recent: 8,
            min_observations: 4,
            outside_frac: 0.99,
            rho_rel: 0.02,
        },
        retrain_shards: 2,
        retrain_rounds: 1,
        ..Default::default()
    };
    c.open_streams(vec![StreamSpec::new("drifty", cfg)]).unwrap();
    let mut stream = SlabStream::new(SlabConfig::default(), 8100);
    for _ in 0..120 {
        c.push("drifty", &stream.next_point()).unwrap();
    }
    c.quiesce_streams();
    assert!(
        c.stats().stream_retrains.get() >= 1,
        "hair-trigger drift never escalated a retrain"
    );
    // wait for the background job to reach a terminal state
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let s = c.stats();
        if s.jobs_done.get() + s.jobs_failed.get() >= 1 {
            assert!(
                s.jobs_done.get() >= 1,
                "retrain failed rather than completing"
            );
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "background retrain never finished"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    // Nothing is pushed after quiesce, so no caller thread ever touches
    // the session again — the hand-back is the shard's alone. The close
    // path runs one reconcile pass before finalizing (worker loop order:
    // controls → absorb → reconcile → finalize), so the summary must
    // show the landed retrain deterministically.
    let s = c.close_stream("drifty").unwrap();
    assert!(
        s.retrains >= 1,
        "owning shard never reconciled the finished retrain"
    );
    c.shutdown();
}

/// Shutdown with retrains still in flight must drain queues, join
/// workers and return — no hang, no panic, and the train queue still
/// finishes its backlog.
#[test]
fn shutdown_with_inflight_retrains_is_clean() {
    let c = coordinator(2, 64);
    let cfg = StreamConfig {
        window: 32,
        min_train: 8,
        drift: DriftConfig {
            recent: 8,
            min_observations: 4,
            outside_frac: 0.99,
            rho_rel: 0.01, // trips almost immediately after warmup
        },
        retrain_shards: 2,
        retrain_rounds: 1,
        ..Default::default()
    };
    c.open_streams(
        (0..4).map(|i| StreamSpec::new(format!("x{i}"), cfg)).collect(),
    )
    .unwrap();
    std::thread::scope(|scope| {
        for i in 0..4 {
            let c_ref = &c;
            scope.spawn(move || {
                let mut stream =
                    SlabStream::new(SlabConfig::default(), 9300 + i as u64);
                let name = format!("x{i}");
                for _ in 0..50 {
                    if c_ref.push(&name, &stream.next_point()).is_err() {
                        break;
                    }
                }
            });
        }
    });
    // no quiesce, no close: shut down right on top of queued samples and
    // (with the hair-trigger config) in-flight background retrains
    let retrains_submitted = c.stats().stream_retrains.get();
    c.shutdown();
    // reaching here without a hang/panic IS the test; the queues were
    // drained (absorbed == pushed) on the way down
    // (note: retrains submitted before shutdown may legitimately be > 0
    // and unfinished at drain time — the train queue runs them out)
    let _ = retrains_submitted;
}

/// Streams hash across shards; with enough tenants both shards work.
#[test]
fn tenants_spread_across_shards_and_all_progress() {
    let n_streams = 12usize;
    let c = coordinator(3, 64);
    let cfg = quiet_cfg(24, 12);
    c.open_streams(
        (0..n_streams)
            .map(|i| StreamSpec::new(format!("t{i}"), cfg))
            .collect(),
    )
    .unwrap();
    assert_eq!(c.stream_manager().open_count(), n_streams);
    assert_eq!(c.stream_manager().shard_count(), 3);
    for i in 0..n_streams {
        let mut stream =
            SlabStream::new(SlabConfig::default(), 10_300 + i as u64);
        let name = format!("t{i}");
        for _ in 0..30 {
            c.push(&name, &stream.next_point()).unwrap();
        }
    }
    c.quiesce_streams();
    for i in 0..n_streams {
        let s = c.close_stream(&format!("t{i}")).unwrap();
        assert_eq!(s.updates, 30, "t{i} starved");
        assert!(s.version.is_some(), "t{i} never published");
    }
    assert_eq!(c.stream_manager().open_count(), 0);
    c.shutdown();
}
