//! Errata-regression tests: every deviation from the paper's text that
//! DESIGN.md §1.1 documents is pinned here, with the failure mode the
//! uncorrected version would produce. Training goes through the unified
//! `Trainer` API (bit-identical to the legacy SMO path — see
//! api_parity.rs).

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::linalg::Matrix;
use slabsvm::solver::smo::{solve_gamma_relaxed, SmoParams};
use slabsvm::solver::{check_params, fbar, kkt_violation, FitReport, Trainer};

fn paper_params() -> SmoParams {
    SmoParams { nu1: 0.5, nu2: 0.01, eps: 2.0 / 3.0, ..Default::default() }
}

fn fit(x: &Matrix, p: &SmoParams) -> FitReport {
    Trainer::from_smo_params(*p).kernel(Kernel::Linear).fit(x).unwrap()
}

/// Erratum A (the big one): eqs. (30)–(32) drop Σα = 1 / Σᾱ = ε in
/// favour of their difference. The relaxed problem has a strictly lower
/// optimum whose solution is dual-infeasible for the true OCSSVM: its
/// negative mass exceeds ε. The faithful block SMO keeps both sums.
#[test]
fn gamma_relaxation_is_not_the_ocssvm_dual() {
    let ds = SlabConfig::default().generate(300, 1);
    let k = Kernel::Linear.gram(&ds.x, 4);
    let p = paper_params();

    let (gamma_rel, _, _, rel_stats) = solve_gamma_relaxed(&k, &p).unwrap();
    let report = fit(&ds.x, &p);

    // faithful solution conserves both sums
    let sa: f64 = report.dual.alpha.iter().sum();
    let sb: f64 = report.dual.alpha_bar.iter().sum();
    assert!((sa - 1.0).abs() < 1e-9);
    assert!((sb - p.eps).abs() < 1e-9);

    // relaxed solution violates the hidden constraint...
    let neg_mass: f64 = gamma_rel.iter().filter(|g| **g < 0.0).map(|g| -*g).sum();
    assert!(
        neg_mass > p.eps * 1.5,
        "relaxed negative mass {neg_mass} should blow past eps={}",
        p.eps
    );
    // ...which buys it a strictly lower objective (larger feasible set)
    assert!(rel_stats.objective < 0.9 * report.stats.objective);
}

/// Erratum B: with a linear kernel, a slab exists only if the data's
/// radial spread satisfies R_min/R_max > ε; on origin-crossing data even
/// the faithful dual collapses to w ≈ 0 (degenerate slab). This is why
/// the figures' toy data must sit away from the origin — undocumented in
/// the paper.
#[test]
fn linear_kernel_needs_radial_margin() {
    let p = paper_params();

    // origin-crossing band: R_min/R_max ≈ 0.26 < eps = 2/3 -> collapse
    let near = SlabConfig { offset: 0.8, ..Default::default() }.generate(300, 2);
    let out_near = fit(&near.x, &p);
    // offset band: R_min/R_max ≈ 0.92 > 2/3 -> macroscopic slab
    let far = SlabConfig::default().generate(300, 2);
    let out_far = fit(&far.x, &p);

    assert!(
        out_near.stats.objective < 1e-6,
        "origin-crossing data must degenerate, got obj {}",
        out_near.stats.objective
    );
    assert!(
        out_far.stats.objective > 1.0,
        "offset data must not degenerate, got obj {}",
        out_far.stats.objective
    );
}

/// Erratum #1/#5 (KKT case table): at the α cap the condition is
/// s ≤ ρ1 (lower-plane margin violator), at the ᾱ cap it is s ≥ ρ2 —
/// the paper's signs in (3) and the derived cases would have them
/// reversed. The γ-form helper must encode the corrected table.
#[test]
fn kkt_case_table_is_errata_corrected() {
    let (lo, hi, tol) = (-0.1, 0.2, 1e-9);
    // γ at hi with s far BELOW ρ1: satisfied (outlier below the plane)
    assert_eq!(kkt_violation(0.2, -5.0, 0.0, 1.0, lo, hi, tol), 0.0);
    // γ at hi with s above ρ1: violation (the uncorrected table would
    // call this satisfied)
    assert!(kkt_violation(0.2, 0.5, 0.0, 1.0, lo, hi, tol) > 0.0);
    // γ at lo with s far ABOVE ρ2: satisfied (violator above the slab)
    assert_eq!(kkt_violation(-0.1, 9.0, 0.0, 1.0, lo, hi, tol), 0.0);
    // γ at lo with s below ρ2: violation
    assert!(kkt_violation(-0.1, 0.5, 0.0, 1.0, lo, hi, tol) > 0.0);
}

/// Erratum #4: the max-|f̄| first choice must range over KKT violators
/// only. A literal argmax over ALL points keeps selecting the deepest
/// interior point (largest f̄ > 0), which satisfies KKT and admits no
/// productive pair — SMO would loop forever. We verify the solver
/// terminates AND that interior points indeed maximize |f̄|.
#[test]
fn paper_heuristic_must_be_restricted_to_violators() {
    let ds = SlabConfig::default().generate(200, 3);
    let out = fit(&ds.x, &paper_params()).dual;
    // the max |f̄| point at the optimum is interior (not a violator)
    let mut best_fbar = f64::MIN;
    let mut best_i = 0;
    for i in 0..out.s.len() {
        let f = fbar(out.s[i], out.rho1, out.rho2).abs();
        if f > best_fbar {
            best_fbar = f;
            best_i = i;
        }
    }
    // that point sits strictly inside the slab with gamma == 0-ish:
    // selecting it (as the literal reading would) can make no progress
    let g = out.gamma[best_i];
    assert!(
        out.s[best_i] > out.rho1 - 1e-6 && out.s[best_i] < out.rho2 + 1e-6
            || g.abs() > 0.0,
        "max-|f̄| point should be interior at the optimum"
    );
}

/// Erratum #7: the stopping rule must be "no violator above tol", not
/// the paper's "at most one violator" — a lone violator pairs fine with
/// a non-violating partner. We pin this by checking the solver's final
/// state has NO violation above the scaled tolerance (not one).
#[test]
fn converged_state_has_zero_violators() {
    let ds = SlabConfig::default().generate(500, 4);
    let p = paper_params();
    let out = fit(&ds.x, &p).dual;
    let m = out.gamma.len() as f64;
    let (lo, hi) = check_params(500, p.nu1, p.nu2, p.eps).unwrap();
    let scale = 1.0 + out.s.iter().map(|v| v.abs()).sum::<f64>() / m;
    let viol_count = (0..500)
        .filter(|&i| {
            kkt_violation(out.gamma[i], out.s[i], out.rho1, out.rho2, lo, hi, 1e-12)
                > p.tol * scale * 2.0
        })
        .count();
    assert_eq!(viol_count, 0, "no point may violate KKT at exit");
}

/// Erratum #3 (eq. 52 typo `1/(ν_i m)`): the α box cap uses ν₁. Pinned
/// via check_params.
#[test]
fn alpha_cap_uses_nu1() {
    let (lo, hi) = check_params(100, 0.25, 0.5, 0.5).unwrap();
    assert!((hi - 1.0 / (0.25 * 100.0)).abs() < 1e-15);
    assert!((lo + 0.5 / (0.5 * 100.0)).abs() < 1e-15);
}

/// Fig. 1 / Fig. 2 constants both produce valid, ordered slabs — the
/// captions' parameter sets are mutually inconsistent in the text but
/// both must work.
#[test]
fn both_figure_parameter_sets_work() {
    let ds = SlabConfig::default().generate(400, 5);
    for (nu1, nu2, eps) in [(0.5, 0.01, 2.0 / 3.0), (0.2, 0.08, 0.5)] {
        let p = SmoParams { nu1, nu2, eps, ..Default::default() };
        let report = fit(&ds.x, &p);
        assert!(
            report.dual.rho1 < report.dual.rho2,
            "slab must be ordered for nu1={nu1} nu2={nu2} eps={eps}"
        );
        assert!(report.model.width() > 0.0);
    }
}
