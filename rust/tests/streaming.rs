//! Streaming subsystem end-to-end: incremental/batch parity on a pinned
//! stream, independent certification, and the drift → background
//! retrain → hot-swap pipeline under live scoring traffic.

use slabsvm::coordinator::{BatcherConfig, Coordinator, JobStatus};
use slabsvm::data::synthetic::{
    Drift, DriftSchedule, SlabConfig, SlabStream,
};
use slabsvm::kernel::Kernel;
use slabsvm::metrics::roc_auc;
use slabsvm::runtime::Engine;
use slabsvm::solver::validate::certify;
use slabsvm::solver::Trainer;
use slabsvm::stream::{
    DriftConfig, IncrementalConfig, IncrementalSmo, StreamConfig,
};

/// Acceptance: after N incremental adds + M decremental evictions on a
/// pinned synthetic stream, objective, (ρ1, ρ2) and decision AUC match a
/// from-scratch batch `Trainer` fit on the same window within 1e-3
/// relative tolerance.
#[test]
fn incremental_matches_batch_after_adds_and_evictions() {
    let cfg = IncrementalConfig::default();
    let mut inc = IncrementalSmo::new(Kernel::Linear, 160, 2, cfg);
    let mut stream = SlabStream::new(SlabConfig::default(), 9001);
    // 160 adds fill the window; 60 more each evict the oldest
    for _ in 0..220 {
        inc.push(&stream.next_point()).unwrap();
    }
    let streamed = inc.report();
    let window = inc.window().matrix();
    let batch = Trainer::from_smo_params(cfg.smo)
        .kernel(Kernel::Linear)
        .fit(&window)
        .unwrap();

    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-9);
    assert!(
        rel(streamed.stats.objective, batch.stats.objective) < 1e-3,
        "objective: streamed {} vs batch {}",
        streamed.stats.objective,
        batch.stats.objective
    );
    assert!(
        rel(streamed.dual.rho1, batch.dual.rho1) < 1e-3,
        "rho1: streamed {} vs batch {}",
        streamed.dual.rho1,
        batch.dual.rho1
    );
    assert!(
        rel(streamed.dual.rho2, batch.dual.rho2) < 1e-3,
        "rho2: streamed {} vs batch {}",
        streamed.dual.rho2,
        batch.dual.rho2
    );

    let eval = SlabConfig::default().generate_eval(300, 300, 9002);
    let margins = |m: &slabsvm::solver::ocssvm::SlabModel| -> Vec<f64> {
        (0..eval.len()).map(|i| m.margin(eval.x.row(i))).collect()
    };
    let auc_streamed = roc_auc(&eval.y, &margins(&streamed.model));
    let auc_batch = roc_auc(&eval.y, &margins(&batch.model));
    assert!(
        (auc_streamed - auc_batch).abs() < 1e-3,
        "AUC: streamed {auc_streamed} vs batch {auc_batch}"
    );
}

/// The streamed dual certifies against a freshly built Gram matrix —
/// independent of every incremental bookkeeping path.
#[test]
fn streamed_solution_certifies_independently() {
    let cfg = IncrementalConfig::default();
    let mut inc = IncrementalSmo::new(Kernel::Rbf { g: 0.05 }, 90, 2, cfg);
    let mut stream = SlabStream::new(SlabConfig::default(), 9003);
    for _ in 0..140 {
        inc.push(&stream.next_point()).unwrap();
    }
    let report = inc.report();
    let k = Kernel::Rbf { g: 0.05 }.gram(&inc.window().matrix(), 2);
    certify(
        &k,
        &report.dual.alpha,
        &report.dual.alpha_bar,
        report.dual.rho1,
        report.dual.rho2,
        cfg.smo.nu1,
        cfg.smo.nu2,
        cfg.smo.eps,
        1e-3,
    )
    .expect("streamed dual must satisfy feasibility + KKT");
}

/// Acceptance: a mean-shift drift injected mid-stream trips the
/// DriftMonitor, the background cascade retrain completes, and the
/// registry serves the new model version while scoring continues with
/// no request errors.
#[test]
fn drift_trips_background_retrain_while_scoring_continues() {
    let c = Coordinator::start(
        Engine::Native,
        BatcherConfig { max_batch: 64, max_wait_us: 200, queue_cap: 4096 },
        2,
    );
    let mut session = c.open_stream(
        "live",
        StreamConfig {
            window: 200,
            min_train: 100,
            drift: DriftConfig {
                recent: 48,
                min_observations: 24,
                outside_frac: 0.9,
                rho_rel: 8.0, // the outside-fraction signal drives this test
            },
            retrain_shards: 2,
            retrain_rounds: 2,
            ..Default::default()
        },
    );
    // the band sags well below the learned slab mid-stream
    let mut stream = SlabStream::new(SlabConfig::default(), 4242).with_drift(
        DriftSchedule {
            drift: Drift::MeanShift { delta: -9.0 },
            start: 400,
            duration: 60,
        },
    );

    // a sustained shift may legitimately retrain more than once (each
    // completion re-baselines the monitor against a still-moving stream);
    // one in-flight job at a time is the invariant
    let mut last_version = 0u64;
    let mut first_submit = None;
    let mut version_at_first_submit = 0u64;
    let mut completed_version = None;
    let mut scored = 0u64;
    for i in 0..900 {
        let x = stream.next_point();
        let in_flight_before = session.pending_retrain();
        let u = c.stream_push(&mut session, &x).unwrap();
        if let Some(v) = u.version {
            assert!(v > last_version, "published version must be monotone");
            last_version = v;
        }
        if let Some(id) = u.retrain_submitted {
            assert!(
                in_flight_before.is_none() || u.retrain_completed.is_some(),
                "submitted a second retrain while one was in flight"
            );
            assert!(i >= 400, "retrain tripped before the drift was injected");
            if first_submit.is_none() {
                first_submit = Some(id);
                version_at_first_submit = last_version;
            }
        }
        if let Some(v) = u.retrain_completed {
            completed_version = Some(v);
        }
        // live scoring traffic throughout — warmup excluded, errors fatal
        if last_version > 0 && i % 7 == 0 {
            let resp = c
                .score("live", vec![x.to_vec()])
                .expect("scoring request failed during streaming/retrain");
            assert_eq!(resp.labels.len(), 1);
            scored += 1;
        }
    }
    let id = first_submit.expect("mean shift never tripped the drift monitor");
    // the first job ran in the background; make sure it landed
    let status = c.wait_job(id).expect("job vanished");
    assert!(
        matches!(status, JobStatus::Done { .. }),
        "background retrain failed: {status:?}"
    );
    if completed_version.is_none() {
        // stream ended before reconciliation; one more push reconciles
        let u = c.stream_push(&mut session, &stream.next_point()).unwrap();
        completed_version = u.retrain_completed;
        if let Some(v) = u.version {
            last_version = v;
        }
    }
    let retrained = completed_version.expect("retrain never reconciled");
    assert!(
        retrained > version_at_first_submit,
        "retrained model must land at a newer registry version"
    );
    assert!(session.retrains() >= 1);
    assert!(scored > 80, "scoring path starved: only {scored} requests");
    // the post-retrain model keeps serving
    let resp = c.score("live", vec![stream.next_point().to_vec()]).unwrap();
    assert_eq!(resp.labels.len(), 1);
    assert!(c.model("live").is_some());
    c.shutdown();
}
