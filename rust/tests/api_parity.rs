//! API-redesign safety net: the unified `Solver` / `Trainer` path must
//! reproduce the legacy per-module `train` free functions **exactly** —
//! same objective, same dual vector γ, same (ρ1, ρ2) — for every
//! [`SolverKind`]. Plus the `FromStr`/`Display` round-trip contracts the
//! CLI and config layers rely on.
//!
//! The legacy shims are deprecated; calling them here is the point.
#![allow(deprecated)]

use slabsvm::cache::{CachedRows, Policy};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::Kernel;
use slabsvm::solver::api::{SolverKind, Trainer, NO_UPPER_PLANE};
use slabsvm::solver::smo::SmoParams;
use slabsvm::solver::warmstart::WarmStartParams;
use slabsvm::solver::{cascade, ocsvm_smo, qp_ipm, qp_pg, smo, warmstart, Heuristic};

/// Objective agreement bound. The two paths run the identical core
/// solve on the identical Gram, so this is slack over bit-equality —
/// and far inside the redesign's 1e-8 acceptance bound.
const OBJ_TOL: f64 = 1e-9;

fn assert_gamma_eq(ours: &[f64], legacy: &[f64], kind: SolverKind) {
    assert_eq!(ours.len(), legacy.len(), "{kind}: gamma length");
    for (i, (a, b)) in ours.iter().zip(legacy).enumerate() {
        assert!(
            (a - b).abs() <= 1e-12,
            "{kind}: gamma[{i}] diverged: {a} vs {b}"
        );
    }
}

#[test]
fn parity_smo() {
    let ds = SlabConfig::default().generate(300, 11);
    let p = SmoParams::default();
    let (legacy_model, legacy) =
        smo::train_full(&ds.x, Kernel::Linear, &p).unwrap();
    let report = Trainer::from_smo_params(p)
        .kernel(Kernel::Linear)
        .fit(&ds.x)
        .unwrap();
    assert!(
        (report.stats.objective - legacy.stats.objective).abs() <= OBJ_TOL,
        "objective: {} vs {}",
        report.stats.objective,
        legacy.stats.objective
    );
    assert_gamma_eq(&report.dual.gamma, &legacy.gamma, SolverKind::Smo);
    assert_eq!(report.dual.rho1, legacy.rho1);
    assert_eq!(report.dual.rho2, legacy.rho2);
    assert_eq!(report.model.n_sv(), legacy_model.n_sv());

    // the single deprecated-model entry point agrees too
    let single = smo::train(&ds.x, Kernel::Linear, &p).unwrap();
    assert_eq!(single.rho1, report.model.rho1);

    // and the trait object path (registry-style dispatch) is the same fit
    let via_trait = SolverKind::Smo
        .default_solver()
        .fit(&ds.x, Kernel::Linear)
        .unwrap();
    assert_gamma_eq(&via_trait.dual.gamma, &legacy.gamma, SolverKind::Smo);
}

#[test]
fn parity_pg() {
    let ds = SlabConfig::default().generate(150, 12);
    let p = qp_pg::PgParams::default();
    let k = Kernel::Linear.gram(&ds.x, 4);
    let (alpha, alpha_bar, rho1, rho2, stats) = qp_pg::solve(&k, &p).unwrap();
    let legacy_gamma: Vec<f64> =
        alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();

    let report = Trainer::new(SolverKind::Pg)
        .kernel(Kernel::Linear)
        .fit(&ds.x)
        .unwrap();
    assert!(
        (report.stats.objective - stats.objective).abs() <= OBJ_TOL,
        "objective: {} vs {}",
        report.stats.objective,
        stats.objective
    );
    assert_gamma_eq(&report.dual.gamma, &legacy_gamma, SolverKind::Pg);
    assert_eq!(report.dual.rho1, rho1);
    assert_eq!(report.dual.rho2, rho2);

    // deprecated end-to-end shim
    let (legacy_model, legacy_stats) =
        qp_pg::train(&ds.x, Kernel::Linear, &p).unwrap();
    assert!((legacy_stats.objective - stats.objective).abs() <= OBJ_TOL);
    assert_eq!(legacy_model.rho1, report.model.rho1);
}

#[test]
fn parity_ipm() {
    let ds = SlabConfig::default().generate(100, 13);
    let p = qp_ipm::IpmParams::default();
    let k = Kernel::Linear.gram(&ds.x, 4);
    let (alpha, alpha_bar, rho1, rho2, stats) = qp_ipm::solve(&k, &p).unwrap();
    let legacy_gamma: Vec<f64> =
        alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();

    let report = Trainer::new(SolverKind::Ipm)
        .kernel(Kernel::Linear)
        .fit(&ds.x)
        .unwrap();
    assert!(
        (report.stats.objective - stats.objective).abs() <= OBJ_TOL,
        "objective: {} vs {}",
        report.stats.objective,
        stats.objective
    );
    assert_gamma_eq(&report.dual.gamma, &legacy_gamma, SolverKind::Ipm);
    assert_eq!(report.dual.rho1, rho1);
    assert_eq!(report.dual.rho2, rho2);
}

#[test]
fn parity_ocsvm() {
    let ds = SlabConfig::default().generate(200, 14);
    let p = ocsvm_smo::OcsvmParams::default();
    let k = Kernel::Rbf { g: 0.5 }.gram(&ds.x, 4);
    let (alpha, rho, stats) = ocsvm_smo::solve(&k, &p).unwrap();

    let report = Trainer::new(SolverKind::OcsvmSmo)
        .kernel(Kernel::Rbf { g: 0.5 })
        .nu1(p.nu)
        .fit(&ds.x)
        .unwrap();
    assert!(
        (report.stats.objective - stats.objective).abs() <= OBJ_TOL,
        "objective: {} vs {}",
        report.stats.objective,
        stats.objective
    );
    // the embedding carries gamma = alpha, rho1 = rho, no upper plane
    assert_gamma_eq(&report.dual.gamma, &alpha, SolverKind::OcsvmSmo);
    assert_eq!(report.dual.rho1, rho);
    assert_eq!(report.dual.rho2, NO_UPPER_PLANE);

    // decision parity against the legacy OcsvmModel on held-out points
    let (legacy_model, _) =
        ocsvm_smo::train(&ds.x, Kernel::Rbf { g: 0.5 }, &p).unwrap();
    let eval = SlabConfig::default().generate_eval(100, 100, 15);
    for i in 0..eval.len() {
        assert_eq!(
            report.model.classify(eval.x.row(i)),
            legacy_model.classify(eval.x.row(i)),
            "decision diverged at eval row {i}"
        );
    }
}

#[test]
fn parity_warmstart_layer() {
    let ds = SlabConfig::default().generate(250, 16);
    let p = WarmStartParams { smo: SmoParams::default(), epochs: 2 };
    let (_, legacy) = warmstart::train(&ds.x, Kernel::Linear, &p).unwrap();
    let report = Trainer::from_smo_params(p.smo)
        .kernel(Kernel::Linear)
        .warm_start(p.epochs)
        .fit(&ds.x)
        .unwrap();
    assert!(
        (report.stats.objective - legacy.stats.objective).abs() <= OBJ_TOL,
        "objective: {} vs {}",
        report.stats.objective,
        legacy.stats.objective
    );
    assert_gamma_eq(&report.dual.gamma, &legacy.gamma, SolverKind::Smo);
    assert_eq!(report.dual.rho1, legacy.rho1);
    assert_eq!(report.dual.rho2, legacy.rho2);
}

#[test]
fn parity_cached_layer() {
    let ds = SlabConfig::default().generate(150, 17);
    let p = SmoParams::default();
    let cache = CachedRows::with_policy(&ds.x, Kernel::Linear, 32, Policy::Lru);
    let (_, legacy) = smo::train_cached(&ds.x, Kernel::Linear, &p, cache).unwrap();
    let report = Trainer::from_smo_params(p)
        .kernel(Kernel::Linear)
        .cache_rows(32, Policy::Lru)
        .fit(&ds.x)
        .unwrap();
    assert!(
        (report.stats.objective - legacy.stats.objective).abs() <= OBJ_TOL,
        "objective: {} vs {}",
        report.stats.objective,
        legacy.stats.objective
    );
    assert_gamma_eq(&report.dual.gamma, &legacy.gamma, SolverKind::Smo);
    assert_eq!(report.stats.cache.misses, legacy.stats.cache.misses);
}

#[test]
fn parity_cascade_layer() {
    let ds = SlabConfig::default().generate(400, 18);
    let smo_p = SmoParams { nu1: 0.1, nu2: 0.05, eps: 0.5, ..Default::default() };
    let p = cascade::CascadeParams { smo: smo_p, shards: 4, max_rounds: 3 };
    let (legacy_model, legacy) = cascade::train(&ds.x, Kernel::Linear, &p).unwrap();
    let report = Trainer::from_smo_params(smo_p)
        .kernel(Kernel::Linear)
        .cascade(4, 3)
        .fit(&ds.x)
        .unwrap();
    assert_gamma_eq(&report.dual.gamma, &legacy.outcome.gamma, SolverKind::Smo);
    assert_eq!(report.dual.rho1, legacy.outcome.rho1);
    assert_eq!(report.model.n_sv(), legacy_model.n_sv());
    let trace = report.cascade.as_ref().expect("trace");
    assert_eq!(trace.candidate_sizes, legacy.candidate_sizes);
    assert_eq!(trace.rounds, legacy.rounds);
}

// ---------------------------------------------------------------------------
// FromStr <-> Display round-trips (CLI / config contract)
// ---------------------------------------------------------------------------

#[test]
fn solver_kind_name_roundtrip() {
    for kind in SolverKind::ALL {
        let name = kind.to_string();
        assert_eq!(name.parse::<SolverKind>().unwrap(), kind, "{name}");
    }
    // explicit canonical names stay stable (config files depend on them)
    assert_eq!("smo".parse::<SolverKind>().unwrap(), SolverKind::Smo);
    assert_eq!("pg".parse::<SolverKind>().unwrap(), SolverKind::Pg);
    assert_eq!("ipm".parse::<SolverKind>().unwrap(), SolverKind::Ipm);
    assert_eq!(
        "ocsvm-smo".parse::<SolverKind>().unwrap(),
        SolverKind::OcsvmSmo
    );
}

#[test]
fn solver_kind_rejects_unknown_names() {
    for bad in ["", "newton", "SMO", "smo ", "qp", "interior point"] {
        assert!(
            bad.parse::<SolverKind>().is_err(),
            "{bad:?} should be rejected"
        );
    }
}

#[test]
fn heuristic_name_roundtrip() {
    for h in Heuristic::ALL {
        let name = h.to_string();
        assert_eq!(name.parse::<Heuristic>().unwrap(), h, "{name}");
        assert_eq!(name, h.name());
    }
}

#[test]
fn heuristic_rejects_unknown_names() {
    for bad in ["", "bogus", "PAPER", "max violation"] {
        assert!(bad.parse::<Heuristic>().is_err(), "{bad:?} should be rejected");
    }
}

#[test]
fn every_kind_constructible_from_str_and_fits() {
    // the acceptance criterion, end to end: name -> SolverKind ->
    // Solver::fit, one loop, no per-solver dispatch anywhere
    let ds = SlabConfig::default().generate(90, 19);
    for name in ["smo", "pg", "ipm", "ocsvm-smo", "approx"] {
        let kind: SolverKind = name.parse().unwrap();
        let report = kind
            .default_solver()
            .fit(&ds.x, Kernel::Linear)
            .unwrap_or_else(|e| panic!("{name} failed: {e}"));
        assert!(report.stats.iterations > 0, "{name}");
        assert!(report.model.n_sv() > 0, "{name}");
    }
}
