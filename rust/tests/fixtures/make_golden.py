#!/usr/bin/env python3
"""Generate rust/tests/fixtures/golden-v{1,2,3}.snap.

Writes stream-session snapshots (see rust/src/stream/persist.rs) for a
hand-constructed session whose dual point is analytically exact: with
nu1 = nu2 = 1 the box constraints pin the UNIQUE feasible point
alpha_i = 1/m, abar_i = eps/m, so the state is optimal by construction,
every margin is a dyadic rational (bit-exact in binary), and restore
must reproduce it bitwise with no repair sweep. rho1/rho2 are the
solver's interval-fallback recovery values (all variables at their
bounds): rho1 = max_i s_i, rho2 = min_i s_i.

golden-v1.snap is the frozen format-v1 file (byte-for-byte what the
original generator wrote — it pins the v1 **decode** path: Fifo policy,
ids synthesized from the ring cursor). golden-v2.snap pins the v2
decode path: the eviction-policy tag in the config section
(interior-first, to exercise the non-default tag) and explicit
per-sample ids + the forget counter in the state. golden-v3.snap pins
the current format: v2 plus the training-engine tag and lifted-feature
budget in the config section (exact engine, so no approx resume block
follows the gram checksum).

The script re-decodes what it wrote and checks every field, so an
encoder/decoder skew here fails at generation time, not in CI.
"""
import struct

MAGIC = b"SLABSNAP"
FORMAT_VERSION = 1

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x00000100000001B3
MASK = (1 << 64) - 1


def fnv1a(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK
    return h


def u8(v):
    return struct.pack("<B", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f64(v):
    return struct.pack("<d", v)


def f64s(vs):
    return b"".join(f64(v) for v in vs)


def s(text):
    raw = text.encode()
    return u32(len(raw)) + raw


# ----------------------------------------------------------- the session
NAME = "golden"
WEIGHT = 1
LAST_VERSION = 0

# StreamConfig: linear kernel, dim 2, window 4, min_train 2; SMO params
# are the crate defaults except nu1 = nu2 = 1, eps = 0.5.
DIM, WINDOW, MIN_TRAIN = 2, 4, 2
NU1, NU2, EPS = 1.0, 1.0, 0.5
TOL, MAX_ITER, HEURISTIC, SEED = 1e-5, 500_000, 0, 0
SV_TOL, SHRINKING = 1e-10, 1
REPAIR_MAX_ITER, REFRESH_EVERY = 100_000, 1024
DRIFT_RECENT, DRIFT_MIN_OBS = 128, 64
DRIFT_OUTSIDE_FRAC, DRIFT_RHO_REL = 0.9, 1.0
RETRAIN_SHARDS, RETRAIN_ROUNDS = 4, 2

POINTS = [(1.0, 0.0), (0.0, 1.0), (1.0, 1.0), (0.5, 0.5)]
M = len(POINTS)
ADMITTED = 4
ALPHA = [1.0 / (NU1 * M)] * M        # 0.25 each — the unique feasible point
ALPHA_BAR = [EPS / (NU2 * M)] * M    # 0.125 each
GAMMA = [a - b for a, b in zip(ALPHA, ALPHA_BAR)]  # 0.125 each


def dot(a, b):
    return a[0] * b[0] + a[1] * b[1]


GRAM = [[dot(POINTS[i], POINTS[j]) for j in range(M)] for i in range(M)]
# margins s_i = sum_j gamma_j * K_ij, accumulated left to right exactly
# like IncrementalSmo::margin_of_slot
S = []
for i in range(M):
    acc = 0.0
    for j in range(M):
        acc += GAMMA[j] * GRAM[i][j]
    S.append(acc)
# all variables sit at their bounds -> interval-fallback rho recovery:
# rho1 in [max s, +inf) -> max s; rho2 in (-inf, min s] -> min s
RHO1 = max(S)
RHO2 = min(S)
BASELINED = 1
BASELINE = (RHO1, RHO2)
UPDATES, RETRAINS, REPAIR_ITERATIONS = 4, 0, 0

GRAM_CHECKSUM = fnv1a(b"".join(f64s(row) for row in GRAM))

# ------------------------------------------------------------- encoding
cfg = b"".join(
    [
        u8(0), f64(0.0), f64(0.0), f64(0.0),  # linear kernel, no params
        u64(DIM), u64(WINDOW), u64(MIN_TRAIN),
        f64(NU1), f64(NU2), f64(EPS), f64(TOL),
        u64(MAX_ITER), u8(HEURISTIC), u64(SEED),
        f64(SV_TOL), u8(SHRINKING),
        u64(REPAIR_MAX_ITER), u64(REFRESH_EVERY),
        u64(DRIFT_RECENT), u64(DRIFT_MIN_OBS),
        f64(DRIFT_OUTSIDE_FRAC), f64(DRIFT_RHO_REL),
        u64(RETRAIN_SHARDS), u64(RETRAIN_ROUNDS),
    ]
)

body = b"".join(
    [
        MAGIC,
        u32(FORMAT_VERSION),
        u64(fnv1a(cfg)),
        s(NAME),
        u32(WEIGHT),
        u64(LAST_VERSION),
        cfg,
        u64(M),
        u64(ADMITTED),
        f64s(v for p in POINTS for v in p),
        f64s(ALPHA),
        f64s(ALPHA_BAR),
        f64s(S),
        f64(RHO1),
        f64(RHO2),
        u8(BASELINED),
        u8(1), f64(BASELINE[0]), f64(BASELINE[1]),
        u64(UPDATES),
        u64(RETRAINS),
        u64(REPAIR_ITERATIONS),
        u64(GRAM_CHECKSUM),
    ]
)
blob = body + u64(fnv1a(body))

# ---------------------------------------------------- verification pass
class Dec:
    def __init__(self, buf):
        self.buf, self.pos = buf, 0

    def take(self, n):
        assert self.pos + n <= len(self.buf), "truncated"
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def u8(self):
        return struct.unpack("<B", self.take(1))[0]

    def u32(self):
        return struct.unpack("<I", self.take(4))[0]

    def u64(self):
        return struct.unpack("<Q", self.take(8))[0]

    def f64(self):
        return struct.unpack("<d", self.take(8))[0]

    def f64s(self, n):
        return list(struct.unpack(f"<{n}d", self.take(8 * n)))

    def s(self):
        return self.take(self.u32()).decode()


def verify(buf):
    assert buf[:8] == MAGIC
    body, check = buf[:-8], struct.unpack("<Q", buf[-8:])[0]
    assert fnv1a(body) == check, "payload checksum"
    d = Dec(body)
    d.pos = 12
    fingerprint = d.u64()
    assert d.s() == NAME
    assert d.u32() == WEIGHT
    assert d.u64() == LAST_VERSION
    cfg_start = d.pos
    d.take(len(cfg))
    assert fnv1a(body[cfg_start:d.pos]) == fingerprint, "fingerprint"
    assert d.u64() == M and d.u64() == ADMITTED
    assert d.f64s(M * DIM) == [v for p in POINTS for v in p]
    assert d.f64s(M) == ALPHA and d.f64s(M) == ALPHA_BAR
    assert d.f64s(M) == S
    assert (d.f64(), d.f64()) == (RHO1, RHO2)
    assert d.u8() == BASELINED and d.u8() == 1
    assert (d.f64(), d.f64()) == BASELINE
    assert (d.u64(), d.u64(), d.u64()) == (UPDATES, RETRAINS,
                                           REPAIR_ITERATIONS)
    assert d.u64() == GRAM_CHECKSUM
    assert d.pos == len(body), "trailing bytes"


verify(blob)

out = __file__.replace("make_golden.py", "golden-v1.snap")
with open(out, "wb") as fh:
    fh.write(blob)
print(f"wrote {out}: {len(blob)} bytes")
print(f"  s = {S}  rho1 = {RHO1}  rho2 = {RHO2}")
print(f"  gram checksum {GRAM_CHECKSUM:#018x}")

# ===================================================== format v2 golden
#
# Same analytically-exact dual state, written in the current format:
# config section gains the eviction-policy tag (interior-first = 1, the
# non-default, so the byte is actually exercised), state gains explicit
# per-sample ids and the forget counter. The story the counters tell:
# 10 samples absorbed, 2 forgotten, 4 evicted, 4 resident with
# non-contiguous ids — exactly what a forget-y stream leaves behind.
FORMAT_VERSION_V2 = 2
POLICY_INTERIOR_FIRST = 1
IDS_V2 = [3, 5, 8, 9]          # slot order; unique, all < ADMITTED_V2
ADMITTED_V2 = 10
UPDATES_V2 = 10
FORGETS_V2 = 2

cfg_v2 = cfg + u8(POLICY_INTERIOR_FIRST)

body_v2 = b"".join(
    [
        MAGIC,
        u32(FORMAT_VERSION_V2),
        u64(fnv1a(cfg_v2)),
        s(NAME),
        u32(WEIGHT),
        u64(LAST_VERSION),
        cfg_v2,
        u64(M),
        u64(ADMITTED_V2),
        b"".join(u64(i) for i in IDS_V2),
        f64s(v for p in POINTS for v in p),
        f64s(ALPHA),
        f64s(ALPHA_BAR),
        f64s(S),
        f64(RHO1),
        f64(RHO2),
        u8(BASELINED),
        u8(1), f64(BASELINE[0]), f64(BASELINE[1]),
        u64(UPDATES_V2),
        u64(RETRAINS),
        u64(FORGETS_V2),
        u64(REPAIR_ITERATIONS),
        u64(GRAM_CHECKSUM),
    ]
)
blob_v2 = body_v2 + u64(fnv1a(body_v2))


def verify_v2(buf):
    assert buf[:8] == MAGIC
    body, check = buf[:-8], struct.unpack("<Q", buf[-8:])[0]
    assert fnv1a(body) == check, "payload checksum"
    d = Dec(body)
    assert d.take(8) == MAGIC
    assert d.u32() == FORMAT_VERSION_V2
    fingerprint = d.u64()
    assert d.s() == NAME
    assert d.u32() == WEIGHT
    assert d.u64() == LAST_VERSION
    cfg_start = d.pos
    d.take(len(cfg_v2))
    assert fnv1a(body[cfg_start:d.pos]) == fingerprint, "fingerprint"
    assert body[d.pos - 1] == POLICY_INTERIOR_FIRST, "policy tag"
    assert d.u64() == M and d.u64() == ADMITTED_V2
    assert [d.u64() for _ in range(M)] == IDS_V2
    assert d.f64s(M * DIM) == [v for p in POINTS for v in p]
    assert d.f64s(M) == ALPHA and d.f64s(M) == ALPHA_BAR
    assert d.f64s(M) == S
    assert (d.f64(), d.f64()) == (RHO1, RHO2)
    assert d.u8() == BASELINED and d.u8() == 1
    assert (d.f64(), d.f64()) == BASELINE
    assert (d.u64(), d.u64()) == (UPDATES_V2, RETRAINS)
    assert (d.u64(), d.u64()) == (FORGETS_V2, REPAIR_ITERATIONS)
    assert d.u64() == GRAM_CHECKSUM
    assert d.pos == len(body), "trailing bytes"


verify_v2(blob_v2)

out_v2 = __file__.replace("make_golden.py", "golden-v2.snap")
with open(out_v2, "wb") as fh:
    fh.write(blob_v2)
print(f"wrote {out_v2}: {len(blob_v2)} bytes")
print(f"  policy=interior-first ids={IDS_V2} forgets={FORGETS_V2}")

# ===================================================== format v3 golden
#
# Same dual state and counters as the v2 golden; the config section
# gains the training-engine tag and lifted-feature budget (exact = 0,
# features = 64, the crate defaults — an exact-engine snapshot carries
# no approx resume block, so the state layout is byte-identical to v2).
FORMAT_VERSION_V3 = 3
ENGINE_EXACT = 0
FEATURES_V3 = 64

cfg_v3 = cfg_v2 + u8(ENGINE_EXACT) + u64(FEATURES_V3)

body_v3 = b"".join(
    [
        MAGIC,
        u32(FORMAT_VERSION_V3),
        u64(fnv1a(cfg_v3)),
        s(NAME),
        u32(WEIGHT),
        u64(LAST_VERSION),
        cfg_v3,
        u64(M),
        u64(ADMITTED_V2),
        b"".join(u64(i) for i in IDS_V2),
        f64s(v for p in POINTS for v in p),
        f64s(ALPHA),
        f64s(ALPHA_BAR),
        f64s(S),
        f64(RHO1),
        f64(RHO2),
        u8(BASELINED),
        u8(1), f64(BASELINE[0]), f64(BASELINE[1]),
        u64(UPDATES_V2),
        u64(RETRAINS),
        u64(FORGETS_V2),
        u64(REPAIR_ITERATIONS),
        u64(GRAM_CHECKSUM),
    ]
)
blob_v3 = body_v3 + u64(fnv1a(body_v3))


def verify_v3(buf):
    assert buf[:8] == MAGIC
    body, check = buf[:-8], struct.unpack("<Q", buf[-8:])[0]
    assert fnv1a(body) == check, "payload checksum"
    d = Dec(body)
    assert d.take(8) == MAGIC
    assert d.u32() == FORMAT_VERSION_V3
    fingerprint = d.u64()
    assert d.s() == NAME
    assert d.u32() == WEIGHT
    assert d.u64() == LAST_VERSION
    cfg_start = d.pos
    d.take(len(cfg_v3))
    assert fnv1a(body[cfg_start:d.pos]) == fingerprint, "fingerprint"
    assert body[d.pos - 9] == ENGINE_EXACT, "engine tag"
    assert struct.unpack("<Q", body[d.pos - 8:d.pos])[0] == FEATURES_V3
    assert d.u64() == M and d.u64() == ADMITTED_V2
    assert [d.u64() for _ in range(M)] == IDS_V2
    assert d.f64s(M * DIM) == [v for p in POINTS for v in p]
    assert d.f64s(M) == ALPHA and d.f64s(M) == ALPHA_BAR
    assert d.f64s(M) == S
    assert (d.f64(), d.f64()) == (RHO1, RHO2)
    assert d.u8() == BASELINED and d.u8() == 1
    assert (d.f64(), d.f64()) == BASELINE
    assert (d.u64(), d.u64()) == (UPDATES_V2, RETRAINS)
    assert (d.u64(), d.u64()) == (FORGETS_V2, REPAIR_ITERATIONS)
    assert d.u64() == GRAM_CHECKSUM
    assert d.pos == len(body), "trailing bytes"


verify_v3(blob_v3)

out_v3 = __file__.replace("make_golden.py", "golden-v3.snap")
with open(out_v3, "wb") as fh:
    fh.write(blob_v3)
print(f"wrote {out_v3}: {len(blob_v3)} bytes")
print(f"  engine=exact features={FEATURES_V3} (no approx resume block)")
