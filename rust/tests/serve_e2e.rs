//! End-to-end tests for the network front door (DESIGN.md §9): drive
//! the real `slabsvm serve` binary over real TCP.
//!
//! The headline scenario: three tenants push over HTTP, the process is
//! killed with SIGKILL mid-traffic, a new process restores from the
//! snapshot directory, and the resumed streams (a) keep registry
//! versions monotone across the crash and (b) end at the **same
//! objective** (≤ 1e-9) as an uninterrupted in-process run over the
//! identical sample sequence — the crash is invisible to the math.
//! Plus: a flood against a tiny mailbox observes `429` (never a hang),
//! and scoring under a saturated batcher answers stale with
//! `X-Slab-Stale: 1`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::{SlabConfig, SlabStream};
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::stream::{StreamConfig, StreamPoolConfig, StreamSpec};
use slabsvm::util::json::Json;

// ---------------------------------------------------------------- plumbing

/// A spawned `slabsvm serve` process; killed on drop so a failed
/// assertion never leaks a listener.
struct ServerProc {
    child: Child,
    addr: String,
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn the binary with `serve --addr 127.0.0.1:0 <extra>` and parse
/// the bound port from its stable "listening on {addr}" stdout line.
fn spawn_serve(extra: &[&str]) -> ServerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_slabsvm"))
        .args(["serve", "--addr", "127.0.0.1:0"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn slabsvm serve");
    let stdout = child.stdout.take().expect("child stdout");
    let mut reader = BufReader::new(stdout);
    let mut addr = None;
    for _ in 0..500 {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(rest) = line.trim().strip_prefix("listening on ") {
            addr = Some(rest.to_string());
            break;
        }
    }
    // keep draining stdout so the child never blocks on a full pipe
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    ServerProc { child, addr: addr.expect("server printed no listening line") }
}

struct Resp {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
}

impl Resp {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    fn json(&self) -> Json {
        Json::parse(&self.body).expect("response body is JSON")
    }
}

/// Read exactly one HTTP response (content-length framed) off a
/// keep-alive connection.
fn read_response(conn: &mut TcpStream) -> Resp {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    loop {
        if let Some(head_end) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
            let clen: usize = head
                .lines()
                .find_map(|l| {
                    l.to_ascii_lowercase()
                        .strip_prefix("content-length:")
                        .map(|v| v.trim().parse().expect("content-length"))
                })
                .unwrap_or(0);
            if buf.len() >= head_end + 4 + clen {
                let status = head
                    .split(' ')
                    .nth(1)
                    .and_then(|s| s.parse().ok())
                    .expect("status line");
                let headers = head
                    .lines()
                    .skip(1)
                    .filter_map(|l| l.split_once(':'))
                    .map(|(k, v)| {
                        (k.trim().to_ascii_lowercase(), v.trim().to_string())
                    })
                    .collect();
                let body =
                    String::from_utf8_lossy(&buf[head_end + 4..head_end + 4 + clen])
                        .to_string();
                return Resp { status, headers, body };
            }
        }
        let n = conn.read(&mut tmp).expect("read response");
        assert!(n > 0, "server closed mid-response");
        buf.extend_from_slice(&tmp[..n]);
    }
}

/// One request on an existing keep-alive connection.
fn request(
    conn: &mut TcpStream,
    method: &str,
    path: &str,
    token: Option<&str>,
    body: Option<&str>,
) -> Resp {
    let mut req = format!("{method} {path} HTTP/1.1\r\n");
    if let Some(t) = token {
        req.push_str(&format!("authorization: Bearer {t}\r\n"));
    }
    let body = body.unwrap_or("");
    req.push_str(&format!("content-length: {}\r\n\r\n{body}", body.len()));
    conn.write_all(req.as_bytes()).expect("write request");
    read_response(conn)
}

fn connect(addr: &str) -> TcpStream {
    let conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(20))).expect("timeout");
    conn.set_nodelay(true).expect("nodelay");
    conn
}

/// One-shot request on a fresh connection.
fn oneshot(
    addr: &str,
    method: &str,
    path: &str,
    token: Option<&str>,
    body: Option<&str>,
) -> Resp {
    request(&mut connect(addr), method, path, token, body)
}

fn push_body(x: &[f64]) -> String {
    let vals: Vec<String> = x.iter().map(|v| format!("{v}")).collect();
    format!("{{\"x\": [{}]}}", vals.join(", "))
}

/// Push one sample, retrying briefly on mailbox 429s (the E2E pushes
/// must all land; admission shedding is exercised by its own test).
fn push_sample(conn: &mut TcpStream, name: &str, token: &str, x: &[f64]) {
    let path = format!("/v1/streams/{name}/push");
    for _ in 0..200 {
        let r = request(conn, "POST", &path, Some(token), Some(&push_body(x)));
        match r.status {
            202 => return,
            429 => std::thread::sleep(Duration::from_millis(5)),
            s => panic!("push to {name} failed with {s}: {}", r.body),
        }
    }
    panic!("push to {name} kept shedding");
}

/// Block until every queued sample is absorbed (the quiesce endpoint
/// drains all shard mailboxes before answering).
fn quiesce(addr: &str, token: &str) {
    let r = oneshot(addr, "POST", "/v1/quiesce", Some(token), Some(""));
    assert_eq!(r.status, 200, "quiesce: {}", r.body);
}

fn stream_version(addr: &str, name: &str, token: &str) -> Option<u64> {
    let r =
        oneshot(addr, "GET", &format!("/v1/streams/{name}"), Some(token), None);
    assert_eq!(r.status, 200, "stream info: {}", r.body);
    r.json().get("version").and_then(Json::as_f64).map(|v| v as u64)
}

// ------------------------------------------------------------------- tests

const TENANTS: [(&str, &str); 3] = [("t0", "tok0"), ("t1", "tok1"), ("t2", "tok2")];
const AUTH_SPEC: &str = "t0=tok0,t1=tok1,t2=tok2";
const N1: usize = 80; // samples before the crash
const N2: usize = 24; // samples after restore
const WINDOW: usize = 64;
const MIN_TRAIN: usize = 32;

fn tenant_samples(i: usize, n: usize) -> Vec<Vec<f64>> {
    let mut gen = SlabStream::new(SlabConfig::default(), 100 + i as u64);
    (0..n).map(|_| gen.next_point().to_vec()).collect()
}

fn serve_args<'a>(dir_flag: &'a str, dir: &'a str) -> Vec<&'a str> {
    vec![
        "--tenants", "t0,t1,t2",
        "--auth", AUTH_SPEC,
        "--train-size", "0",
        "--window", "64",
        "--min-train", "32",
        "--shards", "2",
        "--mailbox", "1024",
        // cadence far past the test horizon: the only snapshot that
        // exists is the explicit POST /v1/snapshot, so the restored
        // state is exactly the N1-sample prefix (SIGKILL discards the
        // doomed traffic after it)
        "--checkpoint-ms", "60000",
        dir_flag, dir,
    ]
}

#[test]
fn kill_mid_traffic_restore_is_invisible_to_versions_and_objective() {
    let dir = std::env::temp_dir()
        .join(format!("slabsvm_serve_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let dir_s = dir.to_str().unwrap().to_string();

    let samples: Vec<Vec<Vec<f64>>> =
        (0..TENANTS.len()).map(|i| tenant_samples(i, N1 + N2)).collect();

    // ---- phase A: serve, push N1 per tenant, snapshot, kill -9
    let mut versions_a = Vec::new();
    {
        let mut server =
            spawn_serve(&serve_args("--checkpoint-dir", &dir_s));
        let addr = server.addr.clone();

        // auth is enforced on the way in
        let denied = oneshot(&addr, "POST", "/v1/streams/t0/push",
            Some("wrong"), Some("{\"x\": [0.0, 0.0]}"));
        assert_eq!(denied.status, 401, "{}", denied.body);
        let crossed = oneshot(&addr, "POST", "/v1/streams/t0/push",
            Some("tok1"), Some("{\"x\": [0.0, 0.0]}"));
        assert_eq!(crossed.status, 403, "{}", crossed.body);

        for (i, (name, token)) in TENANTS.iter().enumerate() {
            let mut conn = connect(&addr);
            for x in &samples[i][..N1] {
                push_sample(&mut conn, name, token, x);
            }
        }
        quiesce(&addr, "tok0");
        for (name, token) in &TENANTS {
            let v = stream_version(&addr, name, token)
                .expect("published after N1 > min_train");
            assert!(v >= 1);
            versions_a.push(v);
        }

        // freeze exactly the N1-sample state on disk
        let snap = oneshot(&addr, "POST", "/v1/snapshot", Some("tok0"), Some(""));
        assert_eq!(snap.status, 200, "{}", snap.body);

        // doomed traffic: keep pushing while the process dies
        let flood_addr = addr.clone();
        let flood = std::thread::spawn(move || {
            let Ok(mut conn) = TcpStream::connect(&flood_addr) else {
                return;
            };
            let _ = conn.set_read_timeout(Some(Duration::from_secs(2)));
            let mut doomed = SlabStream::new(SlabConfig::default(), 999);
            for _ in 0..100_000 {
                let x = doomed.next_point();
                let req = format!(
                    "POST /v1/streams/t1/push HTTP/1.1\r\n\
                     authorization: Bearer tok1\r\n\
                     content-length: {}\r\n\r\n{}",
                    push_body(&x).len(),
                    push_body(&x)
                );
                if conn.write_all(req.as_bytes()).is_err() {
                    return; // server died mid-traffic: expected
                }
                let mut tmp = [0u8; 4096];
                match conn.read(&mut tmp) {
                    Ok(n) if n > 0 => {}
                    _ => return,
                }
            }
        });
        std::thread::sleep(Duration::from_millis(150));
        server.child.kill().expect("SIGKILL"); // no graceful anything
        server.child.wait().expect("reap");
        flood.join().expect("flood thread");
    }

    // ---- phase B: restore, check resume info + monotone versions,
    //      push N2 more, close, compare objectives
    let mut objectives_http = Vec::new();
    {
        let server = spawn_serve(&serve_args("--restore-dir", &dir_s));
        let addr = server.addr.clone();

        for (i, (name, token)) in TENANTS.iter().enumerate() {
            let info = oneshot(&addr, "GET", &format!("/v1/streams/{name}"),
                Some(token), None);
            assert_eq!(info.status, 200, "{}", info.body);
            let j = info.json();
            let restored = j.get("restored").expect("restore accounting");
            assert_eq!(
                restored.get("updates").and_then(Json::as_usize),
                Some(N1),
                "restored from the explicit snapshot, tenant {name}"
            );
            let v_b = j.get("version").and_then(Json::as_f64).map(|v| v as u64)
                .expect("restored stream re-published");
            assert!(
                v_b >= versions_a[i],
                "version regressed across restart: {v_b} < {}",
                versions_a[i]
            );

            let mut conn = connect(&addr);
            for x in &samples[i][N1..] {
                push_sample(&mut conn, name, token, x);
            }
        }
        quiesce(&addr, "tok0");
        for (i, (name, token)) in TENANTS.iter().enumerate() {
            let v_after = stream_version(&addr, name, token).unwrap();
            assert!(v_after >= versions_a[i], "monotone after resume pushes");
            let close = oneshot(&addr, "POST",
                &format!("/v1/streams/{name}/close"), Some(token), Some(""));
            assert_eq!(close.status, 200, "{}", close.body);
            let j = close.json();
            assert_eq!(
                j.get("updates").and_then(Json::as_usize),
                Some(N1 + N2),
                "crash+restore lost updates for {name}"
            );
            objectives_http.push(
                j.get("objective").and_then(Json::as_f64).expect("objective"),
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();

    // ---- reference: the same samples through an uninterrupted
    //      in-process coordinator with the identical stream config
    let cfg = StreamConfig {
        kernel: Kernel::Linear,
        dim: 2,
        window: WINDOW,
        min_train: MIN_TRAIN,
        ..Default::default()
    };
    let c = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig::default(),
        1,
        StreamPoolConfig { shards: 2, mailbox_cap: 1024, checkpoint: None },
    );
    c.open_streams(
        TENANTS
            .iter()
            .map(|(n, _)| StreamSpec::new(n.to_string(), cfg.clone()))
            .collect(),
    )
    .unwrap();
    for (i, (name, _)) in TENANTS.iter().enumerate() {
        for x in &samples[i] {
            c.push(name, x).unwrap();
        }
    }
    for (i, (name, _)) in TENANTS.iter().enumerate() {
        let s = c.close_stream(name).unwrap();
        assert_eq!(s.updates as usize, N1 + N2);
        let diff = (s.objective - objectives_http[i]).abs();
        assert!(
            diff <= 1e-9,
            "objective parity broken for {name}: uninterrupted {} vs \
             kill+restore {} (|diff| = {diff:e})",
            s.objective,
            objectives_http[i]
        );
    }
}

#[test]
fn flood_on_tiny_mailbox_observes_429_and_never_hangs() {
    let server = spawn_serve(&[
        "--tenants", "t0",
        "--train-size", "0",
        "--shards", "1",
        "--mailbox", "1",
        // small min_train: absorbs run real SMO, so the worker cannot
        // keep up with a pipelined flood and the cap-1 mailbox fills
        "--window", "512",
        "--min-train", "16",
    ]);
    let addr = server.addr.clone();

    let mut gen = SlabStream::new(SlabConfig::default(), 7);
    let mut conn = connect(&addr);
    const BURST: usize = 256;
    // pipeline the whole burst in one write: the router keeps parsing
    // back-to-back while the shard worker is mid-absorb
    let mut wire = String::new();
    for _ in 0..BURST {
        let body = push_body(&gen.next_point());
        wire.push_str(&format!(
            "POST /v1/streams/t0/push HTTP/1.1\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        ));
    }
    conn.write_all(wire.as_bytes()).expect("write burst");

    let (mut queued, mut shed) = (0usize, 0usize);
    let deadline = Instant::now() + Duration::from_secs(60);
    for _ in 0..BURST {
        assert!(Instant::now() < deadline, "flood hung instead of shedding");
        let r = read_response(&mut conn);
        match r.status {
            202 => queued += 1,
            429 => {
                shed += 1;
                assert_eq!(r.header("retry-after"), Some("1"), "{}", r.body);
                let depth: usize = r
                    .header("x-slab-queue-depth")
                    .expect("depth header on mailbox 429")
                    .parse()
                    .expect("depth is a number");
                assert!(depth >= 1);
            }
            s => panic!("unexpected status {s}: {}", r.body),
        }
    }
    assert!(shed > 0, "cap-1 mailbox never shed over {BURST} pipelined pushes");
    assert!(queued > 0, "some pushes must land");

    // the shed counter is visible to a tokenless scraper
    let metrics = oneshot(&addr, "GET", "/metrics", None, None);
    assert_eq!(metrics.status, 200);
    let shed_line = metrics
        .body
        .lines()
        .find(|l| l.starts_with("slabsvm_serve_shed_total"))
        .expect("shed counter exported");
    let exported: u64 = shed_line
        .rsplit(' ')
        .next()
        .and_then(|v| v.parse().ok())
        .expect("counter value");
    assert!(exported >= shed as u64, "{shed_line} vs observed {shed}");
}

#[test]
fn saturated_batcher_serves_stale_with_version_headers() {
    let server = spawn_serve(&[
        "--tenants", "t0",
        "--train-size", "128",
        // queue_cap 0: every score submission sheds, so the router's
        // stale fallback is the only 200 path
        "--score-queue-cap", "0",
    ]);
    let addr = server.addr.clone();

    let r = oneshot(&addr, "POST", "/v1/score/t0", None,
        Some("{\"queries\": [[0.5, 0.5], [20.0, 3.0]]}"));
    assert_eq!(r.status, 200, "stale fallback must still answer: {}", r.body);
    assert_eq!(r.header("x-slab-stale"), Some("1"), "staleness is declared");
    let version: u64 = r
        .header("x-slab-model-version")
        .expect("version header on every scoring response")
        .parse()
        .expect("version is a number");
    assert!(version >= 1, "stale answers come from a published model");
    let j = r.json();
    assert_eq!(j.get("scores").and_then(Json::as_arr).map(|a| a.len()), Some(2));
    assert_eq!(j.get("labels").and_then(Json::as_arr).map(|a| a.len()), Some(2));

    // and the stale counter ticks
    let metrics = oneshot(&addr, "GET", "/metrics", None, None);
    assert!(
        metrics.body.lines().any(|l| {
            l.starts_with("slabsvm_serve_stale_served_total")
                && !l.ends_with(" 0")
        }),
        "stale counter must be nonzero"
    );
}
