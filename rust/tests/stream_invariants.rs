//! Randomized invariant suite for the streaming dual state.
//!
//! `rust/tests/streaming.rs` pins *endpoint* parity — run a pinned
//! stream, compare the final state to a batch fit. This suite certifies
//! the invariants **after every single operation** of ~200 seeded random
//! add/evict/repair sequences (random window capacity, kernel, (ν₁, ν₂,
//! ε), refresh cadence, and drifting vs stationary input):
//!
//! * box constraints `0 ≤ α ≤ 1/(ν₁m)`, `0 ≤ ᾱ ≤ ε/(ν₂m)`;
//! * dual mass conservation `Σα = 1`, `Σᾱ = ε` (hence `Σγ = 1 − ε`) —
//!   the pair of constraints the paper's γ-form drops (DESIGN.md §1.1,
//!   Erratum A), which the incremental transfers must preserve exactly;
//! * an **independently recomputed** KKT certificate: margins rebuilt
//!   from a fresh Gram matrix via `solver::validate`, not the solver's
//!   incrementally maintained `s`, within the repair tolerance.
//!
//! Also here: the `SlabStream` determinism contract — identical seeds
//! must yield bitwise-identical drift streams (all three drift kinds,
//! composed), because every experiment seed in DESIGN.md depends on it.
//!
//! The **approximate engines** (DESIGN.md §10) run the same gauntlet:
//! ~100 seeded absorb/evict/forget sequences per feature-map engine,
//! with box / Σα = 1 / Σᾱ = ε and a KKT certificate over margins
//! rebuilt *from scratch in lifted space* (w re-accumulated from the
//! feature map, not the engine's incrementally maintained vector)
//! after every single operation.

use slabsvm::data::synthetic::{
    Drift, DriftSchedule, Noise, SlabConfig, SlabStream,
};
use slabsvm::kernel::Kernel;
use slabsvm::solver::smo::SmoParams;
use slabsvm::solver::validate;
use slabsvm::kernel::featmap::{EngineKind, FeatureMap};
use slabsvm::stream::{
    ApproxIncremental, IncrementalConfig, IncrementalSmo, PolicyKind,
};
use slabsvm::util::rng::Rng;

/// Certify every invariant of the current dual state, independently of
/// the solver's own bookkeeping wherever possible.
fn assert_invariants(inc: &IncrementalSmo, ctx: &str) {
    let p = inc.config().smo;
    let m = inc.len();
    assert!(m > 0, "{ctx}: empty solver");
    let report = inc.report();
    let alpha = &report.dual.alpha;
    let alpha_bar = &report.dual.alpha_bar;
    let cap_a = 1.0 / (p.nu1 * m as f64);
    let cap_b = p.eps / (p.nu2 * m as f64);

    // 1. box constraints
    for j in 0..m {
        assert!(
            alpha[j] >= -1e-12 && alpha[j] <= cap_a + 1e-12,
            "{ctx}: alpha[{j}]={} outside [0, {cap_a}]",
            alpha[j]
        );
        assert!(
            alpha_bar[j] >= -1e-12 && alpha_bar[j] <= cap_b + 1e-12,
            "{ctx}: alpha_bar[{j}]={} outside [0, {cap_b}]",
            alpha_bar[j]
        );
    }

    // 2. dual mass conservation
    let sum_a: f64 = alpha.iter().sum();
    let sum_b: f64 = alpha_bar.iter().sum();
    let sum_g: f64 = report.dual.gamma.iter().sum();
    assert!((sum_a - 1.0).abs() < 1e-9, "{ctx}: sum(alpha)={sum_a}");
    assert!(
        (sum_b - p.eps).abs() < 1e-9,
        "{ctx}: sum(alpha_bar)={sum_b} want {}",
        p.eps
    );
    assert!(
        (sum_g - (1.0 - p.eps)).abs() < 1e-9,
        "{ctx}: sum(gamma)={sum_g} want {}",
        1.0 - p.eps
    );

    // 3. independent KKT certificate: fresh Gram, recomputed margins —
    // none of the incremental bookkeeping (rank-1 updates, periodic
    // refresh, slot reuse) is trusted here
    let k = inc.window().kernel().gram(&inc.window().matrix(), 1);
    let cls_tol = cap_a.min(cap_b) * 1e-6;
    let cert = validate::report(
        &k,
        alpha,
        alpha_bar,
        report.dual.rho1,
        report.dual.rho2,
        p.nu1,
        p.nu2,
        p.eps,
        cls_tol,
    );
    assert!(
        cert.max_box_violation <= 1e-9,
        "{ctx}: box violation {}",
        cert.max_box_violation
    );
    assert!(
        cert.sum_alpha_violation <= 1e-9
            && cert.sum_alpha_bar_violation <= 1e-9,
        "{ctx}: sum violations {} / {}",
        cert.sum_alpha_violation,
        cert.sum_alpha_bar_violation
    );
    // The repair sweeps stop at p.tol in margin-scaled units (the same
    // scaling the solver uses); allow slack for the certificate's
    // different bound-classification epsilon and fp accumulation.
    let margin_scale = 1.0
        + report.dual.s.iter().map(|v| v.abs()).sum::<f64>() / m as f64;
    let kkt_tol = p.tol * margin_scale * 4.0;
    assert!(
        cert.max_kkt_violation <= kkt_tol,
        "{ctx}: KKT violation {} > {kkt_tol} (worst index {})",
        cert.max_kkt_violation,
        cert.worst_index
    );
}

/// ~200 seeded random operation sequences; invariants certified after
/// EVERY push (growth adds, steady-state evict+add, repair included).
#[test]
fn randomized_sequences_preserve_invariants_after_every_op() {
    for seq in 0..200u64 {
        let mut rng = Rng::new(0xD1CE_0000 + seq);
        let cap = 8 + rng.below(25); // window capacity in [8, 32]
        let kernel = if rng.below(2) == 0 {
            Kernel::Linear
        } else {
            Kernel::Rbf { g: 0.02 + 0.2 * rng.uniform() }
        };
        let smo = SmoParams {
            nu1: [0.3, 0.5, 0.8][rng.below(3)],
            nu2: [0.05, 0.1, 0.2][rng.below(3)],
            eps: [0.4, 2.0 / 3.0][rng.below(2)],
            ..SmoParams::default()
        };
        let cfg = IncrementalConfig {
            smo,
            refresh_every: [4, 64, 1024][rng.below(3)],
            ..IncrementalConfig::default()
        };

        let mut inc = IncrementalSmo::new(kernel, cap, 2, cfg);
        let mut stream =
            SlabStream::new(SlabConfig::default(), 0x5EED_0000 + seq);
        if rng.below(2) == 0 {
            // half the sequences run on a drifting band — eviction and
            // repair under moving data, not just stationary noise
            stream = stream.with_drift(DriftSchedule {
                drift: Drift::MeanShift {
                    delta: rng.uniform_range(-6.0, 6.0),
                },
                start: cap,
                duration: rng.below(cap) + 1,
            });
        }

        // past `cap` pushes every further op is an evict + add + repair
        let ops = cap + 1 + rng.below(2 * cap);
        for op in 0..ops {
            inc.push(&stream.next_point()).unwrap_or_else(|e| {
                panic!("seq {seq} op {op}: push failed: {e}")
            });
            assert_invariants(&inc, &format!("seq {seq} op {op}"));
        }
        assert!(inc.len() == cap.min(ops), "seq {seq}: bad window fill");
    }
}

/// ~200 seeded random **removal** sequences (100 per eviction policy):
/// absorbs (growth adds + policy evicts once full) interleaved with
/// `forget(random resident id)` targeted removals, the invariants
/// certified after EVERY operation — box, Σα = 1 / Σᾱ = ε, and the
/// fresh-Gram KKT certificate. Also pins, per sequence, that a bogus
/// forget is a typed error leaving the dual untouched to the bit.
#[test]
fn randomized_removal_sequences_preserve_invariants_after_every_op() {
    for policy in PolicyKind::ALL {
        for seq in 0..100u64 {
            let mut rng = Rng::new(0xF0_1D_0000 + seq);
            let cap = 8 + rng.below(25); // window capacity in [8, 32]
            let kernel = if rng.below(2) == 0 {
                Kernel::Linear
            } else {
                Kernel::Rbf { g: 0.02 + 0.2 * rng.uniform() }
            };
            let smo = SmoParams {
                nu1: [0.3, 0.5, 0.8][rng.below(3)],
                nu2: [0.05, 0.1, 0.2][rng.below(3)],
                eps: [0.4, 2.0 / 3.0][rng.below(2)],
                ..SmoParams::default()
            };
            let cfg = IncrementalConfig {
                smo,
                refresh_every: [4, 64, 1024][rng.below(3)],
                policy,
                ..IncrementalConfig::default()
            };

            let mut inc = IncrementalSmo::new(kernel, cap, 2, cfg);
            let mut stream =
                SlabStream::new(SlabConfig::default(), 0x5EED_F000 + seq);
            if rng.below(2) == 0 {
                stream = stream.with_drift(DriftSchedule {
                    drift: Drift::MeanShift {
                        delta: rng.uniform_range(-6.0, 6.0),
                    },
                    start: cap,
                    duration: rng.below(cap) + 1,
                });
            }

            let ops = cap + 1 + rng.below(2 * cap);
            for op in 0..ops {
                // ~30% forgets once enough residents exist; the rest
                // absorbs — so sequences mix growth adds, policy evicts
                // (the window refills to full after removals) and
                // targeted removals at every window fill level
                if inc.len() >= 3 && rng.below(10) < 3 {
                    let ids = inc.window().ids().to_vec();
                    let victim = ids[rng.below(ids.len())];
                    inc.forget(victim).unwrap_or_else(|e| {
                        panic!(
                            "{policy:?} seq {seq} op {op}: forget({victim}) \
                             failed: {e}"
                        )
                    });
                } else {
                    inc.push(&stream.next_point()).unwrap_or_else(|e| {
                        panic!("{policy:?} seq {seq} op {op}: push failed: {e}")
                    });
                }
                assert_invariants(&inc, &format!("{policy:?} seq {seq} op {op}"));
            }
            assert!(inc.len() >= 2 && inc.len() <= cap, "{policy:?} seq {seq}");

            // a non-resident id is a typed rejection, bitwise untouched
            let alpha: Vec<u64> =
                inc.alpha().iter().map(|v| v.to_bits()).collect();
            assert!(
                matches!(
                    inc.forget(u64::MAX),
                    Err(slabsvm::Error::Unlearning(_))
                ),
                "{policy:?} seq {seq}: bogus forget must be typed"
            );
            let after: Vec<u64> =
                inc.alpha().iter().map(|v| v.to_bits()).collect();
            assert_eq!(alpha, after, "{policy:?} seq {seq}");
        }
    }
}

/// The certificate embedded in the streamed `FitReport` agrees with the
/// independent recomputation (same invariants, solver-maintained
/// margins) — a divergence means the incremental `s` drifted.
#[test]
fn embedded_certificate_matches_independent_margins() {
    let mut inc = IncrementalSmo::new(
        Kernel::Rbf { g: 0.08 },
        40,
        2,
        IncrementalConfig::default(),
    );
    let mut stream = SlabStream::new(SlabConfig::default(), 0xCE27);
    for _ in 0..90 {
        inc.push(&stream.next_point()).unwrap();
    }
    let report = inc.report();
    let k = inc.window().kernel().gram(&inc.window().matrix(), 1);
    let m = inc.len();
    let p = inc.config().smo;
    let cls_tol =
        (1.0 / (p.nu1 * m as f64)).min(p.eps / (p.nu2 * m as f64)) * 1e-6;
    let fresh = validate::report(
        &k,
        &report.dual.alpha,
        &report.dual.alpha_bar,
        report.dual.rho1,
        report.dual.rho2,
        p.nu1,
        p.nu2,
        p.eps,
        cls_tol,
    );
    assert!(
        (fresh.max_kkt_violation - report.certificate.max_kkt_violation)
            .abs()
            < 1e-6,
        "certificates diverged: fresh {} vs embedded {}",
        fresh.max_kkt_violation,
        report.certificate.max_kkt_violation
    );
    assert!((fresh.objective - report.certificate.objective).abs() < 1e-8);
}

// ------------------------------------------------- SlabStream determinism

/// Two streams built from identical seed + schedules must agree
/// **bitwise** on every sample, with all three drift kinds composed and
/// ramping — the contract every pinned experiment seed relies on.
#[test]
fn slab_stream_identical_seeds_are_bitwise_identical() {
    let mk = || {
        SlabStream::new(
            SlabConfig { noise: Noise::Laplace, ..Default::default() },
            0xD27F_7
        )
        .with_drift(DriftSchedule {
            drift: Drift::MeanShift { delta: -7.5 },
            start: 100,
            duration: 60,
        })
        .with_drift(DriftSchedule {
            drift: Drift::VarianceInflation { factor: 2.5 },
            start: 180,
            duration: 40,
        })
        .with_drift(DriftSchedule {
            drift: Drift::Rotation { delta: 0.35 },
            start: 260,
            duration: 80,
        })
    };
    let (mut a, mut b) = (mk(), mk());
    for t in 0..600 {
        let pa = a.next_point();
        let pb = b.next_point();
        assert_eq!(
            pa[0].to_bits(),
            pb[0].to_bits(),
            "x diverged at sample {t}: {} vs {}",
            pa[0],
            pb[0]
        );
        assert_eq!(
            pa[1].to_bits(),
            pb[1].to_bits(),
            "y diverged at sample {t}: {} vs {}",
            pa[1],
            pb[1]
        );
    }
    assert_eq!(a.position(), 600);
}

/// `take(n)` must draw the exact same sequence `next_point` does (same
/// generator, same consumption order) — bitwise.
#[test]
fn slab_stream_take_matches_next_point_bitwise() {
    let mk = || {
        SlabStream::new(SlabConfig::default(), 0xBEEF).with_drift(
            DriftSchedule {
                drift: Drift::MeanShift { delta: 3.0 },
                start: 40,
                duration: 0, // step change mid-take
            },
        )
    };
    let mut via_take = mk();
    let m = via_take.take(200);
    let mut via_next = mk();
    for i in 0..200 {
        let p = via_next.next_point();
        assert_eq!(m.get(i, 0).to_bits(), p[0].to_bits(), "row {i} x");
        assert_eq!(m.get(i, 1).to_bits(), p[1].to_bits(), "row {i} y");
    }
}

/// `config_at` is a pure function of the sample index: probing it must
/// not consume randomness or perturb the stream.
#[test]
fn slab_stream_config_probes_do_not_perturb_the_stream() {
    let mk = || {
        SlabStream::new(SlabConfig::default(), 0xAB1E).with_drift(
            DriftSchedule {
                drift: Drift::Rotation { delta: 0.2 },
                start: 10,
                duration: 30,
            },
        )
    };
    let mut probed = mk();
    let mut clean = mk();
    for t in 0..120 {
        // hammer config_at at arbitrary indices between draws
        let _ = probed.config_at(t);
        let _ = probed.config_at(t * 7 % 50);
        let _ = probed.config_at(10_000);
        let pp = probed.next_point();
        let pc = clean.next_point();
        assert_eq!(pp[0].to_bits(), pc[0].to_bits(), "diverged at {t}");
        assert_eq!(pp[1].to_bits(), pc[1].to_bits(), "diverged at {t}");
    }
}

/// Different seeds must actually differ (the determinism above is not
/// degenerate).
#[test]
fn slab_stream_different_seeds_differ() {
    let mut a = SlabStream::new(SlabConfig::default(), 1);
    let mut b = SlabStream::new(SlabConfig::default(), 2);
    let same = (0..64)
        .filter(|_| {
            let (pa, pb) = (a.next_point(), b.next_point());
            pa[0].to_bits() == pb[0].to_bits()
        })
        .count();
    assert!(same < 4, "seeds 1 and 2 nearly coincide: {same}/64");
}

// ------------------------------------------------ approximate engines

/// Certify every invariant of an approx engine's lifted dual state,
/// independently of the engine's own bookkeeping: the weight vector is
/// re-accumulated from scratch through the feature map and the margins
/// recomputed from it before the KKT check.
fn assert_approx_invariants(inc: &ApproxIncremental, ctx: &str) {
    let p = inc.config().smo;
    let m = inc.len();
    assert!(m > 0, "{ctx}: empty engine");
    let alpha = inc.alpha();
    let alpha_bar = inc.alpha_bar();
    let cap_a = 1.0 / (p.nu1 * m as f64);
    let cap_b = p.eps / (p.nu2 * m as f64);

    // 1. box constraints — the lifted transfers keep these exactly
    for j in 0..m {
        assert!(
            alpha[j] >= -1e-12 && alpha[j] <= cap_a + 1e-12,
            "{ctx}: alpha[{j}]={} outside [0, {cap_a}]",
            alpha[j]
        );
        assert!(
            alpha_bar[j] >= -1e-12 && alpha_bar[j] <= cap_b + 1e-12,
            "{ctx}: alpha_bar[{j}]={} outside [0, {cap_b}]",
            alpha_bar[j]
        );
    }

    // 2. dual mass conservation
    let sum_a: f64 = alpha.iter().sum();
    let sum_b: f64 = alpha_bar.iter().sum();
    assert!((sum_a - 1.0).abs() < 1e-9, "{ctx}: sum(alpha)={sum_a}");
    assert!(
        (sum_b - p.eps).abs() < 1e-9,
        "{ctx}: sum(alpha_bar)={sum_b} want {}",
        p.eps
    );

    // 3. independent lifted KKT certificate: re-lift every resident
    // through the map, re-accumulate w = Σγφ(x) from scratch, and
    // recompute the margins — none of the engine's incremental axpy
    // bookkeeping is trusted here
    let map = inc.featmap();
    let d_out = map.d_out();
    let mut scratch = vec![0.0; map.scratch_len().max(1)];
    let mut phi = vec![0.0; m * d_out];
    for i in 0..m {
        map.map_into(
            inc.point(i),
            &mut scratch,
            &mut phi[i * d_out..(i + 1) * d_out],
        );
    }
    let mut w = vec![0.0; d_out];
    for i in 0..m {
        let g = alpha[i] - alpha_bar[i];
        for (wk, pk) in w.iter_mut().zip(&phi[i * d_out..(i + 1) * d_out]) {
            *wk += g * pk;
        }
    }
    let s: Vec<f64> = (0..m)
        .map(|i| {
            w.iter().zip(&phi[i * d_out..(i + 1) * d_out]).map(|(a, b)| a * b).sum()
        })
        .collect();
    let (rho1, rho2) = inc.rho();
    let cls_tol = cap_a.min(cap_b) * 1e-6;
    let cert = validate::report_with_margins(
        alpha, alpha_bar, &s, rho1, rho2, p.nu1, p.nu2, p.eps, cls_tol,
    );
    assert!(
        cert.max_box_violation <= 1e-9,
        "{ctx}: box violation {}",
        cert.max_box_violation
    );
    assert!(
        cert.sum_alpha_violation <= 1e-9
            && cert.sum_alpha_bar_violation <= 1e-9,
        "{ctx}: sum violations {} / {}",
        cert.sum_alpha_violation,
        cert.sum_alpha_bar_violation
    );
    let margin_scale =
        1.0 + s.iter().map(|v| v.abs()).sum::<f64>() / m as f64;
    let kkt_tol = p.tol * margin_scale * 4.0;
    assert!(
        cert.max_kkt_violation <= kkt_tol,
        "{ctx}: lifted KKT violation {} > {kkt_tol} (worst index {})",
        cert.max_kkt_violation,
        cert.worst_index
    );
}

/// ~100 seeded random absorb/evict/forget sequences per approx engine
/// (Nyström warmup + frozen regimes, RFF), invariants certified in
/// lifted space after EVERY operation — the exact suite's gauntlet run
/// on the feature-map path.
#[test]
fn approx_randomized_sequences_preserve_invariants_after_every_op() {
    for engine in [EngineKind::Nystroem, EngineKind::Rff] {
        for seq in 0..50u64 {
            let mut rng = Rng::new(0xA220_0000 + seq);
            let cap = 8 + rng.below(25); // window capacity in [8, 32]
            // RFF needs RBF; Nyström alternates kernels
            let kernel = if engine == EngineKind::Rff || rng.below(2) == 1 {
                Kernel::Rbf { g: 0.02 + 0.2 * rng.uniform() }
            } else {
                Kernel::Linear
            };
            let smo = SmoParams {
                nu1: [0.3, 0.5, 0.8][rng.below(3)],
                nu2: [0.05, 0.1, 0.2][rng.below(3)],
                eps: [0.4, 2.0 / 3.0][rng.below(2)],
                ..SmoParams::default()
            };
            let cfg = IncrementalConfig {
                smo,
                refresh_every: [4, 64, 1024][rng.below(3)],
                engine,
                // 4-16 lifted features: small enough that some
                // sequences stay in Nyström warmup, others freeze
                features: 4 + rng.below(13),
                ..IncrementalConfig::default()
            };

            let mut inc = ApproxIncremental::new(kernel, cap, 2, cfg);
            let mut stream =
                SlabStream::new(SlabConfig::default(), 0x5EED_A000 + seq);
            if rng.below(2) == 0 {
                stream = stream.with_drift(DriftSchedule {
                    drift: Drift::MeanShift {
                        delta: rng.uniform_range(-6.0, 6.0),
                    },
                    start: cap,
                    duration: rng.below(cap) + 1,
                });
            }

            let ops = cap + 1 + rng.below(2 * cap);
            for op in 0..ops {
                // ~25% targeted forgets once enough residents exist;
                // the rest absorbs (growth adds, then policy evicts)
                if inc.len() >= 3 && rng.below(4) == 0 {
                    let ids = inc.ids().to_vec();
                    let victim = ids[rng.below(ids.len())];
                    inc.forget(victim).unwrap_or_else(|e| {
                        panic!(
                            "{engine} seq {seq} op {op}: forget({victim}) \
                             failed: {e}"
                        )
                    });
                } else {
                    inc.push(&stream.next_point()).unwrap_or_else(|e| {
                        panic!("{engine} seq {seq} op {op}: push failed: {e}")
                    });
                }
                assert_approx_invariants(
                    &inc,
                    &format!("{engine} seq {seq} op {op}"),
                );
            }
            assert!(
                inc.len() >= 2 && inc.len() <= cap,
                "{engine} seq {seq}: bad window fill"
            );

            // a non-resident id is a typed rejection, bitwise untouched
            let before: Vec<u64> =
                inc.alpha().iter().map(|v| v.to_bits()).collect();
            assert!(
                matches!(
                    inc.forget(u64::MAX),
                    Err(slabsvm::Error::Unlearning(_))
                ),
                "{engine} seq {seq}: bogus forget must be typed"
            );
            let after: Vec<u64> =
                inc.alpha().iter().map(|v| v.to_bits()).collect();
            assert_eq!(before, after, "{engine} seq {seq}");
        }
    }
}

/// Batch forgets under the approx engine: every id leaves in one
/// repair, all-or-nothing on a bad list, invariants certified after.
#[test]
fn approx_forget_many_is_all_or_nothing() {
    let cfg = IncrementalConfig {
        engine: EngineKind::Rff,
        features: 12,
        ..IncrementalConfig::default()
    };
    let kernel = Kernel::Rbf { g: 0.1 };
    let mut inc = ApproxIncremental::new(kernel, 24, 2, cfg);
    let mut stream = SlabStream::new(SlabConfig::default(), 0xBA7C4);
    for _ in 0..24 {
        inc.push(&stream.next_point()).unwrap();
    }
    let ids = inc.ids().to_vec();
    // bad batch: one bogus id poisons the whole request, state untouched
    let before: Vec<u64> = inc.alpha().iter().map(|v| v.to_bits()).collect();
    assert!(inc.forget_many(&[ids[0], u64::MAX]).is_err());
    let after: Vec<u64> = inc.alpha().iter().map(|v| v.to_bits()).collect();
    assert_eq!(before, after, "failed batch must not touch the dual");
    // good batch: all four leave, invariants hold
    inc.forget_many(&ids[0..4]).unwrap();
    assert_eq!(inc.len(), 20);
    for id in &ids[0..4] {
        assert_eq!(inc.slot_of_id(*id), None);
    }
    assert_approx_invariants(&inc, "after forget_many");
}
