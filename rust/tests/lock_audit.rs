//! Tracked-lock audit, end-to-end (`--features lock-audit`).
//!
//! With the feature on, every `crate::sync` lock in the serving stack
//! records per-thread acquisition stacks and a global lock-order
//! graph, panicking *before blocking* on any cycle. Driving the real
//! sharded manager under producer concurrency therefore turns a lock
//! ordering regression into a deterministic test failure here — no
//! hung CI job, no flaky timeout. The direct-API tests below also pin
//! the panic surfaces (ABBA cycle, self-relock, absorb-under-lock) so
//! a refactor cannot silently neuter the auditor.

#![cfg(feature = "lock-audit")]

use std::panic::{catch_unwind, AssertUnwindSafe};

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::{SlabConfig, SlabStream};
use slabsvm::runtime::Engine;
use slabsvm::stream::{DriftConfig, StreamConfig, StreamPoolConfig, StreamSpec};
use slabsvm::sync::{assert_lock_free, Mutex};

fn panic_text(e: Box<dyn std::any::Any + Send>) -> String {
    e.downcast_ref::<String>()
        .cloned()
        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default()
}

#[test]
fn abba_inversion_panics_before_blocking() {
    let a = Mutex::new("audit-itest.a", ());
    let b = Mutex::new("audit-itest.b", ());
    {
        let _ga = a.lock();
        let _gb = b.lock(); // records a -> b
    }
    let err = catch_unwind(AssertUnwindSafe(|| {
        let _gb = b.lock();
        let _ga = a.lock(); // would close b -> a: cycle
    }))
    .expect_err("inverted order must panic");
    let msg = panic_text(err);
    assert!(msg.contains("lock-order cycle"), "{msg}");
}

#[test]
fn same_instance_relock_panics() {
    let m = Mutex::new("audit-itest.relock", 0u32);
    let _g = m.lock();
    let err = catch_unwind(AssertUnwindSafe(|| {
        let _g2 = m.lock();
    }))
    .expect_err("self-relock must panic");
    let msg = panic_text(err);
    assert!(msg.contains("re-locking"), "{msg}");
}

#[test]
fn assert_lock_free_fires_under_a_held_guard() {
    let m = Mutex::new("audit-itest.holdcheck", ());
    let g = m.lock();
    let err = catch_unwind(AssertUnwindSafe(|| {
        assert_lock_free("audit-itest absorb");
    }))
    .expect_err("assert_lock_free must panic while a guard is held");
    let msg = panic_text(err);
    assert!(msg.contains("while"), "{msg}");
    drop(g);
    // and stays quiet once the guard is gone
    assert_lock_free("audit-itest absorb");
}

/// The real serving stack under tracked locks: concurrent producers
/// into a sharded manager, streams closed while others keep pushing,
/// full shutdown. Any lock held across an absorb or any cross-shard
/// ordering cycle panics deterministically inside this test run; the
/// absorb counts prove the workers survived the whole session.
#[test]
fn serving_stack_runs_clean_under_tracked_locks() {
    let coordinator = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig { max_batch: 32, max_wait_us: 200, queue_cap: 1024 },
        2,
        StreamPoolConfig { shards: 2, mailbox_cap: 16, checkpoint: None },
    );
    let m = coordinator.stream_manager();
    let cfg = StreamConfig {
        window: 40,
        min_train: 20,
        drift: DriftConfig {
            recent: 32,
            min_observations: 16,
            outside_frac: 0.99,
            rho_rel: 50.0,
        },
        ..Default::default()
    };
    let n_streams = 6usize;
    let points = 40usize;
    m.open_streams(
        (0..n_streams)
            .map(|i| StreamSpec::new(format!("audit-{i}"), cfg))
            .collect(),
    )
    .unwrap();

    std::thread::scope(|scope| {
        for i in 0..n_streams {
            let manager = m;
            scope.spawn(move || {
                let mut stream =
                    SlabStream::new(SlabConfig::default(), 9100 + i as u64);
                for _ in 0..points {
                    manager
                        .push(&format!("audit-{i}"), &stream.next_point())
                        .unwrap();
                }
            });
        }
    });

    for i in 0..n_streams {
        let s = m.close_stream(&format!("audit-{i}")).unwrap();
        assert_eq!(
            s.updates, points as u64,
            "audit-{i} lost absorbs under tracked locks"
        );
    }
    coordinator.shutdown();
}
