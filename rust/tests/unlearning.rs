//! First-principles certification of targeted unlearning.
//!
//! The removal test suite the feature ships under:
//!
//! * **unlearning ≡ retrain** — `forget(x)` followed by the
//!   warm-started repair must land on the same optimum a from-scratch
//!   fit on the window minus x finds, to ≤ 1e-6 objective/ρ parity
//!   (both solvers run at `tol = 1e-9`, so each sits within ~1e-7
//!   margin units of the optimum and the comparison is meaningful);
//! * **exact mass removal** — the forgotten sample's α/ᾱ leave the
//!   dual entirely (Σα = 1, Σᾱ = ε still hold over the survivors, its
//!   id no longer resolves);
//! * a **fresh-Gram KKT certificate** on every post-forget state —
//!   margins recomputed from scratch via `solver::validate`, none of
//!   the incremental bookkeeping trusted;
//! * **typed failure** — forgetting a non-resident id (or the last
//!   resident sample) is `Error::Unlearning`, the state is untouched,
//!   and a shard worker serving the stream survives it.

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::SlabConfig;
use slabsvm::error::Error;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::smo::SmoParams;
use slabsvm::solver::{validate, SolverKind, Trainer};
use slabsvm::stream::{
    IncrementalConfig, IncrementalSmo, PolicyKind, StreamConfig, StreamSpec,
};
use slabsvm::util::rng::Rng;

/// Fresh-Gram KKT certificate of the current dual (margins recomputed
/// from a from-scratch Gram matrix — the incremental `s` is not
/// consulted).
fn certify_fresh(inc: &IncrementalSmo, ctx: &str) {
    let p = inc.config().smo;
    let m = inc.len();
    let report = inc.report();
    let k = inc.window().kernel().gram(&inc.window().matrix(), 1);
    let cap_a = 1.0 / (p.nu1 * m as f64);
    let cap_b = p.eps / (p.nu2 * m as f64);
    let cert = validate::report(
        &k,
        &report.dual.alpha,
        &report.dual.alpha_bar,
        report.dual.rho1,
        report.dual.rho2,
        p.nu1,
        p.nu2,
        p.eps,
        cap_a.min(cap_b) * 1e-6,
    );
    assert!(cert.max_box_violation <= 1e-9, "{ctx}: box {cert:?}");
    assert!(
        cert.sum_alpha_violation <= 1e-9 && cert.sum_alpha_bar_violation <= 1e-9,
        "{ctx}: mass sums broken: {cert:?}"
    );
    let margin_scale =
        1.0 + report.dual.s.iter().map(|v| v.abs()).sum::<f64>() / m as f64;
    assert!(
        cert.max_kkt_violation <= p.tol * margin_scale * 4.0,
        "{ctx}: KKT violation {} (tol {})",
        cert.max_kkt_violation,
        p.tol * margin_scale * 4.0
    );
}

/// `forget(x)` + repair vs a from-scratch fit on window ∖ {x}: ≤ 1e-6
/// objective and ρ parity, for every seed, both eviction policies,
/// linear and RBF kernels.
#[test]
fn forget_then_repair_matches_from_scratch_retrain() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(0xF0_6E7 + seed);
        let cap = 16 + rng.below(25); // window in [16, 40]
        let kernel = if seed % 2 == 0 {
            Kernel::Linear
        } else {
            Kernel::Rbf { g: 0.02 + 0.1 * rng.uniform() }
        };
        let smo = SmoParams {
            nu1: [0.3, 0.5, 0.8][rng.below(3)],
            nu2: [0.05, 0.1][rng.below(2)],
            eps: [0.4, 2.0 / 3.0][rng.below(2)],
            // both paths solve essentially to the optimum, so the 1e-6
            // parity bound measures the unlearning path, not solver slack
            tol: 1e-9,
            ..SmoParams::default()
        };
        let cfg = IncrementalConfig {
            smo,
            policy: if rng.below(2) == 0 {
                PolicyKind::Fifo
            } else {
                PolicyKind::InteriorFirst
            },
            ..IncrementalConfig::default()
        };
        let mut inc = IncrementalSmo::new(kernel, cap, 2, cfg);
        let ds = SlabConfig::default().generate(cap + rng.below(cap), seed);
        for i in 0..ds.len() {
            inc.push(ds.x.row(i)).unwrap();
        }

        // forget a random resident sample
        let ids = inc.window().ids().to_vec();
        let victim = ids[rng.below(ids.len())];
        let m_before = inc.len();
        inc.forget(victim).unwrap();

        // exact removal: id gone, window shrunk, dual mass conserved
        assert_eq!(inc.len(), m_before - 1, "seed {seed}");
        assert_eq!(inc.window().slot_of_id(victim), None, "seed {seed}");
        let sa: f64 = inc.alpha().iter().sum();
        let sb: f64 = inc.alpha_bar().iter().sum();
        assert!((sa - 1.0).abs() < 1e-9, "seed {seed}: sum(alpha)={sa}");
        assert!(
            (sb - smo.eps).abs() < 1e-9,
            "seed {seed}: sum(alpha_bar)={sb}"
        );
        certify_fresh(&inc, &format!("seed {seed} post-forget"));

        // the from-scratch reference on exactly the surviving window
        let streamed = inc.report();
        let batch = Trainer::from_smo_params(smo)
            .solver(SolverKind::Smo)
            .kernel(kernel)
            .fit(&inc.window().matrix())
            .unwrap();
        let rel_obj = (streamed.stats.objective - batch.stats.objective).abs()
            / batch.stats.objective.abs().max(1e-9);
        assert!(
            rel_obj <= 1e-6,
            "seed {seed}: objective parity {rel_obj:.3e}: forget+repair \
             {} vs retrain {}",
            streamed.stats.objective,
            batch.stats.objective
        );
        let rho_scale = 1.0 + batch.dual.rho1.abs().max(batch.dual.rho2.abs());
        assert!(
            (streamed.dual.rho1 - batch.dual.rho1).abs() / rho_scale <= 1e-6
                && (streamed.dual.rho2 - batch.dual.rho2).abs() / rho_scale
                    <= 1e-6,
            "seed {seed}: rho parity: [{}, {}] vs [{}, {}]",
            streamed.dual.rho1,
            streamed.dual.rho2,
            batch.dual.rho1,
            batch.dual.rho2
        );
    }
}

/// Forgetting several samples in a row keeps matching the from-scratch
/// fit — removals compose.
#[test]
fn repeated_forgets_compose() {
    let smo = SmoParams { tol: 1e-9, ..SmoParams::default() };
    let cfg = IncrementalConfig { smo, ..IncrementalConfig::default() };
    let mut inc = IncrementalSmo::new(Kernel::Linear, 30, 2, cfg);
    let ds = SlabConfig::default().generate(42, 77);
    for i in 0..42 {
        inc.push(ds.x.row(i)).unwrap();
    }
    let mut rng = Rng::new(0xC0117);
    for round in 0..8 {
        let ids = inc.window().ids().to_vec();
        inc.forget(ids[rng.below(ids.len())]).unwrap();
        certify_fresh(&inc, &format!("round {round}"));
    }
    assert_eq!(inc.len(), 22);
    let streamed = inc.report();
    let batch = Trainer::from_smo_params(smo)
        .kernel(Kernel::Linear)
        .fit(&inc.window().matrix())
        .unwrap();
    let rel = (streamed.stats.objective - batch.stats.objective).abs()
        / batch.stats.objective.abs().max(1e-9);
    assert!(rel <= 1e-6, "8 composed forgets diverged: {rel:.3e}");
}

/// Non-resident ids (never admitted / already evicted / already
/// forgotten) and last-sample removals are typed errors that leave the
/// dual untouched to the bit.
#[test]
fn bad_forgets_are_typed_and_leave_state_untouched() {
    let mut inc =
        IncrementalSmo::new(Kernel::Linear, 8, 2, IncrementalConfig::default());
    let ds = SlabConfig::default().generate(12, 78);
    for i in 0..12 {
        inc.push(ds.x.row(i)).unwrap();
    }
    let alpha: Vec<u64> = inc.alpha().iter().map(|v| v.to_bits()).collect();
    let s: Vec<u64> = inc.margins().iter().map(|v| v.to_bits()).collect();
    for bad in [0u64, 3, 12, u64::MAX] {
        // ids 0..=3 were FIFO-evicted, 12+ never admitted
        let err = inc.forget(bad).unwrap_err();
        assert!(
            matches!(err, Error::Unlearning(_)),
            "id {bad}: want Error::Unlearning, got {err:?}"
        );
    }
    let alpha_after: Vec<u64> =
        inc.alpha().iter().map(|v| v.to_bits()).collect();
    let s_after: Vec<u64> = inc.margins().iter().map(|v| v.to_bits()).collect();
    assert_eq!(alpha, alpha_after, "rejected forgets must not touch α");
    assert_eq!(s, s_after, "rejected forgets must not touch the margins");
}

/// The mailbox path: `Coordinator::forget` routes to the owning shard,
/// re-publishes the shrunk model at a higher registry version, rejects
/// bad ids with a typed error, and the shard worker keeps absorbing
/// afterwards (the acceptance shape of "a malformed forget must not
/// panic the worker").
#[test]
fn coordinator_forget_republishes_and_survives_bad_ids() {
    let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    let cfg = StreamConfig { window: 32, min_train: 16, ..Default::default() };
    c.open_streams(vec![
        StreamSpec::new("a", cfg),
        StreamSpec::new("b", cfg).eviction(PolicyKind::InteriorFirst),
    ])
    .unwrap();
    let ds = SlabConfig::default().generate(40, 79);
    for i in 0..40 {
        c.push("a", ds.x.row(i)).unwrap();
        c.push("b", ds.x.row(i)).unwrap();
    }
    c.quiesce_streams();
    let v_before = c.registry().version("a").unwrap();

    // FIFO stream "a" holds ids 8..=39
    let out = c.forget("a", 15).unwrap();
    assert_eq!(
        (out.name.as_str(), out.ids.as_slice(), out.resident),
        ("a", &[15u64][..], 31)
    );
    let v_forget = out.version.expect("warm stream must re-publish");
    assert!(v_forget > v_before, "forget must bump the registry version");
    // the hot-swapped model no longer carries the forgotten point: the
    // served model equals the session's post-removal solver state
    // (checked through a snapshot sweep — the worker owns the session)
    let snap_dir = std::env::temp_dir()
        .join(format!("slabsvm_unlearn_{}", std::process::id()));
    std::fs::create_dir_all(&snap_dir).unwrap();
    let outcomes = c.snapshot_streams(&snap_dir).unwrap();
    assert!(outcomes.iter().all(|o| o.result.is_ok()));
    let snap = slabsvm::stream::persist::read_snapshot(
        &slabsvm::stream::persist::snapshot_path(&snap_dir, "a"),
    )
    .unwrap();
    std::fs::remove_dir_all(&snap_dir).ok();
    assert_eq!(snap.forgets, 1);
    assert_eq!(snap.len, 31);
    assert!(!snap.ids.contains(&15), "forgotten id must leave the window");
    let served = c.registry().get("a").unwrap();
    assert_eq!(
        served.rho1.to_bits(),
        snap.rho1.to_bits(),
        "served model must be the post-removal state"
    );

    // bad ids: typed error through the mailbox, worker stays alive
    for bad in [0u64, 15, 999] {
        let err = c.forget("a", bad).unwrap_err();
        assert!(
            matches!(err, Error::Unlearning(_)),
            "id {bad}: want Error::Unlearning through the mailbox, got {err:?}"
        );
    }
    assert!(c.forget("ghost", 1).is_err(), "unknown stream is an error");

    // batch unlearning: one mailbox round-trip withdraws both ids with
    // a single repair sweep and a single re-publish
    let out = c.forget_many("a", &[20, 30]).unwrap();
    assert_eq!(
        (out.ids.as_slice(), out.resident),
        (&[20u64, 30][..], 29)
    );
    let v_batch = out.version.expect("warm stream must re-publish");
    assert!(v_batch > v_forget, "batch forget must bump the version");
    // a poisoned batch (one already-forgotten id) is all-or-nothing:
    // the resident id listed alongside it must survive untouched
    let err = c.forget_many("a", &[25, 15]).unwrap_err();
    assert!(matches!(err, Error::Unlearning(_)), "got {err:?}");
    assert!(c.forget("a", 25).is_ok(), "id 25 must survive the bad batch");

    // both streams keep absorbing after the (rejected) forgets
    for i in 0..5 {
        c.push("a", ds.x.row(i)).unwrap();
        c.push("b", ds.x.row(i)).unwrap();
    }
    c.quiesce_streams();
    assert_eq!(c.close_stream("a").unwrap().updates, 45);
    assert_eq!(c.close_stream("b").unwrap().updates, 45);
    assert_eq!(c.stats().stream_forgets.get(), 4);
    c.shutdown();
}

/// Unlearning interacts with the policies: under InteriorFirst the
/// support set stays resident, and forgetting a support vector forces
/// the repair to rebuild the slab without it.
#[test]
fn forgetting_a_support_vector_moves_the_slab_honestly() {
    let smo = SmoParams { tol: 1e-9, ..SmoParams::default() };
    let cfg = IncrementalConfig {
        smo,
        policy: PolicyKind::InteriorFirst,
        ..IncrementalConfig::default()
    };
    let mut inc = IncrementalSmo::new(Kernel::Linear, 24, 2, cfg);
    let ds = SlabConfig::default().generate(36, 80);
    for i in 0..36 {
        inc.push(ds.x.row(i)).unwrap();
    }
    // the heaviest |γ| resident is certainly a support vector
    let (sv_slot, _) = inc
        .alpha()
        .iter()
        .zip(inc.alpha_bar())
        .map(|(a, b)| (a - b).abs())
        .enumerate()
        .fold((0, f64::MIN), |acc, (i, g)| if g > acc.1 { (i, g) } else { acc });
    let sv_id = inc.window().id(sv_slot);
    inc.forget(sv_id).unwrap();
    certify_fresh(&inc, "post-SV-forget");
    // and the result still matches the from-scratch fit on the survivors
    let batch = Trainer::from_smo_params(smo)
        .kernel(Kernel::Linear)
        .fit(&inc.window().matrix())
        .unwrap();
    let rel = (inc.report().stats.objective - batch.stats.objective).abs()
        / batch.stats.objective.abs().max(1e-9);
    assert!(rel <= 1e-6, "SV removal diverged from retrain: {rel:.3e}");
}
