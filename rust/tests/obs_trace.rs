//! Observability integration tests (DESIGN.md §8).
//!
//! The end-to-end trace test drives a real push through the 2-shard
//! session manager and asserts the acceptance bound of ISSUE 7: the
//! recorded span chain is connected (one trace id from enqueue to
//! publish), its stages are contiguous and monotone, their durations
//! sum to the end-to-end latency, and the Repair span carries the
//! solver's own iteration count. The golden tests pin the exposition
//! formats: `prometheus_text` must stay parseable Prometheus text
//! (format 0.0.4) with stable metric names, and `json_lines` must
//! stay one canonical-JSON object per metric.

use slabsvm::coordinator::{BatcherConfig, Coordinator, ServiceStats};
use slabsvm::data::synthetic::{SlabConfig, SlabStream};
use slabsvm::kernel::Kernel;
use slabsvm::obs::{self, Stage};
use slabsvm::runtime::Engine;
use slabsvm::stream::{StreamConfig, StreamPoolConfig, StreamSpec};
use slabsvm::util::json::Json;

fn stream_cfg(window: usize) -> StreamConfig {
    StreamConfig {
        kernel: Kernel::Linear,
        dim: 2,
        window,
        min_train: window / 2,
        ..Default::default()
    }
}

/// One push's reconstructed stage chain.
struct Chain {
    queue: obs::Span,
    absorb: obs::Span,
    publish: obs::Span,
    gram: obs::Span,
    repair: obs::Span,
}

fn chain_for(trace: u64) -> Option<Chain> {
    let spans = obs::spans_for(trace);
    let find = |stage: Stage| spans.iter().copied().find(|s| s.stage == stage);
    Some(Chain {
        queue: find(Stage::Queue)?,
        absorb: find(Stage::Absorb)?,
        publish: find(Stage::Publish)?,
        gram: find(Stage::Gram)?,
        repair: find(Stage::Repair)?,
    })
}

#[test]
fn push_yields_connected_contiguous_span_chain() {
    obs::set_enabled(true);
    let window = 32;
    let c = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig::default(),
        1,
        StreamPoolConfig { shards: 2, mailbox_cap: 64, checkpoint: None },
    );
    c.open_streams(vec![
        StreamSpec::new("trace-left", stream_cfg(window)),
        StreamSpec::new("trace-right", stream_cfg(window)),
    ])
    .expect("open streams");
    let mut left = SlabStream::new(SlabConfig::default(), 99);
    let mut right = SlabStream::new(SlabConfig::default(), 100);
    for _ in 0..(window + window / 2) {
        c.push("trace-left", &left.next_point()).expect("push left");
        c.push("trace-right", &right.next_point()).expect("push right");
    }
    c.quiesce_streams();

    // group retained spans by trace; keep fully published chains
    let mut traces: Vec<u64> = obs::recent_spans(usize::MAX)
        .into_iter()
        .filter(|s| s.trace != 0)
        .map(|s| s.trace)
        .collect();
    traces.sort_unstable();
    traces.dedup();
    let chains: Vec<Chain> =
        traces.iter().filter_map(|&t| chain_for(t)).collect();
    assert!(
        !chains.is_empty(),
        "no push produced a full queue/absorb/publish/gram/repair chain"
    );

    for ch in &chains {
        // one trace, one stream, one owning shard across the chain
        let shard = ch.queue.shard;
        assert!(shard < 2, "shard index {shard} out of range");
        for s in [&ch.absorb, &ch.publish, &ch.gram, &ch.repair] {
            assert_eq!(s.shard, shard, "chain crossed shards");
            assert_eq!(s.stream, ch.queue.stream, "chain crossed streams");
        }
        let name = obs::stream_name(ch.queue.stream)
            .expect("traced stream name must be interned");
        assert!(name.starts_with("trace-"), "unexpected stream {name}");

        // contiguous by construction: queue ends where absorb starts,
        // absorb ends where publish starts
        assert_eq!(ch.queue.end_us(), ch.absorb.start_us, "queue→absorb");
        assert_eq!(ch.absorb.end_us(), ch.publish.start_us, "absorb→publish");

        // stage durations decompose the end-to-end latency: exact by
        // construction, and comfortably inside the 10% acceptance bound
        let end_to_end = ch.publish.end_us() - ch.queue.start_us;
        let sum = ch.queue.dur_us + ch.absorb.dur_us + ch.publish.dur_us;
        assert_eq!(sum, end_to_end, "stage sum != end-to-end latency");
        assert!(
            10 * sum.abs_diff(end_to_end) <= end_to_end.max(1),
            "stage sum {sum}us outside 10% of end-to-end {end_to_end}us"
        );

        // Gram/Repair nest inside Absorb (2us slack: the sub-stages are
        // clocked separately, so truncation can disagree by a tick)
        assert!(
            ch.gram.start_us + 2 >= ch.absorb.start_us,
            "gram sub-span starts before its absorb"
        );
        assert!(
            ch.repair.end_us() <= ch.absorb.end_us() + 2,
            "repair sub-span outlives its absorb"
        );
        assert!(
            ch.gram.end_us() <= ch.repair.start_us + 2,
            "gram and repair sub-spans overlap"
        );
        // the solver's SolveStats ride both the repair span and its
        // parent absorb span
        assert_eq!(ch.repair.iters, ch.absorb.iters, "iters mismatch");
    }
    assert!(
        chains.iter().any(|c| c.repair.iters > 0),
        "no repair span carried solver iterations"
    );

    // the flight recorder saw the same lifecycle, in order
    let events = obs::drain_events();
    let t = chains[0].queue.trace;
    let at = |kind: obs::EventKind| {
        events
            .iter()
            .find(|e| e.trace == t && e.kind == kind)
            .map(|e| e.t_us)
    };
    let enq = at(obs::EventKind::PushEnqueued).expect("push_enqueued");
    let start = at(obs::EventKind::AbsorbStart).expect("absorb_start");
    let done = at(obs::EventKind::AbsorbEnd).expect("absorb_end");
    assert!(enq <= start && start <= done, "event timestamps not monotone");

    c.shutdown();
}

// ------------------------------------------------------------- golden

/// Minimal Prometheus text-format (0.0.4) line validator.
fn assert_prometheus_line(line: &str) {
    if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
        return;
    }
    let (metric, value) =
        line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line}"));
    assert!(
        value.parse::<f64>().is_ok(),
        "unparseable sample value in {line:?}"
    );
    let name = metric.split('{').next().unwrap_or("");
    assert!(
        !name.is_empty()
            && name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
        "illegal metric name in {line:?}"
    );
    if let Some(rest) = metric.strip_prefix(name) {
        if !rest.is_empty() {
            assert!(
                rest.starts_with("{le=\"") && rest.ends_with("\"}"),
                "unexpected label block in {line:?}"
            );
        }
    }
}

#[test]
fn prometheus_text_golden() {
    let stats = ServiceStats::new();
    stats.requests.add(2);
    stats.absorb_latency.record_us(100);
    let text = slabsvm::obs::prometheus_text(&slabsvm::obs::registry(&stats));

    // pinned counter block: HELP, TYPE, then the bare sample
    assert!(
        text.starts_with(
            "# HELP slabsvm_requests_total scoring requests accepted\n\
             # TYPE slabsvm_requests_total counter\n\
             slabsvm_requests_total 2\n"
        ),
        "counter exposition changed:\n{text}"
    );
    // pinned histogram tail: cumulative buckets end at +Inf == count
    assert!(text.contains("# TYPE slabsvm_absorb_latency_us histogram\n"));
    assert!(text.contains("slabsvm_absorb_latency_us_bucket{le=\"+Inf\"} 1\n"));
    assert!(text.contains("slabsvm_absorb_latency_us_sum 100\n"));
    assert!(text.contains("slabsvm_absorb_latency_us_count 1\n"));

    for line in text.lines() {
        assert_prometheus_line(line);
    }
}

#[test]
fn coordinator_metrics_text_is_valid_prometheus() {
    let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    let text = c.metrics_text();
    c.shutdown();
    assert_eq!(
        text.lines().filter(|l| l.starts_with("# TYPE ")).count(),
        23,
        "registry size drifted — update the golden tests deliberately"
    );
    for line in text.lines() {
        assert_prometheus_line(line);
    }
}

#[test]
fn json_lines_golden() {
    let stats = ServiceStats::new();
    stats.scored.add(7);
    let lines = slabsvm::obs::json_lines(&slabsvm::obs::registry(&stats));
    assert_eq!(lines.lines().count(), 23);

    // pinned first line: canonical JSON, alphabetical keys
    assert_eq!(
        lines.lines().next().unwrap(),
        "{\"name\":\"slabsvm_requests_total\",\"type\":\"counter\",\"value\":0}",
        "counter JSON shape changed"
    );

    let mut saw_scored = false;
    for line in lines.lines() {
        let v = Json::parse(line).expect("every line parses");
        let name = v.get("name").and_then(Json::as_str).expect("name");
        assert!(name.starts_with("slabsvm_"), "unprefixed {name}");
        match v.get("type").and_then(Json::as_str) {
            Some("counter") => {
                let val = v.get("value").and_then(Json::as_f64).expect("value");
                if name == "slabsvm_scored_total" {
                    assert_eq!(val, 7.0);
                    saw_scored = true;
                }
            }
            Some("histogram") => {
                assert!(v.get("count").is_some(), "{name} lacks count");
                assert!(v.get("sum_us").is_some(), "{name} lacks sum_us");
                assert!(
                    v.get("buckets").and_then(Json::as_arr).is_some(),
                    "{name} lacks bucket pairs"
                );
            }
            other => panic!("unknown metric type {other:?} on {name}"),
        }
    }
    assert!(saw_scored, "slabsvm_scored_total missing from JSON export");
}
