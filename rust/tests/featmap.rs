//! Feature-map contract suite (DESIGN.md §10, experiment KA1).
//!
//! Pins the [`slabsvm::kernel::featmap`] contracts the approximate
//! engines are built on:
//!
//! * RFF is an **unbiased** estimator of the RBF kernel with
//!   Monte-Carlo error O(1/√P) — checked across ≥50 independent seeds;
//! * the Nyström lifted Gram is PSD, and **exact** when every training
//!   point is a landmark;
//! * both maps are bitwise-deterministic by seed and invariant to
//!   thread count;
//! * the approx trainer lands within 0.02 AUC of the exact SMO at
//!   Table-1 scale, across kernels and a lifted-dimension sweep;
//! * exported models are structurally m-independent (Nyström folds to
//!   n_sv ≤ L, RFF to one lifted row), so scoring is O(d·D);
//! * composition guards: approx + f32 and approx + cascade are typed
//!   config errors (referenced from `rust/tests/precision.rs`).

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::featmap::{
    EngineKind, FeatMap, FeatureMap, NystroemMap, RffMap,
};
use slabsvm::kernel::{Kernel, Precision};
use slabsvm::linalg::{sym_eig, Matrix};
use slabsvm::metrics::roc_auc;
use slabsvm::solver::{SolverKind, Trainer};

fn lift(map: &impl FeatureMap, x: &[f64]) -> Vec<f64> {
    let mut scratch = vec![0.0; map.scratch_len().max(1)];
    let mut out = vec![0.0; map.d_out()];
    map.map_into(x, &mut scratch, &mut out);
    out
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

// ------------------------------------------------------- RFF estimator

#[test]
fn rff_is_unbiased_for_rbf_within_the_monte_carlo_bound() {
    let g = 0.7;
    let kernel = Kernel::Rbf { g };
    let d_out = 256usize; // P = 128 cos/sin pairs
    let p_pairs = (d_out / 2) as f64;
    let pairs: &[(&[f64], &[f64])] = &[
        (&[0.3, -1.1], &[0.8, 0.4]),
        (&[2.0, 0.0], &[2.0, 0.0]),
        (&[-0.5, 0.25], &[1.5, -0.75]),
        (&[0.0, 0.0], &[0.9, -0.2]),
    ];
    let n_seeds = 64usize;
    for &(x, y) in pairs {
        let exact = kernel.eval(x, y);
        let mut sum = 0.0;
        for seed in 0..n_seeds as u64 {
            let map = RffMap::new(2, d_out, g, 1000 + seed).unwrap();
            let est = dot(&lift(&map, x), &lift(&map, y));
            // per-seed: Monte-Carlo error O(1/√P), generous constant
            assert!(
                (est - exact).abs() < 6.0 / p_pairs.sqrt(),
                "seed {seed}: |{est} - {exact}| breaches the 1/√P bound"
            );
            sum += est;
        }
        // across seeds the estimator must *converge* on the kernel —
        // biased maps pass per-seed bounds but fail this
        let mean = sum / n_seeds as f64;
        let tol = 4.0 / (p_pairs * n_seeds as f64).sqrt();
        assert!(
            (mean - exact).abs() < tol,
            "mean over {n_seeds} seeds {mean} vs exact {exact} \
             (tol {tol}): estimator is biased"
        );
    }
}

#[test]
fn rff_lifted_norm_is_one_at_zero_distance() {
    // k(x,x) = 1 for RBF; ⟨φ(x), φ(x)⟩ = (1/P)·Σ(cos²+sin²) = 1 exactly
    let map = RffMap::new(3, 64, 0.2, 9).unwrap();
    let x = [0.4, -2.0, 1.0];
    let phi = lift(&map, &x);
    assert!((dot(&phi, &phi) - 1.0).abs() < 1e-12);
}

// ---------------------------------------------------- Nyström exactness

#[test]
fn nystroem_is_exact_when_every_point_is_a_landmark() {
    let ds = SlabConfig::default().generate(40, 11);
    for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.5 }] {
        let map = NystroemMap::new(kernel, ds.x.clone()).unwrap();
        for i in 0..ds.x.rows() {
            let pi = lift(&map, ds.x.row(i));
            for j in i..ds.x.rows() {
                let pj = lift(&map, ds.x.row(j));
                let approx = dot(&pi, &pj);
                let exact = kernel.eval(ds.x.row(i), ds.x.row(j));
                assert!(
                    (approx - exact).abs() <= 1e-9,
                    "{}: lifted Gram[{i},{j}] = {approx}, exact {exact}",
                    kernel.family()
                );
            }
        }
    }
}

#[test]
fn nystroem_lifted_gram_is_psd() {
    let ds = SlabConfig::default().generate(60, 12);
    let landmarks = ds.x.select_rows(&(0..12).collect::<Vec<_>>());
    for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.8 }] {
        let map = NystroemMap::new(kernel, landmarks.clone()).unwrap();
        let m = ds.x.rows();
        let mut gram = Matrix::zeros(m, m);
        let rows: Vec<Vec<f64>> =
            (0..m).map(|i| lift(&map, ds.x.row(i))).collect();
        for i in 0..m {
            for j in 0..m {
                gram.set(i, j, dot(&rows[i], &rows[j]));
            }
        }
        let (eigvals, _) = sym_eig(&gram);
        let min = eigvals.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            min >= -1e-10,
            "{}: lifted Gram has eigenvalue {min} < 0",
            kernel.family()
        );
    }
}

// --------------------------------------------------------- determinism

#[test]
fn maps_are_bitwise_deterministic_by_seed() {
    let x = [1.25, -0.5];
    let a = RffMap::new(2, 128, 0.3, 42).unwrap();
    let b = RffMap::new(2, 128, 0.3, 42).unwrap();
    let c = RffMap::new(2, 128, 0.3, 43).unwrap();
    let (pa, pb, pc) = (lift(&a, &x), lift(&b, &x), lift(&c, &x));
    assert_eq!(
        pa.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        pb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "same seed must map bitwise-identically"
    );
    assert_ne!(
        pa.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        pc.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "different seeds must draw different frequencies"
    );

    let ds = SlabConfig::default().generate(16, 13);
    let n1 = NystroemMap::new(Kernel::Rbf { g: 0.5 }, ds.x.clone()).unwrap();
    let n2 = NystroemMap::new(Kernel::Rbf { g: 0.5 }, ds.x.clone()).unwrap();
    assert_eq!(
        lift(&n1, &x).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        lift(&n2, &x).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "same landmarks must build the same map"
    );
}

#[test]
fn mapping_is_invariant_to_thread_count() {
    // the maps hold no mutable state: 1 thread and 8 threads mapping
    // the same rows must agree bitwise, in any interleaving
    let ds = SlabConfig::default().generate(64, 14);
    let map = std::sync::Arc::new(
        FeatMap::Rff(RffMap::new(2, 96, 0.4, 77).unwrap()),
    );
    let serial: Vec<Vec<u64>> = (0..ds.x.rows())
        .map(|i| {
            lift(map.as_ref(), ds.x.row(i))
                .iter()
                .map(|v| v.to_bits())
                .collect()
        })
        .collect();
    let rows: Vec<Vec<f64>> =
        (0..ds.x.rows()).map(|i| ds.x.row(i).to_vec()).collect();
    let rows = std::sync::Arc::new(rows);
    let mut handles = Vec::new();
    for t in 0..8usize {
        let map = map.clone();
        let rows = rows.clone();
        handles.push(std::thread::spawn(move || {
            let mut out = Vec::new();
            let mut i = t;
            while i < rows.len() {
                let bits: Vec<u64> = lift(map.as_ref(), &rows[i])
                    .iter()
                    .map(|v| v.to_bits())
                    .collect();
                out.push((i, bits));
                i += 8;
            }
            out
        }));
    }
    for h in handles {
        for (i, bits) in h.join().unwrap() {
            assert_eq!(bits, serial[i], "row {i} differs across threads");
        }
    }
}

#[test]
fn approx_training_is_bitwise_deterministic_by_seed() {
    let ds = SlabConfig::default().generate(200, 15);
    for engine in [EngineKind::Nystroem, EngineKind::Rff] {
        let fit = || {
            Trainer::new(SolverKind::Approx)
                .kernel(Kernel::Rbf { g: 0.5 })
                .engine(engine)
                .features(32)
                .seed(7)
                .fit(&ds.x)
                .unwrap()
        };
        let (a, b) = (fit(), fit());
        assert_eq!(
            a.model.rho1.to_bits(),
            b.model.rho1.to_bits(),
            "{engine}: rho1 not reproducible"
        );
        let q = [0.7, -0.3];
        assert_eq!(
            a.model.score(&q).to_bits(),
            b.model.score(&q).to_bits(),
            "{engine}: scores not reproducible"
        );
    }
}

// ------------------------------------------------- accuracy vs exact

#[test]
fn approx_auc_is_within_two_points_of_exact_at_table1_scale() {
    let train = SlabConfig::default().generate(300, 21);
    let eval = SlabConfig::default().generate_eval(250, 250, 22);
    let truth = &eval.y;
    let sweep: &[(EngineKind, Kernel, usize)] = &[
        (EngineKind::Nystroem, Kernel::Linear, 32),
        (EngineKind::Nystroem, Kernel::Linear, 64),
        (EngineKind::Nystroem, Kernel::Rbf { g: 0.5 }, 32),
        (EngineKind::Nystroem, Kernel::Rbf { g: 0.5 }, 64),
        (EngineKind::Rff, Kernel::Rbf { g: 0.5 }, 64),
        (EngineKind::Rff, Kernel::Rbf { g: 0.5 }, 128),
    ];
    for &(engine, kernel, d) in sweep {
        let exact = Trainer::new(SolverKind::Smo)
            .kernel(kernel)
            .fit(&train.x)
            .unwrap()
            .model;
        let approx = Trainer::new(SolverKind::Approx)
            .kernel(kernel)
            .engine(engine)
            .features(d)
            .fit(&train.x)
            .unwrap()
            .model;
        let score_all = |m: &slabsvm::solver::ocssvm::SlabModel| -> Vec<f64> {
            (0..eval.x.rows()).map(|i| m.score(eval.x.row(i))).collect()
        };
        let auc_exact = roc_auc(truth, &score_all(&exact));
        let auc_approx = roc_auc(truth, &score_all(&approx));
        assert!(
            (auc_exact - auc_approx).abs() <= 0.02,
            "{engine}/{}/D={d}: AUC {auc_approx:.4} vs exact \
             {auc_exact:.4} — gap exceeds 0.02",
            kernel.family()
        );
    }
}

// ------------------------------------------- structural m-independence

#[test]
fn exported_models_are_structurally_m_independent() {
    // scoring cost must be pinned by D, not by how many samples were
    // resident: Nyström folds to ≤ L support rows, RFF to exactly one
    for m in [100usize, 400] {
        let ds = SlabConfig::default().generate(m, 31);
        let ny = Trainer::new(SolverKind::Approx)
            .kernel(Kernel::Rbf { g: 0.5 })
            .engine(EngineKind::Nystroem)
            .features(24)
            .fit(&ds.x)
            .unwrap()
            .model;
        assert!(
            ny.n_sv() <= 24,
            "m={m}: nystroem model has {} SVs > 24 landmarks",
            ny.n_sv()
        );
        assert!(
            ny.featmap.is_none(),
            "nystroem must fold to a plain kernel model"
        );
        let rff = Trainer::new(SolverKind::Approx)
            .kernel(Kernel::Rbf { g: 0.5 })
            .engine(EngineKind::Rff)
            .features(24)
            .fit(&ds.x)
            .unwrap()
            .model;
        assert_eq!(
            rff.x_sv.rows(),
            1,
            "m={m}: rff model must store exactly the lifted weight row"
        );
        assert!(rff.featmap.is_some(), "rff scoring needs its map");
    }
}

// --------------------------------------------------- composition guards

#[test]
fn approx_rejects_f32_and_cascade_composition() {
    let ds = SlabConfig::default().generate(50, 41);
    let err = Trainer::new(SolverKind::Approx)
        .kernel(Kernel::Rbf { g: 0.5 })
        .precision(Precision::F32)
        .fit(&ds.x)
        .unwrap_err();
    assert!(
        err.to_string().contains("f32"),
        "want the f32 composition guard, got: {err}"
    );
    let err = Trainer::new(SolverKind::Approx)
        .kernel(Kernel::Rbf { g: 0.5 })
        .cascade(4, 2)
        .fit(&ds.x)
        .unwrap_err();
    assert!(
        err.to_string().contains("cascade"),
        "want the cascade composition guard, got: {err}"
    );
}

#[test]
fn rff_requires_the_rbf_kernel_as_a_typed_error() {
    let ds = SlabConfig::default().generate(50, 42);
    let err = Trainer::new(SolverKind::Approx)
        .kernel(Kernel::Linear)
        .engine(EngineKind::Rff)
        .fit(&ds.x)
        .unwrap_err();
    assert!(
        err.to_string().contains("rbf"),
        "want the rff kernel guard, got: {err}"
    );
}
