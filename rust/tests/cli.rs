//! End-to-end CLI tests: drive the real `slabsvm` binary the way a user
//! does — train → save → predict → eval → figures → sweep — and check
//! the outputs and exit codes.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_slabsvm"))
}

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("slabsvm_cli_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn train_accepts_any_solver_kind_and_rejects_unknown() {
    // own directory: sibling tests remove tmpdir() concurrently
    let dir = std::env::temp_dir()
        .join(format!("slabsvm_cli_solvers_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // every SolverKind name trains through the same subcommand
    for solver in ["smo", "pg", "ipm", "ocsvm-smo"] {
        let model = dir.join(format!("m_{solver}.json"));
        let out = bin()
            .args(["train", "--solver", solver, "--size", "120", "--out"])
            .arg(&model)
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "--solver {solver} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(model.exists(), "--solver {solver} wrote no model");
    }
    // unknown solver name fails with a clear error
    let out = bin()
        .args(["train", "--solver", "newton", "--size", "50", "--out", "/tmp/x.json"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown solver"));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn stream_subcommand_runs_online_updates() {
    let out = bin()
        .args([
            "stream",
            "--points",
            "400",
            "--window",
            "96",
            "--min-train",
            "48",
            "--drift",
            "mean-shift",
            "--drift-at",
            "200",
            "--drift-len",
            "40",
            "--drift-amount",
            "-8.0",
            "--report-every",
            "200",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stream failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("streaming 400 samples"), "missing banner: {text}");
    assert!(text.contains("done: 400 updates"), "missing summary: {text}");
    assert!(text.contains("updates/s"));
}

#[test]
fn stream_subcommand_multi_tenant_mode() {
    let out = bin()
        .args([
            "stream",
            "--streams",
            "3",
            "--shards",
            "2",
            "--points",
            "120",
            "--window",
            "48",
            "--min-train",
            "24",
            "--drift",
            "none",
            "--evict",
            "interior-first",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "multi-tenant stream failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("streaming 120 samples x 3 tenants through 2 shard"),
        "missing banner: {text}"
    );
    for tenant in ["tenant-0", "tenant-1", "tenant-2"] {
        assert!(
            text.contains(&format!("{tenant}: 120 updates")),
            "missing per-tenant summary for {tenant}: {text}"
        );
    }
    assert!(
        text.contains("aggregate: 360 samples over 3 tenants"),
        "missing aggregate line: {text}"
    );
    assert!(text.contains("backpressure_waits="), "missing stream stats: {text}");
}

#[test]
fn snapshot_then_restore_resumes_the_fleet() {
    let dir = std::env::temp_dir()
        .join(format!("slabsvm_cli_snap_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // write a snapshot directory from a short synthetic fleet
    let out = bin()
        .args([
            "snapshot", "--streams", "2", "--points", "90", "--window",
            "48", "--min-train", "24", "--out",
        ])
        .arg(&dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "snapshot failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("snapshotted 2/2 streams"), "{text}");
    let snaps: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            e.path().extension().and_then(|x| x.to_str()) == Some("snap")
        })
        .collect();
    assert_eq!(snaps.len(), 2, "expected two .snap files");

    // the format is self-describing: --inspect prints from the file alone
    let out = bin()
        .args(["snapshot", "--inspect"])
        .arg(snaps[0].path())
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "inspect failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("format v2"), "{text}");
    assert!(text.contains("window=48"), "{text}");
    assert!(text.contains("policy=fifo"), "{text}");

    // a fresh coordinator resumes the fleet and keeps absorbing
    let out = bin()
        .args([
            "stream", "--streams", "2", "--points", "40", "--window", "48",
            "--min-train", "24", "--drift", "none", "--restore-dir",
        ])
        .arg(&dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "restore failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("restored 'tenant-0': 90 updates"),
        "missing restore line: {text}"
    );
    // 90 pre-restart + 40 new absorbs per tenant
    assert!(
        text.contains("tenant-0: 130 updates"),
        "restored session did not resume its counters: {text}"
    );

    // corrupt/truncated snapshots fail cleanly, not with a panic
    let victim = snaps[0].path();
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() / 2]).unwrap();
    let out = bin()
        .args(["snapshot", "--inspect"])
        .arg(&victim)
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("snapshot error"), "unexpected error: {err}");

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn forget_subcommand_edits_a_snapshot_in_place() {
    let dir = std::env::temp_dir()
        .join(format!("slabsvm_cli_forget_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // build a snapshot directory (FIFO: resident ids after 90 pushes
    // through a 48-slot window are deterministically 42..=89)
    let out = bin()
        .args([
            "snapshot", "--streams", "1", "--points", "90", "--window",
            "48", "--min-train", "24", "--out",
        ])
        .arg(&dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "snapshot failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let snap = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .find(|e| {
            e.path().extension().and_then(|x| x.to_str()) == Some("snap")
        })
        .expect("no snapshot written")
        .path();

    // the manager envelope (registry version watermark) before the edit
    let out = bin()
        .args(["snapshot", "--inspect"])
        .arg(&snap)
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    let watermark = text
        .split_whitespace()
        .find(|t| t.starts_with("last_version="))
        .expect("inspect must print last_version")
        .to_string();
    assert_ne!(watermark, "last_version=0", "warm fleet must have published");

    // remove two resident samples by their 0-based arrival indices
    let out = bin()
        .args(["forget", "--snapshot"])
        .arg(&snap)
        .args(["--id", "50,60"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "forget failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("forgot sample 50"), "{text}");
    assert!(text.contains("forgot sample 60"), "{text}");
    assert!(text.contains("48 -> 46 resident"), "{text}");

    // the rewritten (in-place) snapshot reflects the removals
    let out = bin()
        .args(["snapshot", "--inspect"])
        .arg(&snap)
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("resident=46"), "{text}");
    assert!(text.contains("forgets=2"), "{text}");
    // the rewrite must not reset the registry version watermark (a
    // later --restore-dir would otherwise regress published versions)
    assert!(
        text.contains(&watermark),
        "forget dropped the version watermark {watermark}: {text}"
    );

    // forgetting an already-forgotten id fails cleanly, typed — and an
    // FIFO-evicted one (id 0) the same way
    for gone in ["50", "0"] {
        let out = bin()
            .args(["forget", "--snapshot"])
            .arg(&snap)
            .args(["--id", gone])
            .output()
            .unwrap();
        assert!(!out.status.success(), "forget of id {gone} must fail");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("unlearning error"), "unexpected error: {err}");
    }

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn help_and_unknown_subcommand() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("train"));
    assert!(text.contains("figures"));

    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn train_predict_eval_roundtrip() {
    let dir = tmpdir();
    let model = dir.join("m.json");

    // train on synthetic data
    let out = bin()
        .args([
            "train", "--data", "synthetic:slab", "--size", "300", "--out",
        ])
        .arg(&model)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("model saved"));
    assert!(model.exists());

    // eval against the default synthetic protocol
    let out = bin()
        .args(["eval", "--model"])
        .arg(&model)
        .args(["--size", "300"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mcc="), "missing metrics: {text}");

    // predict on a CSV of queries
    let queries = dir.join("q.csv");
    std::fs::write(&queries, "20.0,20.0\n-8.0,18.0\n0.0,0.0\n").unwrap();
    let out = bin()
        .args(["predict", "--model"])
        .arg(&model)
        .arg("--queries")
        .arg(&queries)
        .output()
        .unwrap();
    assert!(out.status.success());
    let labels: Vec<&str> = std::str::from_utf8(&out.stdout)
        .unwrap()
        .lines()
        .collect();
    assert_eq!(labels.len(), 3);
    for l in &labels {
        assert!(*l == "1" || *l == "-1", "bad label {l}");
    }
    // the origin is off-band -> anomalous
    assert_eq!(labels[2], "-1");

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn figures_subcommand_writes_files() {
    let dir = tmpdir();
    let out = bin()
        .args(["figures", "--fig", "1", "--out-dir"])
        .arg(&dir)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "figures failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(dir.join("fig1.csv")).unwrap();
    assert!(csv.starts_with("kind,x,y,label"));
    assert!(csv.contains("lower,") && csv.contains("upper,"));
    let svg = std::fs::read_to_string(dir.join("fig1.svg")).unwrap();
    assert!(svg.starts_with("<svg"));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn sweep_subcommand_ranks_grid() {
    let out = bin()
        .args([
            "sweep", "--size", "200", "--nu1", "0.1,0.5", "--nu2", "0.05",
            "--eps-grid", "0.5", "--folds", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "sweep failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("mean MCC"));
    assert!(text.contains("2 grid points"));
}

#[test]
fn bad_arguments_fail_cleanly() {
    // missing required --model
    let out = bin().args(["predict", "--queries", "x.csv"]).output().unwrap();
    assert!(!out.status.success());
    // invalid nu1
    let out = bin()
        .args(["train", "--nu1", "2.0", "--size", "50", "--out", "/tmp/x.json"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("nu1"));
    // unknown figure
    let out = bin().args(["figures", "--fig", "9"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn info_reports_manifest() {
    // works with or without artifacts; just must not crash
    let out = bin().args(["info"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("threads available"));
}
