//! Tier-1: f32 compute mode vs the f64 reference.
//!
//! The contract under test (DESIGN.md §5): [`Precision::F32`] is an
//! accelerator, never a semantics change —
//!
//! * **parity** — on well-conditioned data an f32-mode fit certifies
//!   against the f64 KKT certificate and agrees with the f64 fit on
//!   objective, (ρ1, ρ2) and ranking quality (AUC) within loose,
//!   stated bounds, across every kernel family and solver kind;
//! * **visible fallback** — on data whose structure f32 cannot hold
//!   (distinct points that alias under `as f32` truncation) the
//!   trainer redoes the fit at f64 and says so: `fell_back = true`,
//!   `precision = F64`, and the result is bit-identical to a plain
//!   f64 fit — an f32 fit is never returned uncertified;
//! * **determinism of the blocked path** — the lane-blocked row/Gram
//!   builds are bitwise identical to the scalar `eval` loop in f64
//!   mode and invariant to the thread count in both modes.

use slabsvm::data::synthetic::SlabConfig;
use slabsvm::kernel::{Kernel, Precision};
use slabsvm::linalg::Matrix;
use slabsvm::metrics::roc_auc;
use slabsvm::solver::{SolverKind, Trainer};

const KERNELS: [Kernel; 4] = [
    Kernel::Linear,
    Kernel::Rbf { g: 0.5 },
    Kernel::Poly { g: 0.1, c: 1.0, degree: 2.0 },
    Kernel::Sigmoid { g: 0.05, c: 0.25 },
];

/// Parity bounds on well-conditioned synthetic data: every kernel x
/// every solver kind, f32-certified vs f64 reference. The bounds are
/// deliberately loose — f32 changes the arithmetic — but AUC is tight:
/// single precision must not change what the model *ranks*.
#[test]
fn f32_mode_tracks_f64_across_kernels_and_solvers() {
    let ds = SlabConfig::default().generate(160, 7);
    let eval = SlabConfig::default().generate_eval(150, 150, 8);
    // every kernel under the paper's solver, every f32-capable solver
    // under RBF (the approx engine has no f32 mode — there is no Gram
    // to build at reduced precision; its composition guard is covered
    // in tests/featmap.rs)
    let cases = KERNELS
        .iter()
        .map(|&k| (SolverKind::Smo, k))
        .chain(
            SolverKind::ALL
                .iter()
                .filter(|&&s| s != SolverKind::Approx)
                .map(|&s| (s, KERNELS[1])),
        );
    for (kind, kernel) in cases {
        let base = Trainer::new(kind).kernel(kernel).nu1(0.2).nu2(0.2);
        let r64 = base.clone().fit(&ds.x).unwrap();
        let r32 = base.clone().precision(Precision::F32).fit(&ds.x).unwrap();
        let tag = format!("{kind:?}/{kernel:?}");
        assert!(!r64.fell_back, "{tag}: f64 mode cannot fall back");
        assert_eq!(r64.precision, Precision::F64, "{tag}");
        if r32.fell_back {
            // allowed, but then it must BE the f64 result
            assert_eq!(r32.precision, Precision::F64, "{tag}");
            assert_eq!(
                r32.model.rho1.to_bits(),
                r64.model.rho1.to_bits(),
                "{tag}: fallback must equal the plain f64 fit"
            );
            continue;
        }
        assert_eq!(r32.precision, Precision::F32, "{tag}");
        let scale = r64.stats.objective.abs().max(1.0);
        assert!(
            (r32.stats.objective - r64.stats.objective).abs() <= 1e-3 * scale,
            "{tag}: objective diverged {} vs {}",
            r32.stats.objective,
            r64.stats.objective
        );
        // per-component tolerance: the OCSVM kind pins rho2 to the
        // finite NO_UPPER_PLANE sentinel, which its own scale absorbs
        let tol_of = |r: f64| 1e-2 * r.abs().max(1e-3);
        assert!(
            (r32.model.rho1 - r64.model.rho1).abs() <= tol_of(r64.model.rho1)
                && (r32.model.rho2 - r64.model.rho2).abs()
                    <= tol_of(r64.model.rho2),
            "{tag}: rho diverged ({}, {}) vs ({}, {})",
            r32.model.rho1,
            r32.model.rho2,
            r64.model.rho1,
            r64.model.rho2
        );
        let auc_of = |m: &slabsvm::solver::ocssvm::SlabModel| {
            let margins: Vec<f64> = (0..eval.len())
                .map(|i| m.margin(eval.x.row(i)))
                .collect();
            roc_auc(&eval.y, &margins)
        };
        let (a64, a32) = (auc_of(&r64.model), auc_of(&r32.model));
        assert!(
            (a32 - a64).abs() <= 0.02,
            "{tag}: AUC diverged {a32} vs {a64}"
        );
    }
}

/// Every accepted f32 fit carries a *fresh f64* certificate: the
/// report's KKT violation was measured on re-scored f64 margins, so a
/// certified fit is certified in the reference arithmetic, not in its
/// own. (The bound mirrors the trainer's internal acceptance test.)
#[test]
fn accepted_f32_fits_carry_an_f64_certificate() {
    let ds = SlabConfig::default().generate(200, 21);
    for kernel in KERNELS {
        let r = Trainer::new(SolverKind::Smo)
            .kernel(kernel)
            .precision(Precision::F32)
            .fit(&ds.x)
            .unwrap();
        if r.fell_back {
            assert_eq!(r.precision, Precision::F64, "{kernel:?}");
            continue;
        }
        let mean_s = r.dual.s.iter().map(|v| v.abs()).sum::<f64>()
            / ds.x.rows() as f64;
        assert!(
            r.certificate.max_kkt_violation <= 1e-3 * (1.0 + mean_s),
            "{kernel:?}: accepted f32 fit exceeds the certification \
             bound: {} (margin scale {mean_s})",
            r.certificate.max_kkt_violation
        );
    }
}

/// Ill-conditioned by construction: 64 distinct 1-D points riding a
/// 1e8 offset, spaced 1.0 apart. `as f32` has a 8.0 ulp at that
/// magnitude, so blocks of ~8 *distinct* points alias to the same f32
/// value — the f32 Gram sees duplicated rows (blocks of exact 1s under
/// RBF) where the f64 Gram is near-diagonal. No mass distribution over
/// aliased clones can reproduce the f64 margins, the f64 re-score
/// catches it, and the trainer must visibly fall back.
#[test]
fn aliasing_data_triggers_certified_fallback_to_f64() {
    let m = 64usize;
    let pts: Vec<f64> = (0..m).map(|i| 1.0e8 + i as f64).collect();
    // pin the premise: the points really do alias under truncation
    let aliased = pts
        .windows(2)
        .filter(|w| (w[0] as f32) == (w[1] as f32))
        .count();
    assert!(aliased > m / 4, "premise lost: only {aliased} aliased pairs");
    let x = Matrix::from_vec(m, 1, pts);
    let base = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Rbf { g: 2.0 })
        .nu1(0.1);

    let r32 = base.clone().precision(Precision::F32).fit(&x).unwrap();
    assert!(
        r32.fell_back,
        "f32 fit on aliasing data must fail f64 certification \
         (violation path not taken; precision = {:?})",
        r32.precision
    );
    assert_eq!(r32.precision, Precision::F64, "fallback recomputes in f64");

    // and the fallback IS the reference fit, to the bit
    let r64 = base.fit(&x).unwrap();
    assert_eq!(r32.model.rho1.to_bits(), r64.model.rho1.to_bits());
    assert_eq!(r32.model.rho2.to_bits(), r64.model.rho2.to_bits());
    assert_eq!(
        r32.stats.objective.to_bits(),
        r64.stats.objective.to_bits()
    );
    assert!(!r64.fell_back && r64.precision == Precision::F64);
}

/// The blocked row builder is the scalar `eval` loop, restructured —
/// bitwise, per element, for every kernel family (the property the
/// snapshot Gram checksums and the parallel restore rebuild rely on).
#[test]
fn blocked_row_is_bitwise_scalar_eval() {
    let ds = SlabConfig::default().generate(97, 33);
    let q = ds.x.row(13);
    for kernel in KERNELS {
        let mut out = vec![0.0; ds.x.rows()];
        kernel.row(&ds.x, q, &mut out);
        for (j, &o) in out.iter().enumerate() {
            assert_eq!(
                o.to_bits(),
                kernel.eval(ds.x.row(j), q).to_bits(),
                "{kernel:?} row[{j}]"
            );
        }
    }
}

/// Gram builds are thread-count invariant in BOTH compute modes:
/// `parallel_rows` hands whole rows to workers and each row is the
/// same blocked build regardless of which worker runs it.
#[test]
fn gram_builds_are_thread_count_invariant() {
    let ds = SlabConfig::default().generate(73, 55);
    for kernel in KERNELS {
        for prec in [Precision::F64, Precision::F32] {
            let k1 = kernel.gram_in(prec, &ds.x, 1);
            for threads in [2usize, 3, 8] {
                let kt = kernel.gram_in(prec, &ds.x, threads);
                for i in 0..ds.x.rows() {
                    for j in 0..ds.x.rows() {
                        assert_eq!(
                            k1.get(i, j).to_bits(),
                            kt.get(i, j).to_bits(),
                            "{kernel:?}/{prec:?} t={threads} ({i},{j})"
                        );
                    }
                }
            }
        }
    }
}
