//! Cross-module integration tests: data → solver → metrics → persistence
//! → coordinator, composed the way downstream users compose them — all
//! training through the unified `Trainer` API.

use std::sync::Arc;

use slabsvm::coordinator::{BatcherConfig, Coordinator, JobStatus, TrainRequest};
use slabsvm::data::loaders::{load_csv, save_csv, CsvOptions};
use slabsvm::data::synthetic::{annulus, open_set, SlabConfig};
use slabsvm::kernel::Kernel;
use slabsvm::metrics::roc_auc;
use slabsvm::runtime::Engine;
use slabsvm::solver::ocssvm::SlabModel;
use slabsvm::solver::validate::certify;
use slabsvm::solver::{SolverKind, Trainer};

/// The full paper pipeline at Fig-1 scale: generate → train → certify →
/// evaluate → persist → reload → identical predictions.
#[test]
fn paper_pipeline_fig1_scale() {
    let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);
    // certify against the exact constants the trainer lowered to
    let smo = trainer.smo_params();
    let (nu1, nu2, eps) = (smo.nu1, smo.nu2, smo.eps);
    let ds = SlabConfig::default().generate(1000, 42);
    let report = trainer.fit(&ds.x).unwrap();
    let model = &report.model;

    // certify against an independently built Gram matrix
    let k = Kernel::Linear.gram(&ds.x, 4);
    certify(
        &k,
        &report.dual.alpha,
        &report.dual.alpha_bar,
        report.dual.rho1,
        report.dual.rho2,
        nu1,
        nu2,
        eps,
        1e-2 * (1.0 + report.dual.rho2.abs()),
    )
    .unwrap();

    // meaningful slab + sane metrics
    assert!(model.width() > 0.0);
    let eval = SlabConfig::default().generate_eval(500, 500, 7);
    let cm = model.evaluate(&eval);
    assert!(cm.mcc() > 0.3, "MCC {:.3} too low", cm.mcc());
    let margins: Vec<f64> =
        (0..eval.len()).map(|i| model.margin(eval.x.row(i))).collect();
    assert!(roc_auc(&eval.y, &margins) > 0.8);

    // persistence round-trip preserves behaviour exactly
    let path = std::env::temp_dir().join(format!("it_model_{}.json", std::process::id()));
    model.save(&path).unwrap();
    let reloaded = SlabModel::load(&path).unwrap();
    for i in 0..50 {
        assert_eq!(reloaded.classify(eval.x.row(i)), model.classify(eval.x.row(i)));
    }
    std::fs::remove_file(path).ok();
}

/// CSV round-trip feeds training identically to in-memory data.
#[test]
fn csv_train_matches_in_memory() {
    let ds = SlabConfig::default().generate(300, 5);
    let path = std::env::temp_dir().join(format!("it_csv_{}.csv", std::process::id()));
    save_csv(&ds, &path, false).unwrap();
    let loaded = load_csv(&path, CsvOptions::default()).unwrap();
    assert_eq!(loaded.len(), 300);

    let trainer = Trainer::new(SolverKind::Smo).kernel(Kernel::Linear);
    let r1 = trainer.fit(&ds.x).unwrap();
    let r2 = trainer.fit(&loaded.x).unwrap();
    assert!((r1.stats.objective - r2.stats.objective).abs() < 1e-6);
    assert!((r1.model.rho1 - r2.model.rho1).abs() < 1e-6);
    std::fs::remove_file(path).ok();
}

/// RBF slab encloses a ring that no linear slab can.
#[test]
fn rbf_handles_annulus() {
    let ds = annulus(3.0, 0.1, 400, 11);
    let rbf = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Rbf { g: 0.8 })
        .nu1(0.1)
        .nu2(0.05)
        .eps(0.5)
        .fit(&ds.x)
        .unwrap()
        .model;
    // inside-ring and far-outside points must both be rejected
    let center = [0.0, 0.0];
    let far = [10.0, 10.0];
    let on_ring = [3.0, 0.0];
    assert_eq!(rbf.classify(&center), -1, "ring center must be anomalous");
    assert_eq!(rbf.classify(&far), -1, "far point must be anomalous");
    assert_eq!(rbf.classify(&on_ring), 1, "ring point must be accepted");
}

/// Open-set scenario: slab rejects unseen classes at high MCC, and the
/// margin ranking separates known from unknown.
#[test]
fn open_set_recognition_quality() {
    let sc = open_set(5, 6.0, 0.5, 500, 600, 23);
    let model = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Rbf { g: 0.4 })
        .nu1(0.05)
        .nu2(0.05)
        .eps(0.5)
        .fit(&sc.train.x)
        .unwrap()
        .model;
    let cm = model.evaluate(&sc.eval);
    assert!(cm.mcc() > 0.7, "open-set MCC {:.3}", cm.mcc());
    let margins: Vec<f64> =
        (0..sc.eval.len()).map(|i| model.margin(sc.eval.x.row(i))).collect();
    assert!(roc_auc(&sc.eval.y, &margins) > 0.95);
}

/// OCSSVM vs OCSVM on two-sided anomalies: the slab's raison d'être.
/// Both models train through the same API; only the SolverKind differs.
#[test]
fn slab_beats_single_plane_on_two_sided_anomalies() {
    // healthy band + anomalies on BOTH sides of it
    let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
    let train = cfg.generate(600, 31);
    let eval = cfg.generate_eval(300, 300, 33);

    let slab = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .nu1(0.1)
        .nu2(0.05)
        .eps(0.5)
        .fit(&train.x)
        .unwrap()
        .model;
    let plane = Trainer::new(SolverKind::OcsvmSmo)
        .kernel(Kernel::Linear)
        .nu1(0.1)
        .fit(&train.x)
        .unwrap()
        .model;

    let slab_mcc = slab.evaluate(&eval).mcc();
    let plane_mcc = plane.evaluate(&eval).mcc();
    assert!(
        slab_mcc > plane_mcc,
        "slab {slab_mcc:.3} must beat plane {plane_mcc:.3}"
    );
}

/// Coordinator end-to-end: async training job then batched scoring that
/// matches direct model predictions.
#[test]
fn coordinator_end_to_end() {
    let c = Coordinator::start(
        Engine::Native,
        BatcherConfig { max_batch: 128, max_wait_us: 300, queue_cap: 8192 },
        2,
    );
    let ds = SlabConfig::default().generate(400, 51);
    let id = c.submit_train(TrainRequest {
        name: "it".into(),
        dataset: ds,
        trainer: Trainer::new(SolverKind::Smo).kernel(Kernel::Linear),
    });
    assert!(matches!(c.wait_job(id), Some(JobStatus::Done { .. })));

    let model = c.model("it").unwrap();
    let eval = SlabConfig::default().generate_eval(100, 100, 52);
    let queries: Vec<Vec<f64>> =
        (0..eval.len()).map(|i| eval.x.row(i).to_vec()).collect();
    let resp = c.score("it", queries).unwrap();
    assert_eq!(resp.labels, model.predict(&eval.x));
    assert!(c.stats().scored.get() >= 200);
    c.shutdown();
}

/// A heterogeneous registry: different solver kinds trained through the
/// same coordinator interface, served side by side.
#[test]
fn coordinator_serves_heterogeneous_solvers() {
    let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    let ds = SlabConfig::default().generate(200, 55);
    for (name, kind) in [("smo", SolverKind::Smo), ("pg", SolverKind::Pg)] {
        c.train_blocking(name, &ds, &Trainer::new(kind).kernel(Kernel::Linear))
            .unwrap();
    }
    // the origin sits far off the slab band: every solver rejects it
    let q = vec![vec![0.0, 0.0]];
    assert_eq!(c.score("smo", q.clone()).unwrap().labels[0], -1);
    assert_eq!(c.score("pg", q).unwrap().labels[0], -1);
    c.shutdown();
}

/// Model hot-swap: re-registering a name bumps the version and new
/// requests see the new model.
#[test]
fn coordinator_model_hot_swap() {
    let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    let ds = SlabConfig::default().generate(200, 61);
    c.train_blocking("hot", &ds, &Trainer::default().kernel(Kernel::Linear))
        .unwrap();
    let v1 = c.model("hot").unwrap();

    // retrain with very different nu1 -> different slab
    c.train_blocking(
        "hot",
        &ds,
        &Trainer::default().kernel(Kernel::Linear).nu1(0.05),
    )
    .unwrap();
    let v2 = c.model("hot").unwrap();
    assert!((v1.rho1 - v2.rho1).abs() > 1e-9, "model must have changed");

    let resp = c.score("hot", vec![vec![20.0, 20.0]]).unwrap();
    let direct = v2.classify(&[20.0, 20.0]);
    assert_eq!(resp.labels[0], direct);
    c.shutdown();
}

/// Arc<SlabModel> predictions are thread-safe and deterministic.
#[test]
fn concurrent_prediction_determinism() {
    let ds = SlabConfig::default().generate(300, 71);
    let model = Trainer::new(SolverKind::Smo)
        .kernel(Kernel::Linear)
        .fit(&ds.x)
        .unwrap()
        .model;
    let model = Arc::new(model);
    let eval = SlabConfig::default().generate_eval(50, 50, 72);
    let eval = Arc::new(eval);

    let mut handles = Vec::new();
    for _ in 0..4 {
        let model = Arc::clone(&model);
        let eval = Arc::clone(&eval);
        handles.push(std::thread::spawn(move || model.predict(&eval.x)));
    }
    let first = handles.pop().unwrap().join().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), first);
    }
}
