//! Cross-module integration tests: data → solver → metrics → persistence
//! → coordinator, composed the way downstream users compose them.

use std::sync::Arc;

use slabsvm::coordinator::{BatcherConfig, Coordinator, JobStatus, TrainRequest};
use slabsvm::data::loaders::{load_csv, save_csv, CsvOptions};
use slabsvm::data::synthetic::{annulus, open_set, SlabConfig};
use slabsvm::kernel::Kernel;
use slabsvm::metrics::roc_auc;
use slabsvm::runtime::Engine;
use slabsvm::solver::ocssvm::SlabModel;
use slabsvm::solver::ocsvm_smo::{self, OcsvmParams};
use slabsvm::solver::smo::{train_full, SmoParams};
use slabsvm::solver::validate::certify;

/// The full paper pipeline at Fig-1 scale: generate → train → certify →
/// evaluate → persist → reload → identical predictions.
#[test]
fn paper_pipeline_fig1_scale() {
    let params = SmoParams::default();
    let ds = SlabConfig::default().generate(1000, 42);
    let (model, out) = train_full(&ds.x, Kernel::Linear, &params).unwrap();

    // certify against an independently built Gram matrix
    let k = Kernel::Linear.gram(&ds.x, 4);
    certify(
        &k, &out.alpha, &out.alpha_bar, out.rho1, out.rho2,
        params.nu1, params.nu2, params.eps,
        1e-2 * (1.0 + out.rho2.abs()),
    )
    .unwrap();

    // meaningful slab + sane metrics
    assert!(model.width() > 0.0);
    let eval = SlabConfig::default().generate_eval(500, 500, 7);
    let cm = model.evaluate(&eval);
    assert!(cm.mcc() > 0.3, "MCC {:.3} too low", cm.mcc());
    let margins: Vec<f64> =
        (0..eval.len()).map(|i| model.margin(eval.x.row(i))).collect();
    assert!(roc_auc(&eval.y, &margins) > 0.8);

    // persistence round-trip preserves behaviour exactly
    let path = std::env::temp_dir().join(format!("it_model_{}.json", std::process::id()));
    model.save(&path).unwrap();
    let reloaded = SlabModel::load(&path).unwrap();
    for i in 0..50 {
        assert_eq!(reloaded.classify(eval.x.row(i)), model.classify(eval.x.row(i)));
    }
    std::fs::remove_file(path).ok();
}

/// CSV round-trip feeds training identically to in-memory data.
#[test]
fn csv_train_matches_in_memory() {
    let ds = SlabConfig::default().generate(300, 5);
    let path = std::env::temp_dir().join(format!("it_csv_{}.csv", std::process::id()));
    save_csv(&ds, &path, false).unwrap();
    let loaded = load_csv(&path, CsvOptions::default()).unwrap();
    assert_eq!(loaded.len(), 300);

    let p = SmoParams::default();
    let (m1, o1) = train_full(&ds.x, Kernel::Linear, &p).unwrap();
    let (m2, o2) = train_full(&loaded.x, Kernel::Linear, &p).unwrap();
    assert!((o1.stats.objective - o2.stats.objective).abs() < 1e-6);
    assert!((m1.rho1 - m2.rho1).abs() < 1e-6);
    std::fs::remove_file(path).ok();
}

/// RBF slab encloses a ring that no linear slab can.
#[test]
fn rbf_handles_annulus() {
    let ds = annulus(3.0, 0.1, 400, 11);
    let p = SmoParams { nu1: 0.1, nu2: 0.05, eps: 0.5, ..Default::default() };
    let (rbf, _) = train_full(&ds.x, Kernel::Rbf { g: 0.8 }, &p).unwrap();
    // inside-ring and far-outside points must both be rejected
    let center = [0.0, 0.0];
    let far = [10.0, 10.0];
    let on_ring = [3.0, 0.0];
    assert_eq!(rbf.classify(&center), -1, "ring center must be anomalous");
    assert_eq!(rbf.classify(&far), -1, "far point must be anomalous");
    assert_eq!(rbf.classify(&on_ring), 1, "ring point must be accepted");
}

/// Open-set scenario: slab rejects unseen classes at high MCC, and the
/// margin ranking separates known from unknown.
#[test]
fn open_set_recognition_quality() {
    let sc = open_set(5, 6.0, 0.5, 500, 600, 23);
    let p = SmoParams { nu1: 0.05, nu2: 0.05, eps: 0.5, ..Default::default() };
    let (model, _) = train_full(&sc.train.x, Kernel::Rbf { g: 0.4 }, &p).unwrap();
    let cm = model.evaluate(&sc.eval);
    assert!(cm.mcc() > 0.7, "open-set MCC {:.3}", cm.mcc());
    let margins: Vec<f64> =
        (0..sc.eval.len()).map(|i| model.margin(sc.eval.x.row(i))).collect();
    assert!(roc_auc(&sc.eval.y, &margins) > 0.95);
}

/// OCSSVM vs OCSVM on two-sided anomalies: the slab's raison d'être.
#[test]
fn slab_beats_single_plane_on_two_sided_anomalies() {
    // healthy band + anomalies on BOTH sides of it
    let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
    let train = cfg.generate(600, 31);
    let eval = cfg.generate_eval(300, 300, 33);

    let (slab, _) = train_full(
        &train.x,
        Kernel::Linear,
        &SmoParams { nu1: 0.1, nu2: 0.05, eps: 0.5, ..Default::default() },
    )
    .unwrap();
    let (plane, _) = ocsvm_smo::train(
        &train.x,
        Kernel::Linear,
        &OcsvmParams { nu: 0.1, ..Default::default() },
    )
    .unwrap();

    let slab_mcc = slab.evaluate(&eval).mcc();
    let plane_mcc = plane.evaluate(&eval).mcc();
    assert!(
        slab_mcc > plane_mcc,
        "slab {slab_mcc:.3} must beat plane {plane_mcc:.3}"
    );
}

/// Coordinator end-to-end: async training job then batched scoring that
/// matches direct model predictions.
#[test]
fn coordinator_end_to_end() {
    let c = Coordinator::start(
        Engine::Native,
        BatcherConfig { max_batch: 128, max_wait_us: 300, queue_cap: 8192 },
        2,
    );
    let ds = SlabConfig::default().generate(400, 51);
    let id = c.submit_train(TrainRequest {
        name: "it".into(),
        dataset: ds,
        kernel: Kernel::Linear,
        params: SmoParams::default(),
    });
    assert!(matches!(c.wait_job(id), Some(JobStatus::Done { .. })));

    let model = c.model("it").unwrap();
    let eval = SlabConfig::default().generate_eval(100, 100, 52);
    let queries: Vec<Vec<f64>> =
        (0..eval.len()).map(|i| eval.x.row(i).to_vec()).collect();
    let resp = c.score("it", queries).unwrap();
    assert_eq!(resp.labels, model.predict(&eval.x));
    assert!(c.stats().scored.get() >= 200);
    c.shutdown();
}

/// Model hot-swap: re-registering a name bumps the version and new
/// requests see the new model.
#[test]
fn coordinator_model_hot_swap() {
    let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    let ds = SlabConfig::default().generate(200, 61);
    c.train_blocking("hot", &ds, Kernel::Linear, &SmoParams::default())
        .unwrap();
    let v1 = c.model("hot").unwrap();

    // retrain with very different nu1 -> different slab
    c.train_blocking(
        "hot",
        &ds,
        Kernel::Linear,
        &SmoParams { nu1: 0.05, ..Default::default() },
    )
    .unwrap();
    let v2 = c.model("hot").unwrap();
    assert!((v1.rho1 - v2.rho1).abs() > 1e-9, "model must have changed");

    let resp = c.score("hot", vec![vec![20.0, 20.0]]).unwrap();
    let direct = v2.classify(&[20.0, 20.0]);
    assert_eq!(resp.labels[0], direct);
    c.shutdown();
}

/// Arc<SlabModel> predictions are thread-safe and deterministic.
#[test]
fn concurrent_prediction_determinism() {
    let ds = SlabConfig::default().generate(300, 71);
    let (model, _) = train_full(&ds.x, Kernel::Linear, &SmoParams::default()).unwrap();
    let model = Arc::new(model);
    let eval = SlabConfig::default().generate_eval(50, 50, 72);
    let eval = Arc::new(eval);

    let mut handles = Vec::new();
    for _ in 0..4 {
        let model = Arc::clone(&model);
        let eval = Arc::clone(&eval);
        handles.push(std::thread::spawn(move || model.predict(&eval.x)));
    }
    let first = handles.pop().unwrap().join().unwrap();
    for h in handles {
        assert_eq!(h.join().unwrap(), first);
    }
}
