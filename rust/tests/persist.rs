//! Durability tests for the stream snapshot/restore subsystem (L4
//! persistence): golden-fixture format pinning, corruption/version
//! rejection, bitwise restore parity, the multi-tenant
//! snapshot → kill → restore → continue E2E, and checkpoint hygiene.

use std::path::PathBuf;
use std::time::Duration;

use slabsvm::coordinator::{BatcherConfig, Coordinator};
use slabsvm::data::synthetic::{SlabConfig, SlabStream};
use slabsvm::error::Error;
use slabsvm::kernel::featmap::EngineKind;
use slabsvm::kernel::Kernel;
use slabsvm::runtime::Engine;
use slabsvm::solver::validate;
use slabsvm::stream::{
    persist, CheckpointConfig, PolicyKind, Snapshot, StreamConfig,
    StreamPoolConfig, StreamSession, StreamSpec,
};

/// The committed v1 golden snapshot: a seeded ν₁ = ν₂ = 1 session whose
/// dual point is the unique feasible (hence optimal) one, written by
/// `rust/tests/fixtures/make_golden.py`. It is the frozen v1 **decode**
/// contract — this build reads it as the Fifo policy with ids
/// synthesized from the ring cursor, bitwise-exact forever. (Its
/// canonical re-encoding is the current format; byte-identity of
/// encode() is pinned by the v3 fixture below.)
const GOLDEN: &[u8] = include_bytes!("fixtures/golden-v1.snap");

/// The committed v2 golden snapshot (same generator): the same
/// analytically-exact dual state with the eviction policy tag
/// (interior-first, the non-default) in the config section and
/// explicit non-contiguous sample ids + the forget counter in the
/// state. It pins the frozen v2 **decode** contract — this build reads
/// it as the exact engine with the default feature budget; its
/// canonical re-encoding is format v3.
const GOLDEN_V2: &[u8] = include_bytes!("fixtures/golden-v2.snap");

/// The committed v3 golden snapshot (same generator): v2 plus the
/// training-engine tag and lifted-feature budget in the config section
/// (exact engine — no approx resume block in the state). This is the
/// current format: decode → encode must stay byte-identical forever.
const GOLDEN_V3: &[u8] = include_bytes!("fixtures/golden-v3.snap");

fn golden_config() -> StreamConfig {
    let mut cfg = StreamConfig {
        kernel: Kernel::Linear,
        dim: 2,
        window: 4,
        min_train: 2,
        ..Default::default()
    };
    cfg.incremental.smo.nu1 = 1.0;
    cfg.incremental.smo.nu2 = 1.0;
    cfg.incremental.smo.eps = 0.5;
    cfg
}

fn golden_v2_config() -> StreamConfig {
    let mut cfg = golden_config();
    cfg.incremental.policy = PolicyKind::InteriorFirst;
    cfg
}

/// FNV-1a 64 — the snapshot format's checksum, reimplemented here so
/// corruption tests can re-seal deliberately tampered files (a wrong
/// *field* must be rejected by its own validation, not mask behind the
/// payload checksum).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Re-seal a tampered snapshot: recompute the config fingerprint (the
/// config section spans `cfg_start..cfg_end`) and the trailing payload
/// checksum, so decode reaches the tampered field's own validation.
fn reseal(bytes: &mut [u8], cfg_start: usize, cfg_end: usize) {
    let fp = fnv1a(&bytes[cfg_start..cfg_end]);
    bytes[12..20].copy_from_slice(&fp.to_le_bytes());
    let end = bytes.len() - 8;
    let check = fnv1a(&bytes[..end]);
    bytes[end..].copy_from_slice(&check.to_le_bytes());
}

/// Fixed offsets of the golden files (name "golden" = 6 bytes): the
/// config section starts after magic(8) + version(4) + fingerprint(8) +
/// name(4+6) + weight(4) + last_version(8) = 42 and is 171 bytes in v1,
/// 172 in v2 (the trailing policy tag).
const GOLDEN_CFG_START: usize = 42;
const GOLDEN_V2_CFG_END: usize = GOLDEN_CFG_START + 172;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("slabsvm_persist_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ------------------------------------------------------ golden fixture

#[test]
fn golden_fixture_decodes_with_expected_contents() {
    let snap = Snapshot::decode(GOLDEN).expect("golden fixture must decode");
    assert_eq!(snap.name, "golden");
    assert_eq!(snap.weight, 1);
    assert_eq!(snap.last_version, 0);
    assert_eq!(snap.len, 4);
    assert_eq!(snap.admitted, 4);
    assert_eq!(snap.cfg.window, 4);
    assert_eq!(snap.cfg.dim, 2);
    assert_eq!(snap.cfg.kernel, Kernel::Linear);
    assert_eq!(snap.cfg.incremental.smo.nu1, 1.0);
    assert_eq!(snap.cfg.incremental.smo.eps, 0.5);
    assert_eq!(
        snap.points,
        vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.5, 0.5]
    );
    assert_eq!(snap.alpha, vec![0.25; 4]);
    assert_eq!(snap.alpha_bar, vec![0.125; 4]);
    assert_eq!(snap.s, vec![0.3125, 0.3125, 0.625, 0.3125]);
    assert_eq!(snap.rho1.to_bits(), 0.625f64.to_bits());
    assert_eq!(snap.rho2.to_bits(), 0.3125f64.to_bits());
    assert_eq!(snap.baseline, Some((0.625, 0.3125)));
    assert_eq!(snap.updates, 4);
    assert_eq!(snap.retrains, 0);
    // v1 back-compat: decodes as the Fifo policy, with the ids the v1
    // FIFO window actually held (synthesized from the ring cursor) and
    // a zero forget counter; the decoded version is reported as-is
    // (inspect must say v1 for a v1 file, not the build's version)
    assert_eq!(snap.format_version, 1);
    assert!(snap.describe().contains("format v1"), "{}", snap.describe());
    assert_eq!(snap.cfg.incremental.policy, PolicyKind::Fifo);
    assert_eq!(snap.ids, vec![0, 1, 2, 3]);
    assert_eq!(snap.forgets, 0);
}

#[test]
fn golden_v1_wrapped_ring_cursor_synthesizes_the_right_ids() {
    // admitted=6 over a window of 4: the v1 ring held admits 2..=5 at
    // slots (a % 4) — slot order [4, 5, 2, 3]
    let mut snap = Snapshot::decode(GOLDEN).unwrap();
    snap.admitted = 6;
    snap.updates = 6;
    let bytes = snap.encode(); // canonical v2 carries the ids explicitly
    let back = Snapshot::decode(&bytes).unwrap();
    assert_eq!(back.ids, vec![0, 1, 2, 3], "encode kept the decoded ids");
    // now force the v1 synthesis path: re-write the header as v1 and
    // drop ids/forgets by hand-building the v1 state layout
    let mut v1 = GOLDEN.to_vec();
    // admitted is the u64 right after len, which follows the 171-byte
    // v1 config section
    let admitted_at = GOLDEN_CFG_START + 171 + 8;
    v1[admitted_at..admitted_at + 8].copy_from_slice(&6u64.to_le_bytes());
    reseal(&mut v1, GOLDEN_CFG_START, GOLDEN_CFG_START + 171);
    let wrapped = Snapshot::decode(&v1).unwrap();
    assert_eq!(wrapped.ids, vec![4, 5, 2, 3]);
}

#[test]
fn golden_fixture_restores_with_bitwise_model_and_dual_parity() {
    let (session, info) =
        Snapshot::decode(GOLDEN).unwrap().into_session().unwrap();
    // the ν = 1 dual point is the unique feasible point: it certifies
    // as-is, so no repair ran and the restore is bitwise exact
    assert!(!info.repaired, "optimal golden state must not need repair");
    assert_eq!(info.kkt_violation, 0.0);
    assert_eq!(session.name(), "golden");
    assert_eq!(session.updates(), 4);
    assert_eq!(session.solver().alpha(), &[0.25; 4]);
    assert_eq!(session.solver().alpha_bar(), &[0.125; 4]);
    assert_eq!(
        session.solver().margins(),
        &[0.3125, 0.3125, 0.625, 0.3125]
    );
    let (r1, r2) = session.solver().rho();
    assert_eq!(r1.to_bits(), 0.625f64.to_bits());
    assert_eq!(r2.to_bits(), 0.3125f64.to_bits());
    // model parity: support vectors carry γ = α − ᾱ = 0.125 each
    let model = session.solver().model();
    assert_eq!(model.gamma, vec![0.125; 4]);
    assert_eq!(model.rho1.to_bits(), 0.625f64.to_bits());
    assert_eq!(model.rho2.to_bits(), 0.3125f64.to_bits());
    // fresh-Gram KKT certificate on the restored state
    let gram = Kernel::Linear.gram(&session.solver().matrix(), 1);
    validate::certify(
        &gram,
        session.solver().alpha(),
        session.solver().alpha_bar(),
        r1,
        r2,
        1.0,
        1.0,
        0.5,
        1e-9,
    )
    .expect("restored golden session must certify against a fresh Gram");
}

#[test]
fn golden_v1_reencodes_to_canonical_current_format_losslessly() {
    // v1 files re-encode in the current format (the migration path):
    // the bytes change — version, policy tag, explicit ids, forgets,
    // engine tag, feature budget — but the state is lossless and the
    // new bytes are canonical
    let (session, _) =
        Snapshot::decode(GOLDEN).unwrap().into_session().unwrap();
    let bytes = session.snapshot();
    assert_ne!(bytes, GOLDEN, "re-encode migrates to the current format");
    assert_eq!(
        u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
        persist::FORMAT_VERSION
    );
    let back = Snapshot::decode(&bytes).unwrap();
    assert_eq!(back.format_version, 3);
    assert_eq!(back.cfg.incremental.policy, PolicyKind::Fifo);
    assert_eq!(back.cfg.incremental.engine, EngineKind::Exact);
    assert_eq!(back.ids, vec![0, 1, 2, 3]);
    assert_eq!(back.alpha, vec![0.25; 4]);
    assert_eq!(back.s, vec![0.3125, 0.3125, 0.625, 0.3125]);
    assert_eq!(back.forgets, 0);
    // canonical: a second round-trip is byte-identical
    assert_eq!(back.encode(), bytes);
}

// --------------------------------------------------- golden fixture v2

#[test]
fn golden_v2_fixture_decodes_with_expected_contents() {
    let snap = Snapshot::decode(GOLDEN_V2).expect("golden v2 must decode");
    assert_eq!(snap.format_version, 2);
    assert_eq!(snap.name, "golden");
    assert_eq!(snap.len, 4);
    assert_eq!(snap.admitted, 10);
    assert_eq!(snap.cfg.incremental.policy, PolicyKind::InteriorFirst);
    // the v2 format predates approx engines: decodes as the exact
    // engine with the default feature budget
    assert_eq!(snap.cfg.incremental.engine, EngineKind::Exact);
    assert_eq!(snap.cfg.incremental.features, 64);
    assert_eq!(snap.ids, vec![3, 5, 8, 9], "non-contiguous ids survive");
    assert_eq!(snap.updates, 10);
    assert_eq!(snap.forgets, 2);
    assert_eq!(snap.alpha, vec![0.25; 4]);
    assert_eq!(snap.alpha_bar, vec![0.125; 4]);
    assert_eq!(snap.s, vec![0.3125, 0.3125, 0.625, 0.3125]);
    assert_eq!(snap.rho1.to_bits(), 0.625f64.to_bits());
    assert_eq!(snap.rho2.to_bits(), 0.3125f64.to_bits());
}

#[test]
fn golden_v2_reencodes_to_canonical_v3_losslessly() {
    // v2 files re-encode in the current format (the migration path):
    // the bytes change — version, engine tag, feature budget — but the
    // state is lossless (policy tag, sample ids and forget counter
    // included) and the new bytes are canonical. In fact the migrated
    // bytes ARE the committed v3 golden: same session, current format.
    let (session, info) =
        Snapshot::decode(GOLDEN_V2).unwrap().into_session().unwrap();
    assert!(!info.repaired, "optimal golden state must not need repair");
    assert_eq!(session.forgets(), 2);
    assert_eq!(session.config().incremental.policy, PolicyKind::InteriorFirst);
    assert_eq!(session.solver().ids(), vec![3, 5, 8, 9]);
    let bytes = session.snapshot();
    assert_ne!(bytes, GOLDEN_V2, "re-encode migrates to the current format");
    assert_eq!(
        bytes, GOLDEN_V3,
        "v2 golden must migrate to exactly the v3 golden"
    );
}

#[test]
fn golden_v2_fingerprint_gates_policy_mismatch() {
    // same numbers, different eviction policy -> different fingerprint
    let (session, _) =
        Snapshot::restore_expecting(GOLDEN_V2, &golden_v2_config()).unwrap();
    assert_eq!(session.updates(), 10);
    let err = Snapshot::restore_expecting(GOLDEN_V2, &golden_config())
        .unwrap_err();
    assert!(matches!(err, Error::Snapshot(_)), "got {err:?}");
    assert!(err.to_string().contains("fingerprint"), "{err}");
}

#[test]
fn golden_v2_forgets_resume_and_forget_again() {
    // a restored session keeps forgetting by the surviving ids
    let (mut session, _) =
        Snapshot::decode(GOLDEN_V2).unwrap().into_session().unwrap();
    let err = session.forget(4).unwrap_err(); // never resident
    assert!(matches!(err, Error::Unlearning(_)), "got {err:?}");
    let f = session.forget(5).unwrap();
    assert_eq!(f.resident, 3);
    assert_eq!(session.forgets(), 3);
    assert_eq!(session.solver().slot_of_id(5), None);
    // dual mass is still exactly conserved over the 3 survivors
    let sa: f64 = session.solver().alpha().iter().sum();
    let sb: f64 = session.solver().alpha_bar().iter().sum();
    assert!((sa - 1.0).abs() < 1e-9, "sum(alpha)={sa}");
    assert!((sb - 0.5).abs() < 1e-9, "sum(alpha_bar)={sb}");
}

// --------------------------------------------------- golden fixture v3

#[test]
fn golden_v3_fixture_decodes_with_expected_contents() {
    let snap = Snapshot::decode(GOLDEN_V3).expect("golden v3 must decode");
    assert_eq!(snap.format_version, 3);
    assert!(snap.describe().contains("format v3"), "{}", snap.describe());
    assert!(snap.describe().contains("engine=exact"), "{}", snap.describe());
    assert_eq!(snap.name, "golden");
    assert_eq!(snap.len, 4);
    assert_eq!(snap.admitted, 10);
    assert_eq!(snap.cfg.incremental.policy, PolicyKind::InteriorFirst);
    assert_eq!(snap.cfg.incremental.engine, EngineKind::Exact);
    assert_eq!(snap.cfg.incremental.features, 64);
    assert_eq!(snap.ids, vec![3, 5, 8, 9]);
    assert_eq!(snap.updates, 10);
    assert_eq!(snap.forgets, 2);
    assert_eq!(snap.alpha, vec![0.25; 4]);
    assert_eq!(snap.alpha_bar, vec![0.125; 4]);
    assert_eq!(snap.s, vec![0.3125, 0.3125, 0.625, 0.3125]);
    assert_eq!(snap.rho1.to_bits(), 0.625f64.to_bits());
    assert_eq!(snap.rho2.to_bits(), 0.3125f64.to_bits());
    // exact engine: no approx resume state rode along
    assert!(!snap.approx_frozen);
    assert!(snap.landmarks.is_none());
}

#[test]
fn golden_v3_fixture_roundtrips_byte_identical() {
    // decode → restore → re-snapshot must reproduce the committed file
    // exactly: the v3 encoding is canonical and capture is lossless
    // (policy tag, engine tag, feature budget, sample ids and forget
    // counter included)
    let (session, info) =
        Snapshot::decode(GOLDEN_V3).unwrap().into_session().unwrap();
    assert!(!info.repaired, "optimal golden state must not need repair");
    assert_eq!(session.forgets(), 2);
    assert_eq!(
        session.snapshot(),
        GOLDEN_V3,
        "re-snapshot of the restored v3 golden must be byte-identical"
    );
}

#[test]
fn golden_fixture_fingerprint_gates_config_mismatch() {
    // the exact config restores…
    let (session, _) =
        Snapshot::restore_expecting(GOLDEN, &golden_config()).unwrap();
    assert_eq!(session.updates(), 4);
    // …and the default config (different ν, window, …) is a clean
    // typed error, not a panic
    let err = Snapshot::restore_expecting(GOLDEN, &StreamConfig::default())
        .unwrap_err();
    assert!(
        matches!(err, Error::Snapshot(_)),
        "want Error::Snapshot, got {err:?}"
    );
    assert!(
        err.to_string().contains("fingerprint"),
        "unexpected message: {err}"
    );
}

// ------------------------------------------- corruption and versioning

#[test]
fn unknown_format_version_is_a_clean_typed_error() {
    let mut bytes = GOLDEN.to_vec();
    bytes[8] = 99; // format version field (little-endian u32 at [8..12))
    let err = Snapshot::decode(&bytes).unwrap_err();
    assert!(matches!(err, Error::Snapshot(_)), "got {err:?}");
    assert!(
        err.to_string().contains("version 99"),
        "unexpected message: {err}"
    );
}

#[test]
fn bad_magic_is_a_clean_typed_error() {
    let mut bytes = GOLDEN.to_vec();
    bytes[0] = b'X';
    let err = Snapshot::decode(&bytes).unwrap_err();
    assert!(matches!(err, Error::Snapshot(_)), "got {err:?}");
    assert!(err.to_string().contains("magic"), "unexpected: {err}");
}

#[test]
fn truncation_anywhere_is_a_checksum_error_not_a_panic() {
    // every prefix of a valid snapshot must be rejected cleanly — this
    // is the crash-mid-write contract restore() relies on. v2 cuts
    // include the end of the config section (policy byte at 213) and
    // the id block (230..262).
    for full in [GOLDEN, GOLDEN_V2, GOLDEN_V3] {
        for cut in [
            1,
            8,
            11,
            12,
            20,
            27,
            GOLDEN_CFG_START + 150,
            GOLDEN_V2_CFG_END.min(full.len() - 1),
            (GOLDEN_V2_CFG_END + 20).min(full.len() - 1),
            full.len() / 2,
            full.len() - 1,
        ] {
            let err = Snapshot::decode(&full[..cut]).unwrap_err();
            assert!(
                matches!(err, Error::Snapshot(_)),
                "cut at {cut}: want Error::Snapshot, got {err:?}"
            );
        }
    }
}

#[test]
fn bitflip_in_state_fails_the_payload_checksum() {
    for full in [GOLDEN, GOLDEN_V2, GOLDEN_V3] {
        let mut bytes = full.to_vec();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = Snapshot::decode(&bytes).unwrap_err();
        assert!(
            err.to_string().contains("checksum"),
            "unexpected message: {err}"
        );
    }
    // a flip inside the new v2 fields (policy byte / id block) is
    // caught the same way
    let mut bytes = GOLDEN_V2.to_vec();
    bytes[GOLDEN_V2_CFG_END - 1] ^= 0x01; // the policy tag itself
    assert!(Snapshot::decode(&bytes).is_err());
    let mut bytes = GOLDEN_V2.to_vec();
    bytes[GOLDEN_V2_CFG_END + 20] ^= 0x08; // inside the id block
    assert!(Snapshot::decode(&bytes).is_err());
}

#[test]
fn unknown_engine_tag_is_rejected_after_reseal() {
    // the v3 config section ends policy tag (1) + engine tag (1) +
    // feature budget (8): flip the engine tag to an unknown value and
    // re-seal — the rejection must come from the tag validation itself
    let cfg_end = GOLDEN_V2_CFG_END + 9;
    let mut bytes = GOLDEN_V3.to_vec();
    bytes[cfg_end - 9] = 9;
    reseal(&mut bytes, GOLDEN_CFG_START, cfg_end);
    let err = Snapshot::decode(&bytes).unwrap_err();
    assert!(
        err.to_string().contains("unknown engine tag"),
        "unexpected message: {err}"
    );
}

#[test]
fn unknown_policy_tag_is_rejected_after_reseal() {
    // flip the policy tag to an unknown value and RE-SEAL fingerprint +
    // checksum: the rejection must come from the tag validation itself
    let mut bytes = GOLDEN_V2.to_vec();
    bytes[GOLDEN_V2_CFG_END - 1] = 9;
    reseal(&mut bytes, GOLDEN_CFG_START, GOLDEN_V2_CFG_END);
    let err = Snapshot::decode(&bytes).unwrap_err();
    assert!(
        err.to_string().contains("unknown eviction policy"),
        "unexpected message: {err}"
    );
}

#[test]
fn duplicate_or_future_sample_ids_are_rejected() {
    // duplicate ids: structurally valid bytes, semantically impossible
    let mut snap = Snapshot::decode(GOLDEN_V2).unwrap();
    snap.ids[1] = snap.ids[0];
    let err = Snapshot::decode(&snap.encode()).unwrap_err();
    assert!(
        err.to_string().contains("duplicate sample ids"),
        "unexpected message: {err}"
    );
    // an id at/past the admit counter can never have been assigned
    let mut snap = Snapshot::decode(GOLDEN_V2).unwrap();
    snap.ids[3] = snap.admitted;
    let err = Snapshot::decode(&snap.encode()).unwrap_err();
    assert!(
        err.to_string().contains("admit counter"),
        "unexpected message: {err}"
    );
}

#[test]
fn infeasible_dual_state_is_rejected_before_resume() {
    // re-encode the golden snapshot with a broken Σα: structurally
    // valid (checksums recomputed) but dually infeasible
    let mut snap = Snapshot::decode(GOLDEN).unwrap();
    snap.alpha[0] = 0.75; // Σα = 1.5, and above cap_a = 0.25
    let err = snap.into_session().unwrap_err();
    assert!(matches!(err, Error::Snapshot(_)), "got {err:?}");
}

#[test]
fn inconsistent_ring_cursor_is_rejected() {
    // admitted < resident count is impossible for any real window; a
    // checksum-valid snapshot claiming it must fail decode, not
    // silently corrupt FIFO order after restore
    let mut snap = Snapshot::decode(GOLDEN).unwrap();
    snap.admitted = 2;
    let err = Snapshot::decode(&snap.encode()).unwrap_err();
    assert!(
        err.to_string().contains("ring cursor"),
        "unexpected message: {err}"
    );
}

#[test]
fn gram_checksum_mismatch_is_detected() {
    // tamper with a sample but keep the recorded gram checksum: the
    // re-derived matrix no longer matches what the snapshot was taken
    // over
    let mut snap = Snapshot::decode(GOLDEN).unwrap();
    snap.points[0] = 2.0;
    let err = snap.into_session().unwrap_err();
    assert!(
        err.to_string().contains("gram checksum"),
        "unexpected message: {err}"
    );
}

// -------------------------------------------------- session-level parity

#[test]
fn restored_session_is_bitwise_equal_and_continues_in_parity() {
    for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.05 }] {
        let cfg = StreamConfig {
            kernel,
            window: 64,
            min_train: 32,
            ..Default::default()
        };
        let mut live = StreamSession::new("s", cfg);
        let ds = SlabConfig::default().generate(150, 901);
        for i in 0..100 {
            live.absorb(ds.x.row(i)).unwrap();
        }
        let bytes = live.snapshot();
        let restored = StreamSession::restore(&bytes).unwrap();
        // dual parity at the snapshot point is bitwise
        assert_eq!(restored.solver().alpha(), live.solver().alpha());
        assert_eq!(
            restored.solver().alpha_bar(),
            live.solver().alpha_bar()
        );
        assert_eq!(restored.solver().rho(), live.solver().rho());
        // fresh-Gram KKT certificate for the resumed session
        let report = restored.solver().report();
        let p = cfg.incremental.smo;
        let gram =
            kernel.gram(&restored.solver().matrix(), 1);
        validate::certify(
            &gram,
            &report.dual.alpha,
            &report.dual.alpha_bar,
            report.dual.rho1,
            report.dual.rho2,
            p.nu1,
            p.nu2,
            p.eps,
            1e-3,
        )
        .expect("restored session must pass a fresh-Gram certificate");
        // and both copies absorb the same future identically
        let mut live = live;
        let mut restored = restored;
        for i in 100..150 {
            live.absorb(ds.x.row(i)).unwrap();
            restored.absorb(ds.x.row(i)).unwrap();
        }
        let (lo, ro) = (
            live.solver().report().stats.objective,
            restored.solver().report().stats.objective,
        );
        assert!(
            (lo - ro).abs() <= 1e-9 * lo.abs().max(1.0),
            "{kernel:?}: objective diverged after resume: {lo} vs {ro}"
        );
        let ((l1, l2), (r1, r2)) = (live.solver().rho(), restored.solver().rho());
        assert!((l1 - r1).abs() <= 1e-9 && (l2 - r2).abs() <= 1e-9);
    }
}

/// Satellite of the approx-engine work (DESIGN.md §10): an approx
/// session snapshots, restores, and continues in **bitwise** parity —
/// the RFF map rebuilds from the config seed, frozen Nyström landmarks
/// ride the wire, and `LiftedSlab::restore` re-accumulates `w` in the
/// same row order the live engine used.
#[test]
fn approx_session_snapshot_restore_continue_in_parity() {
    for engine in [EngineKind::Nystroem, EngineKind::Rff] {
        let mut cfg = StreamConfig {
            kernel: Kernel::Rbf { g: 0.3 },
            window: 48,
            min_train: 16,
            ..Default::default()
        };
        cfg.incremental.engine = engine;
        cfg.incremental.features = 16;
        let mut live = StreamSession::new("ap", cfg);
        let ds = SlabConfig::default().generate(120, 3107);
        for i in 0..80 {
            live.absorb(ds.x.row(i)).unwrap();
        }
        let bytes = live.snapshot();
        let restored = StreamSession::restore(&bytes).unwrap();
        assert_eq!(
            restored.config().incremental.engine, engine,
            "engine knob must survive the wire"
        );
        // dual parity at the snapshot point is bitwise
        assert_eq!(restored.solver().alpha(), live.solver().alpha());
        assert_eq!(restored.solver().alpha_bar(), live.solver().alpha_bar());
        assert_eq!(restored.solver().rho(), live.solver().rho());
        assert_eq!(restored.solver().ids(), live.solver().ids());
        // re-snapshot of the restored session is canonical
        assert_eq!(restored.snapshot(), bytes, "{engine}: not canonical");
        // and both copies absorb the same future bitwise-identically:
        // the restored feature map is the live one, coefficient for
        // coefficient, so every lifted margin matches exactly
        let mut live = live;
        let mut restored = restored;
        for i in 80..120 {
            live.absorb(ds.x.row(i)).unwrap();
            restored.absorb(ds.x.row(i)).unwrap();
        }
        assert_eq!(
            restored.solver().alpha(),
            live.solver().alpha(),
            "{engine}: alpha diverged after resume"
        );
        let ((l1, l2), (r1, r2)) =
            (live.solver().rho(), restored.solver().rho());
        assert_eq!(l1.to_bits(), r1.to_bits(), "{engine}: rho1 diverged");
        assert_eq!(l2.to_bits(), r2.to_bits(), "{engine}: rho2 diverged");
        assert_eq!(restored.solver().margins(), live.solver().margins());
    }
}

// --------------------------------------------------- multi-tenant E2E

/// The acceptance E2E: open a multi-tenant fleet, push, snapshot all,
/// kill the coordinator, restore into a fresh one, continue pushing —
/// restored models must be parity-equal (≤ 1e-9 on objective and ρ)
/// with an uninterrupted run, and the resumed dual must pass a
/// fresh-Gram KKT certificate.
#[test]
fn e2e_snapshot_kill_restore_continue_with_model_parity() {
    let n_streams = 3usize;
    let before = 80usize;
    let after = 40usize;
    let cfg = StreamConfig {
        window: 48,
        min_train: 24,
        ..Default::default()
    };
    let seqs: Vec<Vec<[f64; 2]>> = (0..n_streams)
        .map(|i| {
            let mut s = SlabStream::new(SlabConfig::default(), 9100 + i as u64);
            (0..before + after).map(|_| s.next_point()).collect()
        })
        .collect();

    // uninterrupted reference: one session per tenant over the full
    // sequence, plus its state at the snapshot point
    let mut ref_at_snap = Vec::new();
    let mut ref_final = Vec::new();
    for seq in &seqs {
        let mut s = StreamSession::new("ref", cfg);
        for x in &seq[..before] {
            s.absorb(x).unwrap();
        }
        ref_at_snap.push(s.solver().rho());
        for x in &seq[before..] {
            s.absorb(x).unwrap();
        }
        ref_final.push((
            s.solver().report().stats.objective,
            s.solver().rho(),
        ));
    }

    // phase 1: a live fleet absorbs the first chunk and is snapshotted
    let dir = tmpdir("e2e");
    let c1 = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    c1.open_streams(
        (0..n_streams)
            .map(|i| StreamSpec::new(format!("t{i}"), cfg))
            .collect(),
    )
    .unwrap();
    std::thread::scope(|scope| {
        for (i, seq) in seqs.iter().enumerate() {
            let c = &c1;
            scope.spawn(move || {
                let name = format!("t{i}");
                for x in &seq[..before] {
                    c.push(&name, x).unwrap();
                }
            });
        }
    });
    c1.quiesce_streams();
    let outcomes = c1.snapshot_streams(&dir).unwrap();
    assert_eq!(outcomes.len(), n_streams);
    for o in &outcomes {
        assert!(o.result.is_ok(), "snapshot '{}' failed", o.name);
    }
    let versions_before: Vec<u64> = (0..n_streams)
        .map(|i| c1.registry().version(&format!("t{i}")).unwrap())
        .collect();
    // kill the coordinator — sessions, registry, everything is gone
    c1.shutdown();

    // phase 2: a fresh coordinator restores the fleet from disk
    let c2 = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    let restored = c2.restore_streams(&dir).unwrap();
    assert_eq!(restored.len(), n_streams);
    for r in &restored {
        let r = r.result.as_ref().expect("restore failed");
        assert_eq!(r.updates, before as u64);
        assert!(!r.repaired, "post-repair snapshots must restore exactly");
    }
    for (i, &v_before) in versions_before.iter().enumerate() {
        let name = format!("t{i}");
        // restored model is immediately servable, at a version that
        // continues (never resets) the pre-restart sequence
        let v_now = c2.registry().version(&name).unwrap();
        assert!(
            v_now > v_before,
            "{name}: version went backwards: {v_now} after {v_before}"
        );
        let model = c2.registry().get(&name).unwrap();
        let ref_rho = ref_at_snap[i];
        assert!(
            (model.rho1 - ref_rho.0).abs() <= 1e-9
                && (model.rho2 - ref_rho.1).abs() <= 1e-9,
            "{name}: restored model rho diverged from uninterrupted run"
        );
    }

    // phase 3: keep pushing; the resumed fleet must match the
    // uninterrupted reference at the end
    std::thread::scope(|scope| {
        for (i, seq) in seqs.iter().enumerate() {
            let c = &c2;
            scope.spawn(move || {
                let name = format!("t{i}");
                for x in &seq[before..] {
                    c.push(&name, x).unwrap();
                }
            });
        }
    });
    c2.quiesce_streams();
    for (i, &(ref_obj, ref_rho)) in ref_final.iter().enumerate() {
        let s = c2.close_stream(&format!("t{i}")).unwrap();
        assert_eq!(s.updates, (before + after) as u64);
        assert!(
            (s.objective - ref_obj).abs() <= 1e-9 * ref_obj.abs().max(1.0),
            "t{i}: objective diverged: {} vs uninterrupted {ref_obj}",
            s.objective
        );
        assert!(
            (s.rho.0 - ref_rho.0).abs() <= 1e-9
                && (s.rho.1 - ref_rho.1).abs() <= 1e-9,
            "t{i}: rho diverged: {:?} vs {ref_rho:?}",
            s.rho
        );
    }
    c2.shutdown();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn restore_isolates_corrupt_files_per_stream() {
    let dir = tmpdir("isolate");
    // two good snapshots…
    for (name, seed) in [("good-a", 71u64), ("good-b", 72)] {
        let cfg = StreamConfig { window: 32, min_train: 16, ..Default::default() };
        let mut s = StreamSession::new(name, cfg);
        let ds = SlabConfig::default().generate(40, seed);
        for i in 0..40 {
            s.absorb(ds.x.row(i)).unwrap();
        }
        persist::write_atomic(
            &persist::snapshot_path(&dir, name),
            &s.snapshot(),
        )
        .unwrap();
    }
    // …and one garbage file
    std::fs::write(dir.join("junk.snap"), b"definitely not a snapshot")
        .unwrap();

    let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 1);
    let outcomes = c.restore_streams(&dir).unwrap();
    assert_eq!(outcomes.len(), 3);
    let ok: Vec<&str> = outcomes
        .iter()
        .filter_map(|o| o.result.as_ref().ok().map(|r| r.name.as_str()))
        .collect();
    assert_eq!(ok.len(), 2, "both good snapshots must restore: {outcomes:?}");
    let failed: Vec<_> =
        outcomes.iter().filter(|o| o.result.is_err()).collect();
    assert_eq!(failed.len(), 1);
    assert!(failed[0].file.ends_with("junk.snap"));
    assert!(c.stream_manager().is_open("good-a"));
    assert!(c.stream_manager().is_open("good-b"));
    // restoring the same directory again conflicts per-stream (already
    // open), again without touching the healthy state
    let again = c.restore_streams(&dir).unwrap();
    assert!(again.iter().all(|o| o.result.is_err()));
    assert_eq!(c.stream_manager().open_count(), 2);
    c.shutdown();
    std::fs::remove_dir_all(dir).ok();
}

// ------------------------------------------------------- checkpointing

#[test]
fn periodic_checkpoints_land_and_restore() {
    let dir = tmpdir("ckpt");
    let c = Coordinator::start_with_streams(
        Engine::Native,
        BatcherConfig::default(),
        1,
        StreamPoolConfig {
            shards: 2,
            mailbox_cap: 256,
            // zero cadence: every loop tick may checkpoint one dirty
            // session — deterministic for the test, no sleeps needed
            checkpoint: Some(CheckpointConfig::new(&dir, Duration::ZERO)),
        },
    );
    let cfg = StreamConfig { window: 32, min_train: 16, ..Default::default() };
    c.open_streams(vec![
        StreamSpec::new("ck-a", cfg),
        StreamSpec::new("ck-b", cfg),
    ])
    .unwrap();
    let ds = SlabConfig::default().generate(60, 77);
    for i in 0..60 {
        c.push("ck-a", ds.x.row(i)).unwrap();
        c.push("ck-b", ds.x.row(i)).unwrap();
    }
    c.quiesce_streams();
    // graceful shutdown flushes a final checkpoint of every dirty
    // session through the writer thread before it exits
    c.shutdown();

    let files = persist::list_snapshots(&dir).unwrap();
    assert_eq!(files.len(), 2, "one snapshot per stream: {files:?}");
    // no stray temp files may survive the atomic write protocol
    let strays: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            e.path().extension().and_then(|x| x.to_str()) == Some("tmp")
        })
        .collect();
    assert!(strays.is_empty(), "leftover temp files: {strays:?}");
    // the final checkpoints carry the full pre-shutdown state
    for file in &files {
        let snap = persist::read_snapshot(file).unwrap();
        assert_eq!(snap.updates, 60, "{}", file.display());
        let (session, info) = snap.into_session().unwrap();
        assert!(!info.repaired);
        assert!(session.is_warm());
    }
    std::fs::remove_dir_all(dir).ok();
}
