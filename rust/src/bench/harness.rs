//! Timing harness: warmup, repeated samples, robust statistics.

use std::time::Instant;

use crate::linalg::{mean, median};
use crate::util::json::Json;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    /// per-iteration wall-clock seconds, one entry per sample
    pub seconds: Vec<f64>,
    /// optional auxiliary metrics (e.g. mcc, iterations, hit-rate)
    pub extra: Vec<(String, f64)>,
}

impl Sample {
    pub fn median(&self) -> f64 {
        median(&self.seconds)
    }
    pub fn mean(&self) -> f64 {
        mean(&self.seconds)
    }
    pub fn min(&self) -> f64 {
        self.seconds.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    /// median absolute deviation (robust spread)
    pub fn mad(&self) -> f64 {
        let med = self.median();
        let devs: Vec<f64> = self.seconds.iter().map(|s| (s - med).abs()).collect();
        median(&devs)
    }

    /// Machine-readable JSON line.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            ("median_s", Json::num(self.median())),
            ("mean_s", Json::num(self.mean())),
            ("min_s", Json::num(self.min())),
            ("mad_s", Json::num(self.mad())),
            ("samples", Json::num(self.seconds.len() as f64)),
        ];
        for (k, v) in &self.extra {
            fields.push((k.as_str(), Json::num(*v)));
        }
        // keys must outlive: rebuild with owned keys
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Human row: `name  median ± mad  (min)`.
    pub fn row(&self) -> String {
        let extras: Vec<String> = self
            .extra
            .iter()
            .map(|(k, v)| format!("{k}={v:.4}"))
            .collect();
        format!(
            "{:40} {:>10.4}s ±{:>8.4}s  min {:>10.4}s  {}",
            self.name,
            self.median(),
            self.mad(),
            self.min(),
            extras.join(" ")
        )
    }
}

/// Bench runner configuration.
pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    /// cap total time per case (seconds); reduces samples for slow cases
    pub max_seconds: f64,
    results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 1, samples: 5, max_seconds: 120.0, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup: usize, samples: usize, max_seconds: f64) -> Self {
        Bench { warmup, samples, max_seconds, results: Vec::new() }
    }

    /// Honor `SLABSVM_BENCH_FAST=1` (CI smoke mode: 1 sample, no warmup).
    pub fn from_env() -> Self {
        if std::env::var("SLABSVM_BENCH_FAST").as_deref() == Ok("1") {
            Bench::new(0, 1, 30.0)
        } else {
            Bench::default()
        }
    }

    /// Run one case. `f` returns optional extra metrics recorded with
    /// the last sample.
    pub fn run<F>(&mut self, name: &str, mut f: F) -> &Sample
    where
        F: FnMut() -> Vec<(String, f64)>,
    {
        for _ in 0..self.warmup {
            let _ = f();
        }
        let mut seconds = Vec::with_capacity(self.samples);
        let mut extra = Vec::new();
        let t_total = Instant::now();
        for i in 0..self.samples {
            let t0 = Instant::now();
            extra = f();
            seconds.push(t0.elapsed().as_secs_f64());
            if t_total.elapsed().as_secs_f64() > self.max_seconds && i > 0 {
                break;
            }
        }
        self.results.push(Sample { name: name.to_string(), seconds, extra });
        self.results.last().unwrap()
    }

    /// Print the human table + JSON lines for all cases so far.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        for s in &self.results {
            println!("{}", s.row());
        }
        for s in &self.results {
            println!("BENCHJSON {}", s.to_json());
        }
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let mut b = Bench::new(0, 3, 10.0);
        let s = b.run("sleepless", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            vec![("metric".into(), 7.0)]
        });
        assert_eq!(s.seconds.len(), 3);
        assert!(s.median() >= 0.002);
        assert_eq!(s.extra[0].1, 7.0);
        assert!(!s.row().is_empty());
    }

    #[test]
    fn json_line_is_valid() {
        let mut b = Bench::new(0, 1, 10.0);
        b.run("case", Vec::new);
        let j = b.results()[0].to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str(), Some("case"));
        assert_eq!(parsed.get("samples").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn time_cap_reduces_samples() {
        let mut b = Bench::new(0, 100, 0.02);
        let s = b.run("slow", || {
            std::thread::sleep(std::time::Duration::from_millis(15));
            Vec::new()
        });
        assert!(s.seconds.len() < 100);
    }
}
