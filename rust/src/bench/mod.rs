//! Benchmark harness (criterion substitute for the offline crate set).
//!
//! [`harness::Bench`] runs a closure with warmup + repeated timed
//! samples and reports median / mean / MAD / min; benches print both a
//! human table and machine-readable JSON lines so reported numbers
//! are reproducible by re-running the bench binaries.

pub mod harness;

pub use harness::{Bench, Sample};
