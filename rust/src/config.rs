//! Typed run configuration: TOML-subset file + CLI overrides.
//!
//! A [`RunConfig`] gathers everything a `slabsvm train` / `serve` /
//! `bench` invocation needs. Files use a flat TOML subset —
//! `key = value` lines, `#` comments, optional `[section]` headers that
//! prefix keys with `section.` — which covers real config needs without
//! a full TOML parser in the vendored crate set.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::kernel::Kernel;
use crate::solver::api::{SolverKind, Trainer};
use crate::solver::smo::SmoParams;
use crate::solver::Heuristic;

/// Flat key-value config store with typed getters.
#[derive(Clone, Debug, Default)]
pub struct ConfigMap {
    vals: BTreeMap<String, String>,
}

impl ConfigMap {
    /// Parse the TOML subset from text.
    pub fn parse(text: &str) -> Result<ConfigMap> {
        let mut vals = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| {
                    Error::config(format!("line {}: bad section", lineno + 1))
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim().trim_matches('"').to_string();
            vals.insert(key, v);
        }
        Ok(ConfigMap { vals })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<ConfigMap> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Override/insert a key.
    pub fn set(&mut self, key: &str, val: impl Into<String>) {
        self.vals.insert(key.to_string(), val.into());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.vals.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("{key}: not a number: {v}"))),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("{key}: not an integer: {v}"))),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(v) => Err(Error::config(format!("{key}: not a bool: {v}"))),
        }
    }
}

/// Fully resolved run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// which solver trains the model (key: `solver = smo|pg|ipm|ocsvm-smo`)
    pub solver: SolverKind,
    pub smo: SmoParams,
    pub kernel: Kernel,
    /// artifacts directory for the PJRT engine
    pub artifacts_dir: String,
    /// "native" | "pjrt"
    pub engine: String,
    pub seed: u64,
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            solver: SolverKind::Smo,
            smo: SmoParams::default(),
            kernel: Kernel::Linear,
            artifacts_dir: "artifacts".into(),
            engine: "native".into(),
            seed: 42,
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

impl RunConfig {
    /// Build from a config map (each key optional, defaults otherwise).
    pub fn from_map(m: &ConfigMap) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        if let Some(kind) = m.get("solver") {
            c.solver = kind.parse()?;
        }
        c.smo.nu1 = m.get_f64("smo.nu1", c.smo.nu1)?;
        c.smo.nu2 = m.get_f64("smo.nu2", c.smo.nu2)?;
        c.smo.eps = m.get_f64("smo.eps", c.smo.eps)?;
        c.smo.tol = m.get_f64("smo.tol", c.smo.tol)?;
        c.smo.max_iter = m.get_usize("smo.max_iter", c.smo.max_iter)?;
        c.smo.heuristic = parse_heuristic(
            m.get("smo.heuristic").unwrap_or("paper-max-fbar"),
        )?;
        c.kernel = parse_kernel(
            m.get("kernel.family").unwrap_or("linear"),
            m.get_f64("kernel.g", 1.0)?,
            m.get_f64("kernel.c", 0.0)?,
            m.get_f64("kernel.degree", 3.0)?,
        )?;
        if let Some(dir) = m.get("runtime.artifacts") {
            c.artifacts_dir = dir.to_string();
        }
        if let Some(engine) = m.get("runtime.engine") {
            if !matches!(engine, "native" | "pjrt") {
                return Err(Error::config(format!("unknown engine {engine}")));
            }
            c.engine = engine.to_string();
        }
        c.seed = m.get_usize("seed", c.seed as usize)? as u64;
        c.threads = m.get_usize("threads", c.threads)?;
        Ok(c)
    }

    /// Lower into a [`Trainer`] for the unified solver API. Shared
    /// hyper-parameters (ν₁, ν₂, ε, kernel, heuristic, seed) carry over
    /// to any solver kind; the SMO-flavored `tol`/`max_iter` from the
    /// `[smo]` section are applied only when the SMO solver is selected,
    /// so other kinds keep their own per-solver defaults.
    pub fn trainer(&self) -> Trainer {
        let mut t = Trainer::new(self.solver)
            .kernel(self.kernel)
            .nu1(self.smo.nu1)
            .nu2(self.smo.nu2)
            .eps(self.smo.eps)
            .heuristic(self.smo.heuristic)
            .seed(self.seed);
        if self.solver == SolverKind::Smo {
            t = t.tol(self.smo.tol).max_iter(self.smo.max_iter);
        }
        t
    }
}

/// Parse a heuristic name (CLI + config). Thin wrapper over
/// [`Heuristic`]'s `FromStr`, kept for call-site ergonomics.
pub fn parse_heuristic(s: &str) -> Result<Heuristic> {
    s.parse()
}

/// Parse a kernel spec (CLI + config).
pub fn parse_kernel(family: &str, g: f64, c: f64, degree: f64) -> Result<Kernel> {
    match family {
        "linear" => Ok(Kernel::Linear),
        "rbf" => Ok(Kernel::Rbf { g }),
        "poly" => Ok(Kernel::Poly { g, c, degree }),
        "sigmoid" => Ok(Kernel::Sigmoid { g, c }),
        other => Err(Error::config(format!("unknown kernel {other}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let m = ConfigMap::parse(
            "# top comment\nseed = 7\n[smo]\nnu1 = 0.25 # inline\n\n[kernel]\nfamily = \"rbf\"\ng = 0.5\n",
        )
        .unwrap();
        assert_eq!(m.get("seed"), Some("7"));
        assert_eq!(m.get("smo.nu1"), Some("0.25"));
        assert_eq!(m.get("kernel.family"), Some("rbf"));
    }

    #[test]
    fn run_config_from_map() {
        let m = ConfigMap::parse(
            "[smo]\nnu1 = 0.2\nnu2 = 0.08\neps = 0.5\n[kernel]\nfamily = rbf\ng = 0.7\n[runtime]\nengine = pjrt\n",
        )
        .unwrap();
        let c = RunConfig::from_map(&m).unwrap();
        assert_eq!(c.smo.nu1, 0.2);
        assert_eq!(c.smo.eps, 0.5);
        assert_eq!(c.kernel, Kernel::Rbf { g: 0.7 });
        assert_eq!(c.engine, "pjrt");
    }

    #[test]
    fn defaults_apply() {
        let c = RunConfig::from_map(&ConfigMap::default()).unwrap();
        assert_eq!(c.smo.nu1, 0.5);
        assert_eq!(c.kernel, Kernel::Linear);
        assert_eq!(c.engine, "native");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ConfigMap::parse("novalue\n").is_err());
        assert!(ConfigMap::parse("[unclosed\n").is_err());
        let m = ConfigMap::parse("[runtime]\nengine = gpu\n").unwrap();
        assert!(RunConfig::from_map(&m).is_err());
        let m = ConfigMap::parse("[smo]\nnu1 = abc\n").unwrap();
        assert!(RunConfig::from_map(&m).is_err());
    }

    #[test]
    fn heuristic_and_kernel_parsers() {
        assert_eq!(parse_heuristic("paper").unwrap(), Heuristic::PaperMaxFbar);
        assert_eq!(
            parse_heuristic("max-violation").unwrap(),
            Heuristic::MaxViolation
        );
        assert!(parse_heuristic("nope").is_err());
        assert_eq!(parse_kernel("linear", 0.0, 0.0, 0.0).unwrap(), Kernel::Linear);
        assert!(parse_kernel("quantum", 0.0, 0.0, 0.0).is_err());
    }

    #[test]
    fn solver_key_roundtrips_into_trainer() {
        let m = ConfigMap::parse("solver = ipm\n[smo]\nnu1 = 0.25\n").unwrap();
        let c = RunConfig::from_map(&m).unwrap();
        assert_eq!(c.solver, SolverKind::Ipm);
        let t = c.trainer();
        assert_eq!(t.kind(), SolverKind::Ipm);
        // non-SMO kinds must keep their own iteration defaults
        assert_eq!(
            t.ipm_params().max_iter,
            crate::solver::qp_ipm::IpmParams::default().max_iter
        );
        assert_eq!(t.ipm_params().nu1, 0.25);

        let m = ConfigMap::parse("solver = warp-drive\n").unwrap();
        assert!(RunConfig::from_map(&m).is_err());

        // default stays the paper's solver
        let c = RunConfig::from_map(&ConfigMap::default()).unwrap();
        assert_eq!(c.solver, SolverKind::Smo);
        assert_eq!(c.trainer().smo_params().tol, SmoParams::default().tol);
    }
}
