//! Dense row-major matrices + the handful of BLAS-1/3 ops the stack needs.
//!
//! No external BLAS: the hot contraction in this crate is the Gram-matrix
//! build, which [`crate::kernel`] tiles and parallelizes itself; here we
//! keep the primitives simple, safe and branch-light.

/// Dense row-major `f64` matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major vec (len must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn data(&self) -> &[f64] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Immutable row view.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row view.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertical concatenation.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Per-row squared L2 norms.
    pub fn row_sq_norms(&self) -> Vec<f64> {
        (0..self.rows).map(|i| dot(self.row(i), self.row(i))).collect()
    }

    /// Convert to f32 flat buffer (PJRT artifacts are f32).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

/// Dot product with 4-way unrolled accumulation (keeps the dependency
/// chain short; autovectorizes well at opt-level 3).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Squared euclidean distance with 4 independent accumulator lanes
/// (same blocking as [`dot`]: short dependency chains autovectorize at
/// opt-level 3 with no per-element bounds checks).
///
/// Summation order is fixed — lanes then a left-to-right tail — so the
/// result is bitwise reproducible across call sites; every kernel path
/// (scalar eval, blocked row, Gram, parallel restore) funnels through
/// this one function.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] - b[i];
        let d1 = a[i + 1] - b[i + 1];
        let d2 = a[i + 2] - b[i + 2];
        let d3 = a[i + 3] - b[i + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// f32 dot product, lane-blocked like [`dot`]. Inputs are truncated
/// element-wise from f64; accumulation stays in f32 so the whole
/// contraction runs at single precision (the `Precision::F32` compute
/// mode — results are certified against the f64 path downstream).
#[inline]
pub fn dot_f32(a: &[f64], b: &[f64]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] as f32 * b[i] as f32;
        s1 += a[i + 1] as f32 * b[i + 1] as f32;
        s2 += a[i + 2] as f32 * b[i + 2] as f32;
        s3 += a[i + 3] as f32 * b[i + 3] as f32;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] as f32 * b[i] as f32;
    }
    s
}

/// f32 squared euclidean distance, lane-blocked like [`sq_dist`].
#[inline]
pub fn sq_dist_f32(a: &[f64], b: &[f64]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        let d0 = a[i] as f32 - b[i] as f32;
        let d1 = a[i + 1] as f32 - b[i + 1] as f32;
        let d2 = a[i + 2] as f32 - b[i + 2] as f32;
        let d3 = a[i + 3] as f32 - b[i + 3] as f32;
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        let d = a[i] as f32 - b[i] as f32;
        s += d * d;
    }
    s
}

/// Dense mat-vec: out = M v.
pub fn matvec(m: &Matrix, v: &[f64], out: &mut [f64]) {
    assert_eq!(m.cols(), v.len());
    assert_eq!(m.rows(), out.len());
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(m.row(i), v);
    }
}

/// In-place Cholesky factorization A = L Lᵀ of a symmetric
/// positive-definite matrix; returns the lower factor. `jitter` is added
/// to the diagonal (regularization for nearly-singular kernels).
/// Errors with the failing pivot index if A (+jitter I) is not PD.
pub fn cholesky(a: &Matrix, jitter: f64) -> Result<Matrix, usize> {
    assert_eq!(a.rows(), a.cols());
    let n = a.rows();
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j) + if i == j { jitter } else { 0.0 };
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(i);
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve A x = b given the Cholesky factor L (forward + back substitution).
pub fn cholesky_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    // L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.get(i, k) * y[k];
        }
        y[i] = s / l.get(i, i);
    }
    // Lᵀ x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.get(k, i) * x[k];
        }
        x[i] = s / l.get(i, i);
    }
    x
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Eigendecomposition of a symmetric matrix by the cyclic Jacobi
/// method: returns `(eigenvalues, v)` with `a = v · diag(λ) · vᵀ`
/// (eigenvector `k` is **column** `k` of `v`). Only the lower triangle
/// of `a` is read, so a numerically slightly-asymmetric input is
/// symmetrized implicitly.
///
/// Deterministic: fixed sweep order, fixed (non-adaptive) convergence
/// threshold, no randomness and no threading — two calls on the same
/// bytes produce the same bytes, which the Nyström feature map's
/// snapshot-restore path relies on. Cost is O(n³) per sweep with a
/// bounded sweep count; intended for the small (≤ ~2·10³ landmark)
/// matrices of the approximate-engine layer, not general dense
/// eigenproblems.
pub fn sym_eig(a: &Matrix) -> (Vec<f64>, Matrix) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "sym_eig needs a square matrix");
    // working copy (lower triangle mirrored) + accumulated rotations
    let mut w = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            w.set(i, j, a.get(i, j));
            w.set(j, i, a.get(i, j));
        }
    }
    let mut v = Matrix::zeros(n, n);
    for i in 0..n {
        v.set(i, i, 1.0);
    }
    if n < 2 {
        let evals = (0..n).map(|i| w.get(i, i)).collect();
        return (evals, v);
    }
    let scale: f64 = (0..n)
        .map(|i| (0..n).map(|j| w.get(i, j).abs()).fold(0.0, f64::max))
        .fold(0.0, f64::max)
        .max(1.0);
    const MAX_SWEEPS: usize = 64;
    for _ in 0..MAX_SWEEPS {
        // Frobenius norm of the strict upper triangle
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += w.get(p, q) * w.get(p, q);
            }
        }
        if off.sqrt() <= 1e-14 * scale {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = w.get(p, q);
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = w.get(p, p);
                let aqq = w.get(q, q);
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    1.0 / (tau - (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/columns p and q of w
                for k in 0..n {
                    let wkp = w.get(k, p);
                    let wkq = w.get(k, q);
                    w.set(k, p, c * wkp - s * wkq);
                    w.set(k, q, s * wkp + c * wkq);
                }
                for k in 0..n {
                    let wpk = w.get(p, k);
                    let wqk = w.get(q, k);
                    w.set(p, k, c * wpk - s * wqk);
                    w.set(q, k, s * wpk + c * wqk);
                }
                // accumulate the rotation into the eigenvector columns
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let evals = (0..n).map(|i| w.get(i, i)).collect();
    (evals, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_basics() {
        let mut m = Matrix::zeros(2, 3);
        m.set(0, 1, 5.0);
        m.set(1, 2, -2.0);
        assert_eq!(m.get(0, 1), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, -2.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn from_rows_and_select() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn vstack_works() {
        let a = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let b = Matrix::from_rows(&[&[3.0]]);
        let c = a.vstack(&b);
        assert_eq!(c.rows(), 3);
        assert_eq!(c.get(2, 0), 3.0);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 11.0, 11.5]);
    }

    #[test]
    fn sq_dist_works() {
        assert_eq!(sq_dist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn sq_dist_matches_naive_over_odd_lengths() {
        // lane-blocked rewrite must agree with the naive sum for lengths
        // that exercise both full lanes and every tail size
        for n in [1usize, 2, 3, 4, 5, 7, 8, 15, 16, 33] {
            let a: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
            let b: Vec<f64> = (0..n).map(|i| (i as f64 * 1.3).cos()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((sq_dist(&a, &b) - naive).abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn f32_primitives_track_f64() {
        let a: Vec<f64> = (0..21).map(|i| (i as f64 * 0.3).sin()).collect();
        let b: Vec<f64> = (0..21).map(|i| (i as f64 * 0.9).cos()).collect();
        assert!((f64::from(dot_f32(&a, &b)) - dot(&a, &b)).abs() < 1e-4);
        assert!((f64::from(sq_dist_f32(&a, &b)) - sq_dist(&a, &b)).abs() < 1e-4);
    }

    #[test]
    fn matvec_works() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut out = [0.0; 2];
        matvec(&m, &[1.0, 1.0], &mut out);
        assert_eq!(out, [3.0, 7.0]);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        Matrix::from_rows(&[&[1.0, 2.0], &[3.0]]);
    }

    #[test]
    fn cholesky_roundtrip() {
        // SPD matrix: A = B Bᵀ + I
        let b = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut a = Matrix::zeros(2, 2);
        for i in 0..2 {
            for j in 0..2 {
                a.set(i, j, dot(b.row(i), b.row(j)) + if i == j { 1.0 } else { 0.0 });
            }
        }
        let l = cholesky(&a, 0.0).unwrap();
        // L Lᵀ == A
        for i in 0..2 {
            for j in 0..2 {
                let mut s = 0.0;
                for k in 0..2 {
                    s += l.get(i, k) * l.get(j, k);
                }
                assert!((s - a.get(i, j)).abs() < 1e-12);
            }
        }
        // solve A x = rhs
        let rhs = [5.0, -3.0];
        let x = cholesky_solve(&l, &rhs);
        let mut ax = [0.0; 2];
        matvec(&a, &x, &mut ax);
        assert!((ax[0] - 5.0).abs() < 1e-10 && (ax[1] + 3.0).abs() < 1e-10);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig -1, 3
        assert!(cholesky(&a, 0.0).is_err());
        // jitter can rescue near-PSD cases
        assert!(cholesky(&a, 1.1).is_ok());
    }

    /// Random symmetric matrix A = B + Bᵀ of size n.
    fn random_symmetric(n: usize, seed: u64) -> Matrix {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn sym_eig_reconstructs_the_matrix() {
        for (n, seed) in [(1usize, 1u64), (2, 2), (5, 3), (9, 4)] {
            let a = random_symmetric(n, seed);
            let (lam, v) = sym_eig(&a);
            assert_eq!(lam.len(), n);
            // A == V diag(lam) Vᵀ
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += v.get(i, k) * lam[k] * v.get(j, k);
                    }
                    assert!(
                        (s - a.get(i, j)).abs() < 1e-10,
                        "n={n}: A[{i}][{j}] {} vs {}",
                        a.get(i, j),
                        s
                    );
                }
            }
        }
    }

    #[test]
    fn sym_eig_vectors_are_orthonormal() {
        let a = random_symmetric(7, 11);
        let (_, v) = sym_eig(&a);
        for i in 0..7 {
            for j in 0..7 {
                let mut s = 0.0;
                for k in 0..7 {
                    s += v.get(k, i) * v.get(k, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-10, "VᵀV[{i}][{j}] = {s}");
            }
        }
    }

    #[test]
    fn sym_eig_matches_known_spectrum() {
        // [[1,2],[2,1]] has eigenvalues {-1, 3}
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        let (mut lam, _) = sym_eig(&a);
        lam.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((lam[0] + 1.0).abs() < 1e-12);
        assert!((lam[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sym_eig_is_bitwise_deterministic() {
        let a = random_symmetric(6, 21);
        let (l1, v1) = sym_eig(&a);
        let (l2, v2) = sym_eig(&a);
        for (x, y) in l1.iter().zip(&l2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in v1.data().iter().zip(v2.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
