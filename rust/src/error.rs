//! Error taxonomy for the whole stack.
//!
//! One [`Error`] enum spanning data loading, solver, runtime (PJRT) and
//! coordinator failures, so every public API returns [`Result<T>`] with a
//! single error type that callers can match on.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All failure modes of the slabsvm stack.
#[derive(Error, Debug)]
pub enum Error {
    /// Invalid hyper-parameters or config values (e.g. nu outside (0,1]).
    #[error("invalid configuration: {0}")]
    Config(String),

    /// Dataset parsing / shape problems.
    #[error("data error: {0}")]
    Data(String),

    /// Solver failed to converge within its iteration budget.
    #[error("solver did not converge: {0}")]
    NoConvergence(String),

    /// A solution failed feasibility / KKT certification.
    #[error("solution certification failed: {0}")]
    Certification(String),

    /// Problems locating / parsing AOT artifacts (manifest, HLO files).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT client / compile / execute failures from the `xla` crate.
    #[error("pjrt runtime error: {0}")]
    Pjrt(String),

    /// Coordinator-level failures (queue shutdown, deadline exceeded...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Pjrt(e.to_string())
    }
}

impl Error {
    /// Helper for config validation sites.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Helper for data errors.
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
}
