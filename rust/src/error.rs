//! Error taxonomy for the whole stack.
//!
//! One [`Error`] enum spanning data loading, solver, runtime (PJRT) and
//! coordinator failures, so every public API returns [`Result<T>`] with a
//! single error type that callers can match on. Hand-implemented
//! `Display`/`Error` (no proc-macro dependency in the vendored crate set).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// All failure modes of the slabsvm stack.
#[derive(Debug)]
pub enum Error {
    /// Invalid hyper-parameters or config values (e.g. nu outside (0,1]).
    Config(String),

    /// Dataset parsing / shape problems.
    Data(String),

    /// Solver failed to converge within its iteration budget.
    NoConvergence(String),

    /// A solution failed feasibility / KKT certification.
    Certification(String),

    /// Problems locating / parsing AOT artifacts (manifest, HLO files).
    Artifact(String),

    /// PJRT client / compile / execute failures from the `xla` crate.
    Pjrt(String),

    /// Coordinator-level failures (queue shutdown, deadline exceeded...).
    Coordinator(String),

    /// Stream snapshot/restore failures: bad magic, unsupported format
    /// version, checksum or config-fingerprint mismatch, infeasible
    /// persisted dual state.
    Snapshot(String),

    /// Targeted unlearning failures: the sample id is not resident
    /// (never admitted, already evicted, or already forgotten), or the
    /// removal would empty the window.
    Unlearning(String),

    /// A non-blocking push found the stream's mailbox at capacity.
    /// Carries the observed queue depth so admission-control callers
    /// (the HTTP 429 path) can surface it in a Retry-After decision.
    Saturated {
        /// samples queued for the stream at rejection time
        depth: usize,
    },

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::NoConvergence(m) => write!(f, "solver did not converge: {m}"),
            Error::Certification(m) => {
                write!(f, "solution certification failed: {m}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Pjrt(m) => write!(f, "pjrt runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Snapshot(m) => write!(f, "snapshot error: {m}"),
            Error::Unlearning(m) => write!(f, "unlearning error: {m}"),
            Error::Saturated { depth } => {
                write!(f, "mailbox saturated (queue depth {depth})")
            }
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Pjrt(e.to_string())
    }
}

impl Error {
    /// Helper for config validation sites.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Helper for data errors.
    pub fn data(msg: impl Into<String>) -> Self {
        Error::Data(msg.into())
    }
    /// Helper for snapshot/restore errors.
    pub fn snapshot(msg: impl Into<String>) -> Self {
        Error::Snapshot(msg.into())
    }
    /// Helper for targeted-unlearning errors.
    pub fn unlearning(msg: impl Into<String>) -> Self {
        Error::Unlearning(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(
            Error::config("nu out of range").to_string(),
            "invalid configuration: nu out of range"
        );
        assert_eq!(Error::data("bad csv").to_string(), "data error: bad csv");
        assert_eq!(
            Error::snapshot("bad magic").to_string(),
            "snapshot error: bad magic"
        );
        assert_eq!(
            Error::unlearning("id 7 not resident").to_string(),
            "unlearning error: id 7 not resident"
        );
        assert_eq!(
            Error::Saturated { depth: 3 }.to_string(),
            "mailbox saturated (queue depth 3)"
        );
        assert!(Error::NoConvergence("x".into())
            .to_string()
            .starts_with("solver did not converge"));
    }

    #[test]
    fn io_source_is_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
