//! Figure regeneration: the paper's Fig. 1 and Fig. 2 as CSV + SVG.
//!
//! Each figure is a 2-D scatter of the training points plus the two
//! slab hyperplanes (lower red, upper green — the paper's color coding)
//! drawn as lines in input space. Only meaningful for 2-D data and
//! kernels whose decision surface is a line (linear); for non-linear
//! kernels the plane is rendered as an iso-contour sampled on a grid.

use std::io::Write;
use std::path::Path;

use crate::data::Dataset;
use crate::solver::ocssvm::SlabModel;
use crate::Result;

/// Everything needed to draw one figure.
pub struct Figure {
    pub points: Vec<(f64, f64, i8)>,
    /// polyline per plane: (x, y) samples where s(x) = rho
    pub lower_plane: Vec<(f64, f64)>,
    pub upper_plane: Vec<(f64, f64)>,
    pub title: String,
}

/// Sample the two plane contours of a trained 2-D model over the data's
/// bounding box (marching over a grid, linear interpolation on sign
/// changes of s − ρ along grid columns).
pub fn build_figure(model: &SlabModel, ds: &Dataset, title: &str) -> Figure {
    assert_eq!(ds.dim(), 2, "figures are 2-D only");
    let n = ds.len();
    let mut points = Vec::with_capacity(n);
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for i in 0..n {
        let p = ds.x.row(i);
        points.push((p[0], p[1], model.classify(p)));
        xmin = xmin.min(p[0]);
        xmax = xmax.max(p[0]);
        ymin = ymin.min(p[1]);
        ymax = ymax.max(p[1]);
    }
    let pad_x = 0.05 * (xmax - xmin).max(1e-9);
    let pad_y = 0.25 * (ymax - ymin).max(1e-9);
    xmin -= pad_x;
    xmax += pad_x;
    ymin -= pad_y;
    ymax += pad_y;

    let contour = |rho: f64| -> Vec<(f64, f64)> {
        // for each of 200 columns, scan rows for a sign change of s − rho
        let (nx, ny) = (200usize, 400usize);
        let mut line = Vec::new();
        for ix in 0..nx {
            let x = xmin + (xmax - xmin) * ix as f64 / (nx - 1) as f64;
            let mut prev: Option<(f64, f64)> = None; // (y, s - rho)
            for iy in 0..ny {
                let y = ymin + (ymax - ymin) * iy as f64 / (ny - 1) as f64;
                let v = model.score(&[x, y]) - rho;
                if let Some((py, pv)) = prev {
                    if pv == 0.0 || (pv < 0.0) != (v < 0.0) {
                        let t = pv / (pv - v);
                        line.push((x, py + t * (y - py)));
                        break; // first crossing per column is enough
                    }
                }
                prev = Some((y, v));
            }
        }
        line
    };

    Figure {
        points,
        lower_plane: contour(model.rho1),
        upper_plane: contour(model.rho2),
        title: title.to_string(),
    }
}

/// Write the figure as CSV: one `point,x,y,label` row per sample and
/// one `lower|upper,x,y,` row per contour vertex.
pub fn write_csv(fig: &Figure, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "kind,x,y,label")?;
    for &(x, y, l) in &fig.points {
        writeln!(f, "point,{x},{y},{l}")?;
    }
    for &(x, y) in &fig.lower_plane {
        writeln!(f, "lower,{x},{y},")?;
    }
    for &(x, y) in &fig.upper_plane {
        writeln!(f, "upper,{x},{y},")?;
    }
    Ok(())
}

/// Render a standalone SVG (blue points, red lower plane, green upper —
/// the paper's color coding).
pub fn write_svg(fig: &Figure, path: impl AsRef<Path>) -> Result<()> {
    const W: f64 = 900.0;
    const H: f64 = 600.0;
    const M: f64 = 40.0;

    let all_x = fig
        .points
        .iter()
        .map(|p| p.0)
        .chain(fig.lower_plane.iter().map(|p| p.0))
        .chain(fig.upper_plane.iter().map(|p| p.0));
    let all_y = fig
        .points
        .iter()
        .map(|p| p.1)
        .chain(fig.lower_plane.iter().map(|p| p.1))
        .chain(fig.upper_plane.iter().map(|p| p.1));
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    for v in all_x {
        xmin = xmin.min(v);
        xmax = xmax.max(v);
    }
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for v in all_y {
        ymin = ymin.min(v);
        ymax = ymax.max(v);
    }
    let sx = |x: f64| M + (x - xmin) / (xmax - xmin).max(1e-12) * (W - 2.0 * M);
    let sy = |y: f64| H - M - (y - ymin) / (ymax - ymin).max(1e-12) * (H - 2.0 * M);

    let mut s = String::new();
    s.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{W}\" height=\"{H}\" \
         viewBox=\"0 0 {W} {H}\">\n<rect width=\"{W}\" height=\"{H}\" fill=\"white\"/>\n\
         <text x=\"{}\" y=\"24\" font-family=\"sans-serif\" font-size=\"16\" \
         text-anchor=\"middle\">{}</text>\n",
        W / 2.0,
        fig.title
    ));
    for &(x, y, label) in &fig.points {
        let color = if label > 0 { "#3366cc" } else { "#99bbee" };
        s.push_str(&format!(
            "<circle cx=\"{:.2}\" cy=\"{:.2}\" r=\"2.2\" fill=\"{color}\"/>\n",
            sx(x),
            sy(y)
        ));
    }
    for (line, color) in
        [(&fig.lower_plane, "#cc2222"), (&fig.upper_plane, "#22aa22")]
    {
        if line.is_empty() {
            continue;
        }
        let pts: Vec<String> = line
            .iter()
            .map(|&(x, y)| format!("{:.2},{:.2}", sx(x), sy(y)))
            .collect();
        s.push_str(&format!(
            "<polyline points=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"2\"/>\n",
            pts.join(" ")
        ));
    }
    s.push_str("</svg>\n");
    std::fs::write(path, s)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::kernel::Kernel;
    use crate::solver::api::Trainer;

    fn fig() -> Figure {
        let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
        let ds = cfg.generate(200, 121);
        let model = Trainer::default()
            .kernel(Kernel::Linear)
            .fit(&ds.x)
            .unwrap()
            .model;
        build_figure(&model, &ds, "test figure")
    }

    #[test]
    fn figure_has_points_and_planes() {
        let f = fig();
        assert_eq!(f.points.len(), 200);
        // contours must be traced across most of the x range
        assert!(f.lower_plane.len() > 150, "lower {} pts", f.lower_plane.len());
        assert!(f.upper_plane.len() > 150, "upper {} pts", f.upper_plane.len());
    }

    #[test]
    fn planes_are_ordered_vertically() {
        // for the linear kernel on the tilted band, the upper plane's
        // contour sits above the lower plane's at matching x
        let f = fig();
        let avg = |l: &[(f64, f64)]| {
            l.iter().map(|p| p.1).sum::<f64>() / l.len() as f64
        };
        assert!(avg(&f.upper_plane) > avg(&f.lower_plane));
    }

    #[test]
    fn csv_and_svg_written() {
        let f = fig();
        let dir = std::env::temp_dir();
        let csv = dir.join(format!("slabsvm_fig_{}.csv", std::process::id()));
        let svg = dir.join(format!("slabsvm_fig_{}.svg", std::process::id()));
        write_csv(&f, &csv).unwrap();
        write_svg(&f, &svg).unwrap();
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("kind,x,y,label"));
        assert!(csv_text.contains("point,"));
        assert!(csv_text.contains("lower,"));
        let svg_text = std::fs::read_to_string(&svg).unwrap();
        assert!(svg_text.starts_with("<svg"));
        assert!(svg_text.contains("polyline"));
        std::fs::remove_file(csv).ok();
        std::fs::remove_file(svg).ok();
    }
}
