//! L5 — the network front door (DESIGN.md §9).
//!
//! A dependency-free HTTP/1.1 server over `std::net` that exposes the
//! [`Coordinator`](crate::coordinator::Coordinator)'s score /
//! stream-push / forget / snapshot / metrics / trace surface as
//! endpoints, with per-tenant bearer-token auth ([`auth`]), a
//! connection cap + per-tenant token-bucket rate limiting ([`limits`]),
//! and graceful-degradation admission control ([`router`]): a
//! saturated shard mailbox answers `429` + `Retry-After` (the acceptor
//! never blocks), and scoring under batcher saturation falls back to
//! the last *published* model, marked `X-Slab-Stale` /
//! `X-Slab-Model-Version`.
//!
//! ```no_run
//! use std::sync::Arc;
//! use slabsvm::coordinator::{BatcherConfig, Coordinator};
//! use slabsvm::runtime::Engine;
//! use slabsvm::serve::{self, Router, RouterConfig, ServerConfig};
//!
//! let coord = Arc::new(Coordinator::start(
//!     Engine::Native,
//!     BatcherConfig::default(),
//!     2,
//! ));
//! let router = Arc::new(Router::new(coord, RouterConfig::default()));
//! let server = serve::start(router, ServerConfig::default()).unwrap();
//! println!("listening on {}", server.addr());
//! ```
//!
//! Endpoint table, the auth model and the shed-vs-stale decision
//! ladder are documented in DESIGN.md §9; `rust/benches/serve.rs`
//! measures the front door under 10³ concurrent tenant connections
//! (experiment SV1), and `rust/tests/serve_e2e.rs` drives the binary
//! over real TCP through a kill-mid-traffic + restore cycle.

pub mod auth;
pub mod http;
pub mod limits;
pub mod router;
pub mod server;

pub use auth::{Auth, AuthFailure, Tenant};
pub use http::{parse_request, HttpError, HttpLimits, Parsed, Request, Response};
pub use limits::{ConnGauge, RateConfig, RateLimiter};
pub use router::{Router, RouterConfig};
pub use server::{start, Server, ServerConfig};
