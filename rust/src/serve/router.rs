//! Request routing + the graceful-degradation admission ladder.
//!
//! [`Router::handle`] is a pure `Request -> Response` function over an
//! `Arc<Coordinator>` — no sockets in sight, so every admission
//! decision is unit-testable in-process. The ladder (DESIGN.md §9):
//!
//! 1. `/healthz` and `/metrics` answer unconditionally (a scraper must
//!    see the saturation it is diagnosing);
//! 2. authentication — unknown/missing/malformed bearer tokens are 401,
//!    a valid tenant touching another tenant's resource is 403;
//! 3. the per-tenant token bucket — over the rate is `429` +
//!    `Retry-After` (shed, never queued);
//! 4. stream pushes go through the **non-blocking**
//!    [`Coordinator::try_push`]: a saturated mailbox is `429` +
//!    `Retry-After` carrying the queue depth — the worker thread never
//!    blocks on shard backpressure;
//! 5. scoring falls back to the last *published* model when the
//!    batcher sheds ([`Error::Saturated`]): the response is computed
//!    directly from the registry snapshot and marked `X-Slab-Stale: 1`
//!    (plus `X-Slab-Model-Version`, which every scoring response
//!    carries) — degraded freshness, never an outage.
//!
//! Every request mints a trace id and records a [`Stage::Request`]
//! span; a push hands the same id to the shard mailbox, so the
//! request→queue→absorb chain groups under one trace in `/v1/trace`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::Coordinator;
use crate::error::Error;
use crate::obs::{self, Span, Stage};
use crate::serve::auth::{Auth, Tenant};
use crate::serve::http::{Request, Response};
use crate::serve::limits::{RateConfig, RateLimiter};
use crate::stream::RestoredStream;
use crate::sync::RwLock;
use crate::util::json::Json;

/// Router policy knobs (everything the CLI flags feed in).
#[derive(Default)]
pub struct RouterConfig {
    pub auth: Auth,
    /// per-tenant token bucket; `None` = unlimited
    pub rate: Option<RateConfig>,
    /// where `POST /v1/snapshot` writes (`None` disables the endpoint)
    pub snapshot_dir: Option<PathBuf>,
}

/// The serving front door's brain: authn/authz, admission control, and
/// the endpoint table (DESIGN.md §9).
pub struct Router {
    coord: Arc<Coordinator>,
    auth: Auth,
    rate: RateLimiter,
    snapshot_dir: Option<PathBuf>,
    /// pre-restart accounting of streams this process restored, served
    /// by `GET /v1/streams/{name}` so clients can resume after a crash
    restored: RwLock<HashMap<String, RestoredStream>>,
}

fn err_json(status: u16, msg: &str) -> Response {
    Response::json(status, &Json::obj(vec![("error", Json::str(msg))]))
}

/// Map a coordinator-layer failure onto a status code.
fn error_response(e: &Error) -> Response {
    let status = match e {
        Error::Saturated { .. } => 429,
        Error::Unlearning(_) => 404,
        Error::Coordinator(msg) if msg.contains("unknown") => 404,
        Error::Config(_) | Error::Data(_) => 400,
        _ => 500,
    };
    let resp = err_json(status, &e.to_string());
    if status == 429 {
        resp.header("retry-after", "1")
    } else {
        resp
    }
}

fn parse_vec_f64(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

fn parse_matrix(j: &Json) -> Option<Vec<Vec<f64>>> {
    j.as_arr()?.iter().map(parse_vec_f64).collect()
}

fn body_json(req: &Request) -> Result<Json, Response> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| err_json(400, "request body is not UTF-8"))?;
    Json::parse(text).map_err(|e| err_json(400, &e.to_string()))
}

impl Router {
    pub fn new(coord: Arc<Coordinator>, cfg: RouterConfig) -> Router {
        Router {
            coord,
            auth: cfg.auth,
            rate: RateLimiter::new(cfg.rate),
            snapshot_dir: cfg.snapshot_dir,
            restored: RwLock::new("serve_restored", HashMap::new()),
        }
    }

    /// Record restore outcomes so `GET /v1/streams/{name}` can tell a
    /// reconnecting client where its stream resumed from.
    pub fn note_restored(&self, streams: &[RestoredStream]) {
        let mut map = self.restored.write();
        for rs in streams {
            map.insert(rs.name.clone(), rs.clone());
        }
    }

    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.coord
    }

    /// Serve one request: admission ladder + endpoint dispatch, with
    /// the serve counters/histogram and a [`Stage::Request`] span
    /// recorded around the whole thing.
    pub fn handle(&self, req: &Request) -> Response {
        let trace = obs::mint_trace();
        let start_us = obs::now_us();
        let resp = self.dispatch(req, trace);
        let stats = self.coord.stats();
        match resp.status {
            401 | 403 => stats.serve_auth_failed.inc(),
            429 | 503 => stats.serve_shed.inc(),
            _ => stats.serve_accepted.inc(),
        }
        let dur_us = obs::now_us().saturating_sub(start_us);
        stats.serve_latency.record_us(dur_us);
        obs::record_span(Span {
            trace,
            stage: Stage::Request,
            start_us,
            dur_us,
            stream: 0,
            shard: u32::MAX,
            iters: 0,
        });
        resp
    }

    fn dispatch(&self, req: &Request, trace: u64) -> Response {
        let segs: Vec<&str> =
            req.path.split('/').filter(|s| !s.is_empty()).collect();

        // rung 1: liveness + scrape endpoints bypass auth and rate
        match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["healthz"]) => return self.healthz(),
            ("GET", ["metrics"]) => {
                return Response::text(
                    200,
                    "text/plain; version=0.0.4",
                    self.coord.metrics_text(),
                );
            }
            _ => {}
        }

        // rung 2: authentication
        let tenant = match self.auth.authenticate(req) {
            Ok(t) => t,
            Err(f) => {
                return err_json(401, f.message())
                    .header("www-authenticate", "Bearer");
            }
        };

        // rung 3: per-tenant token bucket
        if let Err(retry_s) = self.rate.admit(tenant.name()) {
            return err_json(429, "rate limit exceeded")
                .header("retry-after", retry_s.to_string());
        }

        match (req.method.as_str(), segs.as_slice()) {
            ("GET", ["v1", "trace"]) => self.trace_dump(),
            ("POST", ["v1", "score", model]) => {
                self.guarded(&tenant, model, |r| r.score(model, req))
            }
            ("POST", ["v1", "streams", name, "push"]) => self
                .guarded(&tenant, name, |r| r.push(name, req, trace)),
            ("POST", ["v1", "streams", name, "forget"]) => {
                self.guarded(&tenant, name, |r| r.forget(name, req))
            }
            ("GET", ["v1", "streams", name]) => {
                self.guarded(&tenant, name, |r| r.stream_info(name))
            }
            ("POST", ["v1", "streams", name, "close"]) => {
                self.guarded(&tenant, name, |r| r.close(name))
            }
            ("POST", ["v1", "snapshot"]) => self.snapshot(),
            ("POST", ["v1", "quiesce"]) => {
                self.coord.quiesce_streams();
                Response::json(
                    200,
                    &Json::obj(vec![("quiesced", Json::Bool(true))]),
                )
            }
            (_, segs) if known_path(segs) => {
                err_json(405, "method not allowed for this path")
            }
            _ => err_json(404, "no such endpoint"),
        }
    }

    /// Rung 2b: tenant/resource ownership (403, counted as auth).
    fn guarded(
        &self,
        tenant: &Tenant,
        resource: &str,
        f: impl FnOnce(&Router) -> Response,
    ) -> Response {
        if !tenant.allows(resource) {
            return err_json(
                403,
                &format!(
                    "tenant '{}' may not access '{resource}'",
                    tenant.name()
                ),
            );
        }
        f(self)
    }

    fn healthz(&self) -> Response {
        Response::json(
            200,
            &Json::obj(vec![
                ("ok", Json::Bool(true)),
                (
                    "open_streams",
                    Json::num(
                        self.coord.stream_manager().open_count() as f64,
                    ),
                ),
                (
                    "backlog",
                    Json::num(self.coord.stream_manager().backlog() as f64),
                ),
            ]),
        )
    }

    fn trace_dump(&self) -> Response {
        let spans: Vec<Json> = obs::recent_spans(256)
            .iter()
            .map(|s| s.to_json())
            .collect();
        Response::json(200, &Json::obj(vec![("spans", Json::arr(spans))]))
    }

    // ------------------------------------------------------- scoring

    fn score(&self, model: &str, req: &Request) -> Response {
        let body = match body_json(req) {
            Ok(j) => j,
            Err(resp) => return resp,
        };
        let Some(queries) = body.get("queries").and_then(parse_matrix)
        else {
            return err_json(
                400,
                "body must be {\"queries\": [[f64, ...], ...]}",
            );
        };
        if queries.is_empty() {
            return err_json(400, "queries must be non-empty");
        }
        match self.coord.score(model, queries.clone()) {
            Ok(resp) => {
                let version =
                    self.coord.registry().version(model).unwrap_or(0);
                let scores =
                    resp.scores.iter().map(|&s| Json::num(s)).collect();
                let labels = resp
                    .labels
                    .iter()
                    .map(|&l| Json::num(l as f64))
                    .collect();
                Response::json(
                    200,
                    &Json::obj(vec![
                        ("scores", Json::arr(scores)),
                        ("labels", Json::arr(labels)),
                        (
                            "latency_us",
                            Json::num(resp.latency.as_micros() as f64),
                        ),
                    ]),
                )
                .header("x-slab-model-version", version.to_string())
            }
            // rung 5: batcher shed — serve the last published model
            Err(Error::Saturated { .. }) => self.score_stale(model, &queries),
            Err(e) => error_response(&e),
        }
    }

    /// Degraded scoring path: the batcher queue is saturated, so score
    /// directly against the registry's last published snapshot. The
    /// response is still correct for that version — it is *stale*, not
    /// wrong — and says so in `X-Slab-Stale`.
    fn score_stale(&self, model: &str, queries: &[Vec<f64>]) -> Response {
        let Some((m, version)) = self.coord.registry().get_versioned(model)
        else {
            return err_json(
                503,
                "scoring queue saturated and no model published yet",
            )
            .header("retry-after", "1");
        };
        let dim = m.x_sv.cols();
        if queries.iter().any(|q| q.len() != dim) {
            return err_json(
                400,
                &format!("query dimension mismatch (model dim {dim})"),
            );
        }
        let scores =
            queries.iter().map(|q| Json::num(m.margin(q))).collect();
        let labels = queries
            .iter()
            .map(|q| Json::num(m.classify(q) as f64))
            .collect();
        self.coord.stats().serve_stale_served.inc();
        Response::json(
            200,
            &Json::obj(vec![
                ("scores", Json::arr(scores)),
                ("labels", Json::arr(labels)),
            ]),
        )
        .header("x-slab-model-version", version.to_string())
        .header("x-slab-stale", "1")
    }

    // ------------------------------------------------------- streams

    fn push(&self, name: &str, req: &Request, trace: u64) -> Response {
        let body = match body_json(req) {
            Ok(j) => j,
            Err(resp) => return resp,
        };
        let Some(x) = body.get("x").and_then(parse_vec_f64) else {
            return err_json(400, "body must be {\"x\": [f64, ...]}");
        };
        // rung 4: non-blocking — a saturated mailbox is the client's
        // problem (retry), never this worker thread's (blocked)
        match self.coord.stream_manager().push_opts(
            name,
            &x,
            false,
            Some(trace),
        ) {
            Ok(()) => Response::json(
                202,
                &Json::obj(vec![("queued", Json::Bool(true))]),
            ),
            Err(Error::Saturated { depth }) => err_json(
                429,
                &format!("stream mailbox saturated (depth {depth})"),
            )
            .header("retry-after", "1")
            .header("x-slab-queue-depth", depth.to_string()),
            Err(e) => error_response(&e),
        }
    }

    fn forget(&self, name: &str, req: &Request) -> Response {
        let body = match body_json(req) {
            Ok(j) => j,
            Err(resp) => return resp,
        };
        let Some(ids) = body.get("ids").and_then(|j| {
            j.as_arr()?
                .iter()
                .map(|v| v.as_f64().map(|n| n as u64))
                .collect::<Option<Vec<u64>>>()
        }) else {
            return err_json(400, "body must be {\"ids\": [u64, ...]}");
        };
        match self.coord.forget_many(name, &ids) {
            Ok(out) => Response::json(
                200,
                &Json::obj(vec![
                    ("name", Json::str(&out.name)),
                    (
                        "ids",
                        Json::arr(
                            out.ids
                                .iter()
                                .map(|&i| Json::num(i as f64))
                                .collect(),
                        ),
                    ),
                    (
                        "version",
                        out.version
                            .map(|v| Json::num(v as f64))
                            .unwrap_or(Json::Null),
                    ),
                    ("resident", Json::num(out.resident as f64)),
                ]),
            ),
            Err(e) => error_response(&e),
        }
    }

    fn stream_info(&self, name: &str) -> Response {
        let open = self.coord.stream_manager().is_open(name);
        let restored = self.restored.read().get(name).cloned();
        if !open && restored.is_none() {
            return err_json(404, &format!("unknown stream '{name}'"));
        }
        let mut fields = vec![
            ("name", Json::str(name)),
            ("open", Json::Bool(open)),
            (
                "version",
                self.coord
                    .registry()
                    .version(name)
                    .map(|v| Json::num(v as f64))
                    .unwrap_or(Json::Null),
            ),
        ];
        if let Some(rs) = restored {
            fields.push((
                "restored",
                Json::obj(vec![
                    ("updates", Json::num(rs.updates as f64)),
                    (
                        "version",
                        rs.version
                            .map(|v| Json::num(v as f64))
                            .unwrap_or(Json::Null),
                    ),
                    ("repaired", Json::Bool(rs.repaired)),
                ]),
            ));
        }
        Response::json(200, &Json::obj(fields))
    }

    fn close(&self, name: &str) -> Response {
        match self.coord.close_stream(name) {
            Ok(s) => Response::json(
                200,
                &Json::obj(vec![
                    ("name", Json::str(&s.name)),
                    ("updates", Json::num(s.updates as f64)),
                    ("retrains", Json::num(s.retrains as f64)),
                    (
                        "version",
                        s.version
                            .map(|v| Json::num(v as f64))
                            .unwrap_or(Json::Null),
                    ),
                    ("rho1", Json::num(s.rho.0)),
                    ("rho2", Json::num(s.rho.1)),
                    ("objective", Json::num(s.objective)),
                ]),
            ),
            Err(e) => error_response(&e),
        }
    }

    fn snapshot(&self) -> Response {
        let Some(dir) = self.snapshot_dir.clone() else {
            return err_json(400, "no snapshot directory configured");
        };
        self.coord.quiesce_streams();
        match self.coord.snapshot_streams(&dir) {
            Ok(outcomes) => {
                let rows = outcomes
                    .iter()
                    .map(|o| {
                        Json::obj(vec![
                            ("name", Json::str(&o.name)),
                            ("ok", Json::Bool(o.result.is_ok())),
                        ])
                    })
                    .collect();
                Response::json(
                    200,
                    &Json::obj(vec![("streams", Json::arr(rows))]),
                )
            }
            Err(e) => error_response(&e),
        }
    }
}

/// Paths the router knows (for 405-vs-404 on a method mismatch).
fn known_path(segs: &[&str]) -> bool {
    matches!(
        segs,
        ["healthz"]
            | ["metrics"]
            | ["v1", "trace"]
            | ["v1", "score", _]
            | ["v1", "streams", _]
            | ["v1", "streams", _, "push" | "forget" | "close"]
            | ["v1", "snapshot"]
            | ["v1", "quiesce"]
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatcherConfig;
    use crate::data::synthetic::SlabConfig;
    use crate::kernel::Kernel;
    use crate::runtime::Engine;
    use crate::solver::api::Trainer;
    use crate::stream::{StreamConfig, StreamPoolConfig, StreamSpec};

    fn coordinator(queue_cap: usize, mailbox_cap: usize) -> Arc<Coordinator> {
        Arc::new(Coordinator::start_with_streams(
            Engine::Native,
            BatcherConfig { max_batch: 64, max_wait_us: 200, queue_cap },
            1,
            StreamPoolConfig { shards: 1, mailbox_cap, checkpoint: None },
        ))
    }

    fn open_router(coord: &Arc<Coordinator>) -> Router {
        Router::new(Arc::clone(coord), RouterConfig::default())
    }

    fn request(method: &str, path: &str, body: &str) -> Request {
        request_auth(method, path, body, None)
    }

    fn request_auth(
        method: &str,
        path: &str,
        body: &str,
        token: Option<&str>,
    ) -> Request {
        let mut headers = Vec::new();
        if let Some(t) = token {
            headers.push(("authorization".into(), format!("Bearer {t}")));
        }
        Request {
            method: method.into(),
            path: path.into(),
            headers,
            body: body.as_bytes().to_vec(),
        }
    }

    fn body_of(resp: &Response) -> Json {
        Json::parse(std::str::from_utf8(resp.body_bytes()).unwrap()).unwrap()
    }

    fn train_demo(coord: &Arc<Coordinator>, name: &str) {
        let ds = SlabConfig::default().generate(80, 7);
        coord
            .train_blocking(name, &ds, &Trainer::default().kernel(Kernel::Linear))
            .unwrap();
    }

    #[test]
    fn healthz_and_unknown_paths() {
        let c = coordinator(1024, 64);
        let r = open_router(&c);
        let ok = r.handle(&request("GET", "/healthz", ""));
        assert_eq!(ok.status, 200);
        assert_eq!(body_of(&ok).get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.handle(&request("GET", "/nope", "")).status, 404);
        // method mismatch on a known path is 405, not 404
        assert_eq!(r.handle(&request("GET", "/v1/quiesce", "")).status, 405);
        assert_eq!(c.stats().serve_accepted.get(), 3);
    }

    #[test]
    fn score_fresh_carries_version_header() {
        let c = coordinator(1024, 64);
        train_demo(&c, "m");
        let r = open_router(&c);
        let resp = r.handle(&request(
            "POST",
            "/v1/score/m",
            "{\"queries\": [[0.5, 0.5], [3.0, 3.0]]}",
        ));
        assert_eq!(resp.status, 200, "{:?}", body_of(&resp));
        assert_eq!(resp.header_value("x-slab-model-version"), Some("1"));
        assert!(resp.header_value("x-slab-stale").is_none());
        let body = body_of(&resp);
        assert_eq!(body.get("labels").and_then(Json::as_arr).unwrap().len(), 2);
        assert_eq!(c.stats().serve_stale_served.get(), 0);
    }

    #[test]
    fn score_falls_back_stale_when_batcher_sheds() {
        // queue_cap 0: every batcher submit sheds with Saturated, so
        // the stale path is taken deterministically
        let c = coordinator(0, 64);
        train_demo(&c, "m");
        let r = open_router(&c);
        let resp = r.handle(&request(
            "POST",
            "/v1/score/m",
            "{\"queries\": [[0.5, 0.5]]}",
        ));
        assert_eq!(resp.status, 200, "{:?}", body_of(&resp));
        assert_eq!(resp.header_value("x-slab-stale"), Some("1"));
        assert_eq!(resp.header_value("x-slab-model-version"), Some("1"));
        assert_eq!(c.stats().serve_stale_served.get(), 1);
        // stale labels must agree with direct model predictions
        let m = c.model("m").unwrap();
        let label = body_of(&resp)
            .get("labels")
            .and_then(Json::as_arr)
            .and_then(|a| a.first())
            .and_then(Json::as_f64)
            .unwrap();
        assert_eq!(label as i8, m.classify(&[0.5, 0.5]));
        // no published model at all → 503, still never a hang
        let gone = r.handle(&request(
            "POST",
            "/v1/score/other",
            "{\"queries\": [[0.0, 0.0]]}",
        ));
        assert_eq!(gone.status, 503);
        assert_eq!(gone.header_value("retry-after"), Some("1"));
    }

    #[test]
    fn auth_gates_and_tenant_isolation() {
        let c = coordinator(1024, 64);
        train_demo(&c, "alice");
        let r = Router::new(
            Arc::clone(&c),
            RouterConfig {
                auth: Auth::from_spec("alice=tok-a,bob=tok-b").unwrap(),
                ..RouterConfig::default()
            },
        );
        let q = "{\"queries\": [[0.0, 0.0]]}";
        // no token / bad token → 401 with a challenge
        let missing = r.handle(&request("POST", "/v1/score/alice", q));
        assert_eq!(missing.status, 401);
        assert_eq!(missing.header_value("www-authenticate"), Some("Bearer"));
        let bad =
            r.handle(&request_auth("POST", "/v1/score/alice", q, Some("zz")));
        assert_eq!(bad.status, 401);
        // bob's valid token on alice's model → 403
        let cross = r.handle(&request_auth(
            "POST",
            "/v1/score/alice",
            q,
            Some("tok-b"),
        ));
        assert_eq!(cross.status, 403);
        // alice on her own model → 200
        let own = r.handle(&request_auth(
            "POST",
            "/v1/score/alice",
            q,
            Some("tok-a"),
        ));
        assert_eq!(own.status, 200);
        assert_eq!(c.stats().serve_auth_failed.get(), 3);
        // metrics stays scrapeable without a token
        let m = r.handle(&request("GET", "/metrics", ""));
        assert_eq!(m.status, 200);
        let text = String::from_utf8(m.body_bytes().to_vec()).unwrap();
        assert!(text.contains("slabsvm_serve_auth_failed_total 3"));
    }

    #[test]
    fn rate_limit_sheds_with_retry_after() {
        let c = coordinator(1024, 64);
        let r = Router::new(
            Arc::clone(&c),
            RouterConfig {
                rate: Some(RateConfig { per_second: 0.1, burst: 2.0 }),
                ..RouterConfig::default()
            },
        );
        assert_eq!(r.handle(&request("GET", "/v1/trace", "")).status, 200);
        assert_eq!(r.handle(&request("GET", "/v1/trace", "")).status, 200);
        let shed = r.handle(&request("GET", "/v1/trace", ""));
        assert_eq!(shed.status, 429);
        let retry: u64 =
            shed.header_value("retry-after").unwrap().parse().unwrap();
        assert!(retry >= 1);
        assert_eq!(c.stats().serve_shed.get(), 1);
        // healthz is exempt from the bucket
        assert_eq!(r.handle(&request("GET", "/healthz", "")).status, 200);
    }

    #[test]
    fn push_roundtrip_and_mailbox_429() {
        let c = coordinator(1024, 1);
        c.open_streams(vec![StreamSpec::new(
            "s",
            StreamConfig {
                kernel: Kernel::Linear,
                dim: 2,
                window: 32,
                min_train: 16,
                ..Default::default()
            },
        )])
        .unwrap();
        let r = open_router(&c);
        let push = request("POST", "/v1/streams/s/push", "{\"x\": [0.1, 0.2]}");
        assert_eq!(r.handle(&push).status, 202);
        // unknown stream is 404
        let unknown =
            request("POST", "/v1/streams/zzz/push", "{\"x\": [0.1, 0.2]}");
        assert_eq!(r.handle(&unknown).status, 404);
        // flood the cap-1 mailbox until admission control sheds; the
        // worker drains concurrently, so spin — a 429 must show up
        // without ever blocking this thread
        let mut shed = None;
        for _ in 0..10_000 {
            let resp = r.handle(&push);
            if resp.status == 429 {
                shed = Some(resp);
                break;
            }
            assert_eq!(resp.status, 202);
        }
        let shed = shed.expect("cap-1 mailbox never saturated");
        assert_eq!(shed.header_value("retry-after"), Some("1"));
        assert!(shed.header_value("x-slab-queue-depth").is_some());
        assert!(c.stats().serve_shed.get() >= 1);
        c.quiesce_streams();
    }

    #[test]
    fn stream_info_close_and_forget() {
        let c = coordinator(1024, 256);
        c.open_streams(vec![StreamSpec::new(
            "s",
            StreamConfig {
                kernel: Kernel::Linear,
                dim: 2,
                window: 32,
                min_train: 8,
                ..Default::default()
            },
        )])
        .unwrap();
        let r = open_router(&c);
        let mut gen = crate::data::synthetic::SlabStream::new(
            SlabConfig::default(),
            11,
        );
        for _ in 0..16 {
            let x = gen.next_point();
            let body = format!("{{\"x\": [{}, {}]}}", x[0], x[1]);
            assert_eq!(
                r.handle(&request("POST", "/v1/streams/s/push", &body)).status,
                202
            );
        }
        c.quiesce_streams();
        // info: open, with a published version after warmup
        let info = r.handle(&request("GET", "/v1/streams/s", ""));
        assert_eq!(info.status, 200);
        let j = body_of(&info);
        assert_eq!(j.get("open"), Some(&Json::Bool(true)));
        assert!(j.get("version").and_then(Json::as_f64).unwrap() >= 1.0);
        assert_eq!(r.handle(&request("GET", "/v1/streams/none", "")).status, 404);
        // forget sample 0, then forgetting it again is a typed 404
        let forget =
            r.handle(&request("POST", "/v1/streams/s/forget", "{\"ids\": [0]}"));
        assert_eq!(forget.status, 200, "{:?}", body_of(&forget));
        assert_eq!(
            body_of(&forget).get("resident").and_then(Json::as_usize),
            Some(15)
        );
        let again =
            r.handle(&request("POST", "/v1/streams/s/forget", "{\"ids\": [0]}"));
        assert_eq!(again.status, 404);
        // close returns the final accounting including the objective
        let close = r.handle(&request("POST", "/v1/streams/s/close", ""));
        assert_eq!(close.status, 200);
        let j = body_of(&close);
        assert_eq!(j.get("updates").and_then(Json::as_usize), Some(16));
        assert!(j.get("objective").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn malformed_bodies_are_400() {
        let c = coordinator(1024, 64);
        train_demo(&c, "m");
        let r = open_router(&c);
        for (path, body) in [
            ("/v1/score/m", "not json"),
            ("/v1/score/m", "{\"queries\": \"nope\"}"),
            ("/v1/score/m", "{\"queries\": []}"),
            ("/v1/streams/s/push", "{\"y\": [1]}"),
            ("/v1/streams/s/forget", "{\"ids\": [\"a\"]}"),
        ] {
            let resp = r.handle(&request("POST", path, body));
            assert_eq!(resp.status, 400, "{path} {body}");
        }
    }

    #[test]
    fn restored_info_and_snapshot_endpoint() {
        let c = coordinator(1024, 64);
        let dir = std::env::temp_dir().join(format!(
            "slabsvm-serve-router-{}",
            std::process::id()
        ));
        let r = Router::new(
            Arc::clone(&c),
            RouterConfig {
                snapshot_dir: Some(dir.clone()),
                ..RouterConfig::default()
            },
        );
        r.note_restored(&[RestoredStream {
            name: "s".into(),
            updates: 42,
            version: Some(7),
            repaired: false,
        }]);
        let info = r.handle(&request("GET", "/v1/streams/s", ""));
        assert_eq!(info.status, 200);
        let j = body_of(&info);
        assert_eq!(j.get("open"), Some(&Json::Bool(false)));
        let restored = j.get("restored").unwrap();
        assert_eq!(restored.get("updates").and_then(Json::as_usize), Some(42));
        // snapshot endpoint sweeps (no open streams → empty outcome list)
        let snap = r.handle(&request("POST", "/v1/snapshot", ""));
        assert_eq!(snap.status, 200);
        let _ = std::fs::remove_dir_all(&dir);
        // without a configured dir the endpoint is disabled
        let r2 = open_router(&c);
        assert_eq!(r2.handle(&request("POST", "/v1/snapshot", "")).status, 400);
    }
}
