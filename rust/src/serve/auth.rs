//! Per-tenant bearer-token authentication for the HTTP front door.
//!
//! The model is deliberately small: a static token → tenant map loaded
//! at startup (`--auth "alice=tok-a,bob=tok-b"` on the CLI). A request
//! proves it is tenant T by presenting T's token in
//! `Authorization: Bearer <token>`; T may then touch only resources it
//! owns — the stream/model named exactly `T` or namespaced under
//! `T/` / `T-`. An **empty** map is *open mode* (no `--auth` flag):
//! every request is the anonymous [`Tenant::Open`] with access to
//! everything, which keeps single-user benchmarking friction-free.
//! `GET /healthz` and `GET /metrics` never consult this layer — a
//! scraper needs no tenant identity.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::serve::http::Request;

/// The authenticated principal of one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tenant {
    /// open mode (no tokens configured): full access
    Open,
    /// named tenant; access limited by [`Tenant::allows`]
    Named(String),
}

impl Tenant {
    /// May this principal touch the stream/model named `resource`?
    /// Named tenants own their exact name plus the `name/`- and
    /// `name-`-prefixed namespaces.
    pub fn allows(&self, resource: &str) -> bool {
        match self {
            Tenant::Open => true,
            Tenant::Named(t) => {
                resource == t
                    || resource
                        .strip_prefix(t.as_str())
                        .is_some_and(|rest| {
                            rest.starts_with('/') || rest.starts_with('-')
                        })
            }
        }
    }

    /// Display name (`"open"` for the anonymous principal).
    pub fn name(&self) -> &str {
        match self {
            Tenant::Open => "open",
            Tenant::Named(t) => t,
        }
    }
}

/// Why a request failed authentication (all answer 401).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AuthFailure {
    /// auth is configured but the request carried no Authorization
    MissingToken,
    /// Authorization present but not `Bearer <one-token>`
    MalformedToken,
    /// well-formed token that maps to no tenant
    UnknownToken,
}

impl AuthFailure {
    pub fn message(&self) -> &'static str {
        match self {
            AuthFailure::MissingToken => "missing bearer token",
            AuthFailure::MalformedToken => "malformed authorization header",
            AuthFailure::UnknownToken => "unknown bearer token",
        }
    }
}

/// The startup-loaded token table.
#[derive(Debug, Default)]
pub struct Auth {
    /// token → tenant name; empty = open mode
    tokens: HashMap<String, String>,
}

impl Auth {
    /// Open mode: no tokens, every request is [`Tenant::Open`].
    pub fn open() -> Auth {
        Auth { tokens: HashMap::new() }
    }

    /// Parse a `tenant=token,tenant=token` spec (the `--auth` flag).
    /// Rejects empty names/tokens and duplicate tokens outright —
    /// a half-loaded auth table must never reach the listener.
    pub fn from_spec(spec: &str) -> Result<Auth> {
        let mut tokens = HashMap::new();
        for pair in spec.split(',').filter(|p| !p.trim().is_empty()) {
            let Some((tenant, token)) = pair.split_once('=') else {
                return Err(Error::config(format!(
                    "auth spec entry {pair:?} is not tenant=token"
                )));
            };
            let (tenant, token) = (tenant.trim(), token.trim());
            if tenant.is_empty() || token.is_empty() {
                return Err(Error::config(format!(
                    "auth spec entry {pair:?} has an empty side"
                )));
            }
            if tokens.insert(token.to_string(), tenant.to_string()).is_some()
            {
                return Err(Error::config(format!(
                    "auth spec reuses token {token:?}"
                )));
            }
        }
        Ok(Auth { tokens })
    }

    /// Open mode = no tokens configured.
    pub fn is_open(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Configured tenant names (sorted; CLI startup banner).
    pub fn tenants(&self) -> Vec<String> {
        let mut names: Vec<String> = self.tokens.values().cloned().collect();
        names.sort();
        names.dedup();
        names
    }

    /// Resolve the request's principal. Open mode accepts everything
    /// (even a bogus Authorization header — there is nothing to check
    /// it against); otherwise the bearer token must be present,
    /// well-formed and known.
    pub fn authenticate(
        &self,
        req: &Request,
    ) -> std::result::Result<Tenant, AuthFailure> {
        if self.is_open() {
            return Ok(Tenant::Open);
        }
        match req.bearer_token() {
            None => Err(AuthFailure::MissingToken),
            Some(Err(_)) => Err(AuthFailure::MalformedToken),
            Some(Ok(token)) => match self.tokens.get(token) {
                Some(tenant) => Ok(Tenant::Named(tenant.clone())),
                None => Err(AuthFailure::UnknownToken),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(auth: Option<&str>) -> Request {
        Request {
            method: "GET".into(),
            path: "/".into(),
            headers: auth
                .map(|a| vec![("authorization".into(), a.to_string())])
                .unwrap_or_default(),
            body: Vec::new(),
        }
    }

    #[test]
    fn open_mode_accepts_everything() {
        let auth = Auth::open();
        assert!(auth.is_open());
        assert_eq!(auth.authenticate(&req(None)), Ok(Tenant::Open));
        assert_eq!(
            auth.authenticate(&req(Some("Bearer whatever"))),
            Ok(Tenant::Open)
        );
        assert!(Tenant::Open.allows("anything"));
    }

    #[test]
    fn spec_parses_and_authenticates() {
        let auth = Auth::from_spec("alice=tok-a, bob=tok-b").unwrap();
        assert!(!auth.is_open());
        assert_eq!(auth.tenants(), vec!["alice", "bob"]);
        assert_eq!(
            auth.authenticate(&req(Some("Bearer tok-a"))),
            Ok(Tenant::Named("alice".into()))
        );
        assert_eq!(
            auth.authenticate(&req(Some("Bearer nope"))),
            Err(AuthFailure::UnknownToken)
        );
        assert_eq!(
            auth.authenticate(&req(None)),
            Err(AuthFailure::MissingToken)
        );
        // malformed header forms are a distinct, typed failure
        for bad in ["Basic xyz", "Bearer", "Bearer a b"] {
            assert_eq!(
                auth.authenticate(&req(Some(bad))),
                Err(AuthFailure::MalformedToken),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(Auth::from_spec("no-equals-here").is_err());
        assert!(Auth::from_spec("=tok").is_err());
        assert!(Auth::from_spec("alice=").is_err());
        assert!(Auth::from_spec("a=t,b=t").is_err(), "duplicate token");
        // empty / whitespace specs are open mode
        assert!(Auth::from_spec("").unwrap().is_open());
        assert!(Auth::from_spec(" , ").unwrap().is_open());
    }

    #[test]
    fn tenant_ownership_rules() {
        let t = Tenant::Named("alice".into());
        assert!(t.allows("alice"));
        assert!(t.allows("alice/stream-1"));
        assert!(t.allows("alice-model"));
        assert!(!t.allows("bob"));
        assert!(!t.allows("alicetail"), "prefix alone is not ownership");
        assert!(!t.allows("malice"));
        assert_eq!(t.name(), "alice");
        assert_eq!(Tenant::Open.name(), "open");
    }
}
