//! Admission control primitives: connection gauge + per-tenant token
//! buckets.
//!
//! Both shed instead of queueing — the acceptor thread must never
//! block behind a slow or abusive client (DESIGN.md §9's first rule of
//! the shed-vs-stale ladder). [`ConnGauge`] bounds concurrent
//! connections with an RAII permit (over the cap → immediate 503 +
//! close); [`RateLimiter`] is a classic token bucket per tenant
//! (over the rate → 429 + `Retry-After`), refilled lazily from a
//! monotonic clock so there is no background thread to schedule.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::sync::Mutex;

// ---------------------------------------------------------- connections

/// Bounded concurrent-connection count.
#[derive(Debug)]
pub struct ConnGauge {
    cur: AtomicUsize,
    max: usize,
}

impl ConnGauge {
    pub fn new(max: usize) -> Arc<ConnGauge> {
        Arc::new(ConnGauge { cur: AtomicUsize::new(0), max: max.max(1) })
    }

    /// Claim a connection slot; `None` means the listener is full and
    /// the caller sheds the connection (it must not wait).
    pub fn try_acquire(self: &Arc<ConnGauge>) -> Option<ConnPermit> {
        let mut cur = self.cur.load(Ordering::Relaxed);
        loop {
            if cur >= self.max {
                return None;
            }
            match self.cur.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(ConnPermit { gauge: Arc::clone(self) }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Live connection count (tests / stats banner).
    pub fn active(&self) -> usize {
        self.cur.load(Ordering::Relaxed)
    }
}

/// RAII connection slot — dropping it frees the slot.
#[derive(Debug)]
pub struct ConnPermit {
    gauge: Arc<ConnGauge>,
}

impl Drop for ConnPermit {
    fn drop(&mut self) {
        self.gauge.cur.fetch_sub(1, Ordering::AcqRel);
    }
}

// ---------------------------------------------------------- rate limit

/// Token-bucket parameters (requests/second + burst capacity).
#[derive(Clone, Copy, Debug)]
pub struct RateConfig {
    /// sustained admission rate, tokens (requests) per second
    pub per_second: f64,
    /// bucket capacity: how far a tenant may burst above the rate
    pub burst: f64,
}

struct Bucket {
    tokens: f64,
    last_us: u64,
}

/// Per-tenant token buckets. `None` config = unlimited (no `--rate`
/// flag), which costs one branch per request.
pub struct RateLimiter {
    cfg: Option<RateConfig>,
    epoch: Instant,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl RateLimiter {
    pub fn new(cfg: Option<RateConfig>) -> RateLimiter {
        let cfg = cfg.filter(|c| c.per_second > 0.0);
        RateLimiter {
            cfg,
            epoch: Instant::now(),
            buckets: Mutex::new("serve_rate_buckets", HashMap::new()),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Admit one request for `tenant`, or return the suggested
    /// `Retry-After` in **seconds** (ceiling of the time until one
    /// token refills, ≥ 1 — the header's granularity is whole seconds).
    pub fn admit(&self, tenant: &str) -> Result<(), u64> {
        let Some(cfg) = self.cfg else {
            return Ok(());
        };
        let burst = cfg.burst.max(1.0);
        let now = self.now_us();
        let mut buckets = self.buckets.lock();
        let b = buckets.entry(tenant.to_string()).or_insert(Bucket {
            tokens: burst,
            last_us: now,
        });
        let dt_s = now.saturating_sub(b.last_us) as f64 / 1e6;
        b.tokens = (b.tokens + dt_s * cfg.per_second).min(burst);
        b.last_us = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            let wait_s = (1.0 - b.tokens) / cfg.per_second;
            Err((wait_s.ceil() as u64).max(1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_bounds_and_releases() {
        let g = ConnGauge::new(2);
        let a = g.try_acquire().expect("slot 1");
        let _b = g.try_acquire().expect("slot 2");
        assert!(g.try_acquire().is_none(), "third conn must shed");
        assert_eq!(g.active(), 2);
        drop(a);
        assert_eq!(g.active(), 1);
        assert!(g.try_acquire().is_some(), "freed slot reusable");
    }

    #[test]
    fn unlimited_rate_admits_everything() {
        let rl = RateLimiter::new(None);
        for _ in 0..10_000 {
            assert!(rl.admit("t").is_ok());
        }
    }

    #[test]
    fn bucket_sheds_after_burst_with_retry_after() {
        let rl = RateLimiter::new(Some(RateConfig {
            per_second: 0.5,
            burst: 3.0,
        }));
        // the burst admits instantly, then the bucket is dry
        for i in 0..3 {
            assert!(rl.admit("alice").is_ok(), "burst req {i}");
        }
        let retry = rl.admit("alice").expect_err("must shed");
        // one token at 0.5/s takes 2s; header rounds up to whole seconds
        assert!(retry >= 2, "retry-after {retry}");
        // independent bucket per tenant
        assert!(rl.admit("bob").is_ok());
    }

    #[test]
    fn zero_rate_config_is_unlimited() {
        let rl = RateLimiter::new(Some(RateConfig {
            per_second: 0.0,
            burst: 1.0,
        }));
        for _ in 0..100 {
            assert!(rl.admit("t").is_ok());
        }
    }
}
