//! Hand-rolled HTTP/1.1 request parser + response builder.
//!
//! Pure functions over byte buffers — no I/O, no allocation beyond the
//! parsed request itself — so the whole wire grammar is unit-testable
//! without a socket. The parser is incremental: [`parse_request`]
//! either yields a complete [`Request`] plus the number of bytes it
//! consumed (pipelined requests parse by calling it again on the
//! remainder), asks for more bytes ([`Parsed::Partial`]), or rejects
//! with a typed [`HttpError`] that maps onto a 4xx/5xx status — never
//! a panic (the file is in slablint rule [[R1]]'s scope: malformed
//! bytes from the network must not be able to kill a worker thread).
//!
//! Supported surface, deliberately small: methods the router uses,
//! `HTTP/1.0`/`HTTP/1.1`, `Content-Length` bodies (no chunked
//! transfer-encoding — responses are always sized), keep-alive with
//! pipelining. Every limit ([`HttpLimits`]) rejects with a typed error
//! before buffering unboundedly.

use std::fmt;

use crate::util::json::Json;

/// Default cap on the request line (method + path + version).
pub const DEFAULT_MAX_REQUEST_LINE: usize = 8 * 1024;
/// Default cap on the full header block.
pub const DEFAULT_MAX_HEAD_BYTES: usize = 32 * 1024;
/// Default cap on a request body.
pub const DEFAULT_MAX_BODY_BYTES: usize = 1024 * 1024;

/// Parser limits; every violation is a typed [`HttpError`].
#[derive(Clone, Copy, Debug)]
pub struct HttpLimits {
    pub max_request_line: usize,
    pub max_head_bytes: usize,
    pub max_body_bytes: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits {
            max_request_line: DEFAULT_MAX_REQUEST_LINE,
            max_head_bytes: DEFAULT_MAX_HEAD_BYTES,
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
        }
    }
}

/// Typed request-rejection reasons, each mapping to one status code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum HttpError {
    /// malformed request line (missing parts, bad path, too long)
    BadRequestLine(String),
    /// method token outside the supported set
    UnsupportedMethod(String),
    /// protocol version other than HTTP/1.0 / HTTP/1.1
    UnsupportedVersion(String),
    /// header line without `:`, empty/spaced name, or non-UTF-8 head
    BadHeader(String),
    /// header block exceeded [`HttpLimits::max_head_bytes`]
    HeadersTooLarge(usize),
    /// `Content-Length` not a base-10 integer
    BadContentLength(String),
    /// declared body exceeds [`HttpLimits::max_body_bytes`]
    PayloadTooLarge(usize),
}

impl HttpError {
    /// The status code this rejection answers with.
    pub fn status(&self) -> u16 {
        match self {
            HttpError::BadRequestLine(_)
            | HttpError::BadHeader(_)
            | HttpError::BadContentLength(_) => 400,
            HttpError::UnsupportedMethod(_) => 405,
            HttpError::UnsupportedVersion(_) => 505,
            HttpError::HeadersTooLarge(_) => 431,
            HttpError::PayloadTooLarge(_) => 413,
        }
    }
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HttpError::BadRequestLine(l) => {
                write!(f, "malformed request line: {l}")
            }
            HttpError::UnsupportedMethod(m) => {
                write!(f, "unsupported method: {m}")
            }
            HttpError::UnsupportedVersion(v) => {
                write!(f, "unsupported protocol version: {v}")
            }
            HttpError::BadHeader(h) => write!(f, "malformed header: {h}"),
            HttpError::HeadersTooLarge(n) => {
                write!(f, "header block too large ({n} bytes)")
            }
            HttpError::BadContentLength(v) => {
                write!(f, "bad content-length: {v}")
            }
            HttpError::PayloadTooLarge(n) => {
                write!(f, "request body too large ({n} bytes)")
            }
        }
    }
}

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `(name, value)` pairs in arrival order; names lowercased,
    /// values whitespace-trimmed
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header with this (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// The bearer token of the `Authorization` header, if the header is
    /// present AND well-formed (`Bearer <token>`, non-empty token).
    /// `Some(Err(..))` distinguishes a malformed header (401 with a
    /// reason) from an absent one.
    pub fn bearer_token(&self) -> Option<Result<&str, HttpError>> {
        let raw = self.header("authorization")?;
        let Some(token) = raw.strip_prefix("Bearer ") else {
            return Some(Err(HttpError::BadHeader(format!(
                "authorization: {raw}"
            ))));
        };
        let token = token.trim();
        if token.is_empty() || token.contains(' ') {
            return Some(Err(HttpError::BadHeader(format!(
                "authorization: {raw}"
            ))));
        }
        Some(Ok(token))
    }

    /// Client asked to drop the connection after this response.
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Outcome of one incremental parse attempt.
#[derive(Debug)]
pub enum Parsed {
    /// complete request + bytes consumed from the front of the buffer
    /// (pipelining: re-run the parser on `buf[consumed..]`)
    Complete(Box<Request>, usize),
    /// not enough bytes yet — read more and retry
    Partial,
}

const SEP: &[u8] = b"\r\n\r\n";
const METHODS: &[&str] = &["GET", "POST", "PUT", "DELETE", "HEAD", "OPTIONS"];

/// Parse one request from the front of `buf`. See [`Parsed`].
pub fn parse_request(
    buf: &[u8],
    limits: &HttpLimits,
) -> Result<Parsed, HttpError> {
    let Some(head_len) = buf.windows(SEP.len()).position(|w| w == SEP) else {
        // no terminator yet: reject early once a limit is provably
        // blown, otherwise ask for more bytes
        if buf.len() > limits.max_head_bytes {
            return Err(HttpError::HeadersTooLarge(buf.len()));
        }
        if !buf.iter().any(|&b| b == b'\n')
            && buf.len() > limits.max_request_line
        {
            return Err(HttpError::BadRequestLine(format!(
                "request line exceeds {} bytes",
                limits.max_request_line
            )));
        }
        return Ok(Parsed::Partial);
    };
    if head_len > limits.max_head_bytes {
        return Err(HttpError::HeadersTooLarge(head_len));
    }
    let head_bytes = buf.get(..head_len).unwrap_or_default();
    let head = std::str::from_utf8(head_bytes)
        .map_err(|_| HttpError::BadHeader("non-UTF-8 header bytes".into()))?;

    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    if request_line.len() > limits.max_request_line {
        return Err(HttpError::BadRequestLine(format!(
            "request line exceeds {} bytes",
            limits.max_request_line
        )));
    }
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or_default();
    let path = parts.next().unwrap_or_default();
    let version = parts.next().unwrap_or_default();
    if parts.next().is_some() || method.is_empty() || path.is_empty() {
        return Err(HttpError::BadRequestLine(request_line.to_string()));
    }
    if !METHODS.contains(&method) {
        return Err(HttpError::UnsupportedMethod(method.to_string()));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::UnsupportedVersion(version.to_string()));
    }
    if !path.starts_with('/') {
        return Err(HttpError::BadRequestLine(request_line.to_string()));
    }

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::BadHeader(line.to_string()));
        };
        // a name with embedded whitespace is request smuggling bait
        if name.is_empty() || name.chars().any(|c| c.is_ascii_whitespace()) {
            return Err(HttpError::BadHeader(line.to_string()));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    let body_len = match headers.iter().find(|(n, _)| n == "content-length") {
        None => 0,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| HttpError::BadContentLength(v.clone()))?,
    };
    if body_len > limits.max_body_bytes {
        return Err(HttpError::PayloadTooLarge(body_len));
    }
    let body_start = head_len + SEP.len();
    let total = body_start + body_len;
    if buf.len() < total {
        return Ok(Parsed::Partial);
    }
    let body = buf.get(body_start..total).unwrap_or_default().to_vec();
    Ok(Parsed::Complete(
        Box::new(Request {
            method: method.to_string(),
            path: path.to_string(),
            headers,
            body,
        }),
        total,
    ))
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        204 => "No Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// Response builder: status + headers + sized body, encoded in one
/// buffer so a response is a single `write_all`.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response { status, headers: Vec::new(), body: Vec::new() }
    }

    /// JSON body (canonical encoding; `Content-Type: application/json`).
    pub fn json(status: u16, body: &Json) -> Response {
        Response::new(status)
            .header("content-type", "application/json")
            .body(body.to_string().into_bytes())
    }

    /// Plain/typed text body.
    pub fn text(
        status: u16,
        content_type: &str,
        body: impl Into<Vec<u8>>,
    ) -> Response {
        Response::new(status)
            .header("content-type", content_type)
            .body(body.into())
    }

    /// The 4xx/5xx a typed parse rejection answers with.
    pub fn from_http_error(e: &HttpError) -> Response {
        Response::json(
            e.status(),
            &Json::obj(vec![("error", Json::str(&e.to_string()))]),
        )
    }

    pub fn header(
        mut self,
        name: impl Into<String>,
        value: impl Into<String>,
    ) -> Response {
        self.headers.push((name.into(), value.into()));
        self
    }

    pub fn body(mut self, body: Vec<u8>) -> Response {
        self.body = body;
        self
    }

    /// Header lookup (router tests read back `Retry-After` etc.).
    pub fn header_value(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_bytes(&self) -> &[u8] {
        &self.body
    }

    /// Encode status line + headers + body into one write buffer.
    pub fn encode(&self, keep_alive: bool) -> Vec<u8> {
        let mut out = format!(
            "HTTP/1.1 {} {}\r\n",
            self.status,
            reason(self.status)
        )
        .into_bytes();
        for (name, value) in &self.headers {
            out.extend_from_slice(format!("{name}: {value}\r\n").as_bytes());
        }
        out.extend_from_slice(
            format!("content-length: {}\r\n", self.body.len()).as_bytes(),
        );
        let conn = if keep_alive { "keep-alive" } else { "close" };
        out.extend_from_slice(format!("connection: {conn}\r\n\r\n").as_bytes());
        out.extend_from_slice(&self.body);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(bytes: &[u8]) -> Result<Parsed, HttpError> {
        parse_request(bytes, &HttpLimits::default())
    }

    fn complete(bytes: &[u8]) -> (Request, usize) {
        match parse(bytes) {
            Ok(Parsed::Complete(req, n)) => (*req, n),
            other => panic!("expected complete parse, got {other:?}"),
        }
    }

    #[test]
    fn parses_simple_get() {
        let (req, n) =
            complete(b"GET /healthz HTTP/1.1\r\nhost: x\r\n\r\n");
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(n, b"GET /healthz HTTP/1.1\r\nhost: x\r\n\r\n".len());
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_with_body() {
        let raw =
            b"POST /v1/streams/t/push HTTP/1.1\r\ncontent-length: 9\r\n\r\n{\"x\":[1]}";
        let (req, n) = complete(raw);
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{\"x\":[1]}");
        assert_eq!(n, raw.len());
    }

    #[test]
    fn truncated_request_line_is_partial_not_error() {
        assert!(matches!(parse(b"GET /heal"), Ok(Parsed::Partial)));
        assert!(matches!(parse(b""), Ok(Parsed::Partial)));
        assert!(matches!(
            parse(b"GET / HTTP/1.1\r\nhost: x\r\n"),
            Ok(Parsed::Partial)
        ));
    }

    #[test]
    fn truncated_body_is_partial() {
        let raw = b"POST /p HTTP/1.1\r\ncontent-length: 10\r\n\r\nabc";
        assert!(matches!(parse(raw), Ok(Parsed::Partial)));
    }

    #[test]
    fn malformed_request_lines_are_typed_400() {
        for raw in [
            &b"GET\r\n\r\n"[..],
            b"GET  /two  spaces HTTP/1.1\r\n\r\n",
            b"GET nopath HTTP/1.1\r\n\r\n",
        ] {
            let err = parse(raw).expect_err("must reject");
            assert_eq!(err.status(), 400, "{err}");
        }
    }

    #[test]
    fn unsupported_method_and_version_are_typed() {
        let err = parse(b"BREW /pot HTTP/1.1\r\n\r\n").expect_err("reject");
        assert_eq!(err, HttpError::UnsupportedMethod("BREW".into()));
        assert_eq!(err.status(), 405);
        let err = parse(b"GET / HTTP/2.0\r\n\r\n").expect_err("reject");
        assert_eq!(err.status(), 505);
    }

    #[test]
    fn oversized_request_line_rejected_before_terminator() {
        let limits = HttpLimits {
            max_request_line: 64,
            ..HttpLimits::default()
        };
        let long = vec![b'A'; 100];
        let err = parse_request(&long, &limits).expect_err("reject");
        assert_eq!(err.status(), 400);
    }

    #[test]
    fn oversized_headers_rejected_431() {
        let limits = HttpLimits {
            max_head_bytes: 128,
            ..HttpLimits::default()
        };
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..32 {
            raw.extend_from_slice(format!("h{i}: {}\r\n", "v".repeat(16)).as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        let err = parse_request(&raw, &limits).expect_err("reject");
        assert!(matches!(err, HttpError::HeadersTooLarge(_)));
        assert_eq!(err.status(), 431);
        // also without a terminator in sight
        let endless = vec![b'x'; 256];
        let err = parse_request(&endless, &limits).expect_err("reject");
        assert_eq!(err.status(), 431);
    }

    #[test]
    fn bad_content_length_is_typed_400() {
        let raw = b"POST /p HTTP/1.1\r\ncontent-length: banana\r\n\r\n";
        let err = parse(raw).expect_err("reject");
        assert_eq!(err, HttpError::BadContentLength("banana".into()));
        assert_eq!(err.status(), 400);
        let raw = b"POST /p HTTP/1.1\r\ncontent-length: -5\r\n\r\n";
        assert_eq!(parse(raw).expect_err("reject").status(), 400);
    }

    #[test]
    fn oversized_body_rejected_413_from_declared_length() {
        let limits = HttpLimits {
            max_body_bytes: 16,
            ..HttpLimits::default()
        };
        // rejected on the DECLARED length — no body bytes needed
        let raw = b"POST /p HTTP/1.1\r\ncontent-length: 1000000\r\n\r\n";
        let err = parse_request(raw, &limits).expect_err("reject");
        assert_eq!(err, HttpError::PayloadTooLarge(1_000_000));
        assert_eq!(err.status(), 413);
    }

    #[test]
    fn malformed_headers_are_typed_400() {
        for raw in [
            &b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n"[..],
            b"GET / HTTP/1.1\r\nbad name: v\r\n\r\n",
            b"GET / HTTP/1.1\r\n: empty\r\n\r\n",
        ] {
            assert_eq!(parse(raw).expect_err("reject").status(), 400);
        }
        // non-UTF-8 header bytes
        let raw = b"GET / HTTP/1.1\r\nh: \xff\xfe\r\n\r\n";
        assert_eq!(parse(raw).expect_err("reject").status(), 400);
    }

    #[test]
    fn pipelined_requests_parse_sequentially() {
        let raw = b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\ncontent-length: 2\r\n\r\nhiGET /c HTTP/1.1\r\n\r\n";
        let (r1, n1) = complete(raw);
        assert_eq!(r1.path, "/a");
        let (r2, n2) = complete(raw.get(n1..).unwrap());
        assert_eq!((r2.path.as_str(), r2.body.as_slice()), ("/b", &b"hi"[..]));
        let (r3, _) = complete(raw.get(n1 + n2..).unwrap());
        assert_eq!(r3.path, "/c");
    }

    #[test]
    fn bearer_token_extraction_and_malformed_forms() {
        let mk = |auth: &str| Request {
            method: "GET".into(),
            path: "/".into(),
            headers: vec![("authorization".into(), auth.to_string())],
            body: Vec::new(),
        };
        assert_eq!(mk("Bearer tok-1").bearer_token(), Some(Ok("tok-1")));
        // malformed forms are Some(Err(..)) — typed 4xx, not a panic
        for bad in ["Basic dXNlcg==", "Bearer", "Bearer  ", "Bearer a b"] {
            let t = mk(bad).bearer_token();
            assert!(
                matches!(t, Some(Err(ref e)) if e.status() == 400),
                "{bad:?} -> {t:?}"
            );
        }
        let none = Request {
            method: "GET".into(),
            path: "/".into(),
            headers: Vec::new(),
            body: Vec::new(),
        };
        assert!(none.bearer_token().is_none());
    }

    #[test]
    fn response_encode_shape() {
        let r = Response::json(
            429,
            &Json::obj(vec![("error", Json::str("slow down"))]),
        )
        .header("retry-after", "1");
        let bytes = r.encode(true);
        let text = String::from_utf8(bytes).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("retry-after: 1\r\n"));
        assert!(text.contains("connection: keep-alive\r\n"));
        assert!(text.ends_with("{\"error\":\"slow down\"}"));
        let closed = Response::new(204).encode(false);
        assert!(String::from_utf8(closed)
            .unwrap()
            .contains("connection: close\r\n"));
    }

    #[test]
    fn http_error_statuses_have_reasons() {
        for status in [200, 400, 401, 404, 405, 408, 413, 429, 431, 500, 503, 505]
        {
            assert_ne!(reason(status), "Unknown", "{status}");
        }
    }
}
