//! The TCP front door: listener, connection threads, admission at
//! accept time.
//!
//! Dependency-free `std::net` serving — one acceptor thread plus one
//! thread per live connection, bounded by a [`ConnGauge`]. The
//! acceptor **never blocks on a client**: a connection over the cap is
//! answered `503` and closed immediately (counted as shed), and all
//! per-connection I/O (slow reads, keep-alive idling) happens on the
//! connection's own thread under a read timeout. Requests are parsed
//! incrementally ([`parse_request`]) so pipelined requests on one
//! keep-alive connection are served back-to-back; a framing error
//! answers with its typed 4xx/5xx and closes, because the byte stream
//! past a bad frame cannot be trusted.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;
use crate::serve::http::{parse_request, HttpLimits, Parsed, Response};
use crate::serve::limits::{ConnGauge, ConnPermit};
use crate::serve::router::Router;

/// Listener-level knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// bind address; port 0 picks a free port (see [`Server::addr`])
    pub addr: String,
    /// max live connections; the acceptor sheds (503) above this
    pub max_conns: usize,
    pub limits: HttpLimits,
    /// per-connection read timeout — an idle keep-alive connection is
    /// dropped after this long, freeing its [`ConnGauge`] slot
    pub read_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            max_conns: 1024,
            limits: HttpLimits::default(),
            read_timeout: Duration::from_secs(30),
        }
    }
}

/// A running listener. Dropping it (or calling [`Server::shutdown`])
/// stops the acceptor; live connection threads exit on their next read
/// timeout or client close.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

/// Bind and start serving `router` on its own threads; returns once
/// the socket is listening (so `addr` is immediately connectable).
pub fn start(router: Arc<Router>, cfg: ServerConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let gauge = ConnGauge::new(cfg.max_conns);
    let stop_in = Arc::clone(&stop);
    let acceptor = std::thread::Builder::new()
        .name("serve-accept".to_string())
        .spawn(move || {
            accept_loop(&listener, &router, &cfg, &gauge, &stop_in);
        })?;
    Ok(Server { addr, stop, acceptor: Some(acceptor) })
}

impl Server {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the acceptor thread.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        // unblock the acceptor's accept() with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    router: &Arc<Router>,
    cfg: &ServerConfig,
    gauge: &Arc<ConnGauge>,
    stop: &Arc<AtomicBool>,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = conn else {
            continue;
        };
        match gauge.try_acquire() {
            Some(permit) => {
                let router = Arc::clone(router);
                let limits = cfg.limits;
                let timeout = cfg.read_timeout;
                let stop = Arc::clone(stop);
                let spawned = std::thread::Builder::new()
                    .name("serve-conn".to_string())
                    .spawn(move || {
                        handle_conn(
                            stream, &router, &limits, timeout, permit, &stop,
                        );
                    });
                if spawned.is_err() {
                    // thread exhaustion: shed like an over-cap conn
                    router.coordinator().stats().serve_shed.inc();
                }
            }
            None => {
                // over the connection cap: shed immediately — the
                // acceptor must stay free to answer the next client
                router.coordinator().stats().serve_shed.inc();
                let resp = Response::json(
                    503,
                    &crate::util::json::Json::obj(vec![(
                        "error",
                        crate::util::json::Json::str(
                            "connection limit reached",
                        ),
                    )]),
                )
                .header("retry-after", "1");
                let mut stream = stream;
                let _ = stream.write_all(&resp.encode(false));
            }
        }
    }
}

fn handle_conn(
    mut stream: TcpStream,
    router: &Arc<Router>,
    limits: &HttpLimits,
    timeout: Duration,
    _permit: ConnPermit,
    stop: &Arc<AtomicBool>,
) {
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_nodelay(true);
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 8192];
    loop {
        // serve every complete pipelined request already buffered
        loop {
            match parse_request(&buf, limits) {
                Ok(Parsed::Complete(req, consumed)) => {
                    buf.drain(..consumed);
                    let keep_alive = !req.wants_close();
                    let resp = router.handle(&req);
                    if stream.write_all(&resp.encode(keep_alive)).is_err()
                        || !keep_alive
                    {
                        return;
                    }
                }
                Ok(Parsed::Partial) => break,
                Err(e) => {
                    // typed rejection, then close: bytes after a bad
                    // frame have no trustworthy boundary
                    let resp = Response::from_http_error(&e);
                    let _ = stream.write_all(&resp.encode(false));
                    return;
                }
            }
        }
        if stop.load(Ordering::Acquire) {
            return;
        }
        match stream.read(&mut tmp) {
            Ok(0) => return,
            Ok(n) => {
                buf.extend_from_slice(tmp.get(..n).unwrap_or_default());
            }
            Err(_) => return, // timeout or reset: drop the connection
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, Coordinator};
    use crate::runtime::Engine;
    use crate::serve::router::RouterConfig;
    use crate::stream::StreamPoolConfig;

    fn start_test_server(max_conns: usize) -> (Server, Arc<Router>) {
        let coord = Arc::new(Coordinator::start_with_streams(
            Engine::Native,
            BatcherConfig::default(),
            1,
            StreamPoolConfig { shards: 1, mailbox_cap: 64, checkpoint: None },
        ));
        let router = Arc::new(Router::new(coord, RouterConfig::default()));
        let server = start(
            Arc::clone(&router),
            ServerConfig {
                max_conns,
                read_timeout: Duration::from_secs(5),
                ..ServerConfig::default()
            },
        )
        .expect("bind loopback");
        (server, router)
    }

    /// Read exactly one HTTP response (head + content-length body).
    fn read_response(stream: &mut TcpStream) -> String {
        let mut buf = Vec::new();
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(head_end) =
                buf.windows(4).position(|w| w == b"\r\n\r\n")
            {
                let head = String::from_utf8_lossy(&buf[..head_end]);
                let clen: usize = head
                    .lines()
                    .find_map(|l| {
                        l.to_ascii_lowercase()
                            .strip_prefix("content-length:")
                            .map(|v| v.trim().parse().unwrap())
                    })
                    .unwrap_or(0);
                if buf.len() >= head_end + 4 + clen {
                    return String::from_utf8_lossy(&buf[..head_end + 4 + clen])
                        .to_string();
                }
            }
            let n = stream.read(&mut tmp).expect("read response");
            assert!(n > 0, "connection closed mid-response");
            buf.extend_from_slice(&tmp[..n]);
        }
    }

    #[test]
    fn serves_healthz_over_real_tcp() {
        let (mut server, _router) = start_test_server(16);
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let resp = read_response(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("\"ok\":true"), "{resp}");
        server.shutdown();
    }

    #[test]
    fn keep_alive_pipelining_two_requests_one_write() {
        let (mut server, _router) = start_test_server(16);
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(
            b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n",
        )
        .unwrap();
        let first = read_response(&mut conn);
        assert!(first.starts_with("HTTP/1.1 200"), "{first}");
        assert!(first.contains("connection: keep-alive"), "{first}");
        let second = read_response(&mut conn);
        assert!(second.contains("slabsvm_serve_accepted_total"), "{second}");
        server.shutdown();
    }

    #[test]
    fn over_cap_connection_is_shed_503_not_queued() {
        let (mut server, router) = start_test_server(1);
        // conn A occupies the single slot (prove it by round-tripping)
        let mut a = TcpStream::connect(server.addr()).unwrap();
        a.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert!(read_response(&mut a).starts_with("HTTP/1.1 200"));
        // conn B must be answered 503 immediately
        let mut b = TcpStream::connect(server.addr()).unwrap();
        let resp = read_response(&mut b);
        assert!(resp.starts_with("HTTP/1.1 503"), "{resp}");
        assert!(resp.contains("retry-after: 1"), "{resp}");
        assert!(
            router.coordinator().stats().serve_shed.get() >= 1,
            "shed counter"
        );
        server.shutdown();
    }

    #[test]
    fn malformed_frame_gets_typed_status_then_close() {
        let (mut server, _router) = start_test_server(16);
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(b"BREW /pot HTTP/1.1\r\n\r\n").unwrap();
        let resp = read_response(&mut conn);
        assert!(resp.starts_with("HTTP/1.1 405"), "{resp}");
        assert!(resp.contains("connection: close"), "{resp}");
        // server closes after the typed rejection
        let mut rest = Vec::new();
        let n = conn.read_to_end(&mut rest).unwrap_or(0);
        assert_eq!(n, 0, "no bytes after close");
        server.shutdown();
    }

    #[test]
    fn connection_close_header_is_honored() {
        let (mut server, _router) = start_test_server(16);
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(
            b"GET /healthz HTTP/1.1\r\nconnection: close\r\n\r\n",
        )
        .unwrap();
        let resp = read_response(&mut conn);
        assert!(resp.contains("connection: close"), "{resp}");
        let mut rest = Vec::new();
        assert_eq!(conn.read_to_end(&mut rest).unwrap_or(0), 0);
        server.shutdown();
    }
}
