//! Synchronization primitives for the serving stack, in two builds:
//!
//! * **release** (default): transparent newtypes over [`std::sync`]
//!   with poison *recovery* — `lock()` / `read()` / `write()` return
//!   the guard directly instead of a `LockResult`. A poisoned lock is
//!   not a reason to panic a shard worker: every structure guarded
//!   here (mailboxes, job tables, route maps) is kept consistent by
//!   its own invariants, not by unwind flags, so the wrapper takes the
//!   guard out of the `PoisonError` and carries on. This removes the
//!   `.lock().unwrap()` pattern from the data plane wholesale (lint
//!   rule [[R1]]) at zero runtime cost.
//!
//! * **audited** (`cfg(any(test, feature = "lock-audit"))`): the same
//!   API backed by [`tracked`] — every `Mutex`/`RwLock` carries a
//!   name, acquisitions are recorded per thread, a global lock-order
//!   graph accumulates `held → acquired` edges keyed by lock *class*
//!   (name), and an acquisition that would close a cycle in that graph
//!   panics with the offending chain **before** blocking, turning a
//!   potential deadlock into a deterministic test failure. The
//!   runtime side of lint rule [[R2]]: [`assert_lock_free`] panics if
//!   the calling thread holds any tracked lock, and is asserted at
//!   every absorb/repair/checkpoint entry point.
//!
//! The release build never compiles the tracking code, so the audit
//! layer costs nothing outside tests; CI runs the concurrency suite
//! with `--features lock-audit` so the graph is exercised per commit.

#[cfg(any(test, feature = "lock-audit"))]
pub mod tracked;

#[cfg(any(test, feature = "lock-audit"))]
pub use tracked::{
    assert_lock_free, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};

#[cfg(not(any(test, feature = "lock-audit")))]
mod plain {
    use std::sync::PoisonError;
    use std::time::Duration;

    pub type MutexGuard<'a, T> = std::sync::MutexGuard<'a, T>;
    pub type RwLockReadGuard<'a, T> = std::sync::RwLockReadGuard<'a, T>;
    pub type RwLockWriteGuard<'a, T> = std::sync::RwLockWriteGuard<'a, T>;

    /// [`std::sync::Mutex`] with poison recovery. The `name` is the
    /// lock's class in the audited build; it is not stored here.
    pub struct Mutex<T> {
        inner: std::sync::Mutex<T>,
    }

    impl<T> Mutex<T> {
        pub fn new(_name: &'static str, value: T) -> Mutex<T> {
            Mutex { inner: std::sync::Mutex::new(value) }
        }

        pub fn lock(&self) -> MutexGuard<'_, T> {
            self.inner.lock().unwrap_or_else(PoisonError::into_inner)
        }
    }

    /// [`std::sync::RwLock`] with poison recovery.
    pub struct RwLock<T> {
        inner: std::sync::RwLock<T>,
    }

    impl<T> RwLock<T> {
        pub fn new(_name: &'static str, value: T) -> RwLock<T> {
            RwLock { inner: std::sync::RwLock::new(value) }
        }

        pub fn read(&self) -> RwLockReadGuard<'_, T> {
            self.inner.read().unwrap_or_else(PoisonError::into_inner)
        }

        pub fn write(&self) -> RwLockWriteGuard<'_, T> {
            self.inner.write().unwrap_or_else(PoisonError::into_inner)
        }
    }

    /// [`std::sync::Condvar`] whose waits hand the guard back directly.
    #[derive(Default)]
    pub struct Condvar {
        inner: std::sync::Condvar,
    }

    impl Condvar {
        pub fn new() -> Condvar {
            Condvar { inner: std::sync::Condvar::new() }
        }

        pub fn notify_one(&self) {
            self.inner.notify_one();
        }

        pub fn notify_all(&self) {
            self.inner.notify_all();
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
            self.inner.wait(guard).unwrap_or_else(PoisonError::into_inner)
        }

        /// Returns the reacquired guard and whether the wait timed out.
        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> (MutexGuard<'a, T>, bool) {
            match self.inner.wait_timeout(guard, dur) {
                Ok((g, t)) => (g, t.timed_out()),
                Err(poisoned) => {
                    let (g, t) = poisoned.into_inner();
                    (g, t.timed_out())
                }
            }
        }
    }

    /// Runtime side of the no-lock-across-absorb rule; free in release.
    #[inline(always)]
    pub fn assert_lock_free(_context: &str) {}
}

#[cfg(not(any(test, feature = "lock-audit")))]
pub use plain::{
    assert_lock_free, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};
