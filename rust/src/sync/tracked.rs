//! Tracked locks: the dynamic half of the concurrency lint.
//!
//! Every [`Mutex`]/[`RwLock`] created here belongs to a *class* (its
//! `&'static str` name — all shard mailboxes are one class) and is one
//! *instance* of that class. Each thread keeps a stack of the tracked
//! locks it currently holds; each acquisition
//!
//! 1. panics if this thread already holds the same instance (a
//!    guaranteed self-deadlock with `std` locks);
//! 2. records a `held-class → acquired-class` edge, with the thread
//!    and hold-set that first produced it, into a process-global
//!    lock-order graph;
//! 3. runs a DFS from the acquired class and panics with the chain if
//!    the new edge closed a cycle — the classic ABBA pattern is
//!    reported *before* the acquisition blocks, so a test fails
//!    deterministically instead of hanging. Nested instances of the
//!    same class count as a cycle too (there is no consistent order
//!    between two mailboxes).
//!
//! [`assert_lock_free`] is the runtime form of the "no lock held
//! across an absorb" invariant: called at every absorb / repair /
//! checkpoint entry point, it panics if the calling thread holds any
//! tracked lock. [`Condvar::wait`] participates correctly: the wait
//! releases the lock (popped from the hold stack) and the reacquire is
//! re-checked like any other acquisition.
//!
//! Edges accumulate for the process lifetime (the graph is append-only
//! and tiny — one node per lock class), so a cycle is caught even when
//! the two halves of the inversion happen in different tests.

use std::cell::RefCell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex as StdMutex, OnceLock, PoisonError};
use std::time::Duration;

// ------------------------------------------------------------ registry

#[derive(Default)]
struct Graph {
    /// class name → class id (index into `names`)
    ids: HashMap<&'static str, usize>,
    names: Vec<&'static str>,
    /// held-class → acquired-class, with the context that first made it
    edges: HashMap<usize, HashMap<usize, String>>,
}

fn graph() -> &'static StdMutex<Graph> {
    static GRAPH: OnceLock<StdMutex<Graph>> = OnceLock::new();
    GRAPH.get_or_init(|| StdMutex::new(Graph::default()))
}

fn intern(name: &'static str) -> usize {
    let mut g = graph().lock().unwrap_or_else(PoisonError::into_inner);
    if let Some(&id) = g.ids.get(name) {
        return id;
    }
    let id = g.names.len();
    g.names.push(name);
    g.ids.insert(name, id);
    id
}

fn next_instance() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

thread_local! {
    /// (class, instance, name) of every tracked lock this thread holds,
    /// oldest first.
    static HELD: RefCell<Vec<(usize, u64, &'static str)>> =
        const { RefCell::new(Vec::new()) };
}

/// DFS from `start`: the names along a path that returns to `start`,
/// if the edge set contains one.
fn find_cycle(g: &Graph, start: usize) -> Option<String> {
    let mut stack = vec![(start, vec![start])];
    let mut visited = vec![false; g.names.len()];
    while let Some((node, path)) = stack.pop() {
        let Some(nexts) = g.edges.get(&node) else { continue };
        for (&next, ctx) in nexts {
            if next == start {
                let mut chain: Vec<&str> = path
                    .iter()
                    .map(|&c| g.names.get(c).copied().unwrap_or("?"))
                    .collect();
                chain.push(g.names.get(start).copied().unwrap_or("?"));
                return Some(format!(
                    "{} (closing edge first seen: {ctx})",
                    chain.join(" -> ")
                ));
            }
            if let Some(seen) = visited.get_mut(next) {
                if !*seen {
                    *seen = true;
                    let mut p = path.clone();
                    p.push(next);
                    stack.push((next, p));
                }
            }
        }
    }
    None
}

/// Record an acquisition attempt by this thread. Panics on a relock of
/// the same instance or on a lock-order cycle; called BEFORE blocking
/// on the underlying lock, so the report preempts the deadlock.
fn on_acquire(class: usize, instance: u64, name: &'static str) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if held.iter().any(|&(_, inst, _)| inst == instance) {
            panic!(
                "lock-audit: thread '{}' re-locking '{name}' which it \
                 already holds (self-deadlock)",
                thread_label()
            );
        }
        if !held.is_empty() {
            let mut g =
                graph().lock().unwrap_or_else(PoisonError::into_inner);
            for &(held_class, _, held_name) in held.iter() {
                g.edges.entry(held_class).or_default().entry(class).or_insert_with(
                    || {
                        format!(
                            "'{held_name}' held while acquiring '{name}' \
                             on thread '{}'",
                            thread_label()
                        )
                    },
                );
            }
            if let Some(cycle) = find_cycle(&g, class) {
                panic!(
                    "lock-audit: acquiring '{name}' on thread '{}' closes \
                     a lock-order cycle: {cycle}",
                    thread_label()
                );
            }
        }
        held.push((class, instance, name));
    });
}

/// The instance is no longer held by this thread (guard drop or the
/// release half of a condvar wait).
fn on_release(instance: u64) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) =
            held.iter().rposition(|&(_, inst, _)| inst == instance)
        {
            held.remove(pos);
        }
    });
}

fn thread_label() -> String {
    std::thread::current().name().unwrap_or("<unnamed>").to_string()
}

/// Panics if the calling thread holds any tracked lock. Asserted at
/// absorb / repair / checkpoint entry points: the runtime form of the
/// "no lock held across an absorb" invariant.
pub fn assert_lock_free(context: &str) {
    HELD.with(|h| {
        let held = h.borrow();
        if !held.is_empty() {
            let names: Vec<&str> =
                held.iter().map(|&(_, _, n)| n).collect();
            panic!(
                "lock-audit: {context} entered on thread '{}' while \
                 holding tracked lock(s): {}",
                thread_label(),
                names.join(", ")
            );
        }
    });
}

// --------------------------------------------------------------- Mutex

/// A named, order-tracked mutex with poison recovery.
pub struct Mutex<T> {
    inner: StdMutex<T>,
    name: &'static str,
    class: usize,
    instance: u64,
}

impl<T> Mutex<T> {
    pub fn new(name: &'static str, value: T) -> Mutex<T> {
        Mutex {
            inner: StdMutex::new(value),
            name,
            class: intern(name),
            instance: next_instance(),
        }
    }

    pub fn lock(&self) -> MutexGuard<'_, T> {
        on_acquire(self.class, self.instance, self.name);
        let inner =
            self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        MutexGuard {
            inner: Some(inner),
            class: self.class,
            instance: self.instance,
            name: self.name,
        }
    }
}

pub struct MutexGuard<'a, T> {
    /// `None` only transiently inside a condvar wait (the lock is
    /// released there; drop then does no release bookkeeping).
    inner: Option<std::sync::MutexGuard<'a, T>>,
    class: usize,
    instance: u64,
    name: &'static str,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_deref().expect("guard taken by condvar wait")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_deref_mut().expect("guard taken by condvar wait")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            on_release(self.instance);
        }
    }
}

// ------------------------------------------------------------- Condvar

/// Condvar over tracked [`MutexGuard`]s: the wait releases the lock in
/// the hold stack and the reacquire is re-checked like a fresh lock.
#[derive(Default)]
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar { inner: std::sync::Condvar::new() }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        let (class, instance, name) =
            (guard.class, guard.instance, guard.name);
        let inner = guard.inner.take().expect("guard taken by condvar wait");
        on_release(instance);
        drop(guard);
        let inner =
            self.inner.wait(inner).unwrap_or_else(PoisonError::into_inner);
        on_acquire(class, instance, name);
        MutexGuard { inner: Some(inner), class, instance, name }
    }

    /// Returns the reacquired guard and whether the wait timed out.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let (class, instance, name) =
            (guard.class, guard.instance, guard.name);
        let inner = guard.inner.take().expect("guard taken by condvar wait");
        on_release(instance);
        drop(guard);
        let (inner, timed_out) = match self.inner.wait_timeout(inner, dur) {
            Ok((g, t)) => (g, t.timed_out()),
            Err(poisoned) => {
                let (g, t) = poisoned.into_inner();
                (g, t.timed_out())
            }
        };
        on_acquire(class, instance, name);
        (MutexGuard { inner: Some(inner), class, instance, name }, timed_out)
    }
}

// -------------------------------------------------------------- RwLock

/// A named, order-tracked reader-writer lock with poison recovery.
/// Read and write acquisitions both participate in the order graph
/// (a read held across a write attempt deadlocks just as hard).
pub struct RwLock<T> {
    inner: std::sync::RwLock<T>,
    name: &'static str,
    class: usize,
    instance: u64,
}

impl<T> RwLock<T> {
    pub fn new(name: &'static str, value: T) -> RwLock<T> {
        RwLock {
            inner: std::sync::RwLock::new(value),
            name,
            class: intern(name),
            instance: next_instance(),
        }
    }

    pub fn read(&self) -> RwLockReadGuard<'_, T> {
        on_acquire(self.class, self.instance, self.name);
        let inner =
            self.inner.read().unwrap_or_else(PoisonError::into_inner);
        RwLockReadGuard { inner, instance: self.instance }
    }

    pub fn write(&self) -> RwLockWriteGuard<'_, T> {
        on_acquire(self.class, self.instance, self.name);
        let inner =
            self.inner.write().unwrap_or_else(PoisonError::into_inner);
        RwLockWriteGuard { inner, instance: self.instance }
    }
}

pub struct RwLockReadGuard<'a, T> {
    inner: std::sync::RwLockReadGuard<'a, T>,
    instance: u64,
}

impl<T> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        on_release(self.instance);
    }
}

pub struct RwLockWriteGuard<'a, T> {
    inner: std::sync::RwLockWriteGuard<'a, T>,
    instance: u64,
}

impl<T> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        on_release(self.instance);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;

    fn panics_with(f: impl FnOnce(), needle: &str) {
        let err = catch_unwind(AssertUnwindSafe(f))
            .expect_err("expected a lock-audit panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains(needle), "panic message {msg:?} lacks {needle:?}");
    }

    #[test]
    fn plain_lock_roundtrip_and_release() {
        let m = Mutex::new("t.roundtrip", 0i32);
        *m.lock() += 5;
        assert_eq!(*m.lock(), 5);
        // guard dropped each statement: nothing held now
        assert_lock_free("test");
    }

    #[test]
    fn relock_of_same_instance_panics() {
        let m = Mutex::new("t.relock", ());
        let _g = m.lock();
        panics_with(
            || {
                let _g2 = m.lock();
            },
            "re-locking",
        );
    }

    #[test]
    fn assert_lock_free_names_the_held_lock() {
        let m = Mutex::new("t.assert-free", ());
        let _g = m.lock();
        panics_with(|| assert_lock_free("absorb"), "t.assert-free");
    }

    #[test]
    fn abba_order_inversion_panics_with_chain() {
        let a = Arc::new(Mutex::new("t.abba-a", ()));
        let b = Arc::new(Mutex::new("t.abba-b", ()));
        // record a -> b on another thread (clean acquisition order)
        {
            let (a, b) = (Arc::clone(&a), Arc::clone(&b));
            std::thread::spawn(move || {
                let _ga = a.lock();
                let _gb = b.lock();
            })
            .join()
            .expect("ordered thread");
        }
        // the inverted order on this thread must be caught BEFORE it
        // can block, since a->b is already in the graph
        let _gb = b.lock();
        panics_with(
            || {
                let _ga = a.lock();
            },
            "lock-order cycle",
        );
    }

    #[test]
    fn nested_same_class_instances_count_as_a_cycle() {
        // two mailboxes have no consistent order between them
        let a = Mutex::new("t.same-class", ());
        let b = Mutex::new("t.same-class", ());
        let _ga = a.lock();
        panics_with(
            || {
                let _gb = b.lock();
            },
            "lock-order cycle",
        );
    }

    #[test]
    fn condvar_wait_timeout_releases_and_reacquires() {
        let m = Mutex::new("t.cv", 0i32);
        let cv = Condvar::new();
        let g = m.lock();
        let (mut g, timed_out) =
            cv.wait_timeout(g, Duration::from_millis(1));
        assert!(timed_out);
        *g += 1; // reacquired guard still works
        drop(g);
        assert_lock_free("after wait");
    }

    #[test]
    fn rwlock_read_then_distinct_write_orders_cleanly() {
        let r = RwLock::new("t.rw-a", 1);
        let w = RwLock::new("t.rw-b", 2);
        let g = r.read();
        let mut h = w.write(); // a->b edge, no cycle
        *h += *g;
        drop(h);
        drop(g);
        assert_lock_free("after rw");
    }
}
