//! Classification metrics: confusion matrix, MCC (the paper's Table-1
//! metric), precision/recall/F1 and ROC-AUC.
//!
//! MCC (Matthews Correlation Coefficient, Powers 2011 — the paper's
//! reference [27]) is the quality metric Table 1 reports; it remains
//! informative under the heavy class imbalance open-set evaluation sets
//! have, which is why the paper picks it.

/// Binary confusion counts (positive class = +1 "inside the slab").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: u64,
    pub tn: u64,
    pub fp: u64,
    pub fn_: u64,
}

impl Confusion {
    /// Tally from parallel label slices (+1/-1 each).
    pub fn from_labels(truth: &[i8], pred: &[i8]) -> Confusion {
        assert_eq!(truth.len(), pred.len());
        let mut c = Confusion::default();
        for (&t, &p) in truth.iter().zip(pred) {
            match (t > 0, p > 0) {
                (true, true) => c.tp += 1,
                (false, false) => c.tn += 1,
                (false, true) => c.fp += 1,
                (true, false) => c.fn_ += 1,
            }
        }
        c
    }

    pub fn total(&self) -> u64 {
        self.tp + self.tn + self.fp + self.fn_
    }

    pub fn accuracy(&self) -> f64 {
        if self.total() == 0 {
            return 0.0;
        }
        (self.tp + self.tn) as f64 / self.total() as f64
    }

    pub fn precision(&self) -> f64 {
        let d = self.tp + self.fp;
        if d == 0 {
            0.0
        } else {
            self.tp as f64 / d as f64
        }
    }

    pub fn recall(&self) -> f64 {
        let d = self.tp + self.fn_;
        if d == 0 {
            0.0
        } else {
            self.tp as f64 / d as f64
        }
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    /// Matthews Correlation Coefficient in [-1, 1]; 0 when any marginal
    /// is empty (the usual convention).
    pub fn mcc(&self) -> f64 {
        let (tp, tn, fp, fn_) =
            (self.tp as f64, self.tn as f64, self.fp as f64, self.fn_ as f64);
        let denom =
            ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
        if denom == 0.0 {
            0.0
        } else {
            (tp * tn - fp * fn_) / denom
        }
    }
}

/// Convenience: MCC straight from label slices.
pub fn mcc(truth: &[i8], pred: &[i8]) -> f64 {
    Confusion::from_labels(truth, pred).mcc()
}

/// ROC-AUC from real-valued scores (higher = more positive). Handles
/// ties by averaging ranks (equivalent to the Mann-Whitney U statistic).
pub fn roc_auc(truth: &[i8], scores: &[f64]) -> f64 {
    assert_eq!(truth.len(), scores.len());
    let n_pos = truth.iter().filter(|&&t| t > 0).count();
    let n_neg = truth.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // rank with tie-averaging
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
    let mut ranks = vec![0.0f64; scores.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    let sum_pos: f64 = truth
        .iter()
        .zip(&ranks)
        .filter(|(&t, _)| t > 0)
        .map(|(_, &r)| r)
        .sum();
    let u = sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0;
    u / (n_pos * n_neg) as f64
}

/// Balanced accuracy = (TPR + TNR) / 2 — robust to class imbalance.
pub fn balanced_accuracy(c: &Confusion) -> f64 {
    let tpr = if c.tp + c.fn_ == 0 {
        0.0
    } else {
        c.tp as f64 / (c.tp + c.fn_) as f64
    };
    let tnr = if c.tn + c.fp == 0 {
        0.0
    } else {
        c.tn as f64 / (c.tn + c.fp) as f64
    };
    0.5 * (tpr + tnr)
}

/// Area under the precision-recall curve (average precision, step
/// interpolation). Scores ranked descending; ties broken by index.
pub fn pr_auc(truth: &[i8], scores: &[f64]) -> f64 {
    assert_eq!(truth.len(), scores.len());
    let n_pos = truth.iter().filter(|&&t| t > 0).count();
    if n_pos == 0 {
        return 0.0;
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut tp = 0usize;
    let mut ap = 0.0;
    for (rank, &i) in idx.iter().enumerate() {
        if truth[i] > 0 {
            tp += 1;
            ap += tp as f64 / (rank + 1) as f64;
        }
    }
    ap / n_pos as f64
}

/// Precision/recall at a sweep of score thresholds (for PR curves in
/// reports). Returns (threshold, precision, recall) triples, descending
/// threshold.
pub fn pr_curve(truth: &[i8], scores: &[f64], points: usize) -> Vec<(f64, f64, f64)> {
    assert_eq!(truth.len(), scores.len());
    let mut sorted: Vec<f64> = scores.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut out = Vec::with_capacity(points);
    for p in 0..points {
        let k = ((p as f64 / (points - 1).max(1) as f64)
            * (sorted.len() - 1) as f64) as usize;
        let thr = sorted[k];
        let pred: Vec<i8> = scores
            .iter()
            .map(|&s| if s >= thr { 1 } else { -1 })
            .collect();
        let c = Confusion::from_labels(truth, &pred);
        out.push((thr, c.precision(), c.recall()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confusion_tally() {
        let truth = [1, 1, -1, -1, 1];
        let pred = [1, -1, -1, 1, 1];
        let c = Confusion::from_labels(&truth, &pred);
        assert_eq!(c, Confusion { tp: 2, tn: 1, fp: 1, fn_: 1 });
        assert!((c.accuracy() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn perfect_mcc_is_one() {
        let y = [1, -1, 1, -1];
        assert!((mcc(&y, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inverted_mcc_is_minus_one() {
        let y = [1, -1, 1, -1];
        let inv: Vec<i8> = y.iter().map(|&v| -v).collect();
        assert!((mcc(&y, &inv) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_mcc_near_zero() {
        // predictions independent of truth -> MCC ~ 0
        let mut rng = crate::util::rng::Rng::new(77);
        let truth: Vec<i8> =
            (0..5000).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let pred: Vec<i8> = (0..5000)
            .map(|_| if rng.uniform() < 0.5 { 1 } else { -1 })
            .collect();
        assert!(mcc(&truth, &pred).abs() < 0.05);
    }

    #[test]
    fn degenerate_marginals_give_zero() {
        assert_eq!(mcc(&[1, 1, 1], &[1, 1, 1]), 0.0); // no negatives
        assert_eq!(mcc(&[1, -1], &[1, 1]), 0.0); // pred all-positive
    }

    #[test]
    fn known_mcc_value() {
        // tp=90 tn=80 fp=20 fn=10
        let c = Confusion { tp: 90, tn: 80, fp: 20, fn_: 10 };
        let want = (90.0 * 80.0 - 20.0 * 10.0)
            / ((110.0f64) * 100.0 * 100.0 * 90.0).sqrt();
        assert!((c.mcc() - want).abs() < 1e-12);
    }

    #[test]
    fn f1_precision_recall() {
        let c = Confusion { tp: 8, tn: 5, fp: 2, fn_: 4 };
        assert!((c.precision() - 0.8).abs() < 1e-12);
        assert!((c.recall() - 8.0 / 12.0).abs() < 1e-12);
        let f1 = 2.0 * 0.8 * (8.0 / 12.0) / (0.8 + 8.0 / 12.0);
        assert!((c.f1() - f1).abs() < 1e-12);
    }

    #[test]
    fn auc_perfect_and_inverted() {
        let truth = [1, 1, -1, -1];
        assert!((roc_auc(&truth, &[0.9, 0.8, 0.2, 0.1]) - 1.0).abs() < 1e-12);
        assert!((roc_auc(&truth, &[0.1, 0.2, 0.8, 0.9]) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn auc_with_ties_is_half() {
        let truth = [1, -1, 1, -1];
        assert!((roc_auc(&truth, &[0.5, 0.5, 0.5, 0.5]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_known_value() {
        // scores: pos {0.8, 0.4}, neg {0.6, 0.2}
        // pairs won: (0.8>0.6)+(0.8>0.2)+(0.4<0.6 loses)+(0.4>0.2) = 3/4
        let truth = [1, 1, -1, -1];
        assert!((roc_auc(&truth, &[0.8, 0.4, 0.6, 0.2]) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_returns_half() {
        assert_eq!(roc_auc(&[1, 1], &[0.1, 0.9]), 0.5);
    }

    #[test]
    fn balanced_accuracy_values() {
        // perfect
        let c = Confusion { tp: 10, tn: 90, fp: 0, fn_: 0 };
        assert!((balanced_accuracy(&c) - 1.0).abs() < 1e-12);
        // all-positive predictor on imbalanced data: TPR=1, TNR=0 -> 0.5
        let c = Confusion { tp: 10, tn: 0, fp: 90, fn_: 0 };
        assert!((balanced_accuracy(&c) - 0.5).abs() < 1e-12);
        // degenerate empty marginals
        let c = Confusion { tp: 0, tn: 0, fp: 0, fn_: 0 };
        assert_eq!(balanced_accuracy(&c), 0.0);
    }

    #[test]
    fn pr_auc_perfect_ranking_is_one() {
        let truth = [1, 1, -1, -1];
        assert!((pr_auc(&truth, &[0.9, 0.8, 0.2, 0.1]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pr_auc_known_value() {
        // ranking: pos, neg, pos, neg -> AP = (1/1 + 2/3)/2 = 5/6
        let truth = [1, -1, 1, -1];
        let got = pr_auc(&truth, &[0.9, 0.8, 0.7, 0.6]);
        assert!((got - 5.0 / 6.0).abs() < 1e-12, "{got}");
    }

    #[test]
    fn pr_auc_no_positives_is_zero() {
        assert_eq!(pr_auc(&[-1, -1], &[0.1, 0.9]), 0.0);
    }

    #[test]
    fn pr_curve_monotone_recall() {
        let truth = [1, 1, 1, -1, -1, 1, -1, -1];
        let scores = [0.9, 0.85, 0.7, 0.65, 0.5, 0.45, 0.3, 0.1];
        let curve = pr_curve(&truth, &scores, 8);
        // recall is non-decreasing as the threshold drops
        for w in curve.windows(2) {
            assert!(w[1].2 >= w[0].2 - 1e-12, "recall decreased: {curve:?}");
        }
        // the loosest threshold has recall 1
        assert!((curve.last().unwrap().2 - 1.0).abs() < 1e-12);
    }
}
