//! Unified metrics registry + exposition.
//!
//! [`registry`] folds every [`ServiceStats`] counter and histogram
//! into one named-metric list; [`prometheus_text`] and [`json_lines`]
//! render that list generically, so adding a metric to the registry is
//! the *only* step needed to reach both export formats. Lint rule
//! [[R4]] enforces the converse: every `pub` field of `ServiceStats`
//! must appear in the registry builder below (and every registered
//! name must be a unique `slabsvm_`-prefixed identifier), so a counter
//! cannot exist without an export path. Formats are pinned by golden
//! tests in `rust/tests/obs_trace.rs`; front doors are
//! `Coordinator::metrics_text()` / `metrics_json()` and the `slabsvm
//! stats` CLI verb (DESIGN.md §8).

use crate::coordinator::stats::{Counter, Histogram, ServiceStats};
use crate::util::json::Json;

/// A metric's current value.
pub enum MetricValue {
    /// monotone counter
    Counter(u64),
    /// log-bucketed latency histogram: raw (non-cumulative) per-bucket
    /// counts as `(upper_bound_us, count)`, plus totals
    Histogram { buckets: Vec<(u64, u64)>, sum_us: u64, count: u64 },
}

/// One named metric in the registry.
pub struct Metric {
    /// Prometheus-legal name, always `slabsvm_`-prefixed
    pub name: &'static str,
    pub help: &'static str,
    pub value: MetricValue,
}

fn counter(name: &'static str, help: &'static str, c: &Counter) -> Metric {
    Metric { name, help, value: MetricValue::Counter(c.get()) }
}

fn histogram(name: &'static str, help: &'static str, h: &Histogram) -> Metric {
    let buckets = h
        .bucket_counts()
        .into_iter()
        .enumerate()
        .map(|(i, c)| (Histogram::bucket_bound(i), c))
        .collect();
    Metric {
        name,
        help,
        value: MetricValue::Histogram {
            buckets,
            sum_us: h.sum_us(),
            count: h.count(),
        },
    }
}

/// Build the full metric registry from the live service stats. Every
/// `ServiceStats` field maps to exactly one named metric here — rule
/// [[R4]] fails the lint if a field is added without a row below.
pub fn registry(stats: &ServiceStats) -> Vec<Metric> {
    vec![
        counter(
            "slabsvm_requests_total",
            "scoring requests accepted",
            &stats.requests,
        ),
        counter(
            "slabsvm_scored_total",
            "individual query points scored",
            &stats.scored,
        ),
        counter(
            "slabsvm_batches_total",
            "batches executed by the dynamic batcher",
            &stats.batches,
        ),
        counter(
            "slabsvm_errors_total",
            "scoring errors (unknown model etc.)",
            &stats.errors,
        ),
        counter(
            "slabsvm_jobs_done_total",
            "training jobs finished successfully",
            &stats.jobs_done,
        ),
        counter(
            "slabsvm_jobs_failed_total",
            "training jobs failed",
            &stats.jobs_failed,
        ),
        counter(
            "slabsvm_stream_pushes_total",
            "streamed samples enqueued through the session manager",
            &stats.stream_pushes,
        ),
        counter(
            "slabsvm_stream_absorbed_total",
            "streamed samples absorbed by shard workers",
            &stats.stream_absorbed,
        ),
        counter(
            "slabsvm_stream_backpressure_total",
            "producer waits on a full per-stream mailbox (50ms slices)",
            &stats.stream_backpressure,
        ),
        counter(
            "slabsvm_stream_absorb_errors_total",
            "streamed samples whose absorb failed after a successful push",
            &stats.stream_absorb_errors,
        ),
        counter(
            "slabsvm_stream_retrains_total",
            "background retrains escalated by shard workers",
            &stats.stream_retrains,
        ),
        counter(
            "slabsvm_stream_forgets_total",
            "samples removed by targeted unlearning",
            &stats.stream_forgets,
        ),
        counter(
            "slabsvm_stream_checkpoints_total",
            "session snapshots durably written",
            &stats.stream_checkpoints,
        ),
        counter(
            "slabsvm_stream_checkpoint_errors_total",
            "snapshot writes that failed",
            &stats.stream_checkpoint_errors,
        ),
        counter(
            "slabsvm_stream_restores_total",
            "sessions resumed from a snapshot by this process",
            &stats.stream_restores,
        ),
        counter(
            "slabsvm_serve_accepted_total",
            "HTTP requests admitted by the serving front door",
            &stats.serve_accepted,
        ),
        counter(
            "slabsvm_serve_shed_total",
            "HTTP requests shed with 429 (rate limit or saturated mailbox)",
            &stats.serve_shed,
        ),
        counter(
            "slabsvm_serve_auth_failed_total",
            "HTTP requests rejected 401 (bad or missing bearer token)",
            &stats.serve_auth_failed,
        ),
        counter(
            "slabsvm_serve_stale_served_total",
            "scoring requests answered from the last published model",
            &stats.serve_stale_served,
        ),
        histogram(
            "slabsvm_request_latency_us",
            "end-to-end scoring request latency (microseconds)",
            &stats.request_latency,
        ),
        histogram(
            "slabsvm_batch_latency_us",
            "per-batch execution latency (microseconds)",
            &stats.batch_latency,
        ),
        histogram(
            "slabsvm_absorb_latency_us",
            "per-sample incremental absorb latency (microseconds)",
            &stats.absorb_latency,
        ),
        histogram(
            "slabsvm_serve_latency_us",
            "HTTP request latency, parse to response written (microseconds)",
            &stats.serve_latency,
        ),
    ]
}

/// Prometheus text exposition (format version 0.0.4): `# HELP` /
/// `# TYPE` headers, counters as single samples, histograms as
/// cumulative `_bucket{le="…"}` series plus `_sum` / `_count`.
pub fn prometheus_text(metrics: &[Metric]) -> String {
    let mut out = String::new();
    for m in metrics {
        out.push_str(&format!("# HELP {} {}\n", m.name, m.help));
        match &m.value {
            MetricValue::Counter(v) => {
                out.push_str(&format!("# TYPE {} counter\n", m.name));
                out.push_str(&format!("{} {v}\n", m.name));
            }
            MetricValue::Histogram { buckets, sum_us, count } => {
                out.push_str(&format!("# TYPE {} histogram\n", m.name));
                let mut cumulative = 0u64;
                for (bound, c) in buckets {
                    cumulative += c;
                    out.push_str(&format!(
                        "{}_bucket{{le=\"{bound}\"}} {cumulative}\n",
                        m.name
                    ));
                }
                out.push_str(&format!(
                    "{}_bucket{{le=\"+Inf\"}} {count}\n",
                    m.name
                ));
                out.push_str(&format!("{}_sum {sum_us}\n", m.name));
                out.push_str(&format!("{}_count {count}\n", m.name));
            }
        }
    }
    out
}

/// JSON-line exposition: one canonical-JSON object per metric. Counter
/// lines carry `name`/`type`/`value`; histogram lines carry
/// `name`/`type`/`count`/`sum_us` plus raw (non-cumulative)
/// `[upper_bound_us, count]` bucket pairs.
pub fn json_lines(metrics: &[Metric]) -> String {
    let mut out = String::new();
    for m in metrics {
        let json = match &m.value {
            MetricValue::Counter(v) => Json::obj(vec![
                ("name", Json::str(m.name)),
                ("type", Json::str("counter")),
                ("value", Json::num(*v as f64)),
            ]),
            MetricValue::Histogram { buckets, sum_us, count } => Json::obj(vec![
                ("name", Json::str(m.name)),
                ("type", Json::str("histogram")),
                ("count", Json::num(*count as f64)),
                ("sum_us", Json::num(*sum_us as f64)),
                (
                    "buckets",
                    Json::arr(
                        buckets
                            .iter()
                            .map(|&(bound, c)| {
                                Json::arr(vec![
                                    Json::num(bound as f64),
                                    Json::num(c as f64),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        };
        out.push_str(&json.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_stats_field() {
        let stats = ServiceStats::new();
        let metrics = registry(&stats);
        // 19 counters + 4 histograms — a new ServiceStats field must
        // grow this registry (rule [[R4]] checks the same lexically)
        assert_eq!(metrics.len(), 23);
        let mut names: Vec<&str> = metrics.iter().map(|m| m.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 23, "metric names must be unique");
        assert!(metrics.iter().all(|m| m.name.starts_with("slabsvm_")));
    }

    #[test]
    fn prometheus_counter_and_histogram_shape() {
        let stats = ServiceStats::new();
        stats.requests.add(3);
        stats.absorb_latency.record_us(100);
        let text = prometheus_text(&registry(&stats));
        assert!(text.contains("# TYPE slabsvm_requests_total counter"));
        assert!(text.contains("slabsvm_requests_total 3\n"));
        assert!(text.contains("# TYPE slabsvm_absorb_latency_us histogram"));
        assert!(text
            .contains("slabsvm_absorb_latency_us_bucket{le=\"128\"} 1\n"));
        assert!(text.contains("slabsvm_absorb_latency_us_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("slabsvm_absorb_latency_us_sum 100\n"));
        assert!(text.contains("slabsvm_absorb_latency_us_count 1\n"));
    }

    #[test]
    fn json_lines_parse_back() {
        let stats = ServiceStats::new();
        stats.scored.add(7);
        let lines = json_lines(&registry(&stats));
        for line in lines.lines() {
            let parsed = Json::parse(line).expect("every line parses");
            assert!(parsed.to_string().contains("slabsvm_"));
        }
        assert_eq!(lines.lines().count(), 23);
    }
}
