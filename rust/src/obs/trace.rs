//! Span tracing: decompose one `Coordinator::push` into named stages.
//!
//! A trace id is minted at push time ([`mint_trace`]), rides through
//! the owning shard's mailbox alongside the sample, and every stage of
//! the absorb chain records a [`Span`] against it. The stage intervals
//! are **contiguous by construction** — `Queue` ends on the same
//! timestamp `Absorb` starts on, and `Absorb` ends where `Publish`
//! starts — so `queue + absorb + publish` equals the observed
//! enqueue→published latency exactly (the acceptance bound in
//! ISSUE 7 / DESIGN.md §8). `Gram` and `Repair` are sub-spans *inside*
//! `Absorb` (the admit/Gram-maintenance part of `IncrementalSmo::push`
//! vs the warm-started repair sweep) and carry the solver's
//! [`SolveStats`](crate::solver::SolveStats) iteration count.
//!
//! Storage is one global fixed-capacity ring ([`SPAN_CAP`]) of seqlock
//! slots: writers claim an index with a fetch-add and publish with a
//! per-slot sequence word, readers skip torn or overwritten entries —
//! no locks anywhere, and the whole layer is gated on the same switch
//! as the flight recorder ([`super::recorder::enabled`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::recorder::{enabled, stream_name};
use crate::util::json::Json;

/// Spans retained; oldest entries are overwritten.
pub const SPAN_CAP: usize = 8192;

/// Named stages of a push's life (and the serving/train side-channels).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Stage {
    /// enqueue → popped by the owning shard worker
    Queue,
    /// popped → session absorb returned (covers Gram + Repair)
    Absorb,
    /// admit/Gram-maintenance part of the absorb (sub-span)
    Gram,
    /// warm-started SMO repair sweep (sub-span; `iters` = pair updates)
    Repair,
    /// absorb returned → model hot-swapped in the registry
    Publish,
    /// scoring request enqueue → batch execution start (serving side)
    ScoreQueue,
    /// batch execution on the engine (serving side)
    Score,
    /// background full retrain (`Trainer::fit`; `iters` = iterations)
    Retrain,
    /// one HTTP request, parse → response written (serving front door);
    /// a push request's Queue/Absorb spans share its trace id, so the
    /// request→queue→absorb chain groups under one trace
    Request,
}

impl Stage {
    const ALL: [Stage; 9] = [
        Stage::Queue,
        Stage::Absorb,
        Stage::Gram,
        Stage::Repair,
        Stage::Publish,
        Stage::ScoreQueue,
        Stage::Score,
        Stage::Retrain,
        Stage::Request,
    ];

    fn code(self) -> u64 {
        Self::ALL.iter().position(|&s| s == self).unwrap_or(0) as u64
    }

    fn from_code(c: u64) -> Stage {
        *Self::ALL.get(c as usize).unwrap_or(&Stage::Queue)
    }

    /// Stable snake_case name used by the JSONL export.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Absorb => "absorb",
            Stage::Gram => "gram",
            Stage::Repair => "repair",
            Stage::Publish => "publish",
            Stage::ScoreQueue => "score_queue",
            Stage::Score => "score",
            Stage::Retrain => "retrain",
            Stage::Request => "request",
        }
    }
}

/// One timed stage of a trace.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    /// trace id minted at push time (0 = untraced background work)
    pub trace: u64,
    pub stage: Stage,
    /// start on the [`super::recorder::now_us`] clock
    pub start_us: u64,
    pub dur_us: u64,
    /// interned stream id (see [`super::recorder::stream_id`])
    pub stream: u64,
    /// owning shard index (u32::MAX = not shard work)
    pub shard: u32,
    /// solver iterations attached to Repair/Absorb/Retrain spans
    pub iters: u64,
}

impl Span {
    /// Exclusive end timestamp.
    pub fn end_us(&self) -> u64 {
        self.start_us + self.dur_us
    }

    /// Compact JSON object (one line of `slabsvm trace` output).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("stage", Json::str(self.stage.name())),
            ("trace", Json::num(self.trace as f64)),
            ("start_us", Json::num(self.start_us as f64)),
            ("dur_us", Json::num(self.dur_us as f64)),
            ("iters", Json::num(self.iters as f64)),
        ];
        if let Some(name) = stream_name(self.stream) {
            fields.push(("stream", Json::str(&name)));
        }
        if self.shard != u32::MAX {
            fields.push(("shard", Json::num(self.shard as f64)));
        }
        Json::obj(fields)
    }
}

// ------------------------------------------------------------- span ring

/// Seqlock slot, same protocol as the recorder's event rings but
/// multi-writer: the index claimed from `HEAD` by fetch-add names the
/// slot generation, so a reader validating `seq == 2*i + 2` can never
/// accept a half-written entry.
struct Slot {
    seq: AtomicU64,
    trace: AtomicU64,
    /// stage code low 32 bits, shard high 32
    meta: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    stream: AtomicU64,
    iters: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            trace: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
            stream: AtomicU64::new(0),
            iters: AtomicU64::new(0),
        }
    }
}

struct SpanRing {
    slots: Vec<Slot>,
    head: AtomicU64,
}

fn ring() -> &'static SpanRing {
    static RING: OnceLock<SpanRing> = OnceLock::new();
    RING.get_or_init(|| SpanRing {
        slots: (0..SPAN_CAP).map(|_| Slot::new()).collect(),
        head: AtomicU64::new(0),
    })
}

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Mint a fresh nonzero trace id; returns 0 (untraced) while the
/// recorder is disabled so the whole chain stays dark.
#[inline]
pub fn mint_trace() -> u64 {
    if !enabled() {
        return 0;
    }
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// Record one span. No-op while disabled; otherwise a fetch-add plus
/// seven atomic stores — lock-free and allocation-free.
#[inline]
pub fn record_span(span: Span) {
    if !enabled() {
        return;
    }
    let r = ring();
    let h = r.head.fetch_add(1, Ordering::Relaxed);
    let Some(slot) = r.slots.get(h as usize % SPAN_CAP) else {
        return;
    };
    slot.seq.store(2 * h + 1, Ordering::Release);
    slot.trace.store(span.trace, Ordering::Relaxed);
    slot.meta.store(
        span.stage.code() | ((span.shard as u64) << 32),
        Ordering::Relaxed,
    );
    slot.start_us.store(span.start_us, Ordering::Relaxed);
    slot.dur_us.store(span.dur_us, Ordering::Relaxed);
    slot.stream.store(span.stream, Ordering::Relaxed);
    slot.iters.store(span.iters, Ordering::Relaxed);
    slot.seq.store(2 * h + 2, Ordering::Release);
}

fn snapshot() -> Vec<Span> {
    let r = ring();
    let h = r.head.load(Ordering::Acquire);
    let n = h.min(SPAN_CAP as u64);
    let mut out = Vec::with_capacity(n as usize);
    for i in (h - n)..h {
        let Some(slot) = r.slots.get(i as usize % SPAN_CAP) else {
            continue;
        };
        if slot.seq.load(Ordering::Acquire) != 2 * i + 2 {
            continue;
        }
        let meta = slot.meta.load(Ordering::Relaxed);
        let span = Span {
            trace: slot.trace.load(Ordering::Relaxed),
            stage: Stage::from_code(meta & 0xffff_ffff),
            start_us: slot.start_us.load(Ordering::Relaxed),
            dur_us: slot.dur_us.load(Ordering::Relaxed),
            stream: slot.stream.load(Ordering::Relaxed),
            shard: (meta >> 32) as u32,
            iters: slot.iters.load(Ordering::Relaxed),
        };
        if slot.seq.load(Ordering::Acquire) == 2 * i + 2 {
            out.push(span);
        }
    }
    out
}

/// The most recent spans (up to `limit`), oldest first.
pub fn recent_spans(limit: usize) -> Vec<Span> {
    let mut spans = snapshot();
    spans.sort_by_key(|s| s.start_us);
    if spans.len() > limit {
        spans.drain(..spans.len() - limit);
    }
    spans
}

/// All retained spans of one trace, ordered by start time.
pub fn spans_for(trace: u64) -> Vec<Span> {
    let mut spans: Vec<Span> =
        snapshot().into_iter().filter(|s| s.trace == trace).collect();
    spans.sort_by_key(|s| s.start_us);
    spans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::set_enabled;

    #[test]
    fn mint_is_monotone_and_gated() {
        set_enabled(false);
        assert_eq!(mint_trace(), 0);
        set_enabled(true);
        let a = mint_trace();
        let b = mint_trace();
        assert!(b > a && a > 0);
    }

    #[test]
    fn spans_group_by_trace() {
        set_enabled(true);
        let t = mint_trace();
        record_span(Span {
            trace: t,
            stage: Stage::Queue,
            start_us: 100,
            dur_us: 5,
            stream: 1,
            shard: 0,
            iters: 0,
        });
        record_span(Span {
            trace: t,
            stage: Stage::Absorb,
            start_us: 105,
            dur_us: 40,
            stream: 1,
            shard: 0,
            iters: 12,
        });
        let chain = spans_for(t);
        assert_eq!(chain.len(), 2);
        assert_eq!(chain[0].stage, Stage::Queue);
        assert_eq!(chain[0].end_us(), chain[1].start_us, "contiguous");
        assert_eq!(chain[1].iters, 12);
    }

    #[test]
    fn stage_codes_round_trip() {
        for s in Stage::ALL {
            assert_eq!(Stage::from_code(s.code()), s);
            assert!(!s.name().is_empty());
        }
    }
}
