//! Flight recorder: per-thread lock-free ring buffers of typed events.
//!
//! Every thread that records gets its own fixed-capacity ring of
//! seqlock slots (single writer — the owning thread; readers validate
//! a per-slot sequence word and skip torn or overwritten entries), so
//! the absorb hot path never contends on a shared lock or allocates
//! after the ring's one-time registration. The global registry of
//! rings (and the stream-name intern table) is behind a mutex touched
//! only at registration and drain time, never per event.
//!
//! The whole layer is gated on one relaxed [`AtomicBool`]: with the
//! recorder disabled, [`record`] is a single load-and-return — no
//! clock read, no TLS access, no allocation (rule [[R3]] keeps the
//! absorb loops themselves allocation-free either way). Enable via
//! [`set_enabled`] or the `SLABSVM_OBS=1` environment variable
//! (checked once at coordinator start, see [`init_from_env`]).
//!
//! Sizing: [`RING_CAP`] = 4096 events/thread × 6 u64 words/slot =
//! 192 KiB per recording thread, overwriting oldest-first — enough to
//! hold the last few seconds of a busy shard worker, which is the
//! window a postmortem actually needs. Policy and taxonomy live in
//! DESIGN.md §8.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::sync::Mutex;
use crate::util::json::Json;

/// Events per thread ring; oldest entries are overwritten.
pub const RING_CAP: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Is the flight recorder (and span tracer) currently recording?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on or off. Events recorded while off are simply
/// not captured; nothing buffers or blocks.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Enable the recorder when `SLABSVM_OBS` is set to `1`/`true`.
/// Called by `Coordinator::start*`; idempotent, never disables.
pub fn init_from_env() {
    if matches!(
        std::env::var("SLABSVM_OBS").as_deref(),
        Ok("1") | Ok("true")
    ) {
        set_enabled(true);
    }
}

/// Monotonic microseconds since the process-wide recorder epoch (the
/// first call). All event and span timestamps share this clock.
#[inline]
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Typed event kinds — the flight-recorder taxonomy (DESIGN.md §8).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EventKind {
    /// sample accepted into a shard mailbox (`Coordinator::push`)
    PushEnqueued,
    /// shard worker began absorbing a sample into its session
    AbsorbStart,
    /// absorb finished and the model was hot-swapped
    AbsorbEnd,
    /// warm-started repair sweep finished; `value` = SMO iterations
    RepairIters,
    /// background retrain handed to the train queue; `value` = job id
    RetrainSubmitted,
    /// retrain result published to the registry; `value` = version
    RetrainPublished,
    /// retrain cancelled before publish; `value` = job id
    RetrainCancelled,
    /// session checkpoint durably written
    CheckpointWritten,
    /// window eviction chose a victim; `value` = evicted sample id
    Evict,
    /// targeted unlearning removed a sample; `value` = sample id
    Forget,
    /// producer blocked on a full per-stream mailbox (one per 50 ms
    /// wait slice, mirroring `stream_backpressure`)
    MailboxBlocked,
    /// shard worker loop exited (drain/shutdown)
    WorkerExit,
    /// a typed error surfaced on the streaming data plane
    ErrorRaised,
}

impl EventKind {
    const ALL: [EventKind; 13] = [
        EventKind::PushEnqueued,
        EventKind::AbsorbStart,
        EventKind::AbsorbEnd,
        EventKind::RepairIters,
        EventKind::RetrainSubmitted,
        EventKind::RetrainPublished,
        EventKind::RetrainCancelled,
        EventKind::CheckpointWritten,
        EventKind::Evict,
        EventKind::Forget,
        EventKind::MailboxBlocked,
        EventKind::WorkerExit,
        EventKind::ErrorRaised,
    ];

    fn code(self) -> u64 {
        Self::ALL.iter().position(|&k| k == self).unwrap_or(0) as u64
    }

    fn from_code(c: u64) -> EventKind {
        *Self::ALL.get(c as usize).unwrap_or(&EventKind::ErrorRaised)
    }

    /// Stable snake_case name used by the JSONL export.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::PushEnqueued => "push_enqueued",
            EventKind::AbsorbStart => "absorb_start",
            EventKind::AbsorbEnd => "absorb_end",
            EventKind::RepairIters => "repair_iters",
            EventKind::RetrainSubmitted => "retrain_submitted",
            EventKind::RetrainPublished => "retrain_published",
            EventKind::RetrainCancelled => "retrain_cancelled",
            EventKind::CheckpointWritten => "checkpoint_written",
            EventKind::Evict => "evict",
            EventKind::Forget => "forget",
            EventKind::MailboxBlocked => "mailbox_blocked",
            EventKind::WorkerExit => "worker_exit",
            EventKind::ErrorRaised => "error_raised",
        }
    }
}

/// One drained event, timestamped on the [`now_us`] clock.
#[derive(Clone, Debug)]
pub struct EventRecord {
    pub t_us: u64,
    pub kind: EventKind,
    /// trace id minted at push time (0 = untraced)
    pub trace: u64,
    /// FNV-1a hash of the stream name (0 = no stream); resolve with
    /// [`stream_name`]
    pub stream: u64,
    /// shard index the recording worker owns (u32::MAX = not a shard)
    pub shard: u32,
    /// kind-specific payload (iterations, version, sample id, …)
    pub value: u64,
}

impl EventRecord {
    /// Compact JSON object (one line of the postmortem / trace dump).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("event", Json::str(self.kind.name())),
            ("t_us", Json::num(self.t_us as f64)),
            ("trace", Json::num(self.trace as f64)),
            ("value", Json::num(self.value as f64)),
        ];
        if let Some(name) = stream_name(self.stream) {
            fields.push(("stream", Json::str(&name)));
        }
        if self.shard != u32::MAX {
            fields.push(("shard", Json::num(self.shard as f64)));
        }
        Json::obj(fields)
    }
}

// ------------------------------------------------------------- seqlock ring

/// One seqlock slot: `seq` is odd while the writer is mid-update and
/// `2*i + 2` once entry `i` is stable; readers re-check it around the
/// field loads and skip anything torn or overwritten.
struct Slot {
    seq: AtomicU64,
    t_us: AtomicU64,
    /// kind code in the low 32 bits, shard index in the high 32
    meta: AtomicU64,
    trace: AtomicU64,
    stream: AtomicU64,
    value: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            t_us: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            trace: AtomicU64::new(0),
            stream: AtomicU64::new(0),
            value: AtomicU64::new(0),
        }
    }
}

struct ThreadRing {
    slots: Vec<Slot>,
    /// entries ever written; the owning thread is the only writer
    head: AtomicU64,
}

impl ThreadRing {
    fn new() -> ThreadRing {
        ThreadRing {
            slots: (0..RING_CAP).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
        }
    }

    /// Single-writer append (owning thread only).
    fn write(&self, ev: &EventRecord) {
        let h = self.head.load(Ordering::Relaxed);
        let Some(slot) = self.slots.get(h as usize % RING_CAP) else {
            return;
        };
        slot.seq.store(2 * h + 1, Ordering::Release);
        slot.t_us.store(ev.t_us, Ordering::Relaxed);
        slot.meta.store(
            ev.kind.code() | ((ev.shard as u64) << 32),
            Ordering::Relaxed,
        );
        slot.trace.store(ev.trace, Ordering::Relaxed);
        slot.stream.store(ev.stream, Ordering::Relaxed);
        slot.value.store(ev.value, Ordering::Relaxed);
        slot.seq.store(2 * h + 2, Ordering::Release);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Best-effort snapshot: entries overwritten or mid-write while we
    /// read are skipped, never torn.
    fn snapshot(&self, out: &mut Vec<EventRecord>) {
        let h = self.head.load(Ordering::Acquire);
        let n = h.min(RING_CAP as u64);
        for i in (h - n)..h {
            let Some(slot) = self.slots.get(i as usize % RING_CAP) else {
                continue;
            };
            if slot.seq.load(Ordering::Acquire) != 2 * i + 2 {
                continue;
            }
            let rec = EventRecord {
                t_us: slot.t_us.load(Ordering::Relaxed),
                kind: EventKind::from_code(
                    slot.meta.load(Ordering::Relaxed) & 0xffff_ffff,
                ),
                trace: slot.trace.load(Ordering::Relaxed),
                stream: slot.stream.load(Ordering::Relaxed),
                shard: (slot.meta.load(Ordering::Relaxed) >> 32) as u32,
                value: slot.value.load(Ordering::Relaxed),
            };
            if slot.seq.load(Ordering::Acquire) == 2 * i + 2 {
                out.push(rec);
            }
        }
    }
}

// --------------------------------------------------------- global registry

struct Registry {
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    names: Mutex<Vec<(u64, String)>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        rings: Mutex::new("obs-rings", Vec::new()),
        names: Mutex::new("obs-names", Vec::new()),
    })
}

thread_local! {
    static RING: Arc<ThreadRing> = {
        let ring = Arc::new(ThreadRing::new());
        registry().rings.lock().push(Arc::clone(&ring));
        ring
    };
}

/// Record one event. A no-op (one relaxed atomic load) while the
/// recorder is disabled; otherwise a clock read plus six atomic stores
/// into the calling thread's own ring — no locks, no allocation after
/// the thread's first event.
#[inline]
pub fn record(kind: EventKind, trace: u64, stream: u64, shard: u32, value: u64) {
    if !enabled() {
        return;
    }
    let rec = EventRecord { t_us: now_us(), kind, trace, stream, shard, value };
    RING.with(|r| r.write(&rec));
}

/// FNV-1a hash of a stream name — the `stream` id events carry.
pub fn stream_id(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Intern a stream name so drained events and spans resolve back to
/// it. Cold path only (stream open / session creation) — takes the
/// name-table mutex.
pub fn intern_stream(name: &str) -> u64 {
    let id = stream_id(name);
    let mut names = registry().names.lock();
    if !names.iter().any(|(i, _)| *i == id) {
        names.push((id, name.to_string()));
    }
    id
}

/// Resolve an interned stream id back to its name.
pub fn stream_name(id: u64) -> Option<String> {
    if id == 0 {
        return None;
    }
    registry()
        .names
        .lock()
        .iter()
        .find(|(i, _)| *i == id)
        .map(|(_, n)| n.clone())
}

/// Snapshot every thread's ring, merged and sorted by timestamp.
/// Non-destructive: rings keep their contents (they are bounded and
/// overwrite oldest-first, so there is nothing to reclaim).
pub fn drain_events() -> Vec<EventRecord> {
    let mut out = Vec::new();
    for ring in registry().rings.lock().iter() {
        ring.snapshot(&mut out);
    }
    out.sort_by_key(|e| e.t_us);
    out
}

/// Dump the current event buffer as JSONL for postmortem analysis —
/// called when a shard worker dies or a typed error surfaces on the
/// data plane. Returns the path written, or `None` when the recorder
/// is off, the buffer is empty, or the write fails (logged, never a
/// panic: the dump must not take the failing worker down harder).
pub fn postmortem_dump(label: &str) -> Option<std::path::PathBuf> {
    if !enabled() {
        return None;
    }
    let events = drain_events();
    if events.is_empty() {
        return None;
    }
    let dir = std::env::var("SLABSVM_POSTMORTEM_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::env::temp_dir());
    let path = dir.join(format!(
        "slabsvm-postmortem-{}-{label}.jsonl",
        std::process::id()
    ));
    let mut body = String::new();
    for e in &events {
        body.push_str(&e.to_json().to_string());
        body.push('\n');
    }
    match std::fs::write(&path, body) {
        Ok(()) => {
            crate::log_warn!(
                "obs",
                "postmortem: {} events dumped to {}",
                events.len(),
                path.display()
            );
            Some(path)
        }
        Err(e) => {
            crate::log_warn!(
                "obs",
                "postmortem dump to {} failed: {e}",
                path.display()
            );
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_drops_events() {
        set_enabled(false);
        record(EventKind::Evict, 1, 2, 3, 99);
        // no assertion on global state (other tests record concurrently);
        // the contract is simply that this returns without touching TLS
    }

    #[test]
    fn record_and_drain_round_trip() {
        set_enabled(true);
        let stream = intern_stream("rec-test-stream");
        record(EventKind::CheckpointWritten, 7, stream, 4, 42);
        let events = drain_events();
        let mine: Vec<_> = events
            .iter()
            .filter(|e| e.stream == stream && e.trace == 7)
            .collect();
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].kind, EventKind::CheckpointWritten);
        assert_eq!(mine[0].shard, 4);
        assert_eq!(mine[0].value, 42);
        assert_eq!(
            stream_name(stream).as_deref(),
            Some("rec-test-stream")
        );
    }

    #[test]
    fn ring_overwrites_oldest() {
        let ring = ThreadRing::new();
        for i in 0..(RING_CAP as u64 + 10) {
            ring.write(&EventRecord {
                t_us: i,
                kind: EventKind::Evict,
                trace: 0,
                stream: 0,
                shard: 0,
                value: i,
            });
        }
        let mut out = Vec::new();
        ring.snapshot(&mut out);
        assert_eq!(out.len(), RING_CAP);
        assert_eq!(out[0].value, 10, "oldest 10 overwritten");
    }

    #[test]
    fn kind_codes_round_trip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_code(k.code()), k);
            assert!(!k.name().is_empty());
        }
    }

    #[test]
    fn event_json_shape() {
        set_enabled(true);
        let stream = intern_stream("json-shape");
        let e = EventRecord {
            t_us: 5,
            kind: EventKind::RepairIters,
            trace: 9,
            stream,
            shard: 1,
            value: 17,
        };
        let line = e.to_json().to_string();
        assert!(line.contains("\"event\":\"repair_iters\""), "{line}");
        assert!(line.contains("\"stream\":\"json-shape\""), "{line}");
    }
}
