//! Observability for the serving stack (DESIGN.md §8): flight
//! recorder, span tracing, metrics export — dependency-free, std-only.
//!
//! Three layers over one on/off switch:
//!
//! * [`recorder`] — per-thread lock-free ring buffers of typed events
//!   ([`EventKind`]: push/absorb/repair/retrain/checkpoint/evict/
//!   forget/backpressure), stamped with monotonic microseconds and
//!   stream/shard ids, drainable on demand ([`drain_events`]) and
//!   auto-dumped to a JSONL postmortem file when a typed error
//!   surfaces on the streaming data plane ([`postmortem_dump`]).
//! * [`trace`] — a trace id minted at `Coordinator::push`
//!   ([`mint_trace`]) rides the mailbox into the owning shard's
//!   absorb→repair→hot-swap chain; each stage records a [`Span`]
//!   whose intervals are contiguous, so `queue + absorb + publish`
//!   reconstructs the end-to-end push latency exactly, with solver
//!   iteration counts attached to the repair spans.
//! * [`export`] — every [`ServiceStats`](crate::coordinator::stats::ServiceStats)
//!   counter and histogram folded into a named-metric [`registry`]
//!   with Prometheus text ([`prometheus_text`]) and JSON-line
//!   ([`json_lines`]) exposition; `Coordinator::metrics_text()` and
//!   the `slabsvm stats` / `slabsvm trace` CLI verbs are the front
//!   doors.
//!
//! Overhead policy: everything gates on one relaxed atomic bool
//! ([`enabled`], default **off**, opt in via [`set_enabled`] or
//! `SLABSVM_OBS=1`). Disabled, [`record`]/[`record_span`] are a load
//! and a return — the absorb hot path stays allocation-free either
//! way (rule [[R3]]). Enabled, an event is a clock read plus a few
//! relaxed stores into a seqlock ring; nothing on the data plane ever
//! takes a lock or allocates per event.

pub mod export;
pub mod recorder;
pub mod trace;

pub use export::{json_lines, prometheus_text, registry, Metric, MetricValue};
pub use recorder::{
    drain_events, enabled, init_from_env, intern_stream, now_us,
    postmortem_dump, record, set_enabled, stream_id, stream_name, EventKind,
    EventRecord,
};
pub use trace::{
    mint_trace, recent_spans, record_span, spans_for, Span, Stage,
};
