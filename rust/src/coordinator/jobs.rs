//! Asynchronous training-job queue.
//!
//! `submit` enqueues a [`TrainRequest`]; a dedicated trainer thread runs
//! jobs FIFO (training is CPU-saturating, so one at a time keeps tail
//! latency of the scoring path sane), registers the resulting model in
//! the shared [`ModelRegistry`] and flips the job's [`JobStatus`].

use std::collections::HashMap;
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;

use crate::sync::{Condvar, Mutex};

use super::registry::ModelRegistry;
use super::stats::ServiceStats;
use crate::data::Dataset;
use crate::solver::api::Trainer;

/// Opaque job handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

/// Lifecycle of a training job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Queued,
    Running,
    Done {
        /// registry version the model was stored under
        version: u64,
        /// SMO iterations
        iterations: usize,
        /// training seconds
        seconds: f64,
        /// support vectors in the final model
        n_sv: usize,
    },
    Failed {
        error: String,
    },
    /// Superseded via [`TrainQueue::cancel`] before it could publish:
    /// its model (possibly fit on since-deleted data) never reaches
    /// the registry. Terminal, like `Done`/`Failed`.
    Cancelled,
}

/// A training job: any [`Trainer`] configuration (solver kind, kernel,
/// layers) runs through the unified `fit` path.
pub struct TrainRequest {
    /// registry name for the resulting model
    pub name: String,
    pub dataset: Dataset,
    pub trainer: Trainer,
}

enum Msg {
    Job(JobId, TrainRequest),
    Shutdown,
}

/// Handle to the trainer thread. Shared behind an `Arc` by the
/// coordinator and the stream-manager shard workers (which submit
/// drift-escalated retrains), so `shutdown` takes `&self`.
pub struct TrainQueue {
    tx: Sender<Msg>,
    state: Arc<(Mutex<HashMap<JobId, JobStatus>>, Condvar)>,
    next_id: Mutex<u64>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl TrainQueue {
    pub fn start(registry: Arc<ModelRegistry>, stats: Arc<ServiceStats>) -> TrainQueue {
        let (tx, rx) = mpsc::channel::<Msg>();
        let state: Arc<(Mutex<HashMap<JobId, JobStatus>>, Condvar)> =
            Arc::new((Mutex::new("jobs.state", HashMap::new()), Condvar::new()));
        let state2 = Arc::clone(&state);
        let worker = std::thread::Builder::new()
            .name("slabsvm-trainer".into())
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    let (id, req) = match msg {
                        Msg::Job(id, req) => (id, req),
                        Msg::Shutdown => break,
                    };
                    // Queued -> Running only if not already cancelled —
                    // one critical section, so a concurrent cancel()
                    // either lands before (job skipped) or after (the
                    // post-fit check below catches it).
                    let cancelled = {
                        let mut map = state2.0.lock();
                        if matches!(map.get(&id), Some(JobStatus::Cancelled))
                        {
                            true
                        } else {
                            map.insert(id, JobStatus::Running);
                            false
                        }
                    };
                    if cancelled {
                        continue;
                    }
                    let result = req.trainer.fit(&req.dataset.x);
                    // Publish-or-discard atomically with the status: a
                    // cancel that landed while the fit ran means this
                    // model was trained on data that has since been
                    // deleted or replaced — it must never reach the
                    // registry.
                    let (lock, cvar) = &*state2;
                    let mut map = lock.lock();
                    if matches!(map.get(&id), Some(JobStatus::Cancelled)) {
                        cvar.notify_all();
                        continue;
                    }
                    let status = match result {
                        Ok(report) => {
                            let n_sv = report.model.n_sv();
                            let version = registry.insert(&req.name, report.model);
                            stats.jobs_done.inc();
                            // value = the registry version published
                            crate::obs::record(
                                crate::obs::EventKind::RetrainPublished,
                                0,
                                crate::obs::stream_id(&req.name),
                                u32::MAX,
                                version,
                            );
                            JobStatus::Done {
                                version,
                                iterations: report.stats.iterations,
                                seconds: report.stats.seconds,
                                n_sv,
                            }
                        }
                        Err(e) => {
                            stats.jobs_failed.inc();
                            JobStatus::Failed { error: e.to_string() }
                        }
                    };
                    map.insert(id, status);
                    cvar.notify_all();
                }
            })
            .expect("spawn trainer");
        TrainQueue {
            tx,
            state,
            next_id: Mutex::new("jobs.next_id", 1),
            worker: Mutex::new("jobs.worker", Some(worker)),
        }
    }

    /// Enqueue a job, returning its handle immediately.
    pub fn submit(&self, req: TrainRequest) -> JobId {
        let id = {
            let mut n = self.next_id.lock();
            let id = JobId(*n);
            *n += 1;
            id
        };
        // value = the job id, so Submitted/Published/Cancelled events
        // for one retrain correlate in a drained flight recording
        crate::obs::record(
            crate::obs::EventKind::RetrainSubmitted,
            0,
            crate::obs::stream_id(&req.name),
            u32::MAX,
            id.0,
        );
        set_status(&self.state, id, JobStatus::Queued);
        // if the worker is gone the status stays Queued; callers polling
        // wait() would block, so record failure instead
        if self.tx.send(Msg::Job(id, req)).is_err() {
            set_status(
                &self.state,
                id,
                JobStatus::Failed { error: "trainer stopped".into() },
            );
        }
        id
    }

    /// Non-blocking status poll.
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.state.0.lock().get(&id).cloned()
    }

    /// Cancel a queued or running job: its model will never reach the
    /// registry (a fit already in progress is not interrupted — its
    /// result is discarded on completion). The supersede path of
    /// targeted unlearning relies on this: a retrain trained *with* a
    /// since-forgotten sample must not publish. Returns false when the
    /// job is unknown or already terminal (a `Done` job has published;
    /// cancelling cannot unpublish).
    pub fn cancel(&self, id: JobId) -> bool {
        let (lock, cvar) = &*self.state;
        let mut map = lock.lock();
        match map.get(&id) {
            Some(JobStatus::Queued) | Some(JobStatus::Running) => {
                map.insert(id, JobStatus::Cancelled);
                cvar.notify_all();
                crate::obs::record(
                    crate::obs::EventKind::RetrainCancelled,
                    0,
                    0,
                    u32::MAX,
                    id.0,
                );
                true
            }
            _ => false,
        }
    }

    /// Block until the job reaches a terminal state.
    pub fn wait(&self, id: JobId) -> Option<JobStatus> {
        let (lock, cvar) = &*self.state;
        let mut map = lock.lock();
        loop {
            match map.get(&id) {
                None => return None,
                Some(JobStatus::Done { .. })
                | Some(JobStatus::Failed { .. })
                | Some(JobStatus::Cancelled) => return map.get(&id).cloned(),
                _ => {
                    map = cvar.wait(map);
                }
            }
        }
    }

    /// Stop after finishing everything already queued. Idempotent.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
        // take the handle under the lock, join with it released: the
        // join waits out every queued fit, and a concurrent status/wait
        // caller must not queue behind that on the handle lock
        let handle = self.worker.lock().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

fn set_status(
    state: &Arc<(Mutex<HashMap<JobId, JobStatus>>, Condvar)>,
    id: JobId,
    status: JobStatus,
) {
    let (lock, cvar) = &**state;
    lock.lock().insert(id, status);
    cvar.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::kernel::Kernel;

    fn queue() -> (TrainQueue, Arc<ModelRegistry>) {
        let registry = Arc::new(ModelRegistry::new());
        let stats = Arc::new(ServiceStats::new());
        (TrainQueue::start(Arc::clone(&registry), stats), registry)
    }

    #[test]
    fn job_lifecycle() {
        let (q, registry) = queue();
        let ds = SlabConfig::default().generate(80, 101);
        let id = q.submit(TrainRequest {
            name: "j1".into(),
            dataset: ds,
            trainer: Trainer::default().kernel(Kernel::Linear),
        });
        let s = q.wait(id).unwrap();
        match s {
            JobStatus::Done { version, iterations, n_sv, .. } => {
                assert_eq!(version, 1);
                assert!(iterations > 0);
                assert!(n_sv > 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(registry.get("j1").is_some());
        q.shutdown();
    }

    #[test]
    fn unknown_job_is_none() {
        let (q, _) = queue();
        assert!(q.status(JobId(999)).is_none());
        assert!(q.wait(JobId(999)).is_none());
        q.shutdown();
    }

    #[test]
    fn cancelled_job_never_publishes() {
        let (q, registry) = queue();
        // j1 occupies the single worker; j2 is cancelled while queued
        let j1 = q.submit(TrainRequest {
            name: "keep".into(),
            dataset: SlabConfig::default().generate(400, 301),
            trainer: Trainer::default().kernel(Kernel::Linear),
        });
        let j2 = q.submit(TrainRequest {
            name: "superseded".into(),
            dataset: SlabConfig::default().generate(80, 302),
            trainer: Trainer::default().kernel(Kernel::Linear),
        });
        assert!(q.cancel(j2), "queued/running job must be cancellable");
        assert!(matches!(q.wait(j1), Some(JobStatus::Done { .. })));
        assert!(
            matches!(q.wait(j2), Some(JobStatus::Cancelled)),
            "cancelled job must terminate as Cancelled"
        );
        assert!(
            registry.get("superseded").is_none(),
            "a cancelled job's model must never reach the registry"
        );
        // terminal jobs cannot be cancelled
        assert!(!q.cancel(j1));
        assert!(!q.cancel(JobId(999)));
        // the queue keeps working after a cancel
        let j3 = q.submit(TrainRequest {
            name: "after".into(),
            dataset: SlabConfig::default().generate(80, 303),
            trainer: Trainer::default().kernel(Kernel::Linear),
        });
        assert!(matches!(q.wait(j3), Some(JobStatus::Done { .. })));
        assert!(registry.get("after").is_some());
        q.shutdown();
    }

    #[test]
    fn jobs_run_fifo_and_version_bumps() {
        let (q, registry) = queue();
        let mut last = None;
        for seed in 0..3 {
            let ds = SlabConfig::default().generate(60, 200 + seed);
            last = Some(q.submit(TrainRequest {
                name: "same".into(),
                dataset: ds,
                trainer: Trainer::default().kernel(Kernel::Linear),
            }));
        }
        let s = q.wait(last.unwrap()).unwrap();
        match s {
            JobStatus::Done { version, .. } => assert_eq!(version, 3),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(registry.version("same"), Some(3));
        q.shutdown();
    }
}
