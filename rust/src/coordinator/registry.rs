//! Model registry: named, versioned storage of trained models.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::solver::ocssvm::SlabModel;

/// A registered model + metadata.
#[derive(Clone)]
pub struct Entry {
    pub model: Arc<SlabModel>,
    /// monotonically increasing per-name version
    pub version: u64,
}

/// Thread-safe name → model map.
#[derive(Default)]
pub struct ModelRegistry {
    inner: RwLock<HashMap<String, Entry>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or replace; returns the new version.
    pub fn insert(&self, name: &str, model: SlabModel) -> u64 {
        let mut map = self.inner.write().unwrap();
        let version = map.get(name).map_or(1, |e| e.version + 1);
        map.insert(
            name.to_string(),
            Entry { model: Arc::new(model), version },
        );
        version
    }

    pub fn get(&self, name: &str) -> Option<Arc<SlabModel>> {
        self.inner.read().unwrap().get(name).map(|e| Arc::clone(&e.model))
    }

    pub fn version(&self, name: &str) -> Option<u64> {
        self.inner.read().unwrap().get(name).map(|e| e.version)
    }

    pub fn remove(&self, name: &str) -> bool {
        self.inner.write().unwrap().remove(name).is_some()
    }

    /// Sorted model names.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.inner.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Kernel;
    use crate::linalg::Matrix;

    fn dummy() -> SlabModel {
        SlabModel {
            x_sv: Matrix::from_rows(&[&[1.0]]),
            gamma: vec![1.0],
            rho1: 0.0,
            rho2: 1.0,
            kernel: Kernel::Linear,
        }
    }

    #[test]
    fn insert_get_versioning() {
        let r = ModelRegistry::new();
        assert!(r.get("a").is_none());
        assert_eq!(r.insert("a", dummy()), 1);
        assert_eq!(r.insert("a", dummy()), 2);
        assert_eq!(r.version("a"), Some(2));
        assert!(r.get("a").is_some());
        assert_eq!(r.names(), vec!["a"]);
    }

    #[test]
    fn remove_works() {
        let r = ModelRegistry::new();
        r.insert("x", dummy());
        assert!(r.remove("x"));
        assert!(!r.remove("x"));
        assert!(r.is_empty());
    }

    #[test]
    fn concurrent_access() {
        let r = Arc::new(ModelRegistry::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    r.insert(&format!("m{}", (t * 50 + i) % 10), dummy());
                    let _ = r.get(&format!("m{}", i % 10));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.len(), 10);
    }
}
