//! Model registry: named, versioned storage of trained models.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::solver::ocssvm::SlabModel;

/// A registered model + metadata.
#[derive(Clone)]
pub struct Entry {
    pub model: Arc<SlabModel>,
    /// monotonically increasing per-name version
    pub version: u64,
}

/// Thread-safe name → model map.
#[derive(Default)]
pub struct ModelRegistry {
    inner: RwLock<HashMap<String, Entry>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or replace; returns the new version.
    pub fn insert(&self, name: &str, model: SlabModel) -> u64 {
        let mut map = self.inner.write().unwrap();
        let version = map.get(name).map_or(1, |e| e.version + 1);
        map.insert(
            name.to_string(),
            Entry { model: Arc::new(model), version },
        );
        version
    }

    /// Insert at a version no lower than `floor` (still monotone per
    /// name). Snapshot restore uses this to resume the pre-restart
    /// version sequence, so a watcher that recorded versions before the
    /// crash never observes the counter reset.
    pub fn insert_with_floor(
        &self,
        name: &str,
        model: SlabModel,
        floor: u64,
    ) -> u64 {
        let mut map = self.inner.write().unwrap();
        let version = map.get(name).map_or(1, |e| e.version + 1).max(floor);
        map.insert(
            name.to_string(),
            Entry { model: Arc::new(model), version },
        );
        version
    }

    pub fn get(&self, name: &str) -> Option<Arc<SlabModel>> {
        self.inner.read().unwrap().get(name).map(|e| Arc::clone(&e.model))
    }

    /// Model + its version in one consistent read (a `get` followed by a
    /// `version` can straddle a swap; this cannot).
    pub fn get_versioned(&self, name: &str) -> Option<(Arc<SlabModel>, u64)> {
        self.inner
            .read()
            .unwrap()
            .get(name)
            .map(|e| (Arc::clone(&e.model), e.version))
    }

    pub fn version(&self, name: &str) -> Option<u64> {
        self.inner.read().unwrap().get(name).map(|e| e.version)
    }

    pub fn remove(&self, name: &str) -> bool {
        self.inner.write().unwrap().remove(name).is_some()
    }

    /// Sorted model names.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.inner.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Kernel;
    use crate::linalg::Matrix;

    fn dummy() -> SlabModel {
        SlabModel {
            x_sv: Matrix::from_rows(&[&[1.0]]),
            gamma: vec![1.0],
            rho1: 0.0,
            rho2: 1.0,
            kernel: Kernel::Linear,
            featmap: None,
        }
    }

    #[test]
    fn insert_get_versioning() {
        let r = ModelRegistry::new();
        assert!(r.get("a").is_none());
        assert_eq!(r.insert("a", dummy()), 1);
        assert_eq!(r.insert("a", dummy()), 2);
        assert_eq!(r.version("a"), Some(2));
        assert!(r.get("a").is_some());
        assert_eq!(r.names(), vec!["a"]);
    }

    #[test]
    fn remove_works() {
        let r = ModelRegistry::new();
        r.insert("x", dummy());
        assert!(r.remove("x"));
        assert!(!r.remove("x"));
        assert!(r.is_empty());
    }

    /// Model whose internal consistency encodes its version: a reader
    /// that ever sees `gamma[0] != rho1` or `rho2 != rho1 + 1` observed
    /// a torn model.
    fn versioned_model(v: u64) -> SlabModel {
        SlabModel {
            x_sv: Matrix::from_rows(&[&[v as f64]]),
            gamma: vec![v as f64],
            rho1: v as f64,
            rho2: v as f64 + 1.0,
            kernel: Kernel::Linear,
            featmap: None,
        }
    }

    #[test]
    fn hot_swap_is_atomic_and_versions_are_monotone() {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

        let r = Arc::new(ModelRegistry::new());
        r.insert("hot", versioned_model(0));
        let stop = Arc::new(AtomicBool::new(false));
        let readers_up = Arc::new(AtomicU64::new(0));
        let reads = Arc::new(AtomicU64::new(0));

        // concurrent scorers: every observed model must be internally
        // consistent and versions must never go backwards
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let r = Arc::clone(&r);
                let stop = Arc::clone(&stop);
                let readers_up = Arc::clone(&readers_up);
                let reads = Arc::clone(&reads);
                std::thread::spawn(move || {
                    let mut last = 0u64;
                    let mut seen = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let (m, v) = r.get_versioned("hot").expect("present");
                        assert_eq!(
                            m.gamma[0], m.rho1,
                            "torn model at version {v}"
                        );
                        assert_eq!(m.rho2, m.rho1 + 1.0, "torn model");
                        assert_eq!(m.x_sv.get(0, 0), m.rho1, "torn model");
                        assert!(
                            v >= last,
                            "version went backwards: {v} after {last}"
                        );
                        last = v;
                        seen += 1;
                        reads.fetch_add(1, Ordering::SeqCst);
                        if seen == 1 {
                            readers_up.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                    seen
                })
            })
            .collect();

        // don't start swapping until every reader has observed the map
        // at least once — otherwise a loaded machine can finish all the
        // swaps before any reader is scheduled and the check is vacuous
        while readers_up.load(Ordering::SeqCst) < 4 {
            std::thread::yield_now();
        }
        // writer: hundreds of hot swaps
        let before_swaps = reads.load(Ordering::SeqCst);
        for v in 1..=400u64 {
            let got = r.insert("hot", versioned_model(v));
            assert_eq!(got, v + 1); // insert at construction was version 1
        }
        // don't stop until at least one read happened during/after the
        // swaps — otherwise starved readers make the torn-model checks
        // vacuous (they'd only ever have seen the pre-swap state)
        while reads.load(Ordering::SeqCst) <= before_swaps {
            std::thread::yield_now();
        }
        stop.store(true, Ordering::Relaxed);
        for h in readers {
            h.join().unwrap();
        }
        assert!(
            reads.load(Ordering::SeqCst) > before_swaps,
            "readers never overlapped the swaps"
        );
        assert_eq!(r.version("hot"), Some(401));
    }

    #[test]
    fn get_versioned_pairs_model_with_its_version() {
        let r = ModelRegistry::new();
        assert!(r.get_versioned("x").is_none());
        r.insert("x", versioned_model(7));
        let (m, v) = r.get_versioned("x").unwrap();
        assert_eq!(v, 1);
        assert_eq!(m.rho1, 7.0);
    }

    #[test]
    fn concurrent_access() {
        let r = Arc::new(ModelRegistry::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let r = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    r.insert(&format!("m{}", (t * 50 + i) % 10), dummy());
                    let _ = r.get(&format!("m{}", i % 10));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.len(), 10);
    }
}
