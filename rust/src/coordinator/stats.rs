//! Service observability: counters + latency histograms.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram (microseconds, powers of two up to
/// ~67s). Lock-free recording; quantiles are approximate (bucket upper
/// bounds), which is plenty for service dashboards and the S1 bench.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
}

const NBUCKETS: usize = 27; // 2^26 us ≈ 67 s

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record a latency in microseconds.
    pub fn record_us(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(NBUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Number of log-spaced buckets (fixed at construction).
    pub fn n_buckets() -> usize {
        NBUCKETS
    }

    /// Inclusive upper bound of bucket `i` in microseconds — the `le`
    /// label of the Prometheus exposition and the value
    /// [`Histogram::quantile_us`] reports for samples landing there.
    pub fn bucket_bound(i: usize) -> u64 {
        1u64 << (i + 1).min(63)
    }

    /// Raw per-bucket counts (not cumulative), index-aligned with
    /// [`Histogram::bucket_bound`]. This is the exporter's read path:
    /// cumulative Prometheus buckets are summed from it.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Total recorded microseconds (the Prometheus `_sum` series).
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Atomically read **and zero** the histogram: returns
    /// `(bucket counts, count, sum_us)` and leaves the histogram
    /// empty. Each word is swapped individually, so a concurrent
    /// `record_us` lands wholly in either the returned snapshot or the
    /// next one — nothing is lost or double-counted across delta
    /// scrapes (the count/sum may transiently disagree with the
    /// buckets by the in-flight sample, as with any lock-free scrape).
    pub fn reset_snapshot(&self) -> (Vec<u64>, u64, u64) {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.swap(0, Ordering::Relaxed)).collect();
        let count = self.count.swap(0, Ordering::Relaxed);
        let sum = self.sum_us.swap(0, Ordering::Relaxed);
        (buckets, count, sum)
    }

    /// Approximate quantile: upper bound of the bucket containing it.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << NBUCKETS
    }
}

/// All service-level metrics.
pub struct ServiceStats {
    /// scoring requests accepted
    pub requests: Counter,
    /// individual query points scored
    pub scored: Counter,
    /// batches executed
    pub batches: Counter,
    /// scoring errors (unknown model etc.)
    pub errors: Counter,
    /// training jobs finished successfully
    pub jobs_done: Counter,
    /// training jobs failed
    pub jobs_failed: Counter,
    /// end-to-end request latency
    pub request_latency: Histogram,
    /// per-batch execution latency
    pub batch_latency: Histogram,
    /// streamed samples enqueued through the session manager
    pub stream_pushes: Counter,
    /// streamed samples absorbed by shard workers
    pub stream_absorbed: Counter,
    /// producer waits caused by a full per-stream mailbox queue
    /// (backpressure — counted per 50 ms wait slice, never a dropped
    /// sample)
    pub stream_backpressure: Counter,
    /// streamed samples whose absorb failed after a successful push
    /// (the one place the manager can lose a sample — also logged)
    pub stream_absorb_errors: Counter,
    /// background retrains escalated by shard workers
    pub stream_retrains: Counter,
    /// samples removed by targeted unlearning (`forget`)
    pub stream_forgets: Counter,
    /// session snapshots durably written (periodic checkpoints + final
    /// close/drain checkpoints + front-door snapshot sweeps)
    pub stream_checkpoints: Counter,
    /// snapshot writes that failed (also logged with the path)
    pub stream_checkpoint_errors: Counter,
    /// sessions resumed from a snapshot by this process
    pub stream_restores: Counter,
    /// per-sample incremental absorb latency on the shard workers
    pub absorb_latency: Histogram,
    /// HTTP requests admitted by the serving front door (authenticated,
    /// rate-admitted, routed — whether or not the operation succeeded)
    pub serve_accepted: Counter,
    /// HTTP requests shed with 429 (token-bucket rate limit or a
    /// saturated stream mailbox — never a blocked acceptor)
    pub serve_shed: Counter,
    /// HTTP requests rejected 401 (missing/unknown bearer token or a
    /// token presented for another tenant's resource)
    pub serve_auth_failed: Counter,
    /// scoring requests answered from the last published model after
    /// the batcher shed (stale path; response carries `X-Slab-Stale`)
    pub serve_stale_served: Counter,
    /// HTTP request latency, parse → response written
    pub serve_latency: Histogram,
}

impl Default for ServiceStats {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceStats {
    pub fn new() -> Self {
        ServiceStats {
            requests: Counter::default(),
            scored: Counter::default(),
            batches: Counter::default(),
            errors: Counter::default(),
            jobs_done: Counter::default(),
            jobs_failed: Counter::default(),
            request_latency: Histogram::new(),
            batch_latency: Histogram::new(),
            stream_pushes: Counter::default(),
            stream_absorbed: Counter::default(),
            stream_backpressure: Counter::default(),
            stream_absorb_errors: Counter::default(),
            stream_retrains: Counter::default(),
            stream_forgets: Counter::default(),
            stream_checkpoints: Counter::default(),
            stream_checkpoint_errors: Counter::default(),
            stream_restores: Counter::default(),
            absorb_latency: Histogram::new(),
            serve_accepted: Counter::default(),
            serve_shed: Counter::default(),
            serve_auth_failed: Counter::default(),
            serve_stale_served: Counter::default(),
            serve_latency: Histogram::new(),
        }
    }

    /// Average queries per executed batch (batching efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            0.0
        } else {
            self.scored.get() as f64 / b as f64
        }
    }

    /// One-line human summary. Every serving-side [`ServiceStats`]
    /// field is surfaced here or in
    /// [`ServiceStats::stream_summary`] — lint rule [[R4]] checks the
    /// two summaries stay complete as counters are added.
    pub fn summary(&self) -> String {
        format!(
            "requests={} scored={} batches={} (mean batch {:.1}) errors={} \
             jobs_done={} jobs_failed={} \
             p50={}us p99={}us mean={:.0}us \
             batch p50={}us mean={:.0}us \
             serve_accepted={} serve_shed={} serve_auth_failed={} \
             serve_stale_served={} serve p50={}us p99={}us",
            self.requests.get(),
            self.scored.get(),
            self.batches.get(),
            self.mean_batch_size(),
            self.errors.get(),
            self.jobs_done.get(),
            self.jobs_failed.get(),
            self.request_latency.quantile_us(0.5),
            self.request_latency.quantile_us(0.99),
            self.request_latency.mean_us(),
            self.batch_latency.quantile_us(0.5),
            self.batch_latency.mean_us(),
            self.serve_accepted.get(),
            self.serve_shed.get(),
            self.serve_auth_failed.get(),
            self.serve_stale_served.get(),
            self.serve_latency.quantile_us(0.5),
            self.serve_latency.quantile_us(0.99),
        )
    }

    /// One-line human summary of the streaming data plane.
    pub fn stream_summary(&self) -> String {
        format!(
            "pushed={} absorbed={} absorb_errors={} backpressure_waits={} \
             retrains={} forgets={} checkpoints={} checkpoint_errors={} \
             restores={} absorb p50={}us p99={}us mean={:.0}us",
            self.stream_pushes.get(),
            self.stream_absorbed.get(),
            self.stream_absorb_errors.get(),
            self.stream_backpressure.get(),
            self.stream_retrains.get(),
            self.stream_forgets.get(),
            self.stream_checkpoints.get(),
            self.stream_checkpoint_errors.get(),
            self.stream_restores.get(),
            self.absorb_latency.quantile_us(0.5),
            self.absorb_latency.quantile_us(0.99),
            self.absorb_latency.mean_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::new();
        for us in [10u64, 20, 40, 80, 10_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        // p50 falls in the bucket holding 40us -> upper bound 64
        let p50 = h.quantile_us(0.5);
        assert!(p50 >= 32 && p50 <= 128, "p50={p50}");
        // p99 must land at the 10ms outlier's bucket
        let p99 = h.quantile_us(0.99);
        assert!(p99 >= 8192, "p99={p99}");
        assert!((h.mean_us() - 2030.0).abs() < 1.0);
    }

    #[test]
    fn histogram_bucket_accessors_and_reset() {
        let h = Histogram::new();
        h.record_us(3); // bucket 1 (bound 4)
        h.record_us(3);
        h.record_us(100); // bucket 6 (bound 128)
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), Histogram::n_buckets());
        assert_eq!(counts[1], 2);
        assert_eq!(counts[6], 1);
        assert_eq!(Histogram::bucket_bound(1), 4);
        assert_eq!(Histogram::bucket_bound(6), 128);
        assert_eq!(h.sum_us(), 106);

        let (snap, count, sum) = h.reset_snapshot();
        assert_eq!((snap[1], snap[6], count, sum), (2, 1, 3, 106));
        assert_eq!(h.count(), 0, "zeroed after snapshot");
        assert!(h.bucket_counts().iter().all(|&c| c == 0));
        assert_eq!(h.sum_us(), 0);
        // delta scrape: new samples land in the next snapshot only
        h.record_us(3);
        assert_eq!(h.bucket_counts()[1], 1);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn mean_batch_size() {
        let s = ServiceStats::new();
        s.scored.add(100);
        s.batches.add(4);
        assert!((s.mean_batch_size() - 25.0).abs() < 1e-12);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn stream_counters_and_summary() {
        let s = ServiceStats::new();
        s.stream_pushes.add(10);
        s.stream_absorbed.add(10);
        s.stream_backpressure.inc();
        s.stream_retrains.inc();
        s.absorb_latency.record_us(120);
        let line = s.stream_summary();
        assert!(line.contains("pushed=10"), "{line}");
        assert!(line.contains("backpressure_waits=1"), "{line}");
    }
}
