//! Dynamic batcher: queue scoring requests, execute in grouped batches.
//!
//! The serving-policy core (vLLM-router shaped, scaled to this model
//! class): a dispatcher thread drains the request queue, groups by model
//! name, and flushes a group when it reaches `max_batch` queries or the
//! oldest request has waited `max_wait_us`. Flushed batches go to a pool
//! of scoring workers that stack the queries into one matrix and run a
//! single [`Engine::predict`] — amortizing PJRT dispatch overhead across
//! requests, which is exactly what the artifact's batched decision graph
//! is shaped for.
//!
//! Backpressure: the submission queue is bounded (`queue_cap`); when
//! full, `submit` sheds load by failing fast instead of queueing
//! unboundedly (callers see `Error::Coordinator`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::registry::ModelRegistry;
use super::stats::ServiceStats;
use crate::error::Error;
use crate::linalg::Matrix;
use crate::runtime::Engine;
use crate::Result;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// flush a model group at this many queries
    pub max_batch: usize,
    /// flush when the oldest queued request is this old
    pub max_wait_us: u64,
    /// bounded submission queue (backpressure)
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 256, max_wait_us: 500, queue_cap: 8192 }
    }
}

/// Scoring result for one request (in submission order of its queries).
#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub scores: Vec<f64>,
    pub labels: Vec<i8>,
    /// how long the request waited + executed, end to end
    pub latency: Duration,
}

struct Request {
    model: String,
    queries: Vec<Vec<f64>>,
    respond: Sender<Result<ScoreResponse>>,
    enqueued: Instant,
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Handle to the running batcher.
pub struct DynamicBatcher {
    tx: Sender<Msg>,
    inflight: Arc<AtomicUsize>,
    cfg: BatcherConfig,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl DynamicBatcher {
    /// Spawn dispatcher + `workers` scoring threads.
    pub fn start(
        engine: Engine,
        registry: Arc<ModelRegistry>,
        stats: Arc<ServiceStats>,
        cfg: BatcherConfig,
        workers: usize,
    ) -> DynamicBatcher {
        let (tx, rx) = mpsc::channel::<Msg>();
        let inflight = Arc::new(AtomicUsize::new(0));
        let inflight2 = Arc::clone(&inflight);
        let dispatcher = std::thread::Builder::new()
            .name("slabsvm-dispatch".into())
            .spawn(move || {
                dispatch_loop(rx, engine, registry, stats, cfg, workers, inflight2)
            })
            .expect("spawn dispatcher");
        DynamicBatcher { tx, inflight, cfg, dispatcher: Some(dispatcher) }
    }

    /// Enqueue a scoring request (non-blocking; sheds load when full).
    pub fn submit(
        &self,
        model: &str,
        queries: Vec<Vec<f64>>,
    ) -> Receiver<Result<ScoreResponse>> {
        let (rtx, rrx) = mpsc::channel();
        let depth = self.inflight.load(Ordering::Relaxed);
        if depth >= self.cfg.queue_cap {
            // typed shed (same shape as the mailbox push path) so the
            // serving layer can route it to stale-model fallback
            let _ = rtx.send(Err(Error::Saturated { depth }));
            return rrx;
        }
        self.inflight.fetch_add(1, Ordering::Relaxed);
        let req = Request {
            model: model.to_string(),
            queries,
            respond: rtx,
            enqueued: Instant::now(),
        };
        if self.tx.send(Msg::Req(req)).is_err() {
            // dispatcher gone; receiver will see a disconnect
            self.inflight.fetch_sub(1, Ordering::Relaxed);
        }
        rrx
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Dispatcher: accumulate per-model groups, flush on size/deadline.
#[allow(clippy::too_many_arguments)]
fn dispatch_loop(
    rx: Receiver<Msg>,
    engine: Engine,
    registry: Arc<ModelRegistry>,
    stats: Arc<ServiceStats>,
    cfg: BatcherConfig,
    workers: usize,
    inflight: Arc<AtomicUsize>,
) {
    // worker pool fed by a shared work channel
    let (wtx, wrx) = mpsc::channel::<Vec<Request>>();
    let wrx = Arc::new(Mutex::new(wrx));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();
    for w in 0..workers.max(1) {
        let wrx = Arc::clone(&wrx);
        let engine = engine.clone();
        let registry = Arc::clone(&registry);
        let stats = Arc::clone(&stats);
        let stop = Arc::clone(&stop);
        let inflight = Arc::clone(&inflight);
        handles.push(
            std::thread::Builder::new()
                .name(format!("slabsvm-score-{w}"))
                .spawn(move || loop {
                    let batch = {
                        let guard = wrx.lock().unwrap();
                        match guard.recv_timeout(Duration::from_millis(50)) {
                            Ok(b) => b,
                            Err(_) => {
                                if stop.load(Ordering::Relaxed) {
                                    return;
                                }
                                continue;
                            }
                        }
                    };
                    let n = batch.len();
                    execute_batch(&engine, &registry, &stats, batch);
                    inflight.fetch_sub(n, Ordering::Relaxed);
                })
                .expect("spawn worker"),
        );
    }

    // Per-model pending groups. The flush deadline runs from the OLDEST
    // member request's submission time: a request's channel wait counts
    // toward `max_wait_us`, so a group whose oldest request is over-age
    // flushes on the very next dispatcher tick — below `max_batch`, and
    // even if no further request ever arrives. Bursts still coalesce
    // because the whole backlog is drained into groups *before* the
    // deadline scan runs (stale timestamps flush the burst as one batch,
    // not as singletons).
    struct Group {
        reqs: Vec<Request>,
        size: usize,
        /// earliest `enqueued` among member requests
        oldest: Instant,
    }
    let mut pending: HashMap<String, Group> = HashMap::new();
    let mut pending_count = 0usize;
    let mut shutting_down = false;

    loop {
        let wait = if pending_count == 0 {
            Duration::from_millis(100)
        } else {
            Duration::from_micros(cfg.max_wait_us / 2 + 1)
        };
        // block for the first message, then DRAIN the backlog so a burst
        // is coalesced into full batches instead of timing out piecemeal
        let mut incoming = Vec::new();
        match rx.recv_timeout(wait) {
            Ok(msg) => incoming.push(msg),
            Err(mpsc::RecvTimeoutError::Disconnected) => shutting_down = true,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
        while incoming.len() < 4 * cfg.max_batch {
            match rx.try_recv() {
                Ok(msg) => incoming.push(msg),
                Err(_) => break,
            }
        }

        for msg in incoming {
            let req = match msg {
                Msg::Req(req) => req,
                Msg::Shutdown => {
                    shutting_down = true;
                    continue;
                }
            };
            let key = req.model.clone();
            let group = pending.entry(key.clone()).or_insert_with(|| Group {
                reqs: Vec::new(),
                size: 0,
                oldest: req.enqueued,
            });
            group.size += req.queries.len();
            group.oldest = group.oldest.min(req.enqueued);
            group.reqs.push(req);
            pending_count += 1;
            if group.size >= cfg.max_batch {
                if let Some(g) = pending.remove(&key) {
                    pending_count -= g.reqs.len();
                    let _ = wtx.send(g.reqs);
                }
            }
        }

        // deadline-based flush
        let now = Instant::now();
        let keys: Vec<String> = pending
            .iter()
            .filter(|(_, g)| {
                shutting_down || deadline_expired(g.oldest, now, cfg.max_wait_us)
            })
            .map(|(k, _)| k.clone())
            .collect();
        for k in keys {
            if let Some(g) = pending.remove(&k) {
                pending_count -= g.reqs.len();
                let _ = wtx.send(g.reqs);
            }
        }

        if shutting_down && pending_count == 0 {
            break;
        }
    }

    stop.store(true, Ordering::Relaxed);
    drop(wtx);
    for h in handles {
        let _ = h.join();
    }
}

/// Deadline policy: a pending group must flush once its oldest request
/// has waited `max_wait_us` — measured from *submission*, so time spent
/// in the dispatcher's channel counts too.
fn deadline_expired(oldest: Instant, now: Instant, max_wait_us: u64) -> bool {
    now.saturating_duration_since(oldest).as_micros() as u64 >= max_wait_us
}

/// Run one model-grouped batch end-to-end and fan results back out.
fn execute_batch(
    engine: &Engine,
    registry: &ModelRegistry,
    stats: &ServiceStats,
    batch: Vec<Request>,
) {
    if batch.is_empty() {
        return;
    }
    stats.requests.add(batch.len() as u64);
    let name = batch[0].model.clone();
    let Some(model) = registry.get(&name) else {
        stats.errors.add(batch.len() as u64);
        for req in batch {
            let _ = req.respond.send(Err(Error::Coordinator(format!(
                "unknown model '{name}'"
            ))));
        }
        return;
    };
    // stack all queries into one matrix
    let total: usize = batch.iter().map(|r| r.queries.len()).sum();
    let d = model.x_sv.cols();
    let mut stacked = Matrix::zeros(total, d);
    let mut row = 0;
    let mut bad_dim = false;
    for req in &batch {
        for q in &req.queries {
            if q.len() != d {
                bad_dim = true;
                break;
            }
            stacked.row_mut(row).copy_from_slice(q);
            row += 1;
        }
    }
    if bad_dim {
        stats.errors.add(batch.len() as u64);
        for req in batch {
            let _ = req.respond.send(Err(Error::Coordinator(format!(
                "query dimension mismatch (model expects {d})"
            ))));
        }
        return;
    }

    // Serving-side spans (trace 0 — scoring requests carry no push
    // trace): one ScoreQueue span per member request, backdated from
    // its queue wait so the span starts at submission time, and one
    // Score span for the fused predict. `iters` on the Score span is
    // the stacked query count the batch amortized.
    let tracing = crate::obs::enabled();
    if tracing {
        let q_end = crate::obs::now_us();
        for req in &batch {
            let waited = req.enqueued.elapsed().as_micros() as u64;
            crate::obs::record_span(crate::obs::Span {
                trace: 0,
                stage: crate::obs::Stage::ScoreQueue,
                start_us: q_end.saturating_sub(waited),
                dur_us: waited,
                stream: crate::obs::stream_id(&req.model),
                shard: u32::MAX,
                iters: 0,
            });
        }
    }
    let t0 = Instant::now();
    let s_start = if tracing { crate::obs::now_us() } else { 0 };
    let result = engine.predict(&model, &stacked);
    if tracing {
        crate::obs::record_span(crate::obs::Span {
            trace: 0,
            stage: crate::obs::Stage::Score,
            start_us: s_start,
            dur_us: crate::obs::now_us().saturating_sub(s_start),
            stream: crate::obs::stream_id(&name),
            shard: u32::MAX,
            iters: total as u64,
        });
    }
    stats.batch_latency.record(t0.elapsed());
    stats.batches.inc();

    match result {
        Ok((scores, labels)) => {
            stats.scored.add(total as u64);
            let mut off = 0;
            for req in batch {
                let n = req.queries.len();
                let latency = req.enqueued.elapsed();
                stats.request_latency.record(latency);
                let _ = req.respond.send(Ok(ScoreResponse {
                    scores: scores[off..off + n].to_vec(),
                    labels: labels[off..off + n].to_vec(),
                    latency,
                }));
                off += n;
            }
        }
        Err(e) => {
            stats.errors.add(batch.len() as u64);
            let msg = e.to_string();
            for req in batch {
                let _ = req
                    .respond
                    .send(Err(Error::Coordinator(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::kernel::Kernel;
    use crate::solver::{SolverKind, Trainer};

    fn setup(cfg: BatcherConfig) -> (DynamicBatcher, Arc<ModelRegistry>, Arc<ServiceStats>) {
        let registry = Arc::new(ModelRegistry::new());
        let stats = Arc::new(ServiceStats::new());
        let b = DynamicBatcher::start(
            Engine::Native,
            Arc::clone(&registry),
            Arc::clone(&stats),
            cfg,
            2,
        );
        (b, registry, stats)
    }

    fn trained_model() -> crate::solver::ocssvm::SlabModel {
        let ds = SlabConfig::default().generate(100, 91);
        Trainer::new(SolverKind::Smo)
            .kernel(Kernel::Linear)
            .fit(&ds.x)
            .unwrap()
            .model
    }

    #[test]
    fn batches_multiple_requests_together() {
        let (b, registry, stats) = setup(BatcherConfig {
            max_batch: 64,
            max_wait_us: 20_000, // long window so requests coalesce
            queue_cap: 1024,
        });
        registry.insert("m", trained_model());
        let eval = SlabConfig::default().generate_eval(32, 0, 92);
        let rxs: Vec<_> = (0..32)
            .map(|i| b.submit("m", vec![eval.x.row(i).to_vec()]))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        // 32 requests should have been served by far fewer batches
        assert!(
            stats.batches.get() <= 8,
            "batches={} (batching not happening)",
            stats.batches.get()
        );
        assert_eq!(stats.scored.get(), 32);
        b.shutdown();
    }

    #[test]
    fn deadline_flush_fires() {
        let (b, registry, stats) = setup(BatcherConfig {
            max_batch: 1_000_000, // size trigger unreachable
            max_wait_us: 1_000,
            queue_cap: 1024,
        });
        registry.insert("m", trained_model());
        let rx = b.submit("m", vec![vec![20.0, 20.0]]);
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.labels.len(), 1);
        assert_eq!(stats.batches.get(), 1);
        b.shutdown();
    }

    #[test]
    fn deadline_counts_queue_wait_not_group_open() {
        // the policy itself: a request that already waited longer than
        // max_wait_us (e.g. in the dispatcher's channel) must flush on
        // the next tick, regardless of when its group was opened
        let now = Instant::now();
        let waited = now - Duration::from_micros(10_000);
        assert!(deadline_expired(waited, now, 5_000));
        assert!(deadline_expired(waited, now, 10_000));
        assert!(!deadline_expired(now, now, 5_000));
        // clock skew / same-instant never underflows
        assert!(!deadline_expired(now + Duration::from_micros(50), now, 5_000));
    }

    #[test]
    fn overdue_group_below_max_batch_flushes_without_new_arrivals() {
        // regression: a group below max_batch whose oldest request is
        // past max_wait_us must be flushed by the dispatcher's own tick —
        // no follow-up request may be required to unblock it
        let (b, registry, stats) = setup(BatcherConfig {
            max_batch: 1_000_000, // size trigger unreachable
            max_wait_us: 20_000,
            queue_cap: 1024,
        });
        registry.insert("m", trained_model());
        let rx = b.submit("m", vec![vec![20.0, 20.0]]);
        // no further submissions: only the deadline tick can flush
        let resp = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("deadline tick never flushed the group")
            .unwrap();
        assert_eq!(resp.labels.len(), 1);
        assert!(resp.latency >= Duration::from_micros(20_000));
        assert_eq!(stats.batches.get(), 1);
        b.shutdown();
    }

    #[test]
    fn backpressure_sheds_load() {
        let (b, registry, _stats) = setup(BatcherConfig {
            max_batch: 1_000_000,
            max_wait_us: 1_000_000, // never flush during the test
            queue_cap: 4,
        });
        registry.insert("m", trained_model());
        let mut rxs = Vec::new();
        for _ in 0..20 {
            rxs.push(b.submit("m", vec![vec![0.0, 0.0]]));
        }
        // beyond queue_cap submissions must fail fast with the typed
        // saturation error the serving layer's stale fallback keys on
        let failed = rxs
            .iter()
            .filter(|rx| {
                matches!(rx.try_recv(), Ok(Err(Error::Saturated { .. })))
            })
            .count();
        assert!(failed >= 16 - 4, "failed={failed}");
        b.shutdown();
    }

    #[test]
    fn multi_query_request_order_preserved() {
        let (b, registry, _) = setup(BatcherConfig::default());
        let model = trained_model();
        registry.insert("m", model.clone());
        let eval = SlabConfig::default().generate_eval(10, 10, 93);
        let queries: Vec<Vec<f64>> =
            (0..eval.len()).map(|i| eval.x.row(i).to_vec()).collect();
        let resp = b.submit("m", queries).recv().unwrap().unwrap();
        for i in 0..eval.len() {
            assert_eq!(resp.labels[i], model.classify(eval.x.row(i)));
        }
        b.shutdown();
    }
}
