//! Serving + training coordinator (the L3 service around the solver).
//!
//! A [`Coordinator`] owns:
//!
//! * a [`registry::ModelRegistry`] of trained [`SlabModel`]s;
//! * a [`batcher::DynamicBatcher`] — scoring requests are queued and
//!   executed in model-grouped batches (size- or deadline-triggered),
//!   amortizing PJRT dispatch over many queries, vLLM-router style;
//! * a [`jobs::TrainQueue`] — asynchronous training jobs that register
//!   their model on completion;
//! * [`stats`] — latency histograms + counters for every stage.
//!
//! Streaming (the L4 layer) comes in two shapes:
//!
//! * single-writer — [`Coordinator::open_stream`] /
//!   [`Coordinator::stream_push`]: the caller owns a
//!   [`crate::stream::StreamSession`] and pushes samples itself; each
//!   absorbed sample hot-swaps the published model version in the
//!   registry, drift trips escalate a background cascade retrain
//!   through the same train queue (experiment ST1,
//!   `rust/benches/streaming.rs`);
//! * sharded multi-stream — [`Coordinator::open_streams`] /
//!   [`Coordinator::push`] / [`Coordinator::close_stream`]: sessions
//!   live on the [`crate::stream::StreamManager`]'s shard worker
//!   threads (hashed by name, bounded mailboxes with backpressure,
//!   weighted-fair scheduling per shard), so one coordinator drives
//!   many concurrent tenant streams (experiment MS1).
//!
//! Managed streams are durable: shard workers checkpoint sessions
//! periodically ([`crate::stream::CheckpointConfig`] on the pool
//! config), and [`Coordinator::snapshot_streams`] /
//! [`Coordinator::restore_streams`] snapshot and resume the whole
//! fleet across a process restart — restored sessions continue from
//! their persisted window + dual state via a bounded warm-started
//! repair instead of a cold window refill (experiment PS1,
//! `rust/src/stream/persist.rs`).
//!
//! Everything is std-thread based (no async runtime in the vendored
//! crate set); channels are `std::sync::mpsc`, shared state is behind
//! `RwLock`/`Mutex`. The binary's `serve` subcommand exposes this over
//! a dependency-free HTTP/1.1 front door ([`crate::serve`], DESIGN.md
//! §9), and `rust/benches/serving.rs` measures batcher
//! throughput/latency (experiment S1).
//!
//! [`SlabModel`]: crate::solver::ocssvm::SlabModel

pub mod batcher;
pub mod jobs;
pub mod registry;
pub mod stats;

use std::sync::Arc;

use crate::data::Dataset;
use crate::error::Error;
use crate::runtime::Engine;
use crate::solver::api::Trainer;
use crate::solver::ocssvm::SlabModel;
use crate::stream::shard::reconcile_retrain;
use crate::stream::{
    DriftEvent, ForgetOutcome, StreamConfig, StreamManager, StreamPoolConfig,
    StreamSession, StreamSpec, StreamSummary,
};
use crate::Result;

pub use batcher::{BatcherConfig, DynamicBatcher, ScoreResponse};
pub use jobs::{JobId, JobStatus, TrainQueue, TrainRequest};
pub use registry::ModelRegistry;
pub use stats::{Histogram, ServiceStats};

/// What one [`Coordinator::stream_push`] did.
#[derive(Debug, Default)]
pub struct StreamUpdate {
    /// registry version the refreshed model was hot-swapped under
    /// (None during session warmup)
    pub version: Option<u64>,
    /// drift verdict for this sample
    pub drift: Option<DriftEvent>,
    /// background cascade retrain submitted on this push
    pub retrain_submitted: Option<JobId>,
    /// a previously submitted retrain completed; its registry version
    pub retrain_completed: Option<u64>,
}

/// The assembled service.
pub struct Coordinator {
    registry: Arc<ModelRegistry>,
    batcher: DynamicBatcher,
    jobs: Arc<TrainQueue>,
    streams: StreamManager,
    stats: Arc<ServiceStats>,
}

impl Coordinator {
    /// Start the service with `workers` scoring workers on `engine` and
    /// the default stream-manager sizing ([`StreamPoolConfig`]).
    pub fn start(engine: Engine, cfg: BatcherConfig, workers: usize) -> Coordinator {
        Coordinator::start_with_streams(
            engine,
            cfg,
            workers,
            StreamPoolConfig::default(),
        )
    }

    /// [`Coordinator::start`] with explicit stream-manager sizing
    /// (shard worker threads + per-shard mailbox bound).
    pub fn start_with_streams(
        engine: Engine,
        cfg: BatcherConfig,
        workers: usize,
        pool: StreamPoolConfig,
    ) -> Coordinator {
        crate::obs::init_from_env();
        let registry = Arc::new(ModelRegistry::new());
        let stats = Arc::new(ServiceStats::new());
        let batcher = DynamicBatcher::start(
            engine.clone(),
            Arc::clone(&registry),
            Arc::clone(&stats),
            cfg,
            workers,
        );
        let jobs = Arc::new(TrainQueue::start(
            Arc::clone(&registry),
            Arc::clone(&stats),
        ));
        let streams = StreamManager::start(
            pool,
            Arc::clone(&registry),
            Arc::clone(&jobs),
            Arc::clone(&stats),
        );
        Coordinator { registry, batcher, jobs, streams, stats }
    }

    /// Register a pre-trained model under a name.
    pub fn register(&self, name: &str, model: SlabModel) {
        self.registry.insert(name, model);
    }

    /// Fetch a model by name.
    pub fn model(&self, name: &str) -> Option<Arc<SlabModel>> {
        self.registry.get(name)
    }

    /// Train synchronously through the unified solver API and register.
    /// Any [`Trainer`] configuration works — solver kind, kernel and
    /// layers (warm start / cascade / cache) included, so heterogeneous
    /// solvers serve behind this one interface.
    pub fn train_blocking(
        &self,
        name: &str,
        ds: &Dataset,
        trainer: &Trainer,
    ) -> Result<Arc<SlabModel>> {
        let report = trainer.fit(&ds.x)?;
        self.registry.insert(name, report.model);
        self.registry
            .get(name)
            .ok_or_else(|| Error::Coordinator("registration raced".into()))
    }

    /// Submit an asynchronous training job.
    pub fn submit_train(&self, req: TrainRequest) -> JobId {
        self.jobs.submit(req)
    }

    /// Poll a training job.
    pub fn job_status(&self, id: JobId) -> Option<JobStatus> {
        self.jobs.status(id)
    }

    /// Block until a job finishes (returns final status).
    pub fn wait_job(&self, id: JobId) -> Option<JobStatus> {
        self.jobs.wait(id)
    }

    /// Enqueue a scoring request; returns a receiver for the response.
    pub fn score_async(
        &self,
        model: &str,
        queries: Vec<Vec<f64>>,
    ) -> std::sync::mpsc::Receiver<Result<ScoreResponse>> {
        self.batcher.submit(model, queries)
    }

    /// Score synchronously (single request through the batcher).
    pub fn score(&self, model: &str, queries: Vec<Vec<f64>>) -> Result<ScoreResponse> {
        self.score_async(model, queries)
            .recv()
            .map_err(|_| Error::Coordinator("batcher shut down".into()))?
    }

    /// Open a streaming session publishing under `name`. The session is
    /// handed back to the caller (it is single-writer state); every
    /// [`Coordinator::stream_push`] hot-swaps the published model, so
    /// concurrent scorers via [`Coordinator::score`] always see a
    /// complete model at a monotonically increasing version.
    pub fn open_stream(&self, name: &str, cfg: StreamConfig) -> StreamSession {
        StreamSession::new(name, cfg)
    }

    /// Absorb one streamed sample: reconcile any finished background
    /// retrain, update the session's model incrementally, hot-swap the
    /// registry entry, and escalate to a background cascade retrain when
    /// the drift monitor trips. Scoring through the batcher is never
    /// blocked — the retrain runs on the [`TrainQueue`] thread and
    /// registers its model exactly like any other training job.
    pub fn stream_push(
        &self,
        session: &mut StreamSession,
        x: &[f64],
    ) -> Result<StreamUpdate> {
        let mut update = StreamUpdate {
            retrain_completed: reconcile_retrain(
                session,
                &self.registry,
                &self.jobs,
            ),
            ..StreamUpdate::default()
        };
        let absorbed = session.absorb(x)?;
        update.drift = absorbed.drift;
        if let Some(model) = absorbed.model {
            update.version =
                Some(self.registry.insert(session.name(), model));
        }
        if absorbed.retrain_wanted {
            let id = self.submit_train(TrainRequest {
                name: session.name().to_string(),
                dataset: session.window_dataset(),
                trainer: session.retrain_trainer(),
            });
            session.retrain_submitted(id);
            update.retrain_submitted = Some(id);
        }
        Ok(update)
    }

    // ------------------------------------------- sharded multi-stream

    /// Open a set of managed tenant streams on the sharded session
    /// manager (all-or-nothing). Each stream lives on the shard its
    /// name hashes to; samples go in through [`Coordinator::push`].
    pub fn open_streams(&self, specs: Vec<StreamSpec>) -> Result<()> {
        self.streams.open_streams(specs)
    }

    /// Enqueue one sample for a managed stream onto its shard's bounded
    /// mailbox. Blocks under backpressure (never drops); the owning
    /// shard worker absorbs it, hot-swaps the published model and
    /// escalates background retrains exactly like
    /// [`Coordinator::stream_push`] does.
    pub fn push(&self, name: &str, x: &[f64]) -> Result<()> {
        self.streams.push(name, x)
    }

    /// Non-blocking [`Coordinator::push`]: a stream mailbox already at
    /// capacity is a typed [`crate::Error::Saturated`] (carrying the
    /// observed queue depth) instead of a blocked producer. The HTTP
    /// front door ([`crate::serve`]) turns it into `429 Too Many
    /// Requests` + `Retry-After`; both variants share one mailbox
    /// implementation, so admission control can never drop a sample
    /// the blocking path would have kept.
    pub fn try_push(&self, name: &str, x: &[f64]) -> Result<()> {
        self.streams.try_push(name, x)
    }

    /// Targeted unlearning on a managed stream: remove the resident
    /// sample with stable id `id` (the 0-based arrival index of that
    /// stream's pushes), withdraw its dual mass, repair, and hot-swap
    /// the post-removal model — "forget user X" without a retrain. The
    /// command is applied by the owning shard at its next tick, before
    /// samples still queued for the stream; call
    /// [`Coordinator::quiesce_streams`] first when the id might still
    /// be in flight. A background retrain in flight at removal time was
    /// trained on data including the sample — the shard **cancels** it
    /// (a cancelled job's model never reaches the registry, even if its
    /// fit already ran) and submits a fresh retrain of the post-removal
    /// window in its place. Non-resident ids (never absorbed, evicted
    /// by the window, or already forgotten) return a typed
    /// [`crate::Error::Unlearning`] and the stream keeps running.
    pub fn forget(&self, name: &str, id: u64) -> Result<ForgetOutcome> {
        self.streams.forget(name, id)
    }

    /// Batch unlearning: withdraw several resident samples in one shard
    /// tick — one repair sweep, one hot-swap, one replacement retrain —
    /// instead of `k` sequential [`Coordinator::forget`] calls each
    /// paying a full repair and publishing an intermediate model. The
    /// batch is all-or-nothing: any non-resident or duplicated id
    /// rejects the whole request before any mass is withdrawn.
    pub fn forget_many(
        &self,
        name: &str,
        ids: &[u64],
    ) -> Result<ForgetOutcome> {
        self.streams.forget_many(name, ids)
    }

    /// Close a managed stream: drains its queued samples, then returns
    /// its final accounting.
    pub fn close_stream(&self, name: &str) -> Result<StreamSummary> {
        self.streams.close_stream(name)
    }

    /// Block until every queued sample on every shard has been absorbed.
    pub fn quiesce_streams(&self) {
        self.streams.quiesce()
    }

    /// Snapshot every open managed stream into `dir` (atomic writes,
    /// per-stream failure isolation). Call
    /// [`Coordinator::quiesce_streams`] first when every pushed sample
    /// must be captured. Restore into a fresh coordinator with
    /// [`Coordinator::restore_streams`].
    pub fn snapshot_streams(
        &self,
        dir: &std::path::Path,
    ) -> Result<Vec<crate::stream::SnapshotOutcome>> {
        self.streams.snapshot_streams(dir)
    }

    /// Resume every `*.snap` session in `dir` on this coordinator: the
    /// window + dual state restore without a cold refill (bounded
    /// warm-started repair instead of a full retrain), each model is
    /// re-published at or past its pre-restart registry version, and
    /// new samples can be pushed immediately. Per-file failure
    /// isolation: a corrupt snapshot yields an error outcome for that
    /// file while every other stream resumes.
    pub fn restore_streams(
        &self,
        dir: &std::path::Path,
    ) -> Result<Vec<crate::stream::RestoreOutcome>> {
        self.streams.restore_streams(dir)
    }

    /// The sharded session manager (open-stream census, backlog).
    pub fn stream_manager(&self) -> &StreamManager {
        &self.streams
    }

    /// The shared model registry (version probes, direct lookups).
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Every service metric in Prometheus text exposition format
    /// (version 0.0.4) — counters and cumulative-bucket histograms,
    /// built from the [`crate::obs::registry`] so the set of exported
    /// names is pinned by golden tests and lint rule [[R4]]. The
    /// `slabsvm stats` verb prints exactly this.
    pub fn metrics_text(&self) -> String {
        crate::obs::prometheus_text(&crate::obs::registry(&self.stats))
    }

    /// Every service metric as JSON lines (one canonical-JSON object
    /// per metric) — same registry as [`Coordinator::metrics_text`],
    /// machine-friendly shape (`slabsvm stats --format json`).
    pub fn metrics_json(&self) -> String {
        crate::obs::json_lines(&crate::obs::registry(&self.stats))
    }

    /// Graceful shutdown: drains the stream shards first (they publish
    /// models and submit retrains), then the batcher and train queue.
    pub fn shutdown(self) {
        self.streams.shutdown();
        self.batcher.shutdown();
        self.jobs.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::kernel::Kernel;

    fn quick_coordinator() -> Coordinator {
        Coordinator::start(
            Engine::Native,
            BatcherConfig { max_batch: 64, max_wait_us: 200, queue_cap: 1024 },
            2,
        )
    }

    #[test]
    fn train_register_score_roundtrip() {
        let c = quick_coordinator();
        let ds = SlabConfig::default().generate(150, 81);
        c.train_blocking("m1", &ds, &Trainer::default().kernel(Kernel::Linear))
            .unwrap();
        let q = SlabConfig::default().generate_eval(10, 10, 82);
        let queries: Vec<Vec<f64>> =
            (0..q.len()).map(|i| q.x.row(i).to_vec()).collect();
        let resp = c.score("m1", queries).unwrap();
        assert_eq!(resp.labels.len(), 20);
        assert_eq!(resp.scores.len(), 20);
        // must match direct model predictions
        let model = c.model("m1").unwrap();
        let want = model.predict(&q.x);
        assert_eq!(resp.labels, want);
        c.shutdown();
    }

    #[test]
    fn scoring_unknown_model_errors() {
        let c = quick_coordinator();
        let err = c.score("nope", vec![vec![0.0, 0.0]]);
        assert!(err.is_err());
        c.shutdown();
    }

    #[test]
    fn async_train_job_completes() {
        let c = quick_coordinator();
        let ds = SlabConfig::default().generate(100, 83);
        let id = c.submit_train(TrainRequest {
            name: "async1".into(),
            dataset: ds,
            trainer: Trainer::default().kernel(Kernel::Linear),
        });
        let status = c.wait_job(id).unwrap();
        assert!(matches!(status, JobStatus::Done { .. }), "{status:?}");
        assert!(c.model("async1").is_some());
        c.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let c = quick_coordinator();
        let ds = SlabConfig::default().generate(50, 84);
        let id = c.submit_train(TrainRequest {
            name: "bad".into(),
            dataset: ds,
            trainer: Trainer::default().kernel(Kernel::Linear).nu1(-1.0),
        });
        let status = c.wait_job(id).unwrap();
        assert!(matches!(status, JobStatus::Failed { .. }), "{status:?}");
        assert!(c.model("bad").is_none());
        c.shutdown();
    }

    #[test]
    fn stream_push_publishes_and_versions() {
        let c = quick_coordinator();
        let mut s = c.open_stream(
            "live",
            StreamConfig { window: 48, min_train: 24, ..Default::default() },
        );
        let ds = SlabConfig::default().generate(60, 87);
        let mut last_version = 0;
        for i in 0..60 {
            let u = c.stream_push(&mut s, ds.x.row(i)).unwrap();
            if let Some(v) = u.version {
                assert!(v > last_version, "version must be monotone");
                last_version = v;
            }
        }
        // warmup ends at min_train; every later push hot-swaps a version
        assert_eq!(last_version, 60 - 24 + 1);
        // the streamed model serves through the batcher like any other
        let resp = c.score("live", vec![ds.x.row(0).to_vec()]).unwrap();
        assert_eq!(resp.labels.len(), 1);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_scoring_requests() {
        let c = quick_coordinator();
        let ds = SlabConfig::default().generate(120, 85);
        c.train_blocking("m", &ds, &Trainer::default().kernel(Kernel::Linear))
            .unwrap();
        let eval = SlabConfig::default().generate_eval(100, 100, 86);
        let receivers: Vec<_> = (0..eval.len())
            .map(|i| c.score_async("m", vec![eval.x.row(i).to_vec()]))
            .collect();
        let model = c.model("m").unwrap();
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.labels.len(), 1);
            assert_eq!(resp.labels[0], model.classify(eval.x.row(i)));
        }
        assert!(c.stats().scored.get() >= 200);
        c.shutdown();
    }
}
