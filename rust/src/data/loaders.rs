//! File loaders: CSV and libsvm/svmlight formats, plus CSV export.
//!
//! CSV: one sample per line, comma-separated features; an optional final
//! `label` column (+1/-1) is detected via [`CsvOptions::labeled`].
//! libsvm: `label idx:val idx:val ...` with 1-based sparse indices.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use super::Dataset;
use crate::error::{Error, Result};
use crate::linalg::Matrix;

/// CSV parsing options.
#[derive(Clone, Copy, Debug, Default)]
pub struct CsvOptions {
    /// first line is a header to skip
    pub header: bool,
    /// last column is the +1/-1 label
    pub labeled: bool,
}

/// Load a dense CSV file.
pub fn load_csv(path: impl AsRef<Path>, opts: CsvOptions) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())?;
    let reader = BufReader::new(f);
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut labels: Vec<i8> = Vec::new();
    let mut width = None;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        if lineno == 0 && opts.header {
            continue;
        }
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut vals: Vec<f64> = Vec::new();
        for tok in t.split(',') {
            let v: f64 = tok.trim().parse().map_err(|_| {
                Error::data(format!("line {}: bad number {tok:?}", lineno + 1))
            })?;
            vals.push(v);
        }
        if opts.labeled {
            let l = vals.pop().ok_or_else(|| {
                Error::data(format!("line {}: empty row", lineno + 1))
            })?;
            labels.push(if l > 0.0 { 1 } else { -1 });
        }
        match width {
            None => width = Some(vals.len()),
            Some(w) if w != vals.len() => {
                return Err(Error::data(format!(
                    "line {}: expected {w} features, got {}",
                    lineno + 1,
                    vals.len()
                )))
            }
            _ => {}
        }
        rows.push(vals);
    }

    let d = width.unwrap_or(0);
    let n = rows.len();
    if n == 0 {
        return Err(Error::data("empty CSV file".to_string()));
    }
    let mut data = Vec::with_capacity(n * d);
    for r in rows {
        data.extend(r);
    }
    let x = Matrix::from_vec(n, d, data);
    Ok(if opts.labeled {
        Dataset::new(x, labels)
    } else {
        Dataset::unlabeled(x)
    })
}

/// Write a dataset to CSV (features then label).
pub fn save_csv(ds: &Dataset, path: impl AsRef<Path>, labeled: bool) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())?;
    for i in 0..ds.len() {
        let feats: Vec<String> =
            ds.x.row(i).iter().map(|v| format!("{v}")).collect();
        if labeled {
            writeln!(f, "{},{}", feats.join(","), ds.y[i])?;
        } else {
            writeln!(f, "{}", feats.join(","))?;
        }
    }
    Ok(())
}

/// Load a libsvm/svmlight sparse file into a dense matrix.
/// `dim` pads/validates the feature count; pass 0 to infer from data.
pub fn load_libsvm(path: impl AsRef<Path>, dim: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())?;
    let reader = BufReader::new(f);
    let mut entries: Vec<(i8, Vec<(usize, f64)>)> = Vec::new();
    let mut max_idx = 0usize;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut parts = t.split_whitespace();
        let label_tok = parts.next().unwrap();
        let label: f64 = label_tok.parse().map_err(|_| {
            Error::data(format!("line {}: bad label {label_tok:?}", lineno + 1))
        })?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok.split_once(':').ok_or_else(|| {
                Error::data(format!("line {}: bad pair {tok:?}", lineno + 1))
            })?;
            let i: usize = i.parse().map_err(|_| {
                Error::data(format!("line {}: bad index {i:?}", lineno + 1))
            })?;
            if i == 0 {
                return Err(Error::data(format!(
                    "line {}: libsvm indices are 1-based",
                    lineno + 1
                )));
            }
            let v: f64 = v.parse().map_err(|_| {
                Error::data(format!("line {}: bad value {v:?}", lineno + 1))
            })?;
            max_idx = max_idx.max(i);
            feats.push((i - 1, v));
        }
        entries.push((if label > 0.0 { 1 } else { -1 }, feats));
    }

    if entries.is_empty() {
        return Err(Error::data("empty libsvm file".to_string()));
    }
    let d = if dim > 0 {
        if max_idx > dim {
            return Err(Error::data(format!(
                "feature index {max_idx} exceeds declared dim {dim}"
            )));
        }
        dim
    } else {
        max_idx
    };
    let n = entries.len();
    let mut x = Matrix::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for (r, (label, feats)) in entries.into_iter().enumerate() {
        y.push(label);
        for (c, v) in feats {
            x.set(r, c, v);
        }
    }
    Ok(Dataset::new(x, y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn tmpfile(content: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "slabsvm_test_{}_{}.txt",
            std::process::id(),
            content.len()
        ));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn csv_roundtrip() {
        let p = tmpfile("1.0,2.0,1\n3.0,4.0,-1\n");
        let ds =
            load_csv(&p, CsvOptions { header: false, labeled: true }).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 2);
        assert_eq!(ds.y, vec![1, -1]);
        assert_eq!(ds.x.row(1), &[3.0, 4.0]);

        let p2 = p.with_extension("out.csv");
        save_csv(&ds, &p2, true).unwrap();
        let ds2 =
            load_csv(&p2, CsvOptions { header: false, labeled: true }).unwrap();
        assert_eq!(ds2.x.data(), ds.x.data());
        assert_eq!(ds2.y, ds.y);
        std::fs::remove_file(p).ok();
        std::fs::remove_file(p2).ok();
    }

    #[test]
    fn csv_header_and_comments() {
        let p = tmpfile("a,b\n# comment\n1.5,2.5\n");
        let ds =
            load_csv(&p, CsvOptions { header: true, labeled: false }).unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds.x.row(0), &[1.5, 2.5]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_ragged_rejected() {
        let p = tmpfile("1,2\n3\n");
        assert!(load_csv(&p, CsvOptions::default()).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn csv_bad_number_rejected() {
        let p = tmpfile("1,abc\n");
        assert!(load_csv(&p, CsvOptions::default()).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn libsvm_parses_sparse() {
        let p = tmpfile("+1 1:0.5 3:1.5\n-1 2:2.0\n");
        let ds = load_libsvm(&p, 0).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.x.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(ds.x.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1, -1]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn libsvm_zero_index_rejected() {
        let p = tmpfile("+1 0:0.5\n");
        assert!(load_libsvm(&p, 0).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn libsvm_dim_validation() {
        let p = tmpfile("+1 5:1.0\n");
        assert!(load_libsvm(&p, 3).is_err());
        let ds = load_libsvm(&p, 8).unwrap();
        assert_eq!(ds.dim(), 8);
        std::fs::remove_file(p).ok();
    }
}
