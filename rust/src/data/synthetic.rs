//! Synthetic dataset generators.
//!
//! [`SlabConfig`] is the documented stand-in for the paper's undisclosed
//! "toy dataset" (DESIGN.md §Substitutions): 2-D points spread along a
//! linear trend with perpendicular noise, i.e. exactly the geometry the
//! paper's Fig. 1/2 show (a band of blue points that two parallel lines
//! enclose). Negative/anomaly samples for MCC evaluation are drawn *off*
//! the band.
//!
//! Additional generators back the example applications:
//! * [`gaussian_blob`] / [`blobs`] — cluster data for anomaly detection;
//! * [`annulus`] — ring data (non-linear slab, exercises RBF);
//! * [`open_set`] — multi-class mixture where training sees a single
//!   class and evaluation mixes in unseen classes (open-set recognition).

use super::Dataset;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Noise law for the perpendicular spread of the slab band.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Noise {
    Gaussian,
    Laplace,
}

/// Configuration of the slab2d generator.
#[derive(Clone, Debug)]
pub struct SlabConfig {
    /// unit direction of the band (angle in radians vs x-axis)
    pub angle: f64,
    /// offset of the band's center line from the origin
    pub offset: f64,
    /// half-length of the band along its direction
    pub half_len: f64,
    /// scale of the perpendicular noise (sd for gaussian, b for laplace)
    pub spread: f64,
    /// noise law
    pub noise: Noise,
    /// fraction of training points replaced by off-band contamination
    /// (the "expected anomalies in the data" that nu models)
    pub contamination: f64,
}

impl Default for SlabConfig {
    fn default() -> Self {
        SlabConfig {
            angle: 0.45,        // ~26°: visibly tilted like the figures
            // The band sits well away from the origin. This is REQUIRED
            // for the linear kernel: the OCSSVM dual degenerates to the
            // w = 0 solution whenever the data's radial spread allows
            // kernel-space cancellation — concretely, a slab exists only
            // if R_min/R_max > ε over the data's distances to the origin
            // (DESIGN.md §Findings). offset=20 with half_len=3 gives a
            // ratio ≈ 0.92, comfortably above the paper's ε = 2/3.
            offset: 20.0,
            half_len: 3.0,
            spread: 0.25,
            noise: Noise::Gaussian,
            contamination: 0.02,
        }
    }
}

impl SlabConfig {
    /// Band direction unit vector.
    fn dir(&self) -> [f64; 2] {
        [self.angle.cos(), self.angle.sin()]
    }
    /// Perpendicular unit vector (normal of the slab hyperplanes).
    pub fn normal(&self) -> [f64; 2] {
        [-self.angle.sin(), self.angle.cos()]
    }

    fn sample_noise(&self, rng: &mut Rng) -> f64 {
        match self.noise {
            Noise::Gaussian => rng.normal() * self.spread,
            Noise::Laplace => rng.laplace(self.spread),
        }
    }

    /// One on-band point.
    fn sample_on(&self, rng: &mut Rng) -> [f64; 2] {
        let t = rng.uniform_range(-self.half_len, self.half_len);
        let p = self.sample_noise(rng);
        let d = self.dir();
        let n = self.normal();
        [
            t * d[0] + (self.offset + p) * n[0],
            t * d[1] + (self.offset + p) * n[1],
        ]
    }

    /// One off-band (anomalous) point: perpendicular displacement pushed
    /// outside ~4 spreads, either side.
    fn sample_off(&self, rng: &mut Rng) -> [f64; 2] {
        let t = rng.uniform_range(-self.half_len, self.half_len);
        let side = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        let p = side * (self.spread * 4.0 + rng.uniform_range(0.0, self.spread * 8.0));
        let d = self.dir();
        let n = self.normal();
        [
            t * d[0] + (self.offset + p) * n[0],
            t * d[1] + (self.offset + p) * n[1],
        ]
    }

    /// One-class training set of `m` points (contaminated per config).
    pub fn generate(&self, m: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(m * 2);
        for _ in 0..m {
            let p = if rng.uniform() < self.contamination {
                self.sample_off(&mut rng)
            } else {
                self.sample_on(&mut rng)
            };
            data.extend_from_slice(&p);
        }
        Dataset::unlabeled(Matrix::from_vec(m, 2, data))
    }

    /// Labeled evaluation set: `n_pos` on-band (+1) + `n_neg` off-band (-1).
    pub fn generate_eval(&self, n_pos: usize, n_neg: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x5eed_0ff5);
        let mut data = Vec::with_capacity((n_pos + n_neg) * 2);
        let mut y = Vec::with_capacity(n_pos + n_neg);
        for _ in 0..n_pos {
            data.extend_from_slice(&self.sample_on(&mut rng));
            y.push(1);
        }
        for _ in 0..n_neg {
            data.extend_from_slice(&self.sample_off(&mut rng));
            y.push(-1);
        }
        Dataset::new(Matrix::from_vec(n_pos + n_neg, 2, data), y)
    }

    /// Signed perpendicular coordinate of a point (distance from the
    /// band's center line along the slab normal). Ground truth used by
    /// geometry tests: on-band points have |perp - offset| small.
    pub fn perp_coord(&self, p: &[f64]) -> f64 {
        let n = self.normal();
        p[0] * n[0] + p[1] * n[1]
    }
}

/// Isotropic gaussian blob around `center`.
pub fn gaussian_blob(
    center: &[f64],
    sd: f64,
    n: usize,
    rng: &mut Rng,
) -> Matrix {
    let d = center.len();
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        for &c in center {
            data.push(rng.normal_ms(c, sd));
        }
    }
    Matrix::from_vec(n, d, data)
}

/// Mixture of equally-weighted blobs; returns (x, component-id).
pub fn blobs(
    centers: &[&[f64]],
    sd: f64,
    n: usize,
    seed: u64,
) -> (Matrix, Vec<usize>) {
    assert!(!centers.is_empty());
    let d = centers[0].len();
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * d);
    let mut comp = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(centers.len());
        for &v in centers[c] {
            data.push(rng.normal_ms(v, sd));
        }
        comp.push(c);
    }
    (Matrix::from_vec(n, d, data), comp)
}

/// Annulus (ring) in 2-D: radius ~ N(radius, sd), angle uniform.
/// A slab in RBF feature space encloses it; linear kernels cannot.
pub fn annulus(radius: f64, sd: f64, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let r = rng.normal_ms(radius, sd);
        let a = rng.uniform_range(0.0, std::f64::consts::TAU);
        data.push(r * a.cos());
        data.push(r * a.sin());
    }
    Dataset::unlabeled(Matrix::from_vec(n, 2, data))
}

/// Open-set recognition scenario: `k` gaussian classes on a circle of
/// radius `sep`; training data comes from class 0 only, the eval set
/// mixes all classes (class 0 labeled +1, the unseen ones -1).
pub struct OpenSet {
    pub train: Dataset,
    pub eval: Dataset,
}

pub fn open_set(k: usize, sep: f64, sd: f64, m: usize, n_eval: usize, seed: u64) -> OpenSet {
    assert!(k >= 2);
    let mut rng = Rng::new(seed);
    let centers: Vec<[f64; 2]> = (0..k)
        .map(|i| {
            let a = std::f64::consts::TAU * i as f64 / k as f64;
            [sep * a.cos(), sep * a.sin()]
        })
        .collect();

    let train_x = gaussian_blob(&centers[0], sd, m, &mut rng);

    let mut data = Vec::with_capacity(n_eval * 2);
    let mut y = Vec::with_capacity(n_eval);
    for _ in 0..n_eval {
        let c = rng.below(k);
        let p = gaussian_blob(&centers[c], sd, 1, &mut rng);
        data.extend_from_slice(p.row(0));
        y.push(if c == 0 { 1 } else { -1 });
    }
    OpenSet {
        train: Dataset::unlabeled(train_x),
        eval: Dataset::new(Matrix::from_vec(n_eval, 2, data), y),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_shapes_and_determinism() {
        let cfg = SlabConfig::default();
        let a = cfg.generate(500, 42);
        let b = cfg.generate(500, 42);
        assert_eq!(a.len(), 500);
        assert_eq!(a.dim(), 2);
        assert_eq!(a.x.data(), b.x.data());
        let c = cfg.generate(500, 43);
        assert_ne!(a.x.data(), c.x.data());
    }

    #[test]
    fn slab_band_geometry() {
        // Perp coordinates of clean on-band points concentrate near offset.
        let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
        let ds = cfg.generate(2000, 7);
        let perps: Vec<f64> =
            (0..ds.len()).map(|i| cfg.perp_coord(ds.x.row(i))).collect();
        let mean = crate::linalg::mean(&perps);
        let sd = crate::linalg::std_dev(&perps);
        assert!((mean - cfg.offset).abs() < 0.03, "mean perp {mean}");
        assert!((sd - cfg.spread).abs() < 0.03, "perp sd {sd}");
    }

    #[test]
    fn eval_negatives_are_off_band() {
        let cfg = SlabConfig::default();
        let ev = cfg.generate_eval(200, 200, 3);
        for i in 0..ev.len() {
            let dev = (cfg.perp_coord(ev.x.row(i)) - cfg.offset).abs();
            if ev.y[i] < 0 {
                assert!(dev >= cfg.spread * 3.9, "negative too close: {dev}");
            }
        }
        assert_eq!(ev.positives(), 200);
    }

    #[test]
    fn contamination_rate_respected() {
        let cfg = SlabConfig { contamination: 0.2, ..Default::default() };
        let ds = cfg.generate(5000, 11);
        let off = (0..ds.len())
            .filter(|&i| (cfg.perp_coord(ds.x.row(i)) - cfg.offset).abs() > cfg.spread * 3.5)
            .count();
        let rate = off as f64 / ds.len() as f64;
        assert!((rate - 0.2).abs() < 0.03, "contamination rate {rate}");
    }

    #[test]
    fn annulus_radius() {
        let ds = annulus(3.0, 0.1, 1000, 5);
        for i in 0..ds.len() {
            let p = ds.x.row(i);
            let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
            assert!((r - 3.0).abs() < 1.0);
        }
    }

    #[test]
    fn open_set_labels() {
        let os = open_set(4, 5.0, 0.4, 300, 400, 9);
        assert_eq!(os.train.len(), 300);
        assert_eq!(os.eval.len(), 400);
        let pos = os.eval.positives();
        // class 0 is ~1/4 of eval
        assert!(pos > 50 && pos < 150, "pos={pos}");
        // train data sits near the class-0 center (sep, 0)
        let mx = crate::linalg::mean(
            &(0..os.train.len()).map(|i| os.train.x.get(i, 0)).collect::<Vec<_>>(),
        );
        assert!((mx - 5.0).abs() < 0.2);
    }

    #[test]
    fn blobs_components() {
        let (x, comp) = blobs(&[&[0.0, 0.0], &[10.0, 10.0]], 0.5, 400, 21);
        for i in 0..x.rows() {
            let near0 = x.get(i, 0).abs() < 5.0;
            assert_eq!(near0, comp[i] == 0, "row {i} mislabeled");
        }
    }
}
