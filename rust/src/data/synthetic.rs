//! Synthetic dataset generators.
//!
//! [`SlabConfig`] is the documented stand-in for the paper's undisclosed
//! "toy dataset" (DESIGN.md §Substitutions): 2-D points spread along a
//! linear trend with perpendicular noise, i.e. exactly the geometry the
//! paper's Fig. 1/2 show (a band of blue points that two parallel lines
//! enclose). Negative/anomaly samples for MCC evaluation are drawn *off*
//! the band.
//!
//! Additional generators back the example applications:
//! * [`gaussian_blob`] / [`blobs`] — cluster data for anomaly detection;
//! * [`annulus`] — ring data (non-linear slab, exercises RBF);
//! * [`open_set`] — multi-class mixture where training sees a single
//!   class and evaluation mixes in unseen classes (open-set recognition).

use super::Dataset;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Noise law for the perpendicular spread of the slab band.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Noise {
    Gaussian,
    Laplace,
}

/// Configuration of the slab2d generator.
#[derive(Clone, Debug)]
pub struct SlabConfig {
    /// unit direction of the band (angle in radians vs x-axis)
    pub angle: f64,
    /// offset of the band's center line from the origin
    pub offset: f64,
    /// half-length of the band along its direction
    pub half_len: f64,
    /// scale of the perpendicular noise (sd for gaussian, b for laplace)
    pub spread: f64,
    /// noise law
    pub noise: Noise,
    /// fraction of training points replaced by off-band contamination
    /// (the "expected anomalies in the data" that nu models)
    pub contamination: f64,
}

impl Default for SlabConfig {
    fn default() -> Self {
        SlabConfig {
            angle: 0.45,        // ~26°: visibly tilted like the figures
            // The band sits well away from the origin. This is REQUIRED
            // for the linear kernel: the OCSSVM dual degenerates to the
            // w = 0 solution whenever the data's radial spread allows
            // kernel-space cancellation — concretely, a slab exists only
            // if R_min/R_max > ε over the data's distances to the origin
            // (DESIGN.md §Findings). offset=20 with half_len=3 gives a
            // ratio ≈ 0.92, comfortably above the paper's ε = 2/3.
            offset: 20.0,
            half_len: 3.0,
            spread: 0.25,
            noise: Noise::Gaussian,
            contamination: 0.02,
        }
    }
}

impl SlabConfig {
    /// Band direction unit vector.
    fn dir(&self) -> [f64; 2] {
        [self.angle.cos(), self.angle.sin()]
    }
    /// Perpendicular unit vector (normal of the slab hyperplanes).
    pub fn normal(&self) -> [f64; 2] {
        [-self.angle.sin(), self.angle.cos()]
    }

    fn sample_noise(&self, rng: &mut Rng) -> f64 {
        match self.noise {
            Noise::Gaussian => rng.normal() * self.spread,
            Noise::Laplace => rng.laplace(self.spread),
        }
    }

    /// One on-band point.
    fn sample_on(&self, rng: &mut Rng) -> [f64; 2] {
        let t = rng.uniform_range(-self.half_len, self.half_len);
        let p = self.sample_noise(rng);
        let d = self.dir();
        let n = self.normal();
        [
            t * d[0] + (self.offset + p) * n[0],
            t * d[1] + (self.offset + p) * n[1],
        ]
    }

    /// One off-band (anomalous) point: perpendicular displacement pushed
    /// outside ~4 spreads, either side.
    fn sample_off(&self, rng: &mut Rng) -> [f64; 2] {
        let t = rng.uniform_range(-self.half_len, self.half_len);
        let side = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        let p = side * (self.spread * 4.0 + rng.uniform_range(0.0, self.spread * 8.0));
        let d = self.dir();
        let n = self.normal();
        [
            t * d[0] + (self.offset + p) * n[0],
            t * d[1] + (self.offset + p) * n[1],
        ]
    }

    /// One training draw: on-band, or off-band with probability
    /// `contamination`.
    fn sample_train(&self, rng: &mut Rng) -> [f64; 2] {
        if rng.uniform() < self.contamination {
            self.sample_off(rng)
        } else {
            self.sample_on(rng)
        }
    }

    /// One-class training set of `m` points (contaminated per config).
    pub fn generate(&self, m: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(m * 2);
        for _ in 0..m {
            data.extend_from_slice(&self.sample_train(&mut rng));
        }
        Dataset::unlabeled(Matrix::from_vec(m, 2, data))
    }

    /// Labeled evaluation set: `n_pos` on-band (+1) + `n_neg` off-band (-1).
    pub fn generate_eval(&self, n_pos: usize, n_neg: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x5eed_0ff5);
        let mut data = Vec::with_capacity((n_pos + n_neg) * 2);
        let mut y = Vec::with_capacity(n_pos + n_neg);
        for _ in 0..n_pos {
            data.extend_from_slice(&self.sample_on(&mut rng));
            y.push(1);
        }
        for _ in 0..n_neg {
            data.extend_from_slice(&self.sample_off(&mut rng));
            y.push(-1);
        }
        Dataset::new(Matrix::from_vec(n_pos + n_neg, 2, data), y)
    }

    /// Signed perpendicular coordinate of a point (distance from the
    /// band's center line along the slab normal). Ground truth used by
    /// geometry tests: on-band points have |perp - offset| small.
    pub fn perp_coord(&self, p: &[f64]) -> f64 {
        let n = self.normal();
        p[0] * n[0] + p[1] * n[1]
    }
}

// --------------------------------------------------------------- drift

/// How a [`SlabStream`]'s band evolves over a span of the stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Drift {
    /// the band's perpendicular offset moves by `delta` (mean shift)
    MeanShift { delta: f64 },
    /// the perpendicular spread is multiplied by `factor` (variance
    /// inflation; `factor < 1` deflates)
    VarianceInflation { factor: f64 },
    /// the band's direction rotates by `delta` radians (gradual rotation)
    Rotation { delta: f64 },
}

/// One drift episode: ramps linearly from `start` over `duration`
/// samples, then stays fully applied (`duration = 0` is a step change).
#[derive(Clone, Copy, Debug)]
pub struct DriftSchedule {
    pub drift: Drift,
    /// sample index the ramp begins at
    pub start: usize,
    /// samples the ramp spans
    pub duration: usize,
}

impl DriftSchedule {
    /// Ramp progress in [0, 1] at sample `t`.
    fn progress(&self, t: usize) -> f64 {
        if t < self.start {
            0.0
        } else if self.duration == 0 || t >= self.start + self.duration {
            1.0
        } else {
            (t - self.start) as f64 / self.duration as f64
        }
    }
}

/// Unbounded, seeded-deterministic sample stream over an evolving slab
/// band — the workload generator for the streaming subsystem (stream
/// CLI, `benches/streaming.rs`, the drift E2E tests). Two streams built
/// with the same base config, schedules and seed produce identical
/// samples.
pub struct SlabStream {
    base: SlabConfig,
    schedules: Vec<DriftSchedule>,
    rng: Rng,
    t: usize,
}

impl SlabStream {
    pub fn new(base: SlabConfig, seed: u64) -> SlabStream {
        SlabStream { base, schedules: Vec::new(), rng: Rng::new(seed), t: 0 }
    }

    /// Add a drift episode (builder style; episodes compose additively).
    pub fn with_drift(mut self, schedule: DriftSchedule) -> SlabStream {
        self.schedules.push(schedule);
        self
    }

    /// Samples drawn so far.
    pub fn position(&self) -> usize {
        self.t
    }

    /// The effective band configuration at sample `t`, all scheduled
    /// drifts applied at their ramp progress.
    pub fn config_at(&self, t: usize) -> SlabConfig {
        let mut cfg = self.base.clone();
        for s in &self.schedules {
            let p = s.progress(t);
            if p == 0.0 {
                continue;
            }
            match s.drift {
                Drift::MeanShift { delta } => cfg.offset += p * delta,
                Drift::VarianceInflation { factor } => {
                    cfg.spread *= 1.0 + p * (factor - 1.0)
                }
                Drift::Rotation { delta } => cfg.angle += p * delta,
            }
        }
        cfg
    }

    /// Draw the next sample from the band as it stands right now.
    pub fn next_point(&mut self) -> [f64; 2] {
        let cfg = self.config_at(self.t);
        self.t += 1;
        cfg.sample_train(&mut self.rng)
    }

    /// Draw `n` samples into a matrix (row per sample).
    pub fn take(&mut self, n: usize) -> Matrix {
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            data.extend_from_slice(&self.next_point());
        }
        Matrix::from_vec(n, 2, data)
    }
}

/// Isotropic gaussian blob around `center`.
pub fn gaussian_blob(
    center: &[f64],
    sd: f64,
    n: usize,
    rng: &mut Rng,
) -> Matrix {
    let d = center.len();
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        for &c in center {
            data.push(rng.normal_ms(c, sd));
        }
    }
    Matrix::from_vec(n, d, data)
}

/// Mixture of equally-weighted blobs; returns (x, component-id).
pub fn blobs(
    centers: &[&[f64]],
    sd: f64,
    n: usize,
    seed: u64,
) -> (Matrix, Vec<usize>) {
    assert!(!centers.is_empty());
    let d = centers[0].len();
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * d);
    let mut comp = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(centers.len());
        for &v in centers[c] {
            data.push(rng.normal_ms(v, sd));
        }
        comp.push(c);
    }
    (Matrix::from_vec(n, d, data), comp)
}

/// Annulus (ring) in 2-D: radius ~ N(radius, sd), angle uniform.
/// A slab in RBF feature space encloses it; linear kernels cannot.
pub fn annulus(radius: f64, sd: f64, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let r = rng.normal_ms(radius, sd);
        let a = rng.uniform_range(0.0, std::f64::consts::TAU);
        data.push(r * a.cos());
        data.push(r * a.sin());
    }
    Dataset::unlabeled(Matrix::from_vec(n, 2, data))
}

/// Open-set recognition scenario: `k` gaussian classes on a circle of
/// radius `sep`; training data comes from class 0 only, the eval set
/// mixes all classes (class 0 labeled +1, the unseen ones -1).
pub struct OpenSet {
    pub train: Dataset,
    pub eval: Dataset,
}

pub fn open_set(k: usize, sep: f64, sd: f64, m: usize, n_eval: usize, seed: u64) -> OpenSet {
    assert!(k >= 2);
    let mut rng = Rng::new(seed);
    let centers: Vec<[f64; 2]> = (0..k)
        .map(|i| {
            let a = std::f64::consts::TAU * i as f64 / k as f64;
            [sep * a.cos(), sep * a.sin()]
        })
        .collect();

    let train_x = gaussian_blob(&centers[0], sd, m, &mut rng);

    let mut data = Vec::with_capacity(n_eval * 2);
    let mut y = Vec::with_capacity(n_eval);
    for _ in 0..n_eval {
        let c = rng.below(k);
        let p = gaussian_blob(&centers[c], sd, 1, &mut rng);
        data.extend_from_slice(p.row(0));
        y.push(if c == 0 { 1 } else { -1 });
    }
    OpenSet {
        train: Dataset::unlabeled(train_x),
        eval: Dataset::new(Matrix::from_vec(n_eval, 2, data), y),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_shapes_and_determinism() {
        let cfg = SlabConfig::default();
        let a = cfg.generate(500, 42);
        let b = cfg.generate(500, 42);
        assert_eq!(a.len(), 500);
        assert_eq!(a.dim(), 2);
        assert_eq!(a.x.data(), b.x.data());
        let c = cfg.generate(500, 43);
        assert_ne!(a.x.data(), c.x.data());
    }

    #[test]
    fn slab_band_geometry() {
        // Perp coordinates of clean on-band points concentrate near offset.
        let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
        let ds = cfg.generate(2000, 7);
        let perps: Vec<f64> =
            (0..ds.len()).map(|i| cfg.perp_coord(ds.x.row(i))).collect();
        let mean = crate::linalg::mean(&perps);
        let sd = crate::linalg::std_dev(&perps);
        assert!((mean - cfg.offset).abs() < 0.03, "mean perp {mean}");
        assert!((sd - cfg.spread).abs() < 0.03, "perp sd {sd}");
    }

    #[test]
    fn eval_negatives_are_off_band() {
        let cfg = SlabConfig::default();
        let ev = cfg.generate_eval(200, 200, 3);
        for i in 0..ev.len() {
            let dev = (cfg.perp_coord(ev.x.row(i)) - cfg.offset).abs();
            if ev.y[i] < 0 {
                assert!(dev >= cfg.spread * 3.9, "negative too close: {dev}");
            }
        }
        assert_eq!(ev.positives(), 200);
    }

    #[test]
    fn contamination_rate_respected() {
        let cfg = SlabConfig { contamination: 0.2, ..Default::default() };
        let ds = cfg.generate(5000, 11);
        let off = (0..ds.len())
            .filter(|&i| (cfg.perp_coord(ds.x.row(i)) - cfg.offset).abs() > cfg.spread * 3.5)
            .count();
        let rate = off as f64 / ds.len() as f64;
        assert!((rate - 0.2).abs() < 0.03, "contamination rate {rate}");
    }

    #[test]
    fn slab_stream_is_deterministic_and_matches_base_before_drift() {
        let mk = || {
            SlabStream::new(SlabConfig::default(), 77).with_drift(DriftSchedule {
                drift: Drift::MeanShift { delta: -10.0 },
                start: 50,
                duration: 20,
            })
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..120 {
            assert_eq!(a.next_point(), b.next_point());
        }
        assert_eq!(a.position(), 120);
    }

    #[test]
    fn mean_shift_ramps_then_holds() {
        let s = SlabStream::new(SlabConfig::default(), 1).with_drift(
            DriftSchedule {
                drift: Drift::MeanShift { delta: -8.0 },
                start: 100,
                duration: 40,
            },
        );
        let base = SlabConfig::default().offset;
        assert_eq!(s.config_at(0).offset, base);
        assert_eq!(s.config_at(99).offset, base);
        let mid = s.config_at(120).offset; // halfway through the ramp
        assert!((mid - (base - 4.0)).abs() < 1e-12, "mid={mid}");
        assert_eq!(s.config_at(140).offset, base - 8.0);
        assert_eq!(s.config_at(10_000).offset, base - 8.0);
    }

    #[test]
    fn variance_inflation_scales_perpendicular_spread() {
        let s = SlabStream::new(
            SlabConfig { contamination: 0.0, ..Default::default() },
            2,
        )
        .with_drift(
            DriftSchedule {
                drift: Drift::VarianceInflation { factor: 3.0 },
                start: 0,
                duration: 0, // step
            },
        );
        let cfg = s.config_at(5);
        assert!((cfg.spread - SlabConfig::default().spread * 3.0).abs() < 1e-12);
        // drawn points really spread wider (perp sd ≈ 3x base)
        let mut s = s;
        let pts = s.take(3000);
        let perps: Vec<f64> =
            (0..3000).map(|i| cfg.perp_coord(pts.row(i))).collect();
        let sd = crate::linalg::std_dev(&perps);
        assert!((sd - cfg.spread).abs() < 0.1, "sd={sd} want≈{}", cfg.spread);
    }

    #[test]
    fn rotation_turns_the_band_direction() {
        let s = SlabStream::new(
            SlabConfig { contamination: 0.0, ..Default::default() },
            3,
        )
        .with_drift(DriftSchedule {
            drift: Drift::Rotation { delta: 0.3 },
            start: 0,
            duration: 0,
        });
        let rotated = s.config_at(1);
        assert!((rotated.angle - (0.45 + 0.3)).abs() < 1e-12);
        // points concentrate around the ROTATED band's center line
        let mut s = s;
        let pts = s.take(2000);
        let perps: Vec<f64> =
            (0..2000).map(|i| rotated.perp_coord(pts.row(i))).collect();
        let mean = crate::linalg::mean(&perps);
        assert!((mean - rotated.offset).abs() < 0.05, "mean perp {mean}");
    }

    #[test]
    fn composed_drifts_apply_additively() {
        let s = SlabStream::new(SlabConfig::default(), 4)
            .with_drift(DriftSchedule {
                drift: Drift::MeanShift { delta: 2.0 },
                start: 0,
                duration: 0,
            })
            .with_drift(DriftSchedule {
                drift: Drift::VarianceInflation { factor: 2.0 },
                start: 0,
                duration: 0,
            });
        let cfg = s.config_at(1);
        assert!((cfg.offset - 22.0).abs() < 1e-12);
        assert!((cfg.spread - 0.5).abs() < 1e-12);
    }

    #[test]
    fn annulus_radius() {
        let ds = annulus(3.0, 0.1, 1000, 5);
        for i in 0..ds.len() {
            let p = ds.x.row(i);
            let r = (p[0] * p[0] + p[1] * p[1]).sqrt();
            assert!((r - 3.0).abs() < 1.0);
        }
    }

    #[test]
    fn open_set_labels() {
        let os = open_set(4, 5.0, 0.4, 300, 400, 9);
        assert_eq!(os.train.len(), 300);
        assert_eq!(os.eval.len(), 400);
        let pos = os.eval.positives();
        // class 0 is ~1/4 of eval
        assert!(pos > 50 && pos < 150, "pos={pos}");
        // train data sits near the class-0 center (sep, 0)
        let mx = crate::linalg::mean(
            &(0..os.train.len()).map(|i| os.train.x.get(i, 0)).collect::<Vec<_>>(),
        );
        assert!((mx - 5.0).abs() < 0.2);
    }

    #[test]
    fn blobs_components() {
        let (x, comp) = blobs(&[&[0.0, 0.0], &[10.0, 10.0]], 0.5, 400, 21);
        for i in 0..x.rows() {
            let near0 = x.get(i, 0).abs() < 5.0;
            assert_eq!(near0, comp[i] == 0, "row {i} mislabeled");
        }
    }
}
