//! Datasets: synthetic generators + file loaders + splits.
//!
//! The paper evaluates on an undisclosed 2-D "toy dataset"; DESIGN.md
//! §Substitutions defines the documented equivalent ([`synthetic::SlabConfig`],
//! a noisy linear band) plus additional generators for the example
//! applications (gaussian blobs, annulus, open-set multi-class). Loaders
//! read CSV and libsvm-format files so real data can be plugged in.

pub mod cv;
pub mod loaders;
pub mod preprocess;
pub mod synthetic;

use crate::linalg::Matrix;

/// A (possibly labeled) dataset. One-class *training* sets have all-(+1)
/// labels; *evaluation* sets carry +1 (target class) / -1 (anomaly).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// samples, row-major [n, d]
    pub x: Matrix,
    /// +1 target / -1 anomaly
    pub y: Vec<i8>,
}

impl Dataset {
    pub fn new(x: Matrix, y: Vec<i8>) -> Self {
        assert_eq!(x.rows(), y.len(), "label/sample count mismatch");
        Dataset { x, y }
    }

    /// All-positive dataset (one-class training).
    pub fn unlabeled(x: Matrix) -> Self {
        let n = x.rows();
        Dataset { x, y: vec![1; n] }
    }

    pub fn len(&self) -> usize {
        self.x.rows()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Count of positive labels.
    pub fn positives(&self) -> usize {
        self.y.iter().filter(|&&l| l > 0).count()
    }

    /// Subset by row indices.
    pub fn select(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
        }
    }

    /// Keep only positive samples (turn an eval set into a train set).
    pub fn positives_only(&self) -> Dataset {
        let idx: Vec<usize> = (0..self.len()).filter(|&i| self.y[i] > 0).collect();
        self.select(&idx)
    }

    /// Deterministic shuffled train/test split: `train_frac` of rows into
    /// the first returned set.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        assert!((0.0..=1.0).contains(&train_frac));
        let mut idx: Vec<usize> = (0..self.len()).collect();
        let mut rng = crate::util::rng::Rng::new(seed);
        rng.shuffle(&mut idx);
        let k = (self.len() as f64 * train_frac).round() as usize;
        (self.select(&idx[..k]), self.select(&idx[k..]))
    }

    /// Merge two datasets (used to assemble eval sets).
    pub fn concat(&self, other: &Dataset) -> Dataset {
        assert_eq!(self.dim(), other.dim());
        let mut y = self.y.clone();
        y.extend_from_slice(&other.y);
        Dataset { x: self.x.vstack(&other.x), y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_rows(&[
            &[0.0, 0.0],
            &[1.0, 1.0],
            &[2.0, 2.0],
            &[3.0, 3.0],
        ]);
        Dataset::new(x, vec![1, -1, 1, -1])
    }

    #[test]
    fn select_and_positives() {
        let d = toy();
        assert_eq!(d.positives(), 2);
        let p = d.positives_only();
        assert_eq!(p.len(), 2);
        assert_eq!(p.x.row(1), &[2.0, 2.0]);
    }

    #[test]
    fn split_partitions() {
        let d = toy();
        let (a, b) = d.split(0.5, 7);
        assert_eq!(a.len() + b.len(), d.len());
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn concat_stacks() {
        let d = toy();
        let c = d.concat(&d);
        assert_eq!(c.len(), 8);
        assert_eq!(c.y.len(), 8);
    }

    #[test]
    #[should_panic]
    fn mismatched_labels_panic() {
        Dataset::new(Matrix::zeros(3, 2), vec![1, -1]);
    }
}
