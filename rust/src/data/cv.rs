//! Model selection: k-fold cross-validation + grid search for the
//! one-class setting.
//!
//! One-class CV differs from supervised CV: training folds contain only
//! target-class data; the held-out fold provides the positive test half
//! and the caller supplies negatives (synthetic anomalies or a labeled
//! pool) for the metric. [`grid_search`] sweeps (ν₁, ν₂, ε) × kernel
//! candidates and ranks by mean held-out MCC.

use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::metrics::Confusion;
use crate::solver::api::Trainer;
use crate::solver::smo::SmoParams;
use crate::util::rng::Rng;
use crate::Result;

/// Deterministic k-fold index split.
pub fn kfold_indices(n: usize, k: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(k >= 2 && k <= n, "need 2 <= k <= n");
    let mut idx: Vec<usize> = (0..n).collect();
    Rng::new(seed).shuffle(&mut idx);
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &v) in idx.iter().enumerate() {
        folds[i % k].push(v);
    }
    folds
}

/// Result of evaluating one parameter point.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub params: SmoParams,
    pub kernel: Kernel,
    /// per-fold MCC on held-out positives + provided negatives
    pub fold_mcc: Vec<f64>,
    pub mean_mcc: f64,
    pub mean_train_seconds: f64,
}

/// k-fold CV of one (params, kernel) point. `negatives` supplies the
/// anomaly side of every fold's evaluation.
pub fn cross_validate(
    train: &Dataset,
    negatives: &Dataset,
    kernel: Kernel,
    params: &SmoParams,
    k: usize,
    seed: u64,
) -> Result<CvResult> {
    assert_eq!(train.dim(), negatives.dim());
    let folds = kfold_indices(train.len(), k, seed);
    let mut fold_mcc = Vec::with_capacity(k);
    let mut secs = 0.0;
    for held in 0..k {
        let train_idx: Vec<usize> = folds
            .iter()
            .enumerate()
            .filter(|(f, _)| *f != held)
            .flat_map(|(_, v)| v.iter().copied())
            .collect();
        let tr = train.select(&train_idx);
        let report =
            Trainer::from_smo_params(*params).kernel(kernel).fit(&tr.x)?;
        secs += report.stats.seconds;
        let model = report.model;

        // eval set: held-out positives + all negatives
        let held_pos = train.select(&folds[held]);
        let mut truth = vec![1i8; held_pos.len()];
        truth.extend(vec![-1i8; negatives.len()]);
        let mut pred = model.predict(&held_pos.x);
        pred.extend(model.predict(&negatives.x));
        fold_mcc.push(Confusion::from_labels(&truth, &pred).mcc());
    }
    let mean_mcc = crate::linalg::mean(&fold_mcc);
    Ok(CvResult {
        params: *params,
        kernel,
        fold_mcc,
        mean_mcc,
        mean_train_seconds: secs / k as f64,
    })
}

/// Grid search over parameter candidates; returns results sorted by
/// mean MCC, best first.
pub fn grid_search(
    train: &Dataset,
    negatives: &Dataset,
    kernels: &[Kernel],
    nu1s: &[f64],
    nu2s: &[f64],
    epss: &[f64],
    k: usize,
    seed: u64,
) -> Result<Vec<CvResult>> {
    let mut results = Vec::new();
    for &kernel in kernels {
        for &nu1 in nu1s {
            for &nu2 in nu2s {
                for &eps in epss {
                    let params = SmoParams { nu1, nu2, eps, ..Default::default() };
                    // skip infeasible combos instead of erroring the sweep
                    if crate::solver::check_params(
                        train.len() * (k - 1) / k,
                        nu1,
                        nu2,
                        eps,
                    )
                    .is_err()
                    {
                        continue;
                    }
                    results.push(cross_validate(
                        train, negatives, kernel, &params, k, seed,
                    )?);
                }
            }
        }
    }
    results.sort_by(|a, b| b.mean_mcc.partial_cmp(&a.mean_mcc).unwrap());
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    #[test]
    fn kfold_partitions_everything_once() {
        let folds = kfold_indices(103, 5, 3);
        assert_eq!(folds.len(), 5);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn kfold_deterministic() {
        assert_eq!(kfold_indices(50, 4, 9), kfold_indices(50, 4, 9));
        assert_ne!(kfold_indices(50, 4, 9), kfold_indices(50, 4, 10));
    }

    #[test]
    fn cv_produces_sane_mcc() {
        let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
        let train = cfg.generate(300, 21);
        let eval = cfg.generate_eval(0, 100, 22); // negatives only
        let params = SmoParams { nu1: 0.1, nu2: 0.05, eps: 0.5, ..Default::default() };
        let r = cross_validate(&train, &eval, Kernel::Linear, &params, 3, 5)
            .unwrap();
        assert_eq!(r.fold_mcc.len(), 3);
        assert!(r.mean_mcc > 0.3, "cv MCC {:.3}", r.mean_mcc);
    }

    #[test]
    fn grid_search_ranks_and_skips_infeasible() {
        let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
        let train = cfg.generate(150, 31);
        let eval = cfg.generate_eval(0, 60, 32);
        let results = grid_search(
            &train,
            &eval,
            &[Kernel::Linear],
            &[0.1, 0.5],
            &[0.05],
            &[0.5],
            3,
            7,
        )
        .unwrap();
        assert_eq!(results.len(), 2);
        assert!(results[0].mean_mcc >= results[1].mean_mcc);
    }
}
