//! Feature preprocessing: fit/transform scalers with persistence.
//!
//! Kernel methods are scale-sensitive (RBF bandwidths, polynomial
//! coefficients); production pipelines standardize features before
//! training and must apply the *same* affine map at serving time. Both
//! scalers here serialize into the model-adjacent JSON so the
//! coordinator can replay them.

use crate::error::Error;
use crate::linalg::Matrix;
use crate::util::json::Json;
use crate::Result;

/// z-score standardizer: x' = (x − mean) / sd (per feature).
#[derive(Clone, Debug, PartialEq)]
pub struct Standardizer {
    pub mean: Vec<f64>,
    pub sd: Vec<f64>,
}

impl Standardizer {
    /// Fit on a data matrix. Constant features get sd = 1 (no-op scale).
    pub fn fit(x: &Matrix) -> Standardizer {
        let (n, d) = (x.rows(), x.cols());
        let mut mean = vec![0.0; d];
        for i in 0..n {
            for (j, v) in x.row(i).iter().enumerate() {
                mean[j] += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= n.max(1) as f64;
        }
        let mut var = vec![0.0; d];
        for i in 0..n {
            for (j, v) in x.row(i).iter().enumerate() {
                let c = v - mean[j];
                var[j] += c * c;
            }
        }
        let sd = var
            .into_iter()
            .map(|v| {
                let s = (v / n.max(1) as f64).sqrt();
                if s > 1e-12 {
                    s
                } else {
                    1.0
                }
            })
            .collect();
        Standardizer { mean, sd }
    }

    /// Transform a matrix (allocates).
    pub fn transform(&self, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        self.transform_inplace(&mut out);
        out
    }

    pub fn transform_inplace(&self, x: &mut Matrix) {
        let d = x.cols();
        assert_eq!(d, self.mean.len(), "dimension mismatch");
        for i in 0..x.rows() {
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = (row[j] - self.mean[j]) / self.sd[j];
            }
        }
    }

    /// Transform a single point.
    pub fn transform_point(&self, p: &[f64]) -> Vec<f64> {
        p.iter()
            .zip(self.mean.iter().zip(&self.sd))
            .map(|(v, (m, s))| (v - m) / s)
            .collect()
    }

    /// Invert the transform (for reporting in original units).
    pub fn inverse_point(&self, p: &[f64]) -> Vec<f64> {
        p.iter()
            .zip(self.mean.iter().zip(&self.sd))
            .map(|(v, (m, s))| v * s + m)
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("standardizer")),
            ("mean", Json::arr(self.mean.iter().map(|&v| Json::num(v)).collect())),
            ("sd", Json::arr(self.sd.iter().map(|&v| Json::num(v)).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Standardizer> {
        if j.get("kind").and_then(Json::as_str) != Some("standardizer") {
            return Err(Error::data("not a standardizer"));
        }
        let vecf = |k: &str| -> Result<Vec<f64>> {
            Ok(j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::data(format!("missing {k}")))?
                .iter()
                .filter_map(Json::as_f64)
                .collect())
        };
        let mean = vecf("mean")?;
        let sd = vecf("sd")?;
        if mean.len() != sd.len() || mean.is_empty() {
            return Err(Error::data("standardizer shape mismatch"));
        }
        Ok(Standardizer { mean, sd })
    }
}

/// Min-max scaler to [0, 1] (per feature).
#[derive(Clone, Debug, PartialEq)]
pub struct MinMaxScaler {
    pub min: Vec<f64>,
    pub range: Vec<f64>,
}

impl MinMaxScaler {
    pub fn fit(x: &Matrix) -> MinMaxScaler {
        let d = x.cols();
        let mut min = vec![f64::INFINITY; d];
        let mut max = vec![f64::NEG_INFINITY; d];
        for i in 0..x.rows() {
            for (j, v) in x.row(i).iter().enumerate() {
                min[j] = min[j].min(*v);
                max[j] = max[j].max(*v);
            }
        }
        let range = min
            .iter()
            .zip(&max)
            .map(|(lo, hi)| {
                let r = hi - lo;
                if r > 1e-12 {
                    r
                } else {
                    1.0
                }
            })
            .collect();
        MinMaxScaler { min, range }
    }

    pub fn transform(&self, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        let d = out.cols();
        assert_eq!(d, self.min.len());
        for i in 0..out.rows() {
            let row = out.row_mut(i);
            for j in 0..d {
                row[j] = (row[j] - self.min[j]) / self.range[j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn data() -> Matrix {
        let mut rng = Rng::new(7);
        Matrix::from_vec(
            200,
            3,
            (0..600)
                .map(|i| rng.normal_ms((i % 3) as f64 * 10.0, 2.0 + (i % 3) as f64))
                .collect(),
        )
    }

    #[test]
    fn standardizer_zero_mean_unit_sd() {
        let x = data();
        let s = Standardizer::fit(&x);
        let t = s.transform(&x);
        for j in 0..3 {
            let col: Vec<f64> = (0..t.rows()).map(|i| t.get(i, j)).collect();
            assert!(crate::linalg::mean(&col).abs() < 1e-10);
            assert!((crate::linalg::std_dev(&col) - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn transform_point_matches_matrix() {
        let x = data();
        let s = Standardizer::fit(&x);
        let t = s.transform(&x);
        let p = s.transform_point(x.row(5));
        assert_eq!(&p[..], t.row(5));
    }

    #[test]
    fn inverse_roundtrip() {
        let x = data();
        let s = Standardizer::fit(&x);
        let p = x.row(3);
        let back = s.inverse_point(&s.transform_point(p));
        for (a, b) in p.iter().zip(&back) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn constant_feature_is_noop() {
        let x = Matrix::from_rows(&[&[5.0, 1.0], &[5.0, 2.0], &[5.0, 3.0]]);
        let s = Standardizer::fit(&x);
        let t = s.transform(&x);
        for i in 0..3 {
            assert_eq!(t.get(i, 0), 0.0); // centered, unscaled
        }
    }

    #[test]
    fn json_roundtrip() {
        let s = Standardizer::fit(&data());
        let j = s.to_json().to_string();
        let s2 = Standardizer::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn from_json_rejects_garbage() {
        let j = Json::parse(r#"{"kind":"minmax"}"#).unwrap();
        assert!(Standardizer::from_json(&j).is_err());
        let j = Json::parse(r#"{"kind":"standardizer","mean":[1],"sd":[1,2]}"#).unwrap();
        assert!(Standardizer::from_json(&j).is_err());
    }

    #[test]
    fn minmax_maps_to_unit_box() {
        let x = data();
        let s = MinMaxScaler::fit(&x);
        let t = s.transform(&x);
        for i in 0..t.rows() {
            for j in 0..3 {
                let v = t.get(i, j);
                assert!((-1e-12..=1.0 + 1e-12).contains(&v));
            }
        }
    }

    #[test]
    fn training_on_standardized_data_works() {
        // end-to-end sanity: standardizing the slab band moves it to the
        // origin, so the LINEAR kernel degenerates (the R_min/R_max > eps
        // condition breaks) — but RBF still works. This pins the
        // interaction between preprocessing and the kernel choice.
        use crate::data::synthetic::SlabConfig;
        use crate::kernel::Kernel;
        use crate::solver::api::Trainer;
        let ds = SlabConfig::default().generate(200, 9);
        let sc = Standardizer::fit(&ds.x);
        let xs = sc.transform(&ds.x);
        let model = Trainer::default()
            .kernel(Kernel::Rbf { g: 0.5 })
            .nu1(0.3)
            .nu2(0.05)
            .eps(0.5)
            .fit(&xs)
            .unwrap()
            .model;
        assert!(model.n_sv() > 0);
        // a wildly out-of-band point (in standardized space) is rejected
        assert_eq!(model.classify(&[8.0, -8.0]), -1);
    }
}
