//! **The paper's contribution**: SMO for the One-Class Slab SVM dual.
//!
//! Implements Algorithm 1 with the derivations of §3, plus the two
//! errata fixes that make it actually converge to the OCSSVM optimum
//! (DESIGN.md §1.1 / §Findings):
//!
//! * **Block-wise pairs.** The paper re-parameterizes the dual in
//!   γ = α − ᾱ and keeps only Σγ = 1 − ε (eq. 32). That is a strict
//!   *relaxation*: the true dual (16)–(18) constrains Σα = 1 and
//!   Σᾱ = ε separately, and dropping that lets the optimizer move
//!   unbounded overlap mass (Σγ⁻ ≫ ε) and collapse the slab. The
//!   faithful SMO therefore works on (α, ᾱ) directly, with working
//!   pairs chosen inside one block at a time — an (α_a, α_b) pair
//!   conserves Σα, an (ᾱ_a, ᾱ_b) pair conserves Σᾱ. The relaxed
//!   γ-form as printed is kept as [`solve_gamma_relaxed`] for the
//!   errata ablation.
//! * **Analytic update (35)–(37)**: within a block the subproblem is
//!   identical to the paper's: `δ* = ±(s_a − s_b)/η⁻¹` with
//!   `η = 1/(k_aa + k_bb − 2 k_ab)`, clipped to the box window
//!   (38)–(39); the margin vector s = Kγ is updated incrementally in
//!   O(m) via the two kernel rows.
//! * **Selection**: first choice b = argmax |f̄(x)| over **KKT
//!   violators** (eq. (56); restricting to violators is errata #4),
//!   second choice a = argmax |f̄(x_b) − f̄(x_a)| among partners in the
//!   same block that admit a strict-descent transfer.
//! * **ρ recovery (20)–(21)**: ρ₁ = mean margin of free-α SVs,
//!   ρ₂ = mean margin of free-ᾱ SVs, with interval-midpoint fallbacks.
//!
//! Per-iteration cost: O(m) selection + O(m) rank-2 margin update —
//! the paper's scaling claim against O(m²)-per-step QP solvers.
//!
//! Observability: every solve's [`SolveStats`] (iterations, objective,
//! max violation, kernel evals) surfaces downstream — batch fits as the
//! Retrain span a [`Trainer::fit`](crate::solver::Trainer::fit)
//! records, per-sample warm-started repairs as the iteration count on
//! the streaming layer's Repair spans ([`crate::obs`], DESIGN.md §8) —
//! so the paper's few-dozen-iterations repair claim is checkable on a
//! live serving stack, not just in benches.

use std::time::Instant;

use super::ocssvm::SlabModel;
use super::{check_params, fbar, Heuristic, SolveStats};
use crate::cache::{CachedRows, KernelProvider, PrecomputedGram};
use crate::error::Error;
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::util::rng::Rng;
use crate::Result;

/// Hyper-parameters of the SMO trainer.
#[derive(Clone, Copy, Debug)]
pub struct SmoParams {
    /// ν₁ — bounds the fraction of lower-plane outliers (α cap = 1/(ν₁m))
    pub nu1: f64,
    /// ν₂ — bounds the upper-plane violator fraction (ᾱ cap = ε/(ν₂m))
    pub nu2: f64,
    /// ε — total mass assigned to the upper plane (Σᾱ = ε)
    pub eps: f64,
    /// KKT tolerance (margin units)
    pub tol: f64,
    /// iteration budget; [`Error::NoConvergence`] beyond it
    pub max_iter: usize,
    /// working-set selection strategy
    pub heuristic: Heuristic,
    /// seed for [`Heuristic::RandomViolator`]
    pub seed: u64,
    /// |γ| above which a row is kept as a support vector
    pub sv_tol: f64,
    /// Active-set shrinking: variables that sit at a bound with
    /// satisfied KKT for many consecutive selection sweeps are frozen
    /// out of the scan (libsvm-style). A full reactivation + rescan runs
    /// before convergence is declared, so the result is identical — only
    /// the selection cost drops.
    pub shrinking: bool,
}

impl Default for SmoParams {
    fn default() -> Self {
        SmoParams {
            nu1: 0.5,
            nu2: 0.01,
            eps: 2.0 / 3.0,
            tol: 1e-5,
            max_iter: 500_000,
            heuristic: Heuristic::PaperMaxFbar,
            seed: 0,
            sv_tol: 1e-10,
            shrinking: true,
        }
    }
}

/// Consecutive satisfied-at-bound sweeps before a variable is frozen.
const SHRINK_PATIENCE: u16 = 24;

/// Raw solver outcome: the dual point, margins and effort stats.
pub struct SmoOutcome {
    /// lower-plane multipliers α (Σα = 1, 0 ≤ α ≤ 1/(ν₁m))
    pub alpha: Vec<f64>,
    /// upper-plane multipliers ᾱ (Σᾱ = ε, 0 ≤ ᾱ ≤ ε/(ν₂m))
    pub alpha_bar: Vec<f64>,
    /// γ = α − ᾱ (what the model stores)
    pub gamma: Vec<f64>,
    /// margins s = Kγ at exit
    pub s: Vec<f64>,
    pub rho1: f64,
    pub rho2: f64,
    pub stats: SolveStats,
}

/// Which block a working pair lives in.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Block {
    /// lower-plane multipliers α
    Alpha,
    /// upper-plane multipliers ᾱ
    AlphaBar,
}

/// Train on `x` with a precomputed Gram matrix (native engine, parallel
/// build).
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: `Trainer::from_smo_params(*p).kernel(kernel).fit(x)` \
            (solver::api) — same numerics, uniform FitReport"
)]
pub fn train(x: &Matrix, kernel: Kernel, p: &SmoParams) -> Result<SlabModel> {
    let threads = crate::util::threadpool::default_threads();
    let mut provider = PrecomputedGram::build(x, kernel, threads);
    let out = solve(&mut provider, p)?;
    Ok(SlabModel::from_dual(
        x, &out.gamma, out.rho1, out.rho2, kernel, p.sv_tol,
    ))
}

/// Train returning the raw dual outcome too (benches/tests need stats).
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: `Trainer::from_smo_params(*p).kernel(kernel).fit(x)` \
            returns the model, the full dual and the stats in one FitReport"
)]
pub fn train_full(
    x: &Matrix,
    kernel: Kernel,
    p: &SmoParams,
) -> Result<(SlabModel, SmoOutcome)> {
    let threads = crate::util::threadpool::default_threads();
    let mut provider = PrecomputedGram::build(x, kernel, threads);
    let out = solve(&mut provider, p)?;
    let model =
        SlabModel::from_dual(x, &out.gamma, out.rho1, out.rho2, kernel, p.sv_tol);
    Ok((model, out))
}

/// Train with a bounded kernel-row cache instead of the full Gram
/// (memory O(capacity · m); the A2 ablation path).
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: \
            `Trainer::from_smo_params(*p).kernel(kernel).cache_rows(cap, policy).fit(x)`"
)]
pub fn train_cached(
    x: &Matrix,
    kernel: Kernel,
    p: &SmoParams,
    cache: CachedRows,
) -> Result<(SlabModel, SmoOutcome)> {
    let mut provider = cache;
    let out = solve(&mut provider, p)?;
    let model =
        SlabModel::from_dual(x, &out.gamma, out.rho1, out.rho2, kernel, p.sv_tol);
    Ok((model, out))
}

/// Per-variable KKT violation in the faithful (α, ᾱ) dual.
///
/// α block (multiplier ρ₁ for Σα = 1):
///   α = 0 → s ≥ ρ₁;  0 < α < cap → s = ρ₁;  α = cap → s ≤ ρ₁.
/// ᾱ block (multiplier ρ₂ for Σᾱ = ε):
///   ᾱ = 0 → s ≤ ρ₂;  0 < ᾱ < cap → s = ρ₂;  ᾱ = cap → s ≥ ρ₂.
#[inline]
fn viol_alpha(a: f64, s: f64, rho1: f64, cap: f64, tol: f64) -> f64 {
    if a <= tol {
        (rho1 - s).max(0.0)
    } else if a >= cap - tol {
        (s - rho1).max(0.0)
    } else {
        (s - rho1).abs()
    }
}

#[inline]
fn viol_alpha_bar(ab: f64, s: f64, rho2: f64, cap: f64, tol: f64) -> f64 {
    if ab <= tol {
        (s - rho2).max(0.0)
    } else if ab >= cap - tol {
        (rho2 - s).max(0.0)
    } else {
        (s - rho2).abs()
    }
}

/// A dual-feasible starting state (used by warm-start strategies).
/// `s` must equal K(α − ᾱ) exactly — the solver trusts it.
pub struct WarmState {
    pub alpha: Vec<f64>,
    pub alpha_bar: Vec<f64>,
    pub s: Vec<f64>,
}

/// Core SMO loop over any [`KernelProvider`].
pub fn solve<P: KernelProvider>(provider: &mut P, p: &SmoParams) -> Result<SmoOutcome> {
    solve_from(provider, p, None)
}

/// SMO starting from an explicit dual-feasible state (see
/// [`crate::solver::warmstart`]). `None` = the uniform feasible start.
pub fn solve_from<P: KernelProvider>(
    provider: &mut P,
    p: &SmoParams,
    warm: Option<WarmState>,
) -> Result<SmoOutcome> {
    let m = provider.m();
    check_params(m, p.nu1, p.nu2, p.eps)?;
    let cap_a = 1.0 / (p.nu1 * m as f64);
    let cap_b = p.eps / (p.nu2 * m as f64);
    let t0 = Instant::now();
    let mut rng = Rng::new(p.seed);

    // Feasible start: α = 1/m (≤ cap_a since ν₁ ≤ 1), ᾱ = ε/m (≤ cap_b
    // since ν₂ ≤ 1); both sums exact. A warm start overrides all three.
    let (mut alpha, mut alpha_bar, mut s) = match warm {
        Some(w) => {
            assert_eq!(w.alpha.len(), m);
            assert_eq!(w.s.len(), m);
            (w.alpha, w.alpha_bar, w.s)
        }
        None => {
            let alpha = vec![1.0 / m as f64; m];
            let alpha_bar = vec![p.eps / m as f64; m];
            // s = Kγ with γ = α − ᾱ = (1−ε)/m uniformly.
            let init = (1.0 - p.eps) / m as f64;
            let mut s = vec![0.0; m];
            for i in 0..m {
                s[i] = provider.with_row(i, &mut |row| row.iter().sum::<f64>())
                    * init;
            }
            (alpha, alpha_bar, s)
        }
    };

    // Tolerances. KKT violations live in margin units, which scale with
    // the kernel/data magnitude (s is O(100) on the offset slab band),
    // so the convergence tolerance is relative to the margin scale.
    // Alpha-vs-bound classification is a separate, box-relative epsilon.
    let margin_scale =
        1.0 + s.iter().map(|v| v.abs()).sum::<f64>() / m as f64;
    let tol_eff = p.tol * margin_scale;
    let cls = cap_a.min(cap_b) * 1e-9;

    let (mut rho1, mut rho2) = (0.0, 0.0);
    let mut iterations = 0;
    let mut max_viol = f64::INFINITY;
    let mut stalled_rounds = 0usize;

    // Active-set shrinking state: frozen variables are skipped by the
    // selection scan; margins stay exact for everyone (the rank-2 update
    // always touches all of s), so reactivation needs no reconstruction.
    let mut active = vec![true; m];
    let mut sat_streak = vec![0u16; m];
    let mut n_active = m;

    // Diagonal snapshot for second-order partner selection, hoisted out
    // of the per-iteration scan: K_ii never changes during the solve,
    // and paying O(m) provider hits once here keeps the hot selection
    // loop allocation-free (slablint R3).
    let diag: Vec<f64> = if p.heuristic == Heuristic::SecondOrder {
        (0..m).map(|i| provider.diag(i)).collect()
    } else {
        Vec::new()
    };

    let mut rho_stale = 0u32;
    while iterations < p.max_iter {
        // ρ re-estimation is an O(m) pass; the estimates drift slowly
        // (free-SV means), so refreshing every 8 iterations keeps the
        // selection signal fresh at 1/8th the cost. The authoritative
        // full sweep below always refreshes first.
        if rho_stale == 0 {
            recover_rhos_blocks(
                &alpha, &alpha_bar, &s, cap_a, cap_b, cls, &mut rho1, &mut rho2,
            );
            rho_stale = 8;
        }
        rho_stale -= 1;

        // ---- first choice: worst scoring violator over both blocks -----
        let mut best_b = usize::MAX;
        let mut best_block = Block::Alpha;
        let mut best_key = -1.0;
        max_viol = 0.0;
        for i in 0..m {
            if !active[i] {
                continue;
            }
            let va = viol_alpha(alpha[i], s[i], rho1, cap_a, cls);
            let vb = viol_alpha_bar(alpha_bar[i], s[i], rho2, cap_b, cls);
            max_viol = max_viol.max(va).max(vb);
            let (v, block) = if va >= vb { (va, Block::Alpha) } else { (vb, Block::AlphaBar) };
            if v <= tol_eff {
                // shrink candidates: satisfied AND at a bound in both
                // blocks (free SVs keep participating in rho recovery)
                if p.shrinking {
                    let bound_a = alpha[i] <= cls || alpha[i] >= cap_a - cls;
                    let bound_b =
                        alpha_bar[i] <= cls || alpha_bar[i] >= cap_b - cls;
                    if bound_a && bound_b {
                        sat_streak[i] = sat_streak[i].saturating_add(1);
                        if sat_streak[i] >= SHRINK_PATIENCE && n_active > 8 {
                            active[i] = false;
                            n_active -= 1;
                        }
                    } else {
                        sat_streak[i] = 0;
                    }
                }
                continue;
            }
            sat_streak[i] = 0;
            let key = match p.heuristic {
                // paper §3.2: maximize |f̄(x_b)| among violators
                Heuristic::PaperMaxFbar => fbar(s[i], rho1, rho2).abs(),
                Heuristic::MaxViolation | Heuristic::SecondOrder => v,
                Heuristic::RandomViolator => rng.uniform(),
            };
            if key > best_key {
                best_key = key;
                best_b = i;
                best_block = block;
            }
        }
        // Stopping: every variable satisfies its KKT case within tol.
        // (The paper's literal "at most one violator" rule under-
        // converges: a lone violator can still be fixed by pairing with
        // a NON-violating partner — errata #7, DESIGN.md §1.1.)
        if best_b == usize::MAX {
            if rho_stale != 7 {
                // the scan ran on stale ρ estimates; refresh and re-scan
                // before trusting the no-violator verdict
                rho_stale = 0;
                continue;
            }
            if n_active < m {
                // the active set converged; reactivate everything and do
                // one authoritative full sweep before declaring victory
                active.iter_mut().for_each(|a| *a = true);
                sat_streak.iter_mut().for_each(|s| *s = 0);
                n_active = m;
                continue;
            }
            break;
        }
        let b = best_b;
        let block = best_block;

        // ---- second choice within the block -----------------------------
        // Moving δ of block-mass from a to b changes the objective at rate
        // ±δ(s_b − s_a); require a strict-descent direction with box room.
        let fb = fbar(s[b], rho1, rho2);
        let a = if p.heuristic == Heuristic::SecondOrder {
            select_partner_second_order(
                provider, &diag, block, b, &alpha, &alpha_bar, &s, cap_a, cap_b,
            )
        } else {
            select_partner(
                block, b, fb, &alpha, &alpha_bar, &s, rho1, rho2, cap_a, cap_b,
                p.heuristic, &mut rng,
            )
        };
        let Some(a) = a else {
            // b is geometrically blocked this round; let ρ re-estimation
            // run and count a stall (bounded, so we cannot spin forever).
            stalled_rounds += 1;
            iterations += 1;
            if stalled_rounds > 64 {
                break;
            }
            continue;
        };
        stalled_rounds = 0;

        // ---- analytic update (35)-(39), block-signed ---------------------
        let progressed = provider.with_two_rows(a, b, &mut |row_a, row_b| {
            let kaa = row_a[a];
            let kbb = row_b[b];
            let kab = row_a[b];
            let kappa = kaa + kbb - 2.0 * kab;
            match block {
                Block::Alpha => {
                    let t_star = alpha[a] + alpha[b];
                    let l = (t_star - cap_a).max(0.0);
                    let h = cap_a.min(t_star);
                    if h - l <= f64::EPSILON {
                        return false;
                    }
                    let new_b = if kappa > 1e-12 {
                        (alpha[b] + (s[a] - s[b]) / kappa).clamp(l, h)
                    } else if s[a] > s[b] {
                        h
                    } else if s[a] < s[b] {
                        l
                    } else {
                        return false;
                    };
                    let delta = new_b - alpha[b];
                    if delta.abs() < 1e-16 {
                        return false;
                    }
                    alpha[b] = new_b;
                    alpha[a] = t_star - new_b;
                    // γ_b += δ, γ_a −= δ
                    for j in 0..m {
                        s[j] += delta * (row_b[j] - row_a[j]);
                    }
                    true
                }
                Block::AlphaBar => {
                    let t_star = alpha_bar[a] + alpha_bar[b];
                    let l = (t_star - cap_b).max(0.0);
                    let h = cap_b.min(t_star);
                    if h - l <= f64::EPSILON {
                        return false;
                    }
                    // γ = α − ᾱ: increasing ᾱ_b decreases γ_b, so the
                    // 1-D optimum flips sign: δ* = (s_b − s_a)/κ.
                    let new_b = if kappa > 1e-12 {
                        (alpha_bar[b] + (s[b] - s[a]) / kappa).clamp(l, h)
                    } else if s[b] > s[a] {
                        h
                    } else if s[b] < s[a] {
                        l
                    } else {
                        return false;
                    };
                    let delta = new_b - alpha_bar[b];
                    if delta.abs() < 1e-16 {
                        return false;
                    }
                    alpha_bar[b] = new_b;
                    alpha_bar[a] = t_star - new_b;
                    // γ_b −= δ, γ_a += δ
                    for j in 0..m {
                        s[j] += delta * (row_a[j] - row_b[j]);
                    }
                    true
                }
            }
        });

        iterations += 1;
        if !progressed {
            stalled_rounds += 1;
            if stalled_rounds > 64 {
                break;
            }
        } else {
            stalled_rounds = 0;
        }
    }

    if iterations >= p.max_iter && max_viol > tol_eff * 10.0 {
        return Err(Error::NoConvergence(format!(
            "SMO hit max_iter={} with max KKT violation {max_viol:.3e}",
            p.max_iter
        )));
    }

    recover_rhos_blocks(
        &alpha, &alpha_bar, &s, cap_a, cap_b, cls, &mut rho1, &mut rho2,
    );
    let gamma: Vec<f64> =
        alpha.iter().zip(&alpha_bar).map(|(a, ab)| a - ab).collect();
    let objective = 0.5 * gamma.iter().zip(&s).map(|(g, si)| g * si).sum::<f64>();
    let stats = SolveStats {
        iterations,
        objective,
        max_violation: max_viol,
        seconds: t0.elapsed().as_secs_f64(),
        cache: provider.stats(),
        kernel_evals: 0,
    };
    Ok(SmoOutcome { alpha, alpha_bar, gamma, s, rho1, rho2, stats })
}

/// WSS2-style second choice: the partner maximizing the guaranteed
/// objective decrease (s_a − s_b)²/(2κ) with κ = k_aa + k_bb − 2k_ab,
/// restricted to strict-descent-feasible partners. Needs kernel row b
/// (one provider access per iteration — same cost class as the update
/// itself, which also fetches row b). `diag` is the caller's hoisted
/// K_ii snapshot — constant for the whole solve, so this fn stays
/// allocation-free per iteration.
#[allow(clippy::too_many_arguments)]
fn select_partner_second_order<P: KernelProvider>(
    provider: &mut P,
    diag: &[f64],
    block: Block,
    b: usize,
    alpha: &[f64],
    alpha_bar: &[f64],
    s: &[f64],
    cap_a: f64,
    cap_b: f64,
) -> Option<usize> {
    let m = s.len();
    debug_assert_eq!(diag.len(), m);
    let kbb = diag[b];
    provider.with_row(b, &mut |row_b| {
        let mut best = None;
        let mut best_gain = 0.0;
        for i in 0..m {
            if i == b {
                continue;
            }
            let feasible = match block {
                Block::Alpha => {
                    let d = s[i] - s[b];
                    (d > 0.0 && alpha[b] < cap_a - 1e-15 && alpha[i] > 1e-15)
                        || (d < 0.0
                            && alpha[b] > 1e-15
                            && alpha[i] < cap_a - 1e-15)
                }
                Block::AlphaBar => {
                    let d = s[b] - s[i];
                    (d > 0.0 && alpha_bar[b] < cap_b - 1e-15 && alpha_bar[i] > 1e-15)
                        || (d < 0.0
                            && alpha_bar[b] > 1e-15
                            && alpha_bar[i] < cap_b - 1e-15)
                }
            };
            if !feasible {
                continue;
            }
            let kappa = (diag[i] + kbb - 2.0 * row_b[i]).max(1e-12);
            let d = s[i] - s[b];
            let gain = d * d / (2.0 * kappa);
            if gain > best_gain {
                best_gain = gain;
                best = Some(i);
            }
        }
        best
    })
}

/// Second-choice scan: best |f̄(x_b) − f̄(x_a)| partner in `block` that
/// admits a strict-descent transfer with b.
#[allow(clippy::too_many_arguments)]
fn select_partner(
    block: Block,
    b: usize,
    fb: f64,
    alpha: &[f64],
    alpha_bar: &[f64],
    s: &[f64],
    rho1: f64,
    rho2: f64,
    cap_a: f64,
    cap_b: f64,
    heuristic: Heuristic,
    rng: &mut Rng,
) -> Option<usize> {
    let m = s.len();
    let can_pair = |a: usize| -> bool {
        if a == b {
            return false;
        }
        match block {
            // objective rate for δ mass a→b is δ(s_b − s_a):
            // descent if (s_a > s_b, δ>0, need α_b<cap, α_a>0) or mirror.
            Block::Alpha => {
                let d = s[a] - s[b];
                (d > 0.0 && alpha[b] < cap_a - 1e-15 && alpha[a] > 1e-15)
                    || (d < 0.0 && alpha[b] > 1e-15 && alpha[a] < cap_a - 1e-15)
            }
            // ᾱ contributes −ᾱ to γ: rate is δ(s_a − s_b) for ᾱ mass a→b.
            Block::AlphaBar => {
                let d = s[b] - s[a];
                (d > 0.0 && alpha_bar[b] < cap_b - 1e-15 && alpha_bar[a] > 1e-15)
                    || (d < 0.0 && alpha_bar[b] > 1e-15 && alpha_bar[a] < cap_b - 1e-15)
            }
        }
    };
    match heuristic {
        Heuristic::RandomViolator => {
            for _ in 0..32 {
                let mut c = rng.below(m - 1);
                if c >= b {
                    c += 1;
                }
                if can_pair(c) {
                    return Some(c);
                }
            }
            (0..m).find(|&i| can_pair(i))
        }
        _ => {
            let mut best = None;
            let mut best_gap = -1.0;
            for i in 0..m {
                if !can_pair(i) {
                    continue;
                }
                let gap = (fb - fbar(s[i], rho1, rho2)).abs();
                if gap > best_gap {
                    best_gap = gap;
                    best = Some(i);
                }
            }
            best
        }
    }
}

/// Recover ρ₁/ρ₂ (paper eqs. (20)–(21)) from the block structure:
/// ρ₁ = mean margin of free-α SVs, ρ₂ = mean margin of free-ᾱ SVs;
/// fallback = midpoint of the interval the bound cases imply.
#[allow(clippy::too_many_arguments)]
pub fn recover_rhos_blocks(
    alpha: &[f64],
    alpha_bar: &[f64],
    s: &[f64],
    cap_a: f64,
    cap_b: f64,
    tol: f64,
    rho1: &mut f64,
    rho2: &mut f64,
) {
    let m = alpha.len();
    let (mut sum1, mut n1) = (0.0, 0usize);
    let (mut sum2, mut n2) = (0.0, 0usize);
    // interval bounds: ρ₁ ∈ [max_{α=cap} s, min_{α=0} s],
    //                  ρ₂ ∈ [max_{ᾱ=0} s, min_{ᾱ=cap} s]
    let mut lo1 = f64::NEG_INFINITY;
    let mut hi1 = f64::INFINITY;
    let mut lo2 = f64::NEG_INFINITY;
    let mut hi2 = f64::INFINITY;
    for i in 0..m {
        if alpha[i] > tol && alpha[i] < cap_a - tol {
            sum1 += s[i];
            n1 += 1;
        } else if alpha[i] >= cap_a - tol {
            lo1 = lo1.max(s[i]);
        } else {
            hi1 = hi1.min(s[i]);
        }
        if alpha_bar[i] > tol && alpha_bar[i] < cap_b - tol {
            sum2 += s[i];
            n2 += 1;
        } else if alpha_bar[i] >= cap_b - tol {
            hi2 = hi2.min(s[i]);
        } else {
            lo2 = lo2.max(s[i]);
        }
    }
    *rho1 = if n1 > 0 { sum1 / n1 as f64 } else { midpoint(lo1, hi1, s) };
    *rho2 = if n2 > 0 { sum2 / n2 as f64 } else { midpoint(lo2, hi2, s) };
}

fn midpoint(lo: f64, hi: f64, s: &[f64]) -> f64 {
    match (lo.is_finite(), hi.is_finite()) {
        (true, true) => 0.5 * (lo + hi),
        (true, false) => lo,
        (false, true) => hi,
        (false, false) => crate::linalg::median(s),
    }
}

// ---------------------------------------------------------------------------
// The paper's γ-form as printed (eqs. 30–32): kept for the errata ablation.
// ---------------------------------------------------------------------------

/// Solve the *relaxed* γ-form dual exactly as the paper prints it
/// (single sum constraint Σγ = 1 − ε). This is NOT the OCSSVM dual —
/// the missing per-block sum constraints let overlap mass grow and the
/// slab collapse (see `rust/tests/errata.rs` and DESIGN.md §Findings).
/// Returns (γ, ρ₁, ρ₂, stats).
pub fn solve_gamma_relaxed(
    k: &Matrix,
    p: &SmoParams,
) -> Result<(Vec<f64>, f64, f64, SolveStats)> {
    let m = k.rows();
    let (lo, hi) = check_params(m, p.nu1, p.nu2, p.eps)?;
    let t0 = Instant::now();
    let c = 1.0 - p.eps;

    let mut gamma = vec![c / m as f64; m];
    let mut s = vec![0.0; m];
    for i in 0..m {
        s[i] = k.row(i).iter().sum::<f64>() * (c / m as f64);
    }
    let (mut rho1, mut rho2) = (0.0, 0.0);
    let mut iterations = 0;
    let mut max_viol = f64::INFINITY;

    while iterations < p.max_iter {
        // γ-form ρ recovery: free γ>0 ↔ ρ₁, free γ<0 ↔ ρ₂
        recover_rhos_gamma(&gamma, &s, lo, hi, p.tol, &mut rho1, &mut rho2);
        let mut best_b = usize::MAX;
        let mut best_v = p.tol;
        max_viol = 0.0;
        let mut violators = 0;
        for i in 0..m {
            let v = super::kkt_violation(gamma[i], s[i], rho1, rho2, lo, hi, p.tol);
            max_viol = max_viol.max(v);
            if v > p.tol {
                violators += 1;
            }
            if v > best_v {
                best_v = v;
                best_b = i;
            }
        }
        if violators <= 1 || best_b == usize::MAX {
            break;
        }
        let b = best_b;
        let mut a_sel = usize::MAX;
        let mut best_gap = -1.0;
        for i in 0..m {
            if i == b {
                continue;
            }
            let d = s[i] - s[b];
            let ok = (d > 0.0 && gamma[b] < hi - 1e-15 && gamma[i] > lo + 1e-15)
                || (d < 0.0 && gamma[b] > lo + 1e-15 && gamma[i] < hi - 1e-15);
            if !ok {
                continue;
            }
            if d.abs() > best_gap {
                best_gap = d.abs();
                a_sel = i;
            }
        }
        if a_sel == usize::MAX {
            break;
        }
        let a = a_sel;
        let t_star = gamma[a] + gamma[b];
        let l = (t_star - hi).max(lo);
        let h = hi.min(t_star - lo);
        let kappa = k.get(a, a) + k.get(b, b) - 2.0 * k.get(a, b);
        let new_b = if kappa > 1e-12 {
            (gamma[b] + (s[a] - s[b]) / kappa).clamp(l, h)
        } else if s[a] > s[b] {
            h
        } else {
            l
        };
        let delta = new_b - gamma[b];
        if delta.abs() > 1e-16 {
            gamma[b] = new_b;
            gamma[a] = t_star - new_b;
            let (ra, rb) = (k.row(a), k.row(b));
            for j in 0..m {
                s[j] += delta * (rb[j] - ra[j]);
            }
        }
        iterations += 1;
    }

    recover_rhos_gamma(&gamma, &s, lo, hi, p.tol, &mut rho1, &mut rho2);
    let objective = 0.5 * gamma.iter().zip(&s).map(|(g, si)| g * si).sum::<f64>();
    Ok((
        gamma,
        rho1,
        rho2,
        SolveStats {
            iterations,
            objective,
            max_violation: max_viol,
            seconds: t0.elapsed().as_secs_f64(),
            cache: Default::default(),
            kernel_evals: 0,
        },
    ))
}

/// γ-form ρ recovery used by the relaxed ablation solver.
fn recover_rhos_gamma(
    gamma: &[f64],
    s: &[f64],
    lo: f64,
    hi: f64,
    tol: f64,
    rho1: &mut f64,
    rho2: &mut f64,
) {
    let (mut sum1, mut n1, mut sum2, mut n2) = (0.0, 0usize, 0.0, 0usize);
    let (mut lo1, mut hi1) = (f64::NEG_INFINITY, f64::INFINITY);
    let (mut lo2, mut hi2) = (f64::NEG_INFINITY, f64::INFINITY);
    for i in 0..gamma.len() {
        let g = gamma[i];
        if g.abs() <= tol {
            hi1 = hi1.min(s[i]);
            lo2 = lo2.max(s[i]);
        } else if g >= hi - tol {
            lo1 = lo1.max(s[i]);
        } else if g <= lo + tol {
            hi2 = hi2.min(s[i]);
        } else if g > 0.0 {
            sum1 += s[i];
            n1 += 1;
        } else {
            sum2 += s[i];
            n2 += 1;
        }
    }
    *rho1 = if n1 > 0 { sum1 / n1 as f64 } else { midpoint(lo1, hi1, s) };
    *rho2 = if n2 > 0 { sum2 / n2 as f64 } else { midpoint(lo2, hi2, s) };
    if *rho1 > *rho2 {
        let mid = 0.5 * (*rho1 + *rho2);
        *rho1 = mid;
        *rho2 = mid;
    }
}

#[cfg(test)]
mod tests {
    // The deprecated free-function shims are exercised here on purpose:
    // api_parity.rs pins them against the Trainer path, and these tests
    // keep their behavior covered until removal.
    #![allow(deprecated)]

    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::solver::validate::certify;

    fn paper_params() -> SmoParams {
        SmoParams { nu1: 0.5, nu2: 0.01, eps: 2.0 / 3.0, ..Default::default() }
    }

    #[test]
    fn trains_on_slab_data() {
        let ds = SlabConfig::default().generate(300, 1);
        let (model, out) = train_full(&ds.x, Kernel::Linear, &paper_params()).unwrap();
        assert!(out.stats.iterations > 0);
        assert!(model.width() > 0.0, "slab must have positive width");
        assert!(model.n_sv() > 0);
    }

    #[test]
    fn solution_certifies() {
        let ds = SlabConfig::default().generate(200, 2);
        let p = paper_params();
        let (_, out) = train_full(&ds.x, Kernel::Linear, &p).unwrap();
        let k = Kernel::Linear.gram(&ds.x, 2);
        certify(
            &k, &out.alpha, &out.alpha_bar, out.rho1, out.rho2,
            p.nu1, p.nu2, p.eps, 1e-3,
        )
        .expect("SMO solution must satisfy feasibility + KKT");
    }

    #[test]
    fn both_sum_constraints_conserved() {
        let ds = SlabConfig::default().generate(150, 3);
        let p = paper_params();
        let (_, out) = train_full(&ds.x, Kernel::Rbf { g: 0.05 }, &p).unwrap();
        let sa: f64 = out.alpha.iter().sum();
        let sb: f64 = out.alpha_bar.iter().sum();
        assert!((sa - 1.0).abs() < 1e-9, "sum(alpha)={sa}");
        assert!((sb - p.eps).abs() < 1e-9, "sum(alpha_bar)={sb}");
        let sg: f64 = out.gamma.iter().sum();
        assert!((sg - (1.0 - p.eps)).abs() < 1e-9);
    }

    #[test]
    fn box_constraints_respected() {
        let ds = SlabConfig::default().generate(150, 4);
        let p = paper_params();
        let (_, out) = train_full(&ds.x, Kernel::Linear, &p).unwrap();
        let m = out.alpha.len() as f64;
        let cap_a = 1.0 / (p.nu1 * m);
        let cap_b = p.eps / (p.nu2 * m);
        for i in 0..out.alpha.len() {
            assert!(out.alpha[i] >= -1e-12 && out.alpha[i] <= cap_a + 1e-12);
            assert!(out.alpha_bar[i] >= -1e-12 && out.alpha_bar[i] <= cap_b + 1e-12);
        }
    }

    #[test]
    fn margins_match_gamma() {
        // the incrementally maintained s must equal K·gamma at exit
        let ds = SlabConfig::default().generate(120, 5);
        let p = paper_params();
        let (_, out) = train_full(&ds.x, Kernel::Rbf { g: 0.05 }, &p).unwrap();
        let k = Kernel::Rbf { g: 0.05 }.gram(&ds.x, 2);
        for i in 0..out.gamma.len() {
            let si: f64 = (0..out.gamma.len())
                .map(|j| out.gamma[j] * k.get(i, j))
                .sum();
            assert!(
                (si - out.s[i]).abs() < 1e-8,
                "drift at {i}: {si} vs {}",
                out.s[i]
            );
        }
    }

    #[test]
    fn slab_is_ordered_and_meaningful() {
        let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
        let ds = cfg.generate(400, 6);
        let (model, out) =
            train_full(&ds.x, Kernel::Linear, &paper_params()).unwrap();
        assert!(out.rho1 < out.rho2, "rho1={} rho2={}", out.rho1, out.rho2);
        // nu-property: with ν₁ = 0.5, about half the training points are
        // below the lower plane; the inside fraction is ≈ 1 − ν₁ − ν₂.
        let inside = (0..ds.len())
            .filter(|&i| model.classify(ds.x.row(i)) > 0)
            .count() as f64
            / ds.len() as f64;
        assert!(
            (inside - 0.5).abs() < 0.15,
            "inside fraction {inside}, want ≈ 1 − ν₁ = 0.5"
        );
    }

    #[test]
    fn nu_properties_hold() {
        // Schölkopf-style ν-properties, slab version:
        // fraction below ρ1 ≤ ν₁ (+slack), fraction above ρ2 ≤ ν₂ (+slack)
        let cfg = SlabConfig { contamination: 0.0, ..Default::default() };
        let ds = cfg.generate(500, 13);
        for (nu1, nu2, eps) in [(0.5, 0.01, 2.0 / 3.0), (0.2, 0.08, 0.5)] {
            let p = SmoParams { nu1, nu2, eps, ..Default::default() };
            let (_, out) = train_full(&ds.x, Kernel::Linear, &p).unwrap();
            let below = out.s.iter().filter(|&&si| si < out.rho1 - 1e-9).count()
                as f64
                / 500.0;
            let above = out.s.iter().filter(|&&si| si > out.rho2 + 1e-9).count()
                as f64
                / 500.0;
            assert!(below <= nu1 + 0.05, "below={below} > nu1={nu1}");
            assert!(above <= nu2 + 0.05, "above={above} > nu2={nu2}");
        }
    }

    #[test]
    fn heuristics_reach_same_objective() {
        let ds = SlabConfig::default().generate(150, 7);
        let mut objs = Vec::new();
        for h in [
            Heuristic::PaperMaxFbar,
            Heuristic::MaxViolation,
            Heuristic::RandomViolator,
        ] {
            let p = SmoParams { heuristic: h, ..paper_params() };
            let (_, out) = train_full(&ds.x, Kernel::Linear, &p).unwrap();
            objs.push(out.stats.objective);
        }
        let spread = objs.iter().cloned().fold(f64::MIN, f64::max)
            - objs.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            spread < 1e-3 * objs[0].abs().max(1e-3),
            "objectives diverge: {objs:?}"
        );
    }

    #[test]
    fn cached_provider_matches_precomputed() {
        let ds = SlabConfig::default().generate(100, 8);
        let p = paper_params();
        let (_, out_pre) = train_full(&ds.x, Kernel::Linear, &p).unwrap();
        let cache = CachedRows::new(&ds.x, Kernel::Linear, 100); // full capacity
        let (_, out_cache) =
            train_cached(&ds.x, Kernel::Linear, &p, cache).unwrap();
        assert!(
            (out_pre.stats.objective - out_cache.stats.objective).abs() < 1e-9,
            "{} vs {}",
            out_pre.stats.objective,
            out_cache.stats.objective
        );
    }

    #[test]
    fn small_cache_still_converges() {
        let ds = SlabConfig::default().generate(100, 9);
        let p = paper_params();
        let cache = CachedRows::new(&ds.x, Kernel::Linear, 8);
        let (model, out) = train_cached(&ds.x, Kernel::Linear, &p, cache).unwrap();
        assert!(model.width() >= 0.0);
        assert!(out.stats.cache.misses > 0);
        let k = Kernel::Linear.gram(&ds.x, 2);
        certify(
            &k, &out.alpha, &out.alpha_bar, out.rho1, out.rho2,
            p.nu1, p.nu2, p.eps, 1e-3,
        )
        .unwrap();
    }

    #[test]
    fn rejects_bad_params() {
        let ds = SlabConfig::default().generate(50, 10);
        let p = SmoParams { nu1: 0.0, ..paper_params() };
        assert!(train(&ds.x, Kernel::Linear, &p).is_err());
    }

    #[test]
    fn fig2_constants_also_work() {
        // Fig. 2 caption: nu1=0.2, nu2=0.08, eps=1/2
        let ds = SlabConfig::default().generate(200, 11);
        let p = SmoParams { nu1: 0.2, nu2: 0.08, eps: 0.5, ..Default::default() };
        let (model, out) = train_full(&ds.x, Kernel::Linear, &p).unwrap();
        assert!(model.width() > 0.0);
        let k = Kernel::Linear.gram(&ds.x, 2);
        certify(
            &k, &out.alpha, &out.alpha_bar, out.rho1, out.rho2,
            0.2, 0.08, 0.5, 1e-3,
        )
        .unwrap();
    }

    #[test]
    fn gamma_relaxed_collapses_where_faithful_does_not() {
        // The errata finding: the γ-form as printed can move unbounded
        // overlap mass (Σγ⁻ ≫ ε) and drives the objective to ~0 (w → 0)
        // even on data where the faithful dual has a well-defined slab.
        let ds = SlabConfig::default().generate(200, 12); // offset band
        let k = Kernel::Linear.gram(&ds.x, 2);
        let (gamma, _, _, stats) = solve_gamma_relaxed(&k, &paper_params()).unwrap();
        let (_, out) = train_full(&ds.x, Kernel::Linear, &paper_params()).unwrap();
        assert!(
            out.stats.objective > 1.0,
            "faithful objective should be macroscopic, got {}",
            out.stats.objective
        );
        // the relaxation strictly enlarges the feasible set, so its
        // optimum is materially below the faithful one (its solution is
        // dual-INFEASIBLE for the true OCSSVM)
        assert!(
            stats.objective < 0.8 * out.stats.objective,
            "relaxed {} vs faithful {}",
            stats.objective,
            out.stats.objective
        );
        // and the mechanism: the relaxed solution's negative mass exceeds ε
        let neg_mass: f64 = gamma.iter().filter(|g| **g < 0.0).map(|g| -*g).sum();
        assert!(
            neg_mass > paper_params().eps + 0.1,
            "negative mass {neg_mass} should exceed eps"
        );
    }

    #[test]
    fn rho_block_recovery_fallbacks() {
        // no free SVs: alpha at {0, cap}, alpha_bar at {0, cap}
        let alpha = [0.5, 0.5, 0.0, 0.0];
        let alpha_bar = [0.0, 0.0, 0.25, 0.25];
        let s = [0.1, 0.2, 0.9, 1.0];
        let (mut r1, mut r2) = (0.0, 0.0);
        recover_rhos_blocks(&alpha, &alpha_bar, &s, 0.5, 0.25, 1e-9, &mut r1, &mut r2);
        // ρ1 ∈ [max s over α=cap, min s over α=0] = [0.2, 0.9] -> 0.55
        assert!((r1 - 0.55).abs() < 1e-12, "r1={r1}");
        // ρ2 ∈ [max s over ᾱ=0, min s over ᾱ=cap] = [0.2, 0.9] -> 0.55
        assert!((r2 - 0.55).abs() < 1e-12, "r2={r2}");
    }
}
