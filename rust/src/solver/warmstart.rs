//! Stochastic warm start for SMO — the paper's future-work item on
//! combining SMO with SGD-style methods (its ref [36], Gu et al.,
//! "Accelerating Sequential Minimal Optimization via Stochastic
//! Subgradient Descent").
//!
//! Idea, adapted to the block dual: before the exact SMO loop, run a few
//! cheap epochs of *random-pair* analytic updates (no selection scan, no
//! ρ bookkeeping — just the closed-form two-variable step on uniformly
//! random same-block pairs). Each step is the same O(m) rank-2 margin
//! update SMO uses, but the per-iteration overhead drops from two full
//! scans to none, and the crude pass removes the bulk of the initial
//! objective excess. The exact solver then starts close to the optimum
//! and needs far fewer *selected* iterations.
//!
//! Everything stays dual-feasible throughout (same box windows and pair
//! conservation as the main solver), so the warm start changes only the
//! path, never the optimum — asserted by the tests.
//!
//! In the unified API this is the `Trainer::warm_start(epochs)` layer
//! (`solver::api`); [`warm_state`] is the reusable pre-pass it calls.

use super::ocssvm::SlabModel;
use super::smo::{solve_from, SmoOutcome, SmoParams, WarmState};
use crate::cache::{KernelProvider, PrecomputedGram};
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::util::rng::Rng;
use crate::Result;

/// Warm-start configuration.
#[derive(Clone, Copy, Debug)]
pub struct WarmStartParams {
    pub smo: SmoParams,
    /// random-pair epochs (each epoch = m pair updates)
    pub epochs: usize,
}

impl Default for WarmStartParams {
    fn default() -> Self {
        WarmStartParams { smo: SmoParams::default(), epochs: 2 }
    }
}

/// Run the stochastic pre-pass and return the state for [`solve_from`].
pub fn warm_state<P: KernelProvider>(
    provider: &mut P,
    p: &WarmStartParams,
) -> WarmState {
    let m = provider.m();
    let cap_a = 1.0 / (p.smo.nu1 * m as f64);
    let cap_b = p.smo.eps / (p.smo.nu2 * m as f64);
    let mut rng = Rng::new(p.smo.seed ^ 0x5eed_5eed);

    let mut alpha = vec![1.0 / m as f64; m];
    let mut alpha_bar = vec![p.smo.eps / m as f64; m];
    let init = (1.0 - p.smo.eps) / m as f64;
    let mut s = vec![0.0; m];
    for i in 0..m {
        s[i] = provider.with_row(i, &mut |row| row.iter().sum::<f64>()) * init;
    }

    for _ in 0..p.epochs * m {
        // uniformly random same-block pair; alternate blocks
        let in_alpha = rng.uniform() < 0.5;
        let a = rng.below(m);
        let mut b = rng.below(m - 1);
        if b >= a {
            b += 1;
        }
        provider.with_two_rows(a, b, &mut |row_a, row_b| {
            let kappa = row_a[a] + row_b[b] - 2.0 * row_a[b];
            if kappa <= 1e-12 {
                return;
            }
            if in_alpha {
                let t_star = alpha[a] + alpha[b];
                let l = (t_star - cap_a).max(0.0);
                let h = cap_a.min(t_star);
                if h - l <= f64::EPSILON {
                    return;
                }
                let new_b = (alpha[b] + (s[a] - s[b]) / kappa).clamp(l, h);
                let delta = new_b - alpha[b];
                if delta.abs() < 1e-16 {
                    return;
                }
                alpha[b] = new_b;
                alpha[a] = t_star - new_b;
                for j in 0..m {
                    s[j] += delta * (row_b[j] - row_a[j]);
                }
            } else {
                let t_star = alpha_bar[a] + alpha_bar[b];
                let l = (t_star - cap_b).max(0.0);
                let h = cap_b.min(t_star);
                if h - l <= f64::EPSILON {
                    return;
                }
                let new_b = (alpha_bar[b] + (s[b] - s[a]) / kappa).clamp(l, h);
                let delta = new_b - alpha_bar[b];
                if delta.abs() < 1e-16 {
                    return;
                }
                alpha_bar[b] = new_b;
                alpha_bar[a] = t_star - new_b;
                for j in 0..m {
                    s[j] += delta * (row_a[j] - row_b[j]);
                }
            }
        });
    }
    WarmState { alpha, alpha_bar, s }
}

/// Warm-started training end-to-end.
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: \
            `Trainer::from_smo_params(p.smo).kernel(kernel).warm_start(p.epochs).fit(x)` \
            (solver::api) — same pre-pass, same optimum"
)]
pub fn train(
    x: &Matrix,
    kernel: Kernel,
    p: &WarmStartParams,
) -> Result<(SlabModel, SmoOutcome)> {
    let threads = crate::util::threadpool::default_threads();
    let mut provider = PrecomputedGram::build(x, kernel, threads);
    let warm = warm_state(&mut provider, p);
    let out = solve_from(&mut provider, &p.smo, Some(warm))?;
    let model = SlabModel::from_dual(
        x, &out.gamma, out.rho1, out.rho2, kernel, p.smo.sv_tol,
    );
    Ok((model, out))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // legacy shims stay covered until removal

    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::solver::smo::train_full;

    #[test]
    fn warm_state_stays_feasible() {
        let ds = SlabConfig::default().generate(150, 201);
        let p = WarmStartParams::default();
        let mut provider =
            PrecomputedGram::build(&ds.x, Kernel::Linear, 2);
        let w = warm_state(&mut provider, &p);
        let sa: f64 = w.alpha.iter().sum();
        let sb: f64 = w.alpha_bar.iter().sum();
        assert!((sa - 1.0).abs() < 1e-9, "sum(alpha)={sa}");
        assert!((sb - p.smo.eps).abs() < 1e-9);
        let m = w.alpha.len() as f64;
        let cap_a = 1.0 / (p.smo.nu1 * m);
        let cap_b = p.smo.eps / (p.smo.nu2 * m);
        for i in 0..w.alpha.len() {
            assert!(w.alpha[i] >= -1e-15 && w.alpha[i] <= cap_a + 1e-15);
            assert!(w.alpha_bar[i] >= -1e-15 && w.alpha_bar[i] <= cap_b + 1e-15);
        }
        // s must be exactly K gamma
        let k = Kernel::Linear.gram(&ds.x, 2);
        for i in 0..w.alpha.len() {
            let si: f64 = (0..w.alpha.len())
                .map(|j| (w.alpha[j] - w.alpha_bar[j]) * k.get(i, j))
                .sum();
            assert!((si - w.s[i]).abs() < 1e-8, "s drift at {i}");
        }
    }

    #[test]
    fn warmstart_reaches_same_objective() {
        let ds = SlabConfig::default().generate(250, 202);
        let (_, cold) = train_full(&ds.x, Kernel::Linear, &SmoParams::default()).unwrap();
        let (_, warm) = train(&ds.x, Kernel::Linear, &WarmStartParams::default()).unwrap();
        let rel = (warm.stats.objective - cold.stats.objective).abs()
            / cold.stats.objective.abs().max(1e-9);
        assert!(rel < 1e-3, "warm {} vs cold {}", warm.stats.objective, cold.stats.objective);
    }

    #[test]
    fn warmstart_reduces_selected_iterations() {
        let ds = SlabConfig::default().generate(600, 203);
        let (_, cold) = train_full(&ds.x, Kernel::Linear, &SmoParams::default()).unwrap();
        let (_, warm) = train(
            &ds.x,
            Kernel::Linear,
            &WarmStartParams { epochs: 3, ..Default::default() },
        )
        .unwrap();
        assert!(
            warm.stats.iterations < cold.stats.iterations,
            "warm {} iters vs cold {}",
            warm.stats.iterations,
            cold.stats.iterations
        );
    }
}
