//! Solution certification: feasibility + ε-KKT for the OCSSVM dual.
//!
//! Independent of any solver — takes a Gram matrix and a dual point
//! (α, ᾱ) and checks, from first principles:
//!
//! 1. box constraints (17)–(18): 0 ≤ αᵢ ≤ 1/(ν₁m), 0 ≤ ᾱᵢ ≤ ε/(ν₂m);
//! 2. both sum constraints: Σα = 1 and Σᾱ = ε (the constraint the
//!    paper's γ-form drops — see DESIGN.md §Findings);
//! 3. per-block KKT with the given ρ₁/ρ₂, all within `tol`:
//!    α: 0→s≥ρ₁, free→s=ρ₁, cap→s≤ρ₁; ᾱ: 0→s≤ρ₂, free→s=ρ₂, cap→s≥ρ₂.
//!
//! Every solver's output is certified in tests; the benches certify once
//! per configuration before timing (a fast wrong solver is worthless).

use crate::error::Error;
use crate::linalg::Matrix;
use crate::Result;

/// Detailed certification report.
#[derive(Clone, Debug, Default)]
pub struct Certificate {
    pub max_box_violation: f64,
    /// |Σα − 1|
    pub sum_alpha_violation: f64,
    /// |Σᾱ − ε|
    pub sum_alpha_bar_violation: f64,
    pub max_kkt_violation: f64,
    /// index of the worst KKT violator
    pub worst_index: usize,
    pub objective: f64,
}

/// Compute the report without pass/fail judgement. `cls_tol` is the
/// bound-classification tolerance (how close to a bound counts as *at*
/// the bound). Margins are recomputed from `k` (one mat-vec); use
/// [`report_with_margins`] when an exact margin vector is already in
/// hand (every solver maintains one).
#[allow(clippy::too_many_arguments)]
pub fn report(
    k: &Matrix,
    alpha: &[f64],
    alpha_bar: &[f64],
    rho1: f64,
    rho2: f64,
    nu1: f64,
    nu2: f64,
    eps: f64,
    cls_tol: f64,
) -> Certificate {
    let m = alpha.len();
    assert_eq!(k.rows(), m);
    assert_eq!(alpha_bar.len(), m);
    // margins s = K (α − ᾱ)
    let gamma: Vec<f64> = alpha.iter().zip(alpha_bar).map(|(a, b)| a - b).collect();
    let mut s = vec![0.0; m];
    crate::linalg::matvec(k, &gamma, &mut s);
    report_with_margins(alpha, alpha_bar, &s, rho1, rho2, nu1, nu2, eps, cls_tol)
}

/// [`report`] with the margin vector `s = K(α − ᾱ)` supplied by the
/// caller instead of recomputed — O(m) instead of O(m²), and usable when
/// the full Gram matrix was never materialized (bounded row caches).
/// The caller is responsible for `s` being the true margins; solvers
/// maintain them to ~1e-8 (asserted by the margin-drift tests).
#[allow(clippy::too_many_arguments)]
pub fn report_with_margins(
    alpha: &[f64],
    alpha_bar: &[f64],
    s: &[f64],
    rho1: f64,
    rho2: f64,
    nu1: f64,
    nu2: f64,
    eps: f64,
    cls_tol: f64,
) -> Certificate {
    let m = alpha.len();
    assert_eq!(alpha_bar.len(), m);
    assert_eq!(s.len(), m);
    let cap_a = 1.0 / (nu1 * m as f64);
    let cap_b = eps / (nu2 * m as f64);

    let mut cert = Certificate::default();
    for i in 0..m {
        let bv = (-alpha[i])
            .max(alpha[i] - cap_a)
            .max(-alpha_bar[i])
            .max(alpha_bar[i] - cap_b)
            .max(0.0);
        cert.max_box_violation = cert.max_box_violation.max(bv);
    }
    cert.sum_alpha_violation = (alpha.iter().sum::<f64>() - 1.0).abs();
    cert.sum_alpha_bar_violation = (alpha_bar.iter().sum::<f64>() - eps).abs();

    for i in 0..m {
        let va = if alpha[i] <= cls_tol {
            (rho1 - s[i]).max(0.0)
        } else if alpha[i] >= cap_a - cls_tol {
            (s[i] - rho1).max(0.0)
        } else {
            (s[i] - rho1).abs()
        };
        let vb = if alpha_bar[i] <= cls_tol {
            (s[i] - rho2).max(0.0)
        } else if alpha_bar[i] >= cap_b - cls_tol {
            (rho2 - s[i]).max(0.0)
        } else {
            (s[i] - rho2).abs()
        };
        let v = va.max(vb);
        if v > cert.max_kkt_violation {
            cert.max_kkt_violation = v;
            cert.worst_index = i;
        }
    }
    cert.objective = 0.5
        * alpha
            .iter()
            .zip(alpha_bar)
            .zip(s)
            .map(|((a, ab), si)| (a - ab) * si)
            .sum::<f64>();
    cert
}

/// Pass/fail certification with tolerance `tol` (margin units).
#[allow(clippy::too_many_arguments)]
pub fn certify(
    k: &Matrix,
    alpha: &[f64],
    alpha_bar: &[f64],
    rho1: f64,
    rho2: f64,
    nu1: f64,
    nu2: f64,
    eps: f64,
    tol: f64,
) -> Result<Certificate> {
    let m = alpha.len();
    let cap_a = 1.0 / (nu1 * m as f64);
    let cap_b = eps / (nu2 * m as f64);
    // Bound-classification tolerance: strictly box-relative. It must
    // never approach the cap itself (a margin-scaled `tol` can exceed
    // the box at large m), otherwise capped variables get misclassified
    // as zero/free and phantom violations appear.
    let cls_tol = cap_a.min(cap_b) * 1e-6;
    let cert = report(k, alpha, alpha_bar, rho1, rho2, nu1, nu2, eps, cls_tol);

    if cert.max_box_violation > tol {
        return Err(Error::Certification(format!(
            "box violation {:.3e} > {tol:.1e}",
            cert.max_box_violation
        )));
    }
    if cert.sum_alpha_violation > tol * m as f64 {
        return Err(Error::Certification(format!(
            "sum(alpha) violation {:.3e}",
            cert.sum_alpha_violation
        )));
    }
    if cert.sum_alpha_bar_violation > tol * m as f64 {
        return Err(Error::Certification(format!(
            "sum(alpha_bar) violation {:.3e}",
            cert.sum_alpha_bar_violation
        )));
    }
    if cert.max_kkt_violation > tol {
        return Err(Error::Certification(format!(
            "KKT violation {:.3e} at index {} > {tol:.1e} (rho1={rho1:.4}, rho2={rho2:.4}, alpha={:.3e}, alpha_bar={:.3e})",
            cert.max_kkt_violation,
            cert.worst_index,
            alpha[cert.worst_index],
            alpha_bar[cert.worst_index],
        )));
    }
    Ok(cert)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built 2-point problem with a known optimum. K = I,
    /// ν₁ = ν₂ = 0.5, ε = 0.5 → cap_a = 1, cap_b = 0.5.
    /// min ½‖α−ᾱ‖² s.t. Σα=1, Σᾱ=0.5 → symmetric αᵢ=0.5, ᾱᵢ=0.25,
    /// γᵢ = 0.25, s = γ (K=I). Free SVs in both blocks: ρ₁=ρ₂=0.25.
    #[test]
    fn accepts_true_optimum() {
        let k = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let alpha = [0.5, 0.5];
        let alpha_bar = [0.25, 0.25];
        certify(&k, &alpha, &alpha_bar, 0.25, 0.25, 0.5, 0.5, 0.5, 1e-9).unwrap();
    }

    #[test]
    fn rejects_box_violation() {
        let k = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let alpha = [1.5, -0.5]; // outside [0, 1]
        let alpha_bar = [0.25, 0.25];
        assert!(certify(&k, &alpha, &alpha_bar, 0.0, 0.0, 0.5, 0.5, 0.5, 1e-6)
            .is_err());
    }

    #[test]
    fn rejects_dropped_sum_constraint() {
        // the paper's γ-relaxation failure mode: Σᾱ ≠ ε
        let k = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let alpha = [0.5, 0.5];
        let alpha_bar = [0.5, 0.5]; // sums to 1.0, not ε=0.5
        assert!(certify(&k, &alpha, &alpha_bar, 0.0, 0.0, 0.5, 0.5, 0.5, 1e-6)
            .is_err());
    }

    #[test]
    fn rejects_kkt_violation() {
        let k = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        // feasible but with absurd rho's: free SVs must sit on the planes
        let alpha = [0.5, 0.5];
        let alpha_bar = [0.25, 0.25];
        assert!(certify(&k, &alpha, &alpha_bar, -9.0, 9.0, 0.5, 0.5, 0.5, 1e-6)
            .is_err());
    }

    #[test]
    fn report_with_margins_matches_report() {
        let k = Matrix::from_rows(&[&[2.0, 0.5], &[0.5, 2.0]]);
        let alpha = [0.6, 0.4];
        let alpha_bar = [0.3, 0.2];
        let gamma: Vec<f64> =
            alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
        let mut s = vec![0.0; 2];
        crate::linalg::matvec(&k, &gamma, &mut s);
        let full = report(&k, &alpha, &alpha_bar, 0.1, 0.9, 0.5, 0.5, 0.5, 1e-9);
        let fast = report_with_margins(
            &alpha, &alpha_bar, &s, 0.1, 0.9, 0.5, 0.5, 0.5, 1e-9,
        );
        assert_eq!(full.max_box_violation, fast.max_box_violation);
        assert_eq!(full.sum_alpha_violation, fast.sum_alpha_violation);
        assert_eq!(full.max_kkt_violation, fast.max_kkt_violation);
        assert_eq!(full.worst_index, fast.worst_index);
        assert!((full.objective - fast.objective).abs() < 1e-15);
    }

    #[test]
    fn report_objective() {
        let k = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, 2.0]]);
        let alpha = [0.5, 0.5];
        let alpha_bar = [0.25, 0.25];
        let c = report(&k, &alpha, &alpha_bar, 0.5, 0.5, 0.5, 0.5, 0.5, 1e-9);
        // γ = 0.25 each; ½ γᵀKγ = ½ (0.25²·2 + 0.25²·2) = 0.125
        assert!((c.objective - 0.125).abs() < 1e-12);
    }
}
