//! Baseline: Schölkopf ν-one-class SVM trained by SMO (paper ref [2]).
//!
//! The non-slab ancestor the OCSSVM extends. Dual:
//!
//! ```text
//!   min ½ αᵀKα    s.t.  0 ≤ αᵢ ≤ 1/(νm),   Σαᵢ = 1
//! ```
//!
//! with decision f(x) = sgn(Σαᵢ k(xᵢ,x) − ρ). Implemented with the same
//! machinery as the slab SMO (incremental margins, max-violating-pair
//! selection) so timing comparisons are apples-to-apples — the per-
//! iteration cost is identical, only the KKT case table differs:
//!
//! | αᵢ              | condition |
//! |-----------------|-----------|
//! | α = 0           | s ≥ ρ     |
//! | 0 < α < 1/(νm)  | s = ρ     |
//! | α = 1/(νm)      | s ≤ ρ     |

use std::time::Instant;

use super::SolveStats;
use crate::data::Dataset;
use crate::error::Error;
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::metrics::Confusion;
use crate::Result;

/// ν-OCSVM hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct OcsvmParams {
    /// ν — upper bound on the outlier fraction, lower bound on SV fraction
    pub nu: f64,
    pub tol: f64,
    pub max_iter: usize,
    pub sv_tol: f64,
}

impl Default for OcsvmParams {
    fn default() -> Self {
        OcsvmParams { nu: 0.5, tol: 1e-5, max_iter: 200_000, sv_tol: 1e-10 }
    }
}

/// Trained one-class SVM (single hyperplane).
#[derive(Clone, Debug)]
pub struct OcsvmModel {
    pub x_sv: Matrix,
    pub alpha: Vec<f64>,
    pub rho: f64,
    pub kernel: Kernel,
}

impl OcsvmModel {
    pub fn score(&self, x: &[f64]) -> f64 {
        let mut s = 0.0;
        for (i, &a) in self.alpha.iter().enumerate() {
            s += a * self.kernel.eval(self.x_sv.row(i), x);
        }
        s
    }

    /// +1 on/above the hyperplane (target side), −1 below.
    pub fn classify(&self, x: &[f64]) -> i8 {
        if self.score(x) - self.rho >= 0.0 {
            1
        } else {
            -1
        }
    }

    pub fn predict(&self, q: &Matrix) -> Vec<i8> {
        (0..q.rows()).map(|i| self.classify(q.row(i))).collect()
    }

    pub fn evaluate(&self, ds: &Dataset) -> Confusion {
        Confusion::from_labels(&ds.y, &self.predict(&ds.x))
    }

    pub fn n_sv(&self) -> usize {
        self.alpha.len()
    }
}

#[inline]
fn kkt_violation_ocsvm(alpha: f64, s: f64, rho: f64, hi: f64, tol: f64) -> f64 {
    if alpha <= tol {
        (rho - s).max(0.0)
    } else if alpha >= hi - tol {
        (s - rho).max(0.0)
    } else {
        (s - rho).abs()
    }
}

/// Train with SMO on a precomputed Gram matrix.
pub fn solve(k: &Matrix, p: &OcsvmParams) -> Result<(Vec<f64>, f64, SolveStats)> {
    let m = k.rows();
    if m == 0 {
        return Err(Error::config("empty training set"));
    }
    if !(0.0 < p.nu && p.nu <= 1.0) {
        return Err(Error::config(format!("nu must be in (0,1], got {}", p.nu)));
    }
    let hi = 1.0 / (p.nu * m as f64);
    let t0 = Instant::now();

    // Schölkopf's feasible start: α = 1/m (inside [0, hi] since ν ≤ 1)
    let mut alpha = vec![1.0 / m as f64; m];
    let mut s = vec![0.0; m];
    for i in 0..m {
        s[i] = k.row(i).iter().sum::<f64>() / m as f64;
    }

    let mut rho = 0.0;
    let mut iterations = 0;
    let mut max_viol = f64::INFINITY;

    while iterations < p.max_iter {
        // rho = mean margin of free SVs; fallback midpoint
        let (mut sum_f, mut n_f) = (0.0, 0usize);
        let (mut lo_b, mut hi_b) = (f64::NEG_INFINITY, f64::INFINITY);
        for i in 0..m {
            if alpha[i] > p.tol && alpha[i] < hi - p.tol {
                sum_f += s[i];
                n_f += 1;
            } else if alpha[i] >= hi - p.tol {
                lo_b = lo_b.max(s[i]); // s ≤ ρ at upper bound → ρ ≥ s
            } else {
                hi_b = hi_b.min(s[i]); // s ≥ ρ at zero → ρ ≤ s
            }
        }
        rho = if n_f > 0 {
            sum_f / n_f as f64
        } else if lo_b.is_finite() && hi_b.is_finite() {
            0.5 * (lo_b + hi_b)
        } else if lo_b.is_finite() {
            lo_b
        } else if hi_b.is_finite() {
            hi_b
        } else {
            crate::linalg::median(&s)
        };

        // max-violating pair selection
        let mut b = usize::MAX;
        let mut best = p.tol;
        max_viol = 0.0;
        let mut violators = 0;
        for i in 0..m {
            let v = kkt_violation_ocsvm(alpha[i], s[i], rho, hi, p.tol);
            max_viol = max_viol.max(v);
            if v > p.tol {
                violators += 1;
            }
            if v > best {
                best = v;
                b = i;
            }
        }
        if violators <= 1 || b == usize::MAX {
            break;
        }
        // second choice: max |s_b − s_a| among partners that admit a
        // strict-descent transfer (see smo.rs — direction-blind pairing
        // stalls on degenerate [L, H] windows).
        let mut a = usize::MAX;
        let mut best_gap = -1.0;
        for i in 0..m {
            if i == b {
                continue;
            }
            let d = s[i] - s[b];
            let ok = (d > 0.0 && alpha[b] < hi - 1e-14 && alpha[i] > 1e-14)
                || (d < 0.0 && alpha[b] > 1e-14 && alpha[i] < hi - 1e-14);
            if !ok {
                continue;
            }
            let gap = d.abs();
            if gap > best_gap {
                best_gap = gap;
                a = i;
            }
        }
        if a == usize::MAX {
            break; // no descent transfer exists anywhere for b
        }

        let t_star = alpha[a] + alpha[b];
        let l = (t_star - hi).max(0.0);
        let h = hi.min(t_star);
        if h - l <= f64::EPSILON {
            iterations += 1;
            continue;
        }
        let kappa = k.get(a, a) + k.get(b, b) - 2.0 * k.get(a, b);
        let new_b = if kappa > 1e-12 {
            (alpha[b] + (s[a] - s[b]) / kappa).clamp(l, h)
        } else if s[b] > s[a] {
            l
        } else {
            h
        };
        let delta = new_b - alpha[b];
        if delta.abs() > 1e-16 {
            alpha[b] = new_b;
            alpha[a] = t_star - new_b;
            let (ra, rb) = (k.row(a), k.row(b));
            for j in 0..m {
                s[j] += delta * (rb[j] - ra[j]);
            }
        }
        iterations += 1;
    }

    if iterations >= p.max_iter && max_viol > p.tol * 10.0 {
        return Err(Error::NoConvergence(format!(
            "OCSVM-SMO hit max_iter={} with violation {max_viol:.3e}",
            p.max_iter
        )));
    }

    let objective = 0.5 * alpha.iter().zip(&s).map(|(a, si)| a * si).sum::<f64>();
    Ok((
        alpha,
        rho,
        SolveStats {
            iterations,
            objective,
            max_violation: max_viol,
            seconds: t0.elapsed().as_secs_f64(),
            cache: Default::default(),
            kernel_evals: 0,
        },
    ))
}

/// Train an [`OcsvmModel`] end-to-end.
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: `Trainer::new(SolverKind::OcsvmSmo).kernel(kernel).fit(x)` \
            (solver::api) — returns the slab embedding with rho2 = NO_UPPER_PLANE; \
            decision, margin ranking and objective are identical"
)]
pub fn train(x: &Matrix, kernel: Kernel, p: &OcsvmParams) -> Result<(OcsvmModel, SolveStats)> {
    let threads = crate::util::threadpool::default_threads();
    let k = kernel.gram(x, threads);
    let (alpha, rho, stats) = solve(&k, p)?;
    let idx: Vec<usize> =
        (0..x.rows()).filter(|&i| alpha[i].abs() > p.sv_tol).collect();
    Ok((
        OcsvmModel {
            x_sv: x.select_rows(&idx),
            alpha: idx.iter().map(|&i| alpha[i]).collect(),
            rho,
            kernel,
        },
        stats,
    ))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // legacy shims stay covered until removal

    use super::*;
    use crate::data::synthetic::SlabConfig;

    #[test]
    fn trains_and_constraints_hold() {
        let ds = SlabConfig::default().generate(150, 51);
        let p = OcsvmParams::default();
        let k = Kernel::Rbf { g: 0.5 }.gram(&ds.x, 2);
        let (alpha, rho, stats) = solve(&k, &p).unwrap();
        let m = alpha.len() as f64;
        let hi = 1.0 / (p.nu * m);
        for &a in &alpha {
            assert!(a >= -1e-12 && a <= hi + 1e-12);
        }
        let sum: f64 = alpha.iter().sum();
        assert!((sum - 1.0).abs() < 1e-8, "sum={sum}");
        assert!(stats.iterations > 0);
        assert!(rho.is_finite());
    }

    #[test]
    fn nu_property_outlier_fraction() {
        // Schölkopf Prop. 4: fraction of outliers ≤ ν ≤ fraction of SVs
        // (asymptotically; allow slack on a finite sample)
        let ds = SlabConfig { contamination: 0.0, ..Default::default() }
            .generate(400, 52);
        let p = OcsvmParams { nu: 0.3, ..Default::default() };
        let (model, _) = train(&ds.x, Kernel::Rbf { g: 1.0 }, &p).unwrap();
        let outliers = (0..ds.len())
            .filter(|&i| model.classify(ds.x.row(i)) < 0)
            .count() as f64
            / ds.len() as f64;
        assert!(outliers <= 0.3 + 0.05, "outlier fraction {outliers}");
        let sv_frac = model.n_sv() as f64 / ds.len() as f64;
        assert!(sv_frac >= 0.3 - 0.05, "SV fraction {sv_frac}");
    }

    #[test]
    fn separates_blob_from_far_points() {
        let ds = SlabConfig { contamination: 0.0, ..Default::default() }
            .generate(200, 53);
        let (model, _) =
            train(&ds.x, Kernel::Rbf { g: 1.0 }, &OcsvmParams::default()).unwrap();
        // a far-away point must be classified -1
        assert_eq!(model.classify(&[100.0, -100.0]), -1);
    }

    #[test]
    fn rejects_bad_nu() {
        let ds = SlabConfig::default().generate(30, 54);
        let p = OcsvmParams { nu: 0.0, ..Default::default() };
        assert!(train(&ds.x, Kernel::Linear, &p).is_err());
    }
}
