//! Approximate feature-map slab engine (DESIGN.md §10).
//!
//! Trains the one-class slab on **explicitly lifted** features
//! `φ(x) ∈ R^D` (Nyström landmarks or random Fourier features, see
//! [`crate::kernel::featmap`]) with a *linear* kernel in the lifted
//! space, so the lifted Gram `⟨φᵢ, φⱼ⟩` never has to be materialized:
//! the solver maintains the primal weight `w = Σᵢ γᵢ φᵢ` directly and
//! every margin is one D-dimensional dot product. That turns
//!
//! - batch training into O(iter · D) pair updates over an O(m·D)
//!   state (10⁵ samples × D=64 ≈ 51 MB where the exact window Gram
//!   would need 80 GB),
//! - incremental absorbs into O(D) primal pushes plus a budgeted
//!   repair sweep, and
//! - scoring into a single `dot_lifted` — O(d·D), independent of how
//!   many samples are resident.
//!
//! The dual is the paper's slab QP verbatim — box `0 ≤ α ≤ 1/(ν₁m)`,
//! `0 ≤ ᾱ ≤ ε/(ν₂m)`, sums `Σα = 1`, `Σᾱ = ε` — just with
//! `K ≈ ΦΦᵀ`, so the exact engine's KKT certificate applies unchanged
//! in the lifted space (`rust/tests/stream_invariants.rs` re-checks it
//! after every streaming op).
//!
//! Optimizer: pairwise coordinate descent on ½‖w‖². An **α-step**
//! moves mass from the highest-margin reducible coordinate to the
//! lowest-margin increasable one (both sums preserved by
//! construction); an **ᾱ-step** mirrors it on the upper plane. Below
//! [`SCAN_LIMIT`] residents selection is a deterministic greedy scan
//! over refreshed margins (no RNG — snapshot continue-parity is
//! bitwise); above it selection samples a candidate set per step and
//! computes fresh margins only for the sample, keeping per-absorb
//! cost independent of m.

use std::time::Instant;

use super::smo::SmoParams;
use super::validate::{self, Certificate};
use super::SolveStats;
use crate::cache::CacheStats;
use crate::error::Error;
use crate::kernel::featmap::{EngineKind, FeatMap, FeatureMap, NystroemMap, RffMap};
use crate::kernel::{Kernel, Precision};
use crate::linalg::{axpy, dot, Matrix};
use crate::solver::api::{DualSolution, FitReport, Solver, SolverKind};
use crate::solver::ocssvm::SlabModel;
use crate::util::rng::Rng;
use crate::Result;

/// Resident count above which the repair loop switches from the
/// deterministic full greedy scan to sampled selection (per-step cost
/// O(sample·D) instead of O(m + D)). Compile-time so the two regimes
/// are pinned by tests on either side.
pub const SCAN_LIMIT: usize = 4096;

/// Candidate-set size per sampled selection step (large-m mode).
const SAMPLE: usize = 48;

/// Seed mix for the RFF frequency draw, so the map's stream is
/// decorrelated from the solver's own selection RNG at equal seeds.
pub const RFF_SEED_MIX: u64 = 0x52FF_52FF_52FF_52FF;

/// Seed mix for Nyström landmark sampling.
pub const LANDMARK_SEED_MIX: u64 = 0x4C41_4E44_4C41_4E44;

// ---------------------------------------------------------- helpers
//
// The whole file is slablint R1 scope: every row/element access goes
// through checked `.get(..)` forms, never `expr[idx]`.

/// Row `i` of a flat row-major buffer (empty slice on out-of-range —
/// callers guard lengths, the empty slice keeps the path panic-free).
fn row_of(phi: &[f64], d: usize, i: usize) -> &[f64] {
    let start = i * d;
    phi.get(start..start + d).unwrap_or(&[])
}

/// Checked scalar read (0.0 out of range).
fn at(xs: &[f64], i: usize) -> f64 {
    xs.get(i).copied().unwrap_or(0.0)
}

/// Checked scalar write (no-op out of range).
fn set_at(xs: &mut [f64], i: usize, v: f64) {
    if let Some(x) = xs.get_mut(i) {
        *x = v;
    }
}

/// Checked scalar add (no-op out of range).
fn add_at(xs: &mut [f64], i: usize, v: f64) {
    if let Some(x) = xs.get_mut(i) {
        *x += v;
    }
}

/// Restore `Σxs = target` after floating-point drift (or after a
/// removal), spreading the correction greedily under `cap` and keeping
/// `w` consistent (`sign` is +1 for α mass, −1 for ᾱ mass).
fn renorm_mass(
    xs: &mut [f64],
    target: f64,
    cap: f64,
    phi: &[f64],
    d: usize,
    w: &mut [f64],
    sign: f64,
) {
    let sum: f64 = xs.iter().sum();
    let mut diff = target - sum;
    if diff == 0.0 {
        return;
    }
    for i in 0..xs.len() {
        if diff == 0.0 {
            break;
        }
        let Some(x) = xs.get_mut(i) else { break };
        let take = if diff > 0.0 {
            diff.min((cap - *x).max(0.0))
        } else {
            diff.max(-x.max(0.0))
        };
        if take != 0.0 {
            *x += take;
            axpy(sign * take, row_of(phi, d, i), w);
            diff -= take;
        }
    }
}

/// Recover a slab plane from margins + bound pattern: mean margin over
/// the interior set when one exists, else the midpoint of the bracket
/// the two bound sets imply. `at_cap_is_lo` is true for ρ1 (α at cap →
/// s ≤ ρ1) and false for ρ2 (ᾱ at cap → s ≥ ρ2).
fn recover_plane(s: &[f64], mass: &[f64], cap: f64, at_cap_is_lo: bool) -> f64 {
    let thr = cap * 1e-6;
    let mut acc = 0.0;
    let mut n = 0usize;
    for (&si, &mi) in s.iter().zip(mass) {
        if mi > thr && mi < cap - thr {
            acc += si;
            n += 1;
        }
    }
    if n > 0 {
        return acc / n as f64;
    }
    let mut lo = f64::NEG_INFINITY;
    let mut hi = f64::INFINITY;
    for (&si, &mi) in s.iter().zip(mass) {
        let is_cap = mi >= cap - thr;
        let is_zero = mi <= thr;
        if (is_cap && at_cap_is_lo) || (is_zero && !at_cap_is_lo) {
            lo = lo.max(si);
        } else if (is_zero && at_cap_is_lo) || (is_cap && !at_cap_is_lo) {
            hi = hi.min(si);
        }
    }
    match (lo.is_finite(), hi.is_finite()) {
        (true, true) => 0.5 * (lo + hi),
        (true, false) => lo,
        (false, true) => hi,
        (false, false) => 0.0,
    }
}

// ------------------------------------------------------ LiftedSlab

/// The slab dual maintained in an explicit feature space: lifted rows
/// `φᵢ`, multipliers (α, ᾱ), the primal weight `w = Σγᵢφᵢ`, cached
/// margins `sᵢ = ⟨w, φᵢ⟩` and recovered slab offsets.
///
/// Shared by the batch [`ApproxSolver`] and the streaming
/// [`crate::stream::approx::ApproxIncremental`] engine; every
/// structural op (grow / replace / remove) preserves `Σα = 1`,
/// `Σᾱ = ε` and the boxes **exactly** (by rescale or direct transfer,
/// not by post-hoc projection), which is what lets the invariant suite
/// assert feasibility after every single op.
#[derive(Clone, Debug)]
pub struct LiftedSlab {
    d: usize,
    nu1: f64,
    nu2: f64,
    eps: f64,
    tol: f64,
    phi: Vec<f64>,
    diag: Vec<f64>,
    alpha: Vec<f64>,
    alpha_bar: Vec<f64>,
    s: Vec<f64>,
    w: Vec<f64>,
    rho1: f64,
    rho2: f64,
    banned: Vec<u64>,
    epoch: u64,
    rng: Rng,
}

impl LiftedSlab {
    /// Empty state for lifted dimension `d` with the slab
    /// hyper-parameters taken from `p`.
    pub fn new(d: usize, p: &SmoParams) -> LiftedSlab {
        LiftedSlab {
            d,
            nu1: p.nu1,
            nu2: p.nu2,
            eps: p.eps,
            tol: p.tol,
            phi: Vec::new(),
            diag: Vec::new(),
            alpha: Vec::new(),
            alpha_bar: Vec::new(),
            s: Vec::new(),
            w: vec![0.0; d],
            rho1: 0.0,
            rho2: 0.0,
            banned: Vec::new(),
            epoch: 0,
            rng: Rng::new(p.seed ^ 0xA11D_0711),
        }
    }

    /// Resident count m.
    pub fn len(&self) -> usize {
        self.alpha.len()
    }

    /// True when no samples are resident.
    pub fn is_empty(&self) -> bool {
        self.alpha.is_empty()
    }

    /// Lifted dimension D.
    pub fn dim_lifted(&self) -> usize {
        self.d
    }

    /// Lower-plane multipliers α.
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Upper-plane multipliers ᾱ.
    pub fn alpha_bar(&self) -> &[f64] {
        &self.alpha_bar
    }

    /// Cached margins (fresh immediately after
    /// [`refresh_margins`](Self::refresh_margins) / a repair exit;
    /// stale mid-sweep by design).
    pub fn margins(&self) -> &[f64] {
        &self.s
    }

    /// Primal weight vector `w = Σ γᵢ φᵢ` (the whole model, for
    /// scoring via [`FeatureMap::dot_lifted`]).
    pub fn weights(&self) -> &[f64] {
        &self.w
    }

    /// Flat row-major lifted rows (persistence checksums).
    pub fn phi_flat(&self) -> &[f64] {
        &self.phi
    }

    /// Slab offsets (ρ1, ρ2).
    pub fn rho(&self) -> (f64, f64) {
        (self.rho1, self.rho2)
    }

    /// Box caps (1/(ν₁m), ε/(ν₂m)) at the current m.
    pub fn caps(&self) -> (f64, f64) {
        let m = self.len().max(1) as f64;
        (1.0 / (self.nu1 * m), self.eps / (self.nu2 * m))
    }

    /// ε (the upper-plane mass target Σᾱ).
    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Dual objective ½‖w‖² = ½ γᵀ(ΦΦᵀ)γ.
    pub fn objective(&self) -> f64 {
        0.5 * dot(&self.w, &self.w)
    }

    /// Fresh margin of resident `i`: `⟨w, φᵢ⟩`, O(D).
    pub fn margin_of(&self, i: usize) -> f64 {
        dot(&self.w, row_of(&self.phi, self.d, i))
    }

    /// Seed the state from a batch of lifted rows: uniform feasible
    /// start α = 1/m, ᾱ = ε/m (inside both boxes for any ν ∈ (0,1]),
    /// `w` accumulated in fixed row order, margins refreshed, planes
    /// recovered.
    pub fn batch_init(&mut self, phi: &Matrix) {
        debug_assert_eq!(phi.cols(), self.d);
        let m = phi.rows();
        self.phi.clear();
        self.phi.extend_from_slice(phi.data());
        let mf = m as f64;
        self.alpha.clear();
        self.alpha.resize(m, 1.0 / mf);
        self.alpha_bar.clear();
        self.alpha_bar.resize(m, self.eps / mf);
        self.banned.clear();
        self.banned.resize(m, 0);
        self.diag.clear();
        self.s.clear();
        self.s.resize(m, 0.0);
        self.w.iter_mut().for_each(|v| *v = 0.0);
        let g = (1.0 - self.eps) / mf;
        for i in 0..m {
            let row = row_of(&self.phi, self.d, i);
            self.diag.push(dot(row, row));
            axpy(g, row, &mut self.w);
        }
        self.refresh_margins();
        self.recover_rho();
    }

    /// Rebuild from restored dual state + lifted rows (snapshot
    /// restore): `w` is re-accumulated in fixed row order and margins
    /// recomputed from it, so two restores of the same bytes agree
    /// bitwise.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        d: usize,
        p: &SmoParams,
        phi: Vec<f64>,
        alpha: Vec<f64>,
        alpha_bar: Vec<f64>,
        rho1: f64,
        rho2: f64,
    ) -> LiftedSlab {
        let m = alpha.len();
        debug_assert_eq!(phi.len(), m * d);
        debug_assert_eq!(alpha_bar.len(), m);
        let mut out = LiftedSlab::new(d, p);
        out.phi = phi;
        out.alpha = alpha;
        out.alpha_bar = alpha_bar;
        out.rho1 = rho1;
        out.rho2 = rho2;
        out.banned.resize(m, 0);
        out.s.resize(m, 0.0);
        for i in 0..m {
            let row = row_of(&out.phi, d, i);
            out.diag.push(dot(row, row));
        }
        for i in 0..m {
            let g = at(&out.alpha, i) - at(&out.alpha_bar, i);
            axpy(g, row_of(&out.phi, d, i), &mut out.w);
        }
        out.refresh_margins();
        out
    }

    /// Absorb a new lifted row while the window is still growing:
    /// every multiplier rescales by m/(m+1) (the caps rescale by the
    /// same factor, so the boxes hold **exactly**) and the newcomer
    /// takes α = 1/(m+1), ᾱ = ε/(m+1) — both sums land exactly on
    /// their targets. O(D).
    pub fn push_grown(&mut self, phi_new: &[f64]) {
        debug_assert_eq!(phi_new.len(), self.d);
        let m = self.len();
        let mf1 = (m + 1) as f64;
        let f = m as f64 / mf1;
        if m > 0 {
            self.alpha.iter_mut().for_each(|a| *a *= f);
            self.alpha_bar.iter_mut().for_each(|b| *b *= f);
            self.w.iter_mut().for_each(|v| *v *= f);
        } else {
            self.w.iter_mut().for_each(|v| *v = 0.0);
        }
        let g_new = (1.0 - self.eps) / mf1;
        axpy(g_new, phi_new, &mut self.w);
        self.phi.extend_from_slice(phi_new);
        self.diag.push(dot(phi_new, phi_new));
        self.alpha.push(1.0 / mf1);
        self.alpha_bar.push(self.eps / mf1);
        self.banned.push(0);
        self.s.push(dot(&self.w, phi_new));
    }

    /// Steady-state absorb: the newcomer takes over slot `v` AND the
    /// victim's multipliers (same m, same caps — feasibility is
    /// transferred, not re-derived). O(D); the following repair sweep
    /// moves the inherited mass where KKT wants it.
    pub fn replace_row(&mut self, v: usize, phi_new: &[f64]) {
        debug_assert_eq!(phi_new.len(), self.d);
        debug_assert!(v < self.len());
        let g = at(&self.alpha, v) - at(&self.alpha_bar, v);
        axpy(-g, row_of(&self.phi, self.d, v), &mut self.w);
        axpy(g, phi_new, &mut self.w);
        let start = v * self.d;
        if let Some(slot) = self.phi.get_mut(start..start + self.d) {
            slot.copy_from_slice(phi_new);
        }
        set_at(&mut self.diag, v, dot(phi_new, phi_new));
        set_at(&mut self.s, v, dot(&self.w, phi_new));
    }

    /// Remove resident `v` (unlearning): withdraw its γ from `w`,
    /// swap-remove its row, then redistribute the withdrawn α/ᾱ mass
    /// greedily under the **grown** caps of the smaller m (total
    /// headroom 1/ν − 1 + removed ≥ removed for ν ≤ 1, so this always
    /// lands the sums exactly back on target). A uniform inflate would
    /// violate the boxes for ν < 1 — this path never does.
    pub fn remove_row(&mut self, v: usize) {
        let m = self.len();
        debug_assert!(v < m);
        let a_rm = at(&self.alpha, v);
        let b_rm = at(&self.alpha_bar, v);
        let g = a_rm - b_rm;
        axpy(-g, row_of(&self.phi, self.d, v), &mut self.w);
        let last = m - 1;
        if v != last {
            let src = last * self.d;
            self.phi.copy_within(src..src + self.d, v * self.d);
        }
        self.phi.truncate(last * self.d);
        self.alpha.swap_remove(v);
        self.alpha_bar.swap_remove(v);
        self.diag.swap_remove(v);
        self.s.swap_remove(v);
        self.banned.swap_remove(v);
        if last == 0 {
            self.w.iter_mut().for_each(|x| *x = 0.0);
            self.rho1 = 0.0;
            self.rho2 = 0.0;
            return;
        }
        let (cap_a, cap_b) = self.caps();
        renorm_mass(&mut self.alpha, 1.0, cap_a, &self.phi, self.d, &mut self.w, 1.0);
        renorm_mass(
            &mut self.alpha_bar,
            self.eps,
            cap_b,
            &self.phi,
            self.d,
            &mut self.w,
            -1.0,
        );
    }

    /// Recompute every cached margin from `w` (O(m·D)).
    pub fn refresh_margins(&mut self) {
        for i in 0..self.s.len() {
            let v = dot(&self.w, row_of(&self.phi, self.d, i));
            set_at(&mut self.s, i, v);
        }
    }

    /// Recover (ρ1, ρ2) from the current margins + bound pattern.
    pub fn recover_rho(&mut self) {
        let (cap_a, cap_b) = self.caps();
        self.rho1 = recover_plane(&self.s, &self.alpha, cap_a, true);
        self.rho2 = recover_plane(&self.s, &self.alpha_bar, cap_b, false);
        if self.rho2 < self.rho1 {
            let mid = 0.5 * (self.rho1 + self.rho2);
            self.rho1 = mid;
            self.rho2 = mid;
        }
    }

    /// KKT certificate over **fresh** lifted margins (refreshes the
    /// cache first): the exact engine's checker applied to the lifted
    /// Gram's margins, with the same bound-classification tolerance
    /// convention as [`super::api`].
    pub fn certify(&mut self) -> Certificate {
        self.refresh_margins();
        self.recover_rho();
        let (cap_a, cap_b) = self.caps();
        let cls_tol = cap_a.min(cap_b) * 1e-6;
        validate::report_with_margins(
            &self.alpha,
            &self.alpha_bar,
            &self.s,
            self.rho1,
            self.rho2,
            self.nu1,
            self.nu2,
            self.eps,
            cls_tol,
        )
    }

    /// Margin magnitude scale for relative tolerances.
    fn margin_scale(&self) -> f64 {
        let m = self.s.len();
        if m == 0 {
            return 1.0;
        }
        1.0 + self.s.iter().map(|v| v.abs()).sum::<f64>() / m as f64
    }

    /// Steepest remaining α-transfer gain over the cached margins
    /// (max s over reducible − min s over increasable; ≤ 0 ⇒ the α
    /// block satisfies KKT at the current margins).
    fn gap_alpha(&self) -> f64 {
        let (cap_a, _) = self.caps();
        let thr = cap_a * 1e-9;
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for (&si, &ai) in self.s.iter().zip(&self.alpha) {
            if ai < cap_a - thr {
                lo = lo.min(si);
            }
            if ai > thr {
                hi = hi.max(si);
            }
        }
        if lo.is_finite() && hi.is_finite() {
            hi - lo
        } else {
            0.0
        }
    }

    /// Mirror of [`gap_alpha`](Self::gap_alpha) for the ᾱ block
    /// (ᾱ mass wants to sit on the highest margins).
    fn gap_abar(&self) -> f64 {
        let (_, cap_b) = self.caps();
        let thr = cap_b * 1e-9;
        let mut best_up = f64::NEG_INFINITY;
        let mut worst_held = f64::INFINITY;
        for (&si, &bi) in self.s.iter().zip(&self.alpha_bar) {
            if bi < cap_b - thr {
                best_up = best_up.max(si);
            }
            if bi > thr {
                worst_held = worst_held.min(si);
            }
        }
        if best_up.is_finite() && worst_held.is_finite() {
            best_up - worst_held
        } else {
            0.0
        }
    }

    /// Execute one α pair transfer `b → a` given fresh margins.
    /// Returns false when no descent is possible on this pair.
    fn do_alpha_pair(&mut self, a: usize, b: usize, sa: f64, sb: f64) -> bool {
        let (cap_a, _) = self.caps();
        let gain = sb - sa;
        if a == b || gain <= 0.0 {
            return false;
        }
        let da = at(&self.diag, a);
        let db = at(&self.diag, b);
        let eta = da + db
            - 2.0 * dot(row_of(&self.phi, self.d, a), row_of(&self.phi, self.d, b));
        if eta <= 1e-12 * (da + db).max(f64::MIN_POSITIVE) {
            set_at_u64(&mut self.banned, b, self.epoch);
            return false;
        }
        let room = (cap_a - at(&self.alpha, a)).min(at(&self.alpha, b));
        let delta = (gain / eta).min(room);
        if delta <= 0.0 {
            return false;
        }
        add_at(&mut self.alpha, a, delta);
        add_at(&mut self.alpha, b, -delta);
        axpy(delta, row_of(&self.phi, self.d, a), &mut self.w);
        axpy(-delta, row_of(&self.phi, self.d, b), &mut self.w);
        let fa = dot(&self.w, row_of(&self.phi, self.d, a));
        let fb = dot(&self.w, row_of(&self.phi, self.d, b));
        set_at(&mut self.s, a, fa);
        set_at(&mut self.s, b, fb);
        true
    }

    /// Execute one ᾱ pair transfer `b → a` given fresh margins
    /// (ᾱ carries −1 into γ, so `w` moves the other way).
    fn do_abar_pair(&mut self, a: usize, b: usize, sa: f64, sb: f64) -> bool {
        let (_, cap_b) = self.caps();
        let gain = sa - sb;
        if a == b || gain <= 0.0 {
            return false;
        }
        let da = at(&self.diag, a);
        let db = at(&self.diag, b);
        let eta = da + db
            - 2.0 * dot(row_of(&self.phi, self.d, a), row_of(&self.phi, self.d, b));
        if eta <= 1e-12 * (da + db).max(f64::MIN_POSITIVE) {
            set_at_u64(&mut self.banned, b, self.epoch);
            return false;
        }
        let room = (cap_b - at(&self.alpha_bar, a)).min(at(&self.alpha_bar, b));
        let delta = (gain / eta).min(room);
        if delta <= 0.0 {
            return false;
        }
        add_at(&mut self.alpha_bar, a, delta);
        add_at(&mut self.alpha_bar, b, -delta);
        axpy(-delta, row_of(&self.phi, self.d, a), &mut self.w);
        axpy(delta, row_of(&self.phi, self.d, b), &mut self.w);
        let fa = dot(&self.w, row_of(&self.phi, self.d, a));
        let fb = dot(&self.w, row_of(&self.phi, self.d, b));
        set_at(&mut self.s, a, fa);
        set_at(&mut self.s, b, fb);
        true
    }

    /// Greedy α step over the (possibly slightly stale) cached
    /// margins; the chosen pair is re-margined fresh before the
    /// update, so staleness only affects selection quality, never
    /// correctness.
    fn pair_step_alpha(&mut self) -> bool {
        let (cap_a, _) = self.caps();
        let thr = cap_a * 1e-9;
        let mut a = usize::MAX;
        let mut b = usize::MAX;
        let mut s_lo = f64::INFINITY;
        let mut s_hi = f64::NEG_INFINITY;
        for (i, ((&si, &ai), &ban)) in
            self.s.iter().zip(&self.alpha).zip(&self.banned).enumerate()
        {
            if ban == self.epoch {
                continue;
            }
            if ai < cap_a - thr && si < s_lo {
                s_lo = si;
                a = i;
            }
            if ai > thr && si > s_hi {
                s_hi = si;
                b = i;
            }
        }
        if a == usize::MAX || b == usize::MAX {
            return false;
        }
        let sa = self.margin_of(a);
        let sb = self.margin_of(b);
        self.do_alpha_pair(a, b, sa, sb)
    }

    /// Greedy ᾱ step (mirror of [`pair_step_alpha`](Self::pair_step_alpha)).
    fn pair_step_abar(&mut self) -> bool {
        let (_, cap_b) = self.caps();
        let thr = cap_b * 1e-9;
        let mut a = usize::MAX;
        let mut b = usize::MAX;
        let mut s_hi = f64::NEG_INFINITY;
        let mut s_lo = f64::INFINITY;
        for (i, ((&si, &bi), &ban)) in
            self.s.iter().zip(&self.alpha_bar).zip(&self.banned).enumerate()
        {
            if ban == self.epoch {
                continue;
            }
            if bi < cap_b - thr && si > s_hi {
                s_hi = si;
                a = i;
            }
            if bi > thr && si < s_lo {
                s_lo = si;
                b = i;
            }
        }
        if a == usize::MAX || b == usize::MAX {
            return false;
        }
        let sa = self.margin_of(a);
        let sb = self.margin_of(b);
        self.do_abar_pair(a, b, sa, sb)
    }

    /// One sampled α step (large-m mode): draw a candidate set, fresh
    /// margins for candidates only, transfer between the sampled
    /// extremes.
    fn sampled_step_alpha(&mut self) -> bool {
        let m = self.len();
        let (cap_a, _) = self.caps();
        let thr = cap_a * 1e-9;
        let mut a = usize::MAX;
        let mut b = usize::MAX;
        let mut s_lo = f64::INFINITY;
        let mut s_hi = f64::NEG_INFINITY;
        for _ in 0..SAMPLE {
            let i = self.rng.below(m);
            let si = self.margin_of(i);
            set_at(&mut self.s, i, si);
            let ai = at(&self.alpha, i);
            if ai < cap_a - thr && si < s_lo {
                s_lo = si;
                a = i;
            }
            if ai > thr && si > s_hi {
                s_hi = si;
                b = i;
            }
        }
        if a == usize::MAX || b == usize::MAX {
            return false;
        }
        self.do_alpha_pair(a, b, s_lo, s_hi)
    }

    /// One sampled ᾱ step (large-m mode).
    fn sampled_step_abar(&mut self) -> bool {
        let m = self.len();
        let (_, cap_b) = self.caps();
        let thr = cap_b * 1e-9;
        let mut a = usize::MAX;
        let mut b = usize::MAX;
        let mut s_hi = f64::NEG_INFINITY;
        let mut s_lo = f64::INFINITY;
        for _ in 0..SAMPLE {
            let i = self.rng.below(m);
            let si = self.margin_of(i);
            set_at(&mut self.s, i, si);
            let bi = at(&self.alpha_bar, i);
            if bi < cap_b - thr && si > s_hi {
                s_hi = si;
                a = i;
            }
            if bi > thr && si < s_lo {
                s_lo = si;
                b = i;
            }
        }
        if a == usize::MAX || b == usize::MAX {
            return false;
        }
        self.do_abar_pair(a, b, s_hi, s_lo)
    }

    /// Warm-started repair: descend on ½‖w‖² until the transfer gaps
    /// fall under the relative tolerance or the iteration budget is
    /// spent. Returns iterations used (≥ 1: the refresh/renormalize
    /// pass counts as effort).
    ///
    /// `m ≤ SCAN_LIMIT`: outer rounds of full margin refresh +
    /// fp-drift renormalization + deterministic greedy inner sweeps —
    /// no RNG, ties broken by index, so two identical states repair
    /// bitwise identically (snapshot continue-parity). Above the
    /// limit: sampled selection, no full refresh (per-absorb cost
    /// stays independent of m); full refreshes happen only in
    /// [`certify`](Self::certify) / report paths.
    pub fn repair(&mut self, budget: usize) -> usize {
        let m = self.len();
        let mut used = 1usize;
        if m == 0 {
            return used;
        }
        self.epoch = self.epoch.wrapping_add(1);
        let budget = budget.max(1);
        if m <= SCAN_LIMIT {
            let mut rounds = 0usize;
            loop {
                self.refresh_margins();
                self.renormalize();
                let lim = self.tol * self.margin_scale();
                if (self.gap_alpha() <= lim && self.gap_abar() <= lim)
                    || used >= budget
                    || rounds >= 64
                {
                    break;
                }
                rounds += 1;
                let inner = m.max(16).min(budget - used);
                let mut progressed = false;
                for _ in 0..inner {
                    let pa = self.pair_step_alpha();
                    let pb = self.pair_step_abar();
                    used += 1;
                    if pa || pb {
                        progressed = true;
                    } else {
                        break;
                    }
                    if used >= budget {
                        break;
                    }
                }
                if !progressed {
                    break;
                }
            }
            self.refresh_margins();
            self.renormalize();
            self.recover_rho();
        } else {
            let mut dry = 0usize;
            while used < budget && dry < 8 {
                let pa = self.sampled_step_alpha();
                let pb = self.sampled_step_abar();
                used += 1;
                if pa || pb {
                    dry = 0;
                } else {
                    dry += 1;
                }
            }
            self.renormalize();
            self.recover_rho();
        }
        used
    }

    /// Correct floating-point drift on both sum constraints (the
    /// structural ops keep the sums exact in exact arithmetic; repeated
    /// rescales accumulate ~1e-16 per op, folded back here).
    fn renormalize(&mut self) {
        let (cap_a, cap_b) = self.caps();
        renorm_mass(&mut self.alpha, 1.0, cap_a, &self.phi, self.d, &mut self.w, 1.0);
        renorm_mass(
            &mut self.alpha_bar,
            self.eps,
            cap_b,
            &self.phi,
            self.d,
            &mut self.w,
            -1.0,
        );
    }
}

/// Checked u64 write (banned-epoch array).
fn set_at_u64(xs: &mut [u64], i: usize, v: u64) {
    if let Some(x) = xs.get_mut(i) {
        *x = v;
    }
}

// ---------------------------------------------------- ApproxSolver

/// Hyper-parameters of the approximate engine: the slab parameters
/// (reusing [`SmoParams`] — ν's, ε, tolerance, budget, seed, sv_tol)
/// plus the map choice and lifted dimension.
#[derive(Clone, Copy, Debug)]
pub struct ApproxParams {
    /// Slab hyper-parameters + iteration budget + seed.
    pub smo: SmoParams,
    /// Which feature map ([`EngineKind::Exact`] is rejected at fit).
    pub engine: EngineKind,
    /// Lifted dimension D: landmark count for Nyström (clamped to m),
    /// feature count for RFF (rounded up to even).
    pub features: usize,
}

impl Default for ApproxParams {
    fn default() -> Self {
        ApproxParams {
            smo: SmoParams::default(),
            engine: EngineKind::Nystroem,
            features: 64,
        }
    }
}

/// Build the feature map an [`ApproxParams`] choice implies for data
/// of shape (`m` rows × `d_in` cols). Nyström samples its landmarks
/// from `x` with a seeded draw (sorted for determinism); RFF needs no
/// data, only the RBF bandwidth — other kernels are a config error.
pub fn build_map(
    params: &ApproxParams,
    kernel: Kernel,
    x: &Matrix,
) -> Result<FeatMap> {
    match params.engine {
        EngineKind::Exact => Err(Error::config(
            "approx engine requires nystroem or rff (exact has its own solvers)",
        )),
        EngineKind::Nystroem => {
            let m = x.rows();
            if m == 0 {
                return Err(Error::config("nystroem: empty training set"));
            }
            let l = params.features.max(1).min(m);
            let mut rng = Rng::new(params.smo.seed ^ LANDMARK_SEED_MIX);
            let mut idx = rng.sample_indices(m, l);
            idx.sort_unstable();
            let map = NystroemMap::new(kernel, x.select_rows(&idx))?;
            Ok(FeatMap::Nystroem(map))
        }
        EngineKind::Rff => rff_map(params, kernel, x.cols()),
    }
}

/// RFF map for a given input dimension (shared with the streaming
/// engine, which has no batch matrix at construction time).
pub fn rff_map(params: &ApproxParams, kernel: Kernel, d_in: usize) -> Result<FeatMap> {
    let Kernel::Rbf { g } = kernel else {
        return Err(Error::config(format!(
            "rff engine requires the rbf kernel, got {}",
            kernel.family()
        )));
    };
    let p = params.features.max(2);
    let d_out = p + (p % 2);
    let map = RffMap::new(d_in, d_out, g, params.smo.seed ^ RFF_SEED_MIX)?;
    Ok(FeatMap::Rff(map))
}

/// Export a [`SlabModel`] from a trained lifted state. Nyström folds
/// back to a **plain kernel model** over its landmarks
/// (`s(x) = ⟨W^{-1/2}w, k_L(x)⟩` — n_sv ≤ L regardless of m, no
/// featmap carried); RFF keeps the map and stores `w` as the single
/// lifted-space support row.
pub fn export_model(core: &LiftedSlab, map: &FeatMap, sv_tol: f64) -> SlabModel {
    let (rho1, rho2) = core.rho();
    match map {
        FeatMap::Nystroem(m) => {
            let l = m.landmarks().rows();
            let folded: Vec<f64> = (0..l)
                .map(|j| dot(m.wihalf().row(j), core.weights()))
                .collect();
            SlabModel::from_dual(
                m.landmarks(),
                &folded,
                rho1,
                rho2,
                m.kernel(),
                sv_tol,
            )
        }
        FeatMap::Rff(r) => SlabModel {
            x_sv: Matrix::from_vec(1, core.dim_lifted(), core.weights().to_vec()),
            gamma: vec![1.0],
            rho1,
            rho2,
            kernel: Kernel::Rbf { g: r.g() },
            featmap: Some(map.clone()),
        },
    }
}

/// The approximate feature-map engine behind the [`Solver`] trait:
/// lift, train the lifted slab, certify in the lifted space, export.
#[derive(Clone, Copy, Debug, Default)]
pub struct ApproxSolver {
    pub params: ApproxParams,
}

impl ApproxSolver {
    fn fit_impl(&self, x: &Matrix, kernel: Kernel) -> Result<FitReport> {
        let t0 = Instant::now();
        let p = &self.params.smo;
        super::check_params(x.rows(), p.nu1, p.nu2, p.eps)?;
        let map = build_map(&self.params, kernel, x)?;
        let phi = map.map_rows(x);
        let mut core = LiftedSlab::new(map.d_out(), p);
        core.batch_init(&phi);
        let iterations = core.repair(p.max_iter.max(1));
        let certificate = core.certify();
        let model = export_model(&core, &map, p.sv_tol);
        let (rho1, rho2) = core.rho();
        let alpha = core.alpha().to_vec();
        let alpha_bar = core.alpha_bar().to_vec();
        let gamma: Vec<f64> =
            alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
        let s = core.margins().to_vec();
        let stats = SolveStats {
            iterations,
            objective: core.objective(),
            max_violation: certificate.max_kkt_violation,
            seconds: t0.elapsed().as_secs_f64(),
            cache: CacheStats::default(),
            kernel_evals: 0,
        };
        Ok(FitReport {
            model,
            dual: DualSolution { alpha, alpha_bar, gamma, s, rho1, rho2 },
            stats,
            certificate,
            cascade: None,
            precision: Precision::F64,
            fell_back: false,
        })
    }
}

impl Solver for ApproxSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Approx
    }

    /// The approximate engine never consumes a precomputed Gram — the
    /// whole point is to avoid forming K. The argument is accepted
    /// (trait uniformity) and deliberately ignored.
    fn fit_gram(&self, x: &Matrix, kernel: Kernel, _k: &Matrix) -> Result<FitReport> {
        self.fit_impl(x, kernel)
    }

    /// Overridden so end-to-end training skips the O(m²) Gram build
    /// the default implementation would perform.
    fn fit(&self, x: &Matrix, kernel: Kernel) -> Result<FitReport> {
        self.fit_impl(x, kernel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::metrics::roc_auc;

    fn fit_approx(
        engine: EngineKind,
        features: usize,
        kernel: Kernel,
        n: usize,
        seed: u64,
    ) -> FitReport {
        let ds = SlabConfig::default().generate(n, seed);
        let solver = ApproxSolver {
            params: ApproxParams {
                engine,
                features,
                ..ApproxParams::default()
            },
        };
        solver.fit(&ds.x, kernel).unwrap()
    }

    #[test]
    fn batch_fit_is_feasible_and_certified() {
        for (engine, kernel) in [
            (EngineKind::Nystroem, Kernel::Linear),
            (EngineKind::Nystroem, Kernel::Rbf { g: 0.5 }),
            (EngineKind::Rff, Kernel::Rbf { g: 0.5 }),
        ] {
            let r = fit_approx(engine, 32, kernel, 120, 7);
            assert!(r.stats.iterations > 0);
            assert!(r.certificate.sum_alpha_violation < 1e-9, "{engine:?}");
            assert!(r.certificate.sum_alpha_bar_violation < 1e-9, "{engine:?}");
            assert!(r.certificate.max_box_violation < 1e-12, "{engine:?}");
            assert!(r.dual.rho2 >= r.dual.rho1, "{engine:?}");
        }
    }

    #[test]
    fn rff_requires_rbf() {
        let ds = SlabConfig::default().generate(40, 3);
        let solver = ApproxSolver {
            params: ApproxParams {
                engine: EngineKind::Rff,
                features: 16,
                ..ApproxParams::default()
            },
        };
        assert!(solver.fit(&ds.x, Kernel::Linear).is_err());
    }

    #[test]
    fn exact_engine_kind_is_rejected() {
        let ds = SlabConfig::default().generate(20, 3);
        let solver = ApproxSolver {
            params: ApproxParams {
                engine: EngineKind::Exact,
                ..ApproxParams::default()
            },
        };
        assert!(solver.fit(&ds.x, Kernel::Linear).is_err());
    }

    #[test]
    fn deterministic_by_seed() {
        let a = fit_approx(EngineKind::Rff, 32, Kernel::Rbf { g: 0.5 }, 80, 11);
        let b = fit_approx(EngineKind::Rff, 32, Kernel::Rbf { g: 0.5 }, 80, 11);
        assert_eq!(a.dual.rho1.to_bits(), b.dual.rho1.to_bits());
        assert_eq!(a.dual.rho2.to_bits(), b.dual.rho2.to_bits());
        for (x, y) in a.dual.alpha.iter().zip(&b.dual.alpha) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn nystroem_model_is_sv_bounded_by_landmarks() {
        let r = fit_approx(EngineKind::Nystroem, 24, Kernel::Rbf { g: 0.5 }, 200, 5);
        assert!(r.model.n_sv() <= 24, "n_sv={} > L", r.model.n_sv());
        assert!(r.model.featmap.is_none(), "nystroem must fold to plain kernel");
    }

    #[test]
    fn rff_model_is_single_lifted_row() {
        let r = fit_approx(EngineKind::Rff, 32, Kernel::Rbf { g: 0.5 }, 200, 5);
        assert_eq!(r.model.n_sv(), 1);
        assert_eq!(r.model.x_sv.cols(), 32);
        assert!(r.model.featmap.is_some());
    }

    #[test]
    fn approx_auc_tracks_exact() {
        // AUC parity at small scale; full Table-1 parity lives in
        // rust/tests/featmap.rs
        let cfg = SlabConfig::default();
        let ds = cfg.generate(160, 13);
        let eval = cfg.generate_eval(120, 120, 14);
        let (ev, truth) = (&eval.x, &eval.y);
        let kernel = Kernel::Rbf { g: 0.5 };
        let exact = crate::solver::api::Trainer::new(SolverKind::Smo)
            .kernel(kernel)
            .fit(&ds.x)
            .unwrap();
        let approx = ApproxSolver {
            params: ApproxParams {
                engine: EngineKind::Nystroem,
                features: 48,
                ..ApproxParams::default()
            },
        }
        .fit(&ds.x, kernel)
        .unwrap();
        let s_exact: Vec<f64> =
            (0..ev.rows()).map(|i| exact.model.margin(ev.row(i))).collect();
        let s_approx: Vec<f64> =
            (0..ev.rows()).map(|i| approx.model.margin(ev.row(i))).collect();
        let auc_exact = roc_auc(truth, &s_exact);
        let auc_approx = roc_auc(truth, &s_approx);
        assert!(
            (auc_exact - auc_approx).abs() < 0.05,
            "auc exact {auc_exact} vs approx {auc_approx}"
        );
    }

    #[test]
    fn lifted_ops_preserve_invariants() {
        let p = SmoParams { nu1: 0.5, nu2: 0.1, ..SmoParams::default() };
        let mut core = LiftedSlab::new(4, &p);
        let mut rng = Rng::new(3);
        let mk = |rng: &mut Rng| -> Vec<f64> {
            (0..4).map(|_| rng.normal()).collect()
        };
        let check = |core: &LiftedSlab, ctx: &str| {
            let m = core.len();
            if m == 0 {
                return;
            }
            let (cap_a, cap_b) = core.caps();
            let sa: f64 = core.alpha().iter().sum();
            let sb: f64 = core.alpha_bar().iter().sum();
            assert!((sa - 1.0).abs() < 1e-9, "{ctx}: sum alpha {sa}");
            assert!((sb - core.eps()).abs() < 1e-9, "{ctx}: sum abar {sb}");
            for (&a, &b) in core.alpha().iter().zip(core.alpha_bar()) {
                assert!((-1e-12..=cap_a + 1e-12).contains(&a), "{ctx}: alpha {a}");
                assert!((-1e-12..=cap_b + 1e-12).contains(&b), "{ctx}: abar {b}");
            }
        };
        for i in 0..12 {
            let x = mk(&mut rng);
            core.push_grown(&x);
            check(&core, &format!("push {i}"));
        }
        core.repair(4096);
        check(&core, "after repair");
        let y = mk(&mut rng);
        core.replace_row(3, &y);
        check(&core, "replace");
        core.remove_row(5);
        check(&core, "remove");
        core.remove_row(0);
        check(&core, "remove head");
        core.repair(4096);
        check(&core, "repair after removes");
        let cert = core.certify();
        assert!(cert.sum_alpha_violation < 1e-9);
        assert!(cert.max_box_violation < 1e-12);
    }

    #[test]
    fn repair_is_deterministic_below_scan_limit() {
        let p = SmoParams::default();
        let ds = SlabConfig::default().generate(60, 21);
        let map = build_map(
            &ApproxParams { features: 16, ..ApproxParams::default() },
            Kernel::Rbf { g: 0.5 },
            &ds.x,
        )
        .unwrap();
        let phi = map.map_rows(&ds.x);
        let mut a = LiftedSlab::new(map.d_out(), &p);
        let mut b = LiftedSlab::new(map.d_out(), &p);
        a.batch_init(&phi);
        b.batch_init(&phi);
        a.repair(2000);
        b.repair(2000);
        for (x, y) in a.alpha().iter().zip(b.alpha()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.weights().iter().zip(b.weights()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.rho().0.to_bits(), b.rho().0.to_bits());
    }

    #[test]
    fn remove_row_matches_counterexample_regime() {
        // nu=0.5 with a cap-saturated coordinate: the uniform-inflate
        // shortcut would overflow the box here — the greedy
        // redistribution must not
        let p = SmoParams { nu1: 0.5, nu2: 0.5, ..SmoParams::default() };
        let mut core = LiftedSlab::new(2, &p);
        let mut rng = Rng::new(9);
        for _ in 0..4 {
            let x: Vec<f64> = (0..2).map(|_| rng.normal()).collect();
            core.push_grown(&x);
        }
        core.repair(512);
        core.remove_row(1);
        let (cap_a, _) = core.caps();
        for &a in core.alpha() {
            assert!(a <= cap_a + 1e-12, "alpha {a} above cap {cap_a}");
        }
        let sa: f64 = core.alpha().iter().sum();
        assert!((sa - 1.0).abs() < 1e-9);
    }
}
