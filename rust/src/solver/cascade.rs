//! Parallel (cascade) SMO — the paper's future-work item, refs [4][31].
//!
//! Graf-style cascade adapted to the one-class slab setting: split the
//! training set into P shards, train an OCSSVM per shard **in parallel**
//! (std::thread, one full solve per shard), then keep only each shard's
//! support vectors and retrain on their union. Iterate until the
//! support-vector set stabilizes (or `max_rounds`). The final pass over
//! the (much smaller) union yields a model whose objective matches the
//! direct solve to within the union-approximation error — exact when the
//! union contains the true SV set, which the convergence test checks.
//!
//! **ν-rescaling.** The ν-parameterization couples the box caps to the
//! dataset size (cap_a = 1/(ν₁m)), so solving on a SUBSET with the
//! original ν solves a different problem. The union retrain therefore
//! rescales ν' = ν · m / m' so per-point caps — and hence the dual
//! feasible set restricted to the candidates — match the full problem
//! exactly. Feasibility needs ν' ≤ 1, i.e. m' ≥ ν·m: the candidate set
//! is padded with non-candidates when the union is too small.
//!
//! The algorithm lives in the unified API as the [`Trainer::cascade`]
//! layer (`trainer.cascade(shards, max_rounds).fit(x)`), where it
//! composes with **any** [`SolverKind`] — each shard / union solve goes
//! through the same `Solver` path. This module keeps the SMO-flavored
//! [`CascadeParams`]/[`CascadeOutcome`] types and a deprecated `train`
//! shim over the Trainer.
//!
//! Worth it when m is large and the SV fraction is small: per-shard SMO
//! costs fall quadratically with shard size, and shards run in parallel.
//! Ablation note: with the paper's ν₁ = 0.5 HALF the data are support
//! vectors, so the cascade's union barely shrinks — parallelism is the
//! paper's suggestion, but its own hyper-parameters undercut it (see
//! DESIGN.md, experiment index). At ν₁ = 0.1 the cascade wins.
//!
//! [`Trainer::cascade`]: super::api::Trainer::cascade
//! [`SolverKind`]: super::api::SolverKind

use super::api::Trainer;
use super::ocssvm::SlabModel;
use super::smo::{SmoOutcome, SmoParams};
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::Result;

/// Cascade configuration (legacy shim; the unified API takes the same
/// knobs via `Trainer::cascade(shards, max_rounds)`).
#[derive(Clone, Copy, Debug)]
pub struct CascadeParams {
    pub smo: SmoParams,
    /// number of parallel shards in the first layer
    pub shards: usize,
    /// maximum union-retrain rounds after the shard layer
    pub max_rounds: usize,
}

impl Default for CascadeParams {
    fn default() -> Self {
        CascadeParams { smo: SmoParams::default(), shards: 4, max_rounds: 3 }
    }
}

/// Outcome with cascade-specific accounting.
pub struct CascadeOutcome {
    pub outcome: SmoOutcome,
    /// sizes of the candidate set per round (starts at union of shard SVs)
    pub candidate_sizes: Vec<usize>,
    pub rounds: usize,
}

/// Train via the cascade. Falls back to a direct solve when the data is
/// too small to shard meaningfully.
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: \
            `Trainer::from_smo_params(p.smo).kernel(kernel)\
             .cascade(p.shards, p.max_rounds).fit(x)` — the cascade layer \
            now composes with any SolverKind"
)]
pub fn train(
    x: &Matrix,
    kernel: Kernel,
    p: &CascadeParams,
) -> Result<(SlabModel, CascadeOutcome)> {
    let report = Trainer::from_smo_params(p.smo)
        .kernel(kernel)
        .cascade(p.shards, p.max_rounds)
        .fit(x)?;
    let trace = report.cascade.clone().expect("cascade layer always traces");
    let outcome = SmoOutcome {
        alpha: report.dual.alpha,
        alpha_bar: report.dual.alpha_bar,
        gamma: report.dual.gamma,
        s: report.dual.s,
        rho1: report.dual.rho1,
        rho2: report.dual.rho2,
        stats: report.stats,
    };
    Ok((
        report.model,
        CascadeOutcome {
            outcome,
            candidate_sizes: trace.candidate_sizes,
            rounds: trace.rounds,
        },
    ))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shim must keep matching the Trainer layer

    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::solver::api::SolverKind;

    fn sparse_sv_params() -> SmoParams {
        // small nu1 -> few SVs -> cascade's sweet spot
        SmoParams { nu1: 0.1, nu2: 0.05, eps: 0.5, ..Default::default() }
    }

    fn sparse_trainer() -> Trainer {
        Trainer::from_smo_params(sparse_sv_params())
    }

    #[test]
    fn cascade_matches_direct_objective() {
        let ds = SlabConfig::default().generate(600, 91);
        let direct = sparse_trainer().fit(&ds.x).unwrap();
        let casc = sparse_trainer().cascade(4, 4).fit(&ds.x).unwrap();
        let trace = casc.cascade.as_ref().unwrap();
        let rel = (casc.stats.objective - direct.stats.objective).abs()
            / direct.stats.objective.abs().max(1e-9);
        assert!(
            rel < 0.05,
            "cascade {} vs direct {}",
            casc.stats.objective,
            direct.stats.objective
        );
        assert!(casc.model.width() > 0.0);
        assert!(
            trace.candidate_sizes[0] < 600,
            "union should shrink the problem"
        );
    }

    #[test]
    fn cascade_predictions_agree_with_direct() {
        let ds = SlabConfig::default().generate(500, 92);
        let direct = sparse_trainer().fit(&ds.x).unwrap().model;
        let casc = sparse_trainer().cascade(4, 4).fit(&ds.x).unwrap().model;
        let eval = SlabConfig::default().generate_eval(200, 200, 93);
        let agree = (0..eval.len())
            .filter(|&i| direct.classify(eval.x.row(i)) == casc.classify(eval.x.row(i)))
            .count();
        assert!(
            agree as f64 / eval.len() as f64 > 0.97,
            "only {agree}/400 agree"
        );
    }

    #[test]
    fn small_data_falls_back_to_direct() {
        let ds = SlabConfig::default().generate(40, 94);
        let p = CascadeParams { shards: 8, ..Default::default() };
        let (_, casc) = train(&ds.x, Kernel::Linear, &p).unwrap();
        assert_eq!(casc.rounds, 0);
        assert_eq!(casc.candidate_sizes, vec![40]);
    }

    #[test]
    fn global_outcome_is_feasible() {
        let ds = SlabConfig::default().generate(400, 95);
        let p = CascadeParams { smo: sparse_sv_params(), shards: 4, max_rounds: 3 };
        let (_, casc) = train(&ds.x, Kernel::Linear, &p).unwrap();
        // both sums conserved in the global reconstruction
        let sa: f64 = casc.outcome.alpha.iter().sum();
        let sb: f64 = casc.outcome.alpha_bar.iter().sum();
        assert!((sa - 1.0).abs() < 1e-8, "sum(alpha)={sa}");
        assert!((sb - 0.5).abs() < 1e-8, "sum(alpha_bar)={sb}");
    }

    #[test]
    fn shim_matches_trainer_layer_exactly() {
        let ds = SlabConfig::default().generate(400, 96);
        let p = CascadeParams { smo: sparse_sv_params(), shards: 4, max_rounds: 3 };
        let (model, casc) = train(&ds.x, Kernel::Linear, &p).unwrap();
        let report = sparse_trainer().cascade(4, 3).fit(&ds.x).unwrap();
        assert_eq!(casc.outcome.gamma, report.dual.gamma);
        assert_eq!(model.rho1, report.model.rho1);
        assert_eq!(model.rho2, report.model.rho2);
    }

    #[test]
    fn cascade_composes_with_other_solver_kinds() {
        // the ipm per shard: tiny problem so the O(m^3) steps stay cheap
        let ds = SlabConfig::default().generate(160, 97);
        let report = Trainer::new(SolverKind::Ipm)
            .nu1(0.1)
            .nu2(0.05)
            .eps(0.5)
            .cascade(2, 2)
            .fit(&ds.x)
            .unwrap();
        let direct = Trainer::new(SolverKind::Ipm)
            .nu1(0.1)
            .nu2(0.05)
            .eps(0.5)
            .fit(&ds.x)
            .unwrap();
        let rel = (report.stats.objective - direct.stats.objective).abs()
            / direct.stats.objective.abs().max(1e-9);
        assert!(
            rel < 0.05,
            "ipm cascade {} vs direct {}",
            report.stats.objective,
            direct.stats.objective
        );
    }
}
