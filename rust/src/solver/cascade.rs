//! Parallel (cascade) SMO — the paper's future-work item, refs [4][31].
//!
//! Graf-style cascade adapted to the one-class slab setting: split the
//! training set into P shards, train an OCSSVM per shard **in parallel**
//! (std::thread, one full SMO per shard), then keep only each shard's
//! support vectors and retrain on their union. Iterate until the
//! support-vector set stabilizes (or `max_rounds`). The final pass over
//! the (much smaller) union yields a model whose objective matches the
//! direct solve to within the union-approximation error — exact when the
//! union contains the true SV set, which the convergence test checks.
//!
//! Worth it when m is large and the SV fraction is small: per-shard SMO
//! costs fall quadratically with shard size, and shards run in parallel.
//! Ablation note: with the paper's ν₁ = 0.5 HALF the data are support
//! vectors, so the cascade's union barely shrinks — parallelism is the
//! paper's suggestion, but its own hyper-parameters undercut it (see
//! EXPERIMENTS.md). At ν₁ = 0.1 the cascade wins.

use super::ocssvm::SlabModel;
use super::smo::{train_full, SmoOutcome, SmoParams};
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::Result;

/// Cascade configuration.
#[derive(Clone, Copy, Debug)]
pub struct CascadeParams {
    pub smo: SmoParams,
    /// number of parallel shards in the first layer
    pub shards: usize,
    /// maximum union-retrain rounds after the shard layer
    pub max_rounds: usize,
}

impl Default for CascadeParams {
    fn default() -> Self {
        CascadeParams { smo: SmoParams::default(), shards: 4, max_rounds: 3 }
    }
}

/// Outcome with cascade-specific accounting.
pub struct CascadeOutcome {
    pub outcome: SmoOutcome,
    /// sizes of the candidate set per round (starts at union of shard SVs)
    pub candidate_sizes: Vec<usize>,
    pub rounds: usize,
}

/// Train via the cascade. Falls back to a direct solve when the data is
/// too small to shard meaningfully.
pub fn train(
    x: &Matrix,
    kernel: Kernel,
    p: &CascadeParams,
) -> Result<(SlabModel, CascadeOutcome)> {
    let m = x.rows();
    let shards = p.shards.max(1);
    if m < shards * 16 || shards == 1 {
        let (model, outcome) = train_full(x, kernel, &p.smo)?;
        return Ok((
            model,
            CascadeOutcome { outcome, candidate_sizes: vec![m], rounds: 0 },
        ));
    }

    // ---- layer 1: parallel shard solves -------------------------------
    // round-robin assignment keeps shards distributionally balanced
    let mut shard_idx: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for i in 0..m {
        shard_idx[i % shards].push(i);
    }
    let shard_svs: Vec<Result<Vec<usize>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shard_idx
            .iter()
            .map(|idx| {
                let smo = p.smo;
                scope.spawn(move || -> Result<Vec<usize>> {
                    let xs = x.select_rows(idx);
                    let (model, out) = train_full(&xs, kernel, &smo)?;
                    let _ = model;
                    // SVs of this shard, mapped back to global indices
                    Ok(idx
                        .iter()
                        .enumerate()
                        .filter(|(r, _)| out.gamma[*r].abs() > smo.sv_tol)
                        .map(|(_, &g)| g)
                        .collect())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard thread")).collect()
    });
    let mut candidates: Vec<usize> = Vec::new();
    for svs in shard_svs {
        candidates.extend(svs?);
    }
    candidates.sort_unstable();
    candidates.dedup();

    // ---- layer 2+: retrain on the union until the SV set stabilizes ----
    //
    // The ν-parameterization couples the box caps to the dataset size
    // (cap_a = 1/(ν₁ m)), so solving on a SUBSET with the original ν
    // solves a different problem. The union retrain therefore rescales
    // ν' = ν · m / m' so per-point caps — and hence the dual feasible
    // set restricted to the candidates — match the full problem exactly.
    // Feasibility needs ν' ≤ 1, i.e. m' ≥ ν·m: the candidate set is
    // padded with non-candidates when the union is too small.
    let mut candidate_sizes = vec![candidates.len()];
    let mut rounds = 0;
    loop {
        rounds += 1;
        // pad for ν' ≤ 1 feasibility
        let min_size = ((p.smo.nu1.max(p.smo.nu2) * m as f64).ceil() as usize
            + 1)
        .min(m);
        if candidates.len() < min_size {
            for i in 0..m {
                if candidates.len() >= min_size {
                    break;
                }
                if candidates.binary_search(&i).is_err() {
                    candidates.push(i);
                }
            }
            candidates.sort_unstable();
        }
        let m_sub = candidates.len();
        let scale = m as f64 / m_sub as f64;
        let sub_params = SmoParams {
            nu1: (p.smo.nu1 * scale).min(1.0),
            nu2: (p.smo.nu2 * scale).min(1.0),
            ..p.smo
        };
        let xs = x.select_rows(&candidates);
        let (model, out) = train_full(&xs, kernel, &sub_params)?;
        let sv_of_candidates: Vec<usize> = candidates
            .iter()
            .enumerate()
            .filter(|(r, _)| out.gamma[*r].abs() > p.smo.sv_tol)
            .map(|(_, &g)| g)
            .collect();
        // convergence check: does the model violate KKT on any point
        // OUTSIDE the candidate set? (those points have γ = 0, so the
        // check is just "is the margin inside the slab")
        let mut violators: Vec<usize> = Vec::new();
        for i in 0..m {
            if candidates.binary_search(&i).is_ok() {
                continue;
            }
            let s = model.score(x.row(i));
            if s < out.rho1 - p.smo.tol * (1.0 + s.abs())
                || s > out.rho2 + p.smo.tol * (1.0 + s.abs())
            {
                violators.push(i);
            }
        }
        if violators.is_empty() || rounds >= p.max_rounds {
            // rebuild the outcome in GLOBAL index space
            let mut gamma = vec![0.0; m];
            let mut alpha = vec![0.0; m];
            let mut alpha_bar = vec![0.0; m];
            for (r, &g) in candidates.iter().enumerate() {
                gamma[g] = out.gamma[r];
                alpha[g] = out.alpha[r];
                alpha_bar[g] = out.alpha_bar[r];
            }
            let s: Vec<f64> = (0..m).map(|i| model.score(x.row(i))).collect();
            let outcome = SmoOutcome {
                alpha,
                alpha_bar,
                gamma,
                s,
                rho1: out.rho1,
                rho2: out.rho2,
                stats: out.stats,
            };
            let final_model = SlabModel::from_dual(
                x, &outcome.gamma, out.rho1, out.rho2, kernel, p.smo.sv_tol,
            );
            return Ok((
                final_model,
                CascadeOutcome { outcome, candidate_sizes, rounds },
            ));
        }
        // grow the candidate set with the violators and retrain
        candidates = sv_of_candidates;
        candidates.extend(violators);
        candidates.sort_unstable();
        candidates.dedup();
        candidate_sizes.push(candidates.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    fn sparse_sv_params() -> SmoParams {
        // small nu1 -> few SVs -> cascade's sweet spot
        SmoParams { nu1: 0.1, nu2: 0.05, eps: 0.5, ..Default::default() }
    }

    #[test]
    fn cascade_matches_direct_objective() {
        let ds = SlabConfig::default().generate(600, 91);
        let direct = train_full(&ds.x, Kernel::Linear, &sparse_sv_params()).unwrap();
        let p = CascadeParams { smo: sparse_sv_params(), shards: 4, max_rounds: 4 };
        let (model, casc) = train(&ds.x, Kernel::Linear, &p).unwrap();
        let rel = (casc.outcome.stats.objective - direct.1.stats.objective).abs()
            / direct.1.stats.objective.abs().max(1e-9);
        assert!(
            rel < 0.05,
            "cascade {} vs direct {}",
            casc.outcome.stats.objective,
            direct.1.stats.objective
        );
        assert!(model.width() > 0.0);
        assert!(casc.candidate_sizes[0] < 600, "union should shrink the problem");
    }

    #[test]
    fn cascade_predictions_agree_with_direct() {
        let ds = SlabConfig::default().generate(500, 92);
        let (direct, _) = train_full(&ds.x, Kernel::Linear, &sparse_sv_params()).unwrap();
        let p = CascadeParams { smo: sparse_sv_params(), shards: 4, max_rounds: 4 };
        let (casc, _) = train(&ds.x, Kernel::Linear, &p).unwrap();
        let eval = SlabConfig::default().generate_eval(200, 200, 93);
        let agree = (0..eval.len())
            .filter(|&i| direct.classify(eval.x.row(i)) == casc.classify(eval.x.row(i)))
            .count();
        assert!(
            agree as f64 / eval.len() as f64 > 0.97,
            "only {agree}/400 agree"
        );
    }

    #[test]
    fn small_data_falls_back_to_direct() {
        let ds = SlabConfig::default().generate(40, 94);
        let p = CascadeParams { shards: 8, ..Default::default() };
        let (_, casc) = train(&ds.x, Kernel::Linear, &p).unwrap();
        assert_eq!(casc.rounds, 0);
        assert_eq!(casc.candidate_sizes, vec![40]);
    }

    #[test]
    fn global_outcome_is_feasible() {
        let ds = SlabConfig::default().generate(400, 95);
        let p = CascadeParams { smo: sparse_sv_params(), shards: 4, max_rounds: 3 };
        let (_, casc) = train(&ds.x, Kernel::Linear, &p).unwrap();
        // both sums conserved in the global reconstruction
        let sa: f64 = casc.outcome.alpha.iter().sum();
        let sb: f64 = casc.outcome.alpha_bar.iter().sum();
        assert!((sa - 1.0).abs() < 1e-8, "sum(alpha)={sa}");
        assert!((sb - 0.5).abs() < 1e-8, "sum(alpha_bar)={sb}");
    }
}
