//! Trained OCSSVM model: dual vector + slab offsets + decision function.
//!
//! A [`SlabModel`] is what every solver returns and what the serving
//! coordinator registers. The decision function is the paper's eq. (19):
//!
//! ```text
//!   f(x) = sgn( (Σᵢ γᵢ k(xᵢ, x) − ρ1) · (ρ2 − Σᵢ γᵢ k(xᵢ, x)) )
//! ```
//!
//! +1 ⇔ the margin s(x) lands inside the slab [ρ1, ρ2]. Points exactly
//! on a plane (product 0) count as inside.

use crate::data::Dataset;
use crate::kernel::featmap::{FeatMap, FeatureMap, NystroemMap, RffMap};
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::metrics::Confusion;
use crate::util::json::Json;

/// A trained one-class slab SVM.
#[derive(Clone, Debug)]
pub struct SlabModel {
    /// support samples (rows with γ ≠ 0 — non-SVs are dropped at build).
    /// When `featmap` is set, each row is a **lifted-space** weight
    /// vector instead of an input sample (see [`SlabModel::score`]).
    pub x_sv: Matrix,
    /// dual coefficients of the support samples (γ = α − ᾱ)
    pub gamma: Vec<f64>,
    /// lower slab offset
    pub rho1: f64,
    /// upper slab offset
    pub rho2: f64,
    /// kernel the model was trained with
    pub kernel: Kernel,
    /// Feature map for approximate-engine models (DESIGN.md §10).
    /// `None` for every exact model and for Nyström models, which fold
    /// back to plain kernel form at export (`s(x) = ⟨W^{-1/2}w, k_L(x)⟩`
    /// is an ordinary kernel expansion over the landmarks). Only RFF
    /// models carry a map: `x_sv` is then a single row holding the
    /// lifted weight vector `w` and `score` evaluates `⟨w, φ(x)⟩`.
    pub featmap: Option<FeatMap>,
}

impl SlabModel {
    /// Assemble from a full dual vector, dropping non-support rows.
    /// `sv_tol` decides which |γ| count as support vectors.
    pub fn from_dual(
        x: &Matrix,
        gamma_full: &[f64],
        rho1: f64,
        rho2: f64,
        kernel: Kernel,
        sv_tol: f64,
    ) -> Self {
        assert_eq!(x.rows(), gamma_full.len());
        let idx: Vec<usize> = (0..x.rows())
            .filter(|&i| gamma_full[i].abs() > sv_tol)
            .collect();
        let x_sv = x.select_rows(&idx);
        let gamma = idx.iter().map(|&i| gamma_full[i]).collect();
        SlabModel { x_sv, gamma, rho1, rho2, kernel, featmap: None }
    }

    /// Number of support vectors.
    pub fn n_sv(&self) -> usize {
        self.gamma.len()
    }

    /// Slab width ρ2 − ρ1 (> 0 for any meaningful model).
    pub fn width(&self) -> f64 {
        self.rho2 - self.rho1
    }

    /// Margin s(x) = Σ γᵢ k(xᵢ, x), or Σ γᵢ ⟨vᵢ, φ(x)⟩ for
    /// feature-map models (one D-dimensional dot product per row,
    /// independent of how many samples trained the model).
    pub fn score(&self, x: &[f64]) -> f64 {
        if let Some(map) = &self.featmap {
            let mut s = 0.0;
            for (i, &g) in self.gamma.iter().enumerate() {
                s += g * map.dot_lifted(x, self.x_sv.row(i));
            }
            return s;
        }
        let mut s = 0.0;
        for (i, &g) in self.gamma.iter().enumerate() {
            s += g * self.kernel.eval(self.x_sv.row(i), x);
        }
        s
    }

    /// Decision f(x): +1 inside the slab, −1 outside (paper eq. (19)).
    pub fn classify(&self, x: &[f64]) -> i8 {
        let s = self.score(x);
        if (s - self.rho1) * (self.rho2 - s) >= 0.0 {
            1
        } else {
            -1
        }
    }

    /// Batch scores for a query matrix (native engine).
    pub fn scores(&self, q: &Matrix) -> Vec<f64> {
        (0..q.rows()).map(|i| self.score(q.row(i))).collect()
    }

    /// Batch labels for a query matrix (native engine).
    pub fn predict(&self, q: &Matrix) -> Vec<i8> {
        self.scores(q)
            .into_iter()
            .map(|s| if (s - self.rho1) * (self.rho2 - s) >= 0.0 { 1 } else { -1 })
            .collect()
    }

    /// Evaluate on a labeled dataset.
    pub fn evaluate(&self, ds: &Dataset) -> Confusion {
        let pred = self.predict(&ds.x);
        Confusion::from_labels(&ds.y, &pred)
    }

    /// Slab-margin score usable for ROC analysis: positive inside,
    /// magnitude = distance to the nearest plane (the paper's f̄).
    pub fn margin(&self, x: &[f64]) -> f64 {
        let s = self.score(x);
        super::fbar(s, self.rho1, self.rho2)
    }

    // ------------------------------------------------------------ persistence

    /// Serialize to JSON (gamma, rho's, kernel, support matrix, and —
    /// for approximate-engine models — the feature map: RFF persists
    /// only `(g, seed, d_in, d_out)` and redraws the frequencies
    /// deterministically on load; Nyström persists its landmarks and
    /// rebuilds `W^{-1/2}` with the same fixed-order eigensolve).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("rho1", Json::num(self.rho1)),
            ("rho2", Json::num(self.rho2)),
            ("kernel", kernel_json(&self.kernel)),
            ("d", Json::num(self.x_sv.cols() as f64)),
            (
                "gamma",
                Json::arr(self.gamma.iter().map(|&g| Json::num(g)).collect()),
            ),
            (
                "x_sv",
                Json::arr(
                    self.x_sv.data().iter().map(|&v| Json::num(v)).collect(),
                ),
            ),
        ];
        match &self.featmap {
            None => {}
            Some(FeatMap::Rff(m)) => fields.push((
                "featmap",
                Json::obj(vec![
                    ("family", Json::str("rff")),
                    ("g", Json::num(m.g())),
                    ("seed", Json::num(m.seed() as f64)),
                    ("d_in", Json::num(m.d_in() as f64)),
                    ("d_out", Json::num(m.d_out() as f64)),
                ]),
            )),
            Some(FeatMap::Nystroem(m)) => fields.push((
                "featmap",
                Json::obj(vec![
                    ("family", Json::str("nystroem")),
                    ("kernel", kernel_json(&m.kernel())),
                    ("l", Json::num(m.landmarks().rows() as f64)),
                    ("d_in", Json::num(m.landmarks().cols() as f64)),
                    (
                        "landmarks",
                        Json::arr(
                            m.landmarks()
                                .data()
                                .iter()
                                .map(|&v| Json::num(v))
                                .collect(),
                        ),
                    ),
                ]),
            )),
        }
        Json::obj(fields)
    }

    /// Deserialize from [`SlabModel::to_json`] output.
    pub fn from_json(j: &Json) -> crate::Result<SlabModel> {
        use crate::error::Error;
        let num = |k: &str| -> crate::Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::data(format!("model json: missing {k}")))
        };
        let rho1 = num("rho1")?;
        let rho2 = num("rho2")?;
        let d = num("d")? as usize;
        let gamma: Vec<f64> = j
            .get("gamma")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::data("model json: missing gamma"))?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        let flat: Vec<f64> = j
            .get("x_sv")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::data("model json: missing x_sv"))?
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        if d == 0 || flat.len() != gamma.len() * d {
            return Err(Error::data("model json: x_sv shape mismatch"));
        }
        let kj = j.get("kernel").ok_or_else(|| Error::data("missing kernel"))?;
        let kernel = kernel_from_json(kj)?;
        let featmap = match j.get("featmap") {
            None => None,
            Some(fj) => Some(featmap_from_json(fj)?),
        };
        Ok(SlabModel {
            x_sv: Matrix::from_vec(gamma.len(), d, flat),
            gamma,
            rho1,
            rho2,
            kernel,
            featmap,
        })
    }

    /// Save to a file.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> crate::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<std::path::Path>) -> crate::Result<SlabModel> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// Kernel → JSON object (shared by the model body and featmap blocks).
fn kernel_json(k: &Kernel) -> Json {
    match *k {
        Kernel::Linear => Json::obj(vec![("family", Json::str("linear"))]),
        Kernel::Rbf { g } => Json::obj(vec![
            ("family", Json::str("rbf")),
            ("g", Json::num(g)),
        ]),
        Kernel::Poly { g, c, degree } => Json::obj(vec![
            ("family", Json::str("poly")),
            ("g", Json::num(g)),
            ("c", Json::num(c)),
            ("degree", Json::num(degree)),
        ]),
        Kernel::Sigmoid { g, c } => Json::obj(vec![
            ("family", Json::str("sigmoid")),
            ("g", Json::num(g)),
            ("c", Json::num(c)),
        ]),
    }
}

/// Inverse of [`kernel_json`].
fn kernel_from_json(kj: &Json) -> crate::Result<Kernel> {
    use crate::error::Error;
    let fam = kj
        .get("family")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::data("missing kernel family"))?;
    let gk = |k: &str| kj.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    match fam {
        "linear" => Ok(Kernel::Linear),
        "rbf" => Ok(Kernel::Rbf { g: gk("g") }),
        "poly" => Ok(Kernel::Poly { g: gk("g"), c: gk("c"), degree: gk("degree") }),
        "sigmoid" => Ok(Kernel::Sigmoid { g: gk("g"), c: gk("c") }),
        other => Err(Error::data(format!("unknown kernel {other}"))),
    }
}

/// Rebuild a [`FeatMap`] from its model-JSON block. Both maps are
/// reconstructed deterministically (seeded redraw / fixed-order
/// eigensolve), so a saved approximate model scores bitwise the same
/// after load.
fn featmap_from_json(fj: &Json) -> crate::Result<FeatMap> {
    use crate::error::Error;
    let fam = fj
        .get("family")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::data("featmap json: missing family"))?;
    let num = |k: &str| -> crate::Result<f64> {
        fj.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::data(format!("featmap json: missing {k}")))
    };
    match fam {
        "rff" => {
            let map = RffMap::new(
                num("d_in")? as usize,
                num("d_out")? as usize,
                num("g")?,
                num("seed")? as u64,
            )?;
            Ok(FeatMap::Rff(map))
        }
        "nystroem" => {
            let l = num("l")? as usize;
            let d = num("d_in")? as usize;
            let flat: Vec<f64> = fj
                .get("landmarks")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::data("featmap json: missing landmarks"))?
                .iter()
                .filter_map(Json::as_f64)
                .collect();
            if l == 0 || d == 0 || flat.len() != l * d {
                return Err(Error::data("featmap json: landmark shape mismatch"));
            }
            let kernel = kernel_from_json(
                fj.get("kernel")
                    .ok_or_else(|| Error::data("featmap json: missing kernel"))?,
            )?;
            let map = NystroemMap::new(kernel, Matrix::from_vec(l, d, flat))?;
            Ok(FeatMap::Nystroem(map))
        }
        other => Err(Error::data(format!("unknown featmap family {other}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_model() -> SlabModel {
        // single support vector at (1, 0), gamma 1, linear kernel:
        // s(x) = x[0]; slab [0.2, 0.8]
        SlabModel {
            x_sv: Matrix::from_rows(&[&[1.0, 0.0]]),
            gamma: vec![1.0],
            rho1: 0.2,
            rho2: 0.8,
            kernel: Kernel::Linear,
            featmap: None,
        }
    }

    #[test]
    fn score_and_classify() {
        let m = tiny_model();
        assert!((m.score(&[0.5, 3.0]) - 0.5).abs() < 1e-12);
        assert_eq!(m.classify(&[0.5, 0.0]), 1); // inside
        assert_eq!(m.classify(&[0.0, 0.0]), -1); // below rho1
        assert_eq!(m.classify(&[1.0, 0.0]), -1); // above rho2
        assert_eq!(m.classify(&[0.2, 0.0]), 1); // exactly on plane
        assert_eq!(m.classify(&[0.8, 0.0]), 1); // exactly on plane
    }

    #[test]
    fn margin_is_fbar() {
        let m = tiny_model();
        assert!((m.margin(&[0.5, 0.0]) - 0.3).abs() < 1e-12);
        assert!((m.margin(&[0.9, 0.0]) + 0.1).abs() < 1e-12);
    }

    #[test]
    fn from_dual_drops_non_svs() {
        let x = Matrix::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        let gamma = [0.5, 0.0, -0.25];
        let m = SlabModel::from_dual(&x, &gamma, 0.0, 1.0, Kernel::Linear, 1e-12);
        assert_eq!(m.n_sv(), 2);
        assert_eq!(m.gamma, vec![0.5, -0.25]);
        assert_eq!(m.x_sv.row(1), &[3.0]);
        // score must equal the full-dual score
        let s_full: f64 = 0.5 * 1.0 * 4.0 + 0.0 + (-0.25) * 3.0 * 4.0;
        assert!((m.score(&[4.0]) - s_full).abs() < 1e-12);
    }

    #[test]
    fn predict_matches_classify() {
        let m = tiny_model();
        let q = Matrix::from_rows(&[&[0.5, 0.0], &[0.0, 0.0], &[1.0, 0.0]]);
        assert_eq!(m.predict(&q), vec![1, -1, -1]);
    }

    #[test]
    fn evaluate_confusion() {
        let m = tiny_model();
        let q = Matrix::from_rows(&[&[0.5, 0.0], &[0.0, 0.0], &[0.9, 0.0]]);
        let ds = Dataset::new(q, vec![1, -1, 1]);
        let c = m.evaluate(&ds);
        assert_eq!((c.tp, c.tn, c.fp, c.fn_), (1, 1, 0, 1));
    }

    #[test]
    fn json_roundtrip() {
        let m = SlabModel {
            x_sv: Matrix::from_rows(&[&[1.0, -2.0], &[0.5, 0.25]]),
            gamma: vec![0.7, -0.3],
            rho1: -0.1,
            rho2: 0.35,
            kernel: Kernel::Rbf { g: 0.8 },
            featmap: None,
        };
        let j = m.to_json();
        let m2 = SlabModel::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m2.gamma, m.gamma);
        assert_eq!(m2.rho1, m.rho1);
        assert_eq!(m2.kernel, m.kernel);
        assert_eq!(m2.x_sv.data(), m.x_sv.data());
        assert!(m2.featmap.is_none());
        // identical predictions
        let p = [0.3, 0.4];
        assert!((m.score(&p) - m2.score(&p)).abs() < 1e-12);
    }

    #[test]
    fn rff_model_json_roundtrip_scores_bitwise() {
        // an approximate-engine model: x_sv holds the lifted weight
        // vector, the map is redrawn from (g, seed) on load
        let map = RffMap::new(2, 8, 0.5, 99).unwrap();
        let mut rng = crate::util::rng::Rng::new(7);
        let w: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let m = SlabModel {
            x_sv: Matrix::from_vec(1, 8, w),
            gamma: vec![1.0],
            rho1: -0.2,
            rho2: 0.4,
            kernel: Kernel::Linear,
            featmap: Some(FeatMap::Rff(map)),
        };
        let j = m.to_json();
        let m2 = SlabModel::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert!(matches!(m2.featmap, Some(FeatMap::Rff(_))));
        for p in [[0.3, 0.4], [-1.0, 2.0], [0.0, 0.0]] {
            assert_eq!(m.score(&p).to_bits(), m2.score(&p).to_bits());
            assert_eq!(m.classify(&p), m2.classify(&p));
        }
    }

    #[test]
    fn nystroem_model_json_roundtrip_scores_bitwise() {
        let x = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0], &[0.5, 0.5]]);
        let map = NystroemMap::new(Kernel::Rbf { g: 0.7 }, x.clone()).unwrap();
        let m = SlabModel {
            x_sv: Matrix::from_rows(&[&[0.4, -0.1, 0.2]]),
            gamma: vec![1.0],
            rho1: 0.0,
            rho2: 0.5,
            kernel: Kernel::Linear,
            featmap: Some(FeatMap::Nystroem(map)),
        };
        let j = m.to_json();
        let m2 = SlabModel::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        for p in [[0.3, 0.4], [-1.0, 2.0]] {
            assert_eq!(m.score(&p).to_bits(), m2.score(&p).to_bits());
        }
    }

    #[test]
    fn save_load_file() {
        let m = tiny_model();
        let p = std::env::temp_dir().join(format!(
            "slabsvm_model_{}.json",
            std::process::id()
        ));
        m.save(&p).unwrap();
        let m2 = SlabModel::load(&p).unwrap();
        assert_eq!(m2.rho2, 0.8);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        let j = Json::parse(r#"{"rho1":0,"rho2":1,"d":2,"gamma":[1],"x_sv":[1],
                               "kernel":{"family":"linear"}}"#).unwrap();
        assert!(SlabModel::from_json(&j).is_err());
    }
}
