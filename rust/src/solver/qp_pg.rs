//! Baseline: projected-gradient solver for the OCSSVM dual.
//!
//! The generic first-order comparator of DESIGN.md experiment T1-ext,
//! solving the *faithful* dual in (α, ᾱ):
//!
//! ```text
//!   min ½ (α−ᾱ)ᵀK(α−ᾱ)
//!   s.t. 0 ≤ α ≤ 1/(ν₁m), Σα = 1;   0 ≤ ᾱ ≤ ε/(ν₂m), Σᾱ = ε
//! ```
//!
//! The feasible set is a product of two box-simplex polytopes, so the
//! Euclidean projection splits per block; each block projection is the
//! classic continuous-knapsack projection computed by bisection on the
//! hyperplane multiplier. Steps are γ-gradient based: ∇_α = s, ∇_ᾱ = −s
//! with s = K(α−ᾱ), step 1/L with L = λ_max(K) (power iteration) —
//! note the Hessian of the extended system has the same spectral scale.
//!
//! Per-iteration cost is a full O(m²) mat-vec (vs SMO's O(m)), which is
//! precisely the scaling gap Table 1's claim is about.

use std::time::Instant;

use super::ocssvm::SlabModel;
use super::smo::recover_rhos_blocks;
use super::{check_params, SolveStats};
use crate::error::Error;
use crate::kernel::Kernel;
use crate::linalg::{matvec, Matrix};
use crate::Result;

/// Projected-gradient hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct PgParams {
    pub nu1: f64,
    pub nu2: f64,
    pub eps: f64,
    /// KKT tolerance for the exit test (margin units)
    pub tol: f64,
    pub max_iter: usize,
    /// power-iteration steps for the Lipschitz estimate
    pub power_iters: usize,
    pub sv_tol: f64,
}

impl Default for PgParams {
    fn default() -> Self {
        PgParams {
            nu1: 0.5,
            nu2: 0.01,
            eps: 2.0 / 3.0,
            tol: 1e-5,
            max_iter: 100_000,
            power_iters: 30,
            sv_tol: 1e-10,
        }
    }
}

/// Exact projection onto { lo ≤ xᵢ ≤ hi, Σxᵢ = c } by bisection on the
/// hyperplane multiplier (Σ clip(vᵢ − λ) is monotone in λ).
pub fn project(v: &[f64], lo: f64, hi: f64, c: f64) -> Vec<f64> {
    let m = v.len() as f64;
    debug_assert!(c >= lo * m - 1e-9 && c <= hi * m + 1e-9, "infeasible target");
    let sum_at =
        |lambda: f64| -> f64 { v.iter().map(|&vi| (vi - lambda).clamp(lo, hi)).sum() };
    let vmin = v.iter().cloned().fold(f64::INFINITY, f64::min);
    let vmax = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut a = vmin - hi - 1.0;
    let mut b = vmax - lo + 1.0;
    for _ in 0..128 {
        let mid = 0.5 * (a + b);
        if sum_at(mid) > c {
            a = mid;
        } else {
            b = mid;
        }
        if b - a < 1e-15 * (1.0 + vmax.abs()) {
            break;
        }
    }
    let lambda = 0.5 * (a + b);
    v.iter().map(|&vi| (vi - lambda).clamp(lo, hi)).collect()
}

/// Estimate the spectral norm of K by power iteration.
pub(crate) fn spectral_norm(k: &Matrix, iters: usize) -> f64 {
    let m = k.rows();
    let mut v: Vec<f64> = (0..m).map(|i| 1.0 + 0.001 * (i as f64).sin()).collect();
    let mut kv = vec![0.0; m];
    let mut lambda = 1.0;
    for _ in 0..iters {
        matvec(k, &v, &mut kv);
        lambda = kv.iter().map(|x| x * x).sum::<f64>().sqrt();
        if lambda <= 1e-30 {
            return 1.0;
        }
        for (vi, kvi) in v.iter_mut().zip(&kv) {
            *vi = kvi / lambda;
        }
    }
    lambda
}

/// Raw dual solve on a precomputed Gram matrix.
/// Returns (α, ᾱ, ρ₁, ρ₂, stats).
pub fn solve(
    k: &Matrix,
    p: &PgParams,
) -> Result<(Vec<f64>, Vec<f64>, f64, f64, SolveStats)> {
    let m = k.rows();
    check_params(m, p.nu1, p.nu2, p.eps)?;
    let cap_a = 1.0 / (p.nu1 * m as f64);
    let cap_b = p.eps / (p.nu2 * m as f64);
    let t0 = Instant::now();

    let mut alpha = vec![1.0 / m as f64; m];
    let mut alpha_bar = vec![p.eps / m as f64; m];
    let l = spectral_norm(k, p.power_iters).max(1e-12);
    // the extended Hessian [[K,-K],[-K,K]] has λ_max = 2 λ_max(K)
    let step = 1.0 / (2.0 * l);

    // FISTA state (accelerated PG with objective restart): y is the
    // extrapolated point the gradient is evaluated at.
    let mut y_a = alpha.clone();
    let mut y_b = alpha_bar.clone();
    let mut t_acc = 1.0f64;
    let mut prev_obj = f64::INFINITY;
    let mut stall = 0usize;

    let mut s = vec![0.0; m];
    let mut gamma = vec![0.0; m];
    let (mut rho1, mut rho2) = (0.0, 0.0);
    let mut iterations = 0;
    let mut max_viol = f64::INFINITY;
    // KKT exits are measured relative to the margin scale: a first-order
    // method cannot reach absolute 1e-5 when margins are O(100), and the
    // comparison wants "equivalent solution quality", not equal absolute
    // thresholds.
    let mut scale = 1.0f64;

    // classification tolerance for free-vs-bound in the KKT scan
    let cls_a = cap_a * 1e-7;
    let cls_b = cap_b * 1e-7;

    let kkt_scan = |alpha: &[f64],
                    alpha_bar: &[f64],
                    s: &[f64],
                    rho1: f64,
                    rho2: f64|
     -> f64 {
        let mut mv = 0.0f64;
        for i in 0..alpha.len() {
            let va = if alpha[i] <= cls_a {
                (rho1 - s[i]).max(0.0)
            } else if alpha[i] >= cap_a - cls_a {
                (s[i] - rho1).max(0.0)
            } else {
                (s[i] - rho1).abs()
            };
            let vb = if alpha_bar[i] <= cls_b {
                (s[i] - rho2).max(0.0)
            } else if alpha_bar[i] >= cap_b - cls_b {
                (rho2 - s[i]).max(0.0)
            } else {
                (s[i] - rho2).abs()
            };
            mv = mv.max(va).max(vb);
        }
        mv
    };

    while iterations < p.max_iter {
        // gradient at the extrapolated point
        for i in 0..m {
            gamma[i] = y_a[i] - y_b[i];
        }
        matvec(k, &gamma, &mut s);
        let prop_a: Vec<f64> =
            y_a.iter().zip(&s).map(|(a, si)| a - step * si).collect();
        let prop_b: Vec<f64> =
            y_b.iter().zip(&s).map(|(a, si)| a + step * si).collect();
        let new_a = project(&prop_a, 0.0, cap_a, 1.0);
        let new_b = project(&prop_b, 0.0, cap_b, p.eps);

        // FISTA extrapolation
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_acc * t_acc).sqrt());
        let beta = (t_acc - 1.0) / t_next;
        for i in 0..m {
            y_a[i] = new_a[i] + beta * (new_a[i] - alpha[i]);
            y_b[i] = new_b[i] + beta * (new_b[i] - alpha_bar[i]);
        }
        t_acc = t_next;
        alpha = new_a;
        alpha_bar = new_b;
        iterations += 1;

        // periodic convergence check (KKT scan costs an extra mat-vec)
        if iterations % 25 == 0 || iterations == p.max_iter {
            for i in 0..m {
                gamma[i] = alpha[i] - alpha_bar[i];
            }
            matvec(k, &gamma, &mut s);
            scale = s.iter().fold(1.0f64, |acc, v| acc.max(v.abs()));
            let obj =
                0.5 * gamma.iter().zip(&s).map(|(g, si)| g * si).sum::<f64>();
            if obj > prev_obj {
                // objective went up under extrapolation: restart momentum
                t_acc = 1.0;
                y_a.copy_from_slice(&alpha);
                y_b.copy_from_slice(&alpha_bar);
            }
            recover_rhos_blocks(
                &alpha, &alpha_bar, &s, cap_a, cap_b, cls_a.min(cls_b),
                &mut rho1, &mut rho2,
            );
            max_viol = kkt_scan(&alpha, &alpha_bar, &s, rho1, rho2);
            if max_viol <= p.tol * scale {
                break;
            }
            if (prev_obj - obj).abs() <= 1e-14 * obj.abs().max(1e-300) {
                stall += 1;
                if stall >= 4 {
                    break; // objective converged to machine precision
                }
            } else {
                stall = 0;
            }
            prev_obj = obj.min(prev_obj);
        }
    }

    if iterations >= p.max_iter && max_viol > p.tol * scale * 10.0 {
        return Err(Error::NoConvergence(format!(
            "PG hit max_iter={} with KKT violation {max_viol:.3e} (scale {scale:.1e})",
            p.max_iter
        )));
    }

    for i in 0..m {
        gamma[i] = alpha[i] - alpha_bar[i];
    }
    matvec(k, &gamma, &mut s);
    recover_rhos_blocks(
        &alpha, &alpha_bar, &s, cap_a, cap_b, p.tol, &mut rho1, &mut rho2,
    );
    let objective = 0.5 * gamma.iter().zip(&s).map(|(g, si)| g * si).sum::<f64>();
    let stats = SolveStats {
        iterations,
        objective,
        max_violation: max_viol,
        seconds: t0.elapsed().as_secs_f64(),
        cache: Default::default(),
        kernel_evals: 0,
    };
    Ok((alpha, alpha_bar, rho1, rho2, stats))
}

/// Train a [`SlabModel`] with projected gradient.
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: `Trainer::new(SolverKind::Pg).kernel(kernel).fit(x)` \
            (solver::api) — same numerics, uniform FitReport"
)]
pub fn train(x: &Matrix, kernel: Kernel, p: &PgParams) -> Result<(SlabModel, SolveStats)> {
    let threads = crate::util::threadpool::default_threads();
    let k = kernel.gram(x, threads);
    let (alpha, alpha_bar, rho1, rho2, stats) = solve(&k, p)?;
    let gamma: Vec<f64> =
        alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
    Ok((
        SlabModel::from_dual(x, &gamma, rho1, rho2, kernel, p.sv_tol),
        stats,
    ))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // legacy shims stay covered until removal

    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::solver::validate::certify;

    #[test]
    fn projection_box_and_sum() {
        let v = [0.9, -0.8, 0.3, 0.0];
        let p = project(&v, -0.25, 0.5, 0.4);
        let sum: f64 = p.iter().sum();
        assert!((sum - 0.4).abs() < 1e-9, "sum={sum}");
        for &x in &p {
            assert!((-0.25..=0.5).contains(&x));
        }
    }

    #[test]
    fn projection_identity_when_feasible() {
        let v = [0.1, 0.2, 0.1];
        let p = project(&v, 0.0, 0.3, 0.4);
        for (a, b) in v.iter().zip(&p) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn projection_is_idempotent() {
        let v = [3.0, -2.0, 0.5, 0.7, -0.1];
        let p1 = project(&v, -0.5, 1.0, 0.8);
        let p2 = project(&p1, -0.5, 1.0, 0.8);
        for (a, b) in p1.iter().zip(&p2) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn spectral_norm_of_identity() {
        let k = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 1.0]]);
        let l = spectral_norm(&k, 50);
        assert!((l - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pg_certifies_on_slab_data() {
        let ds = SlabConfig::default().generate(120, 31);
        let p = PgParams::default();
        let k = Kernel::Linear.gram(&ds.x, 2);
        let (alpha, alpha_bar, rho1, rho2, stats) = solve(&k, &p).unwrap();
        assert!(stats.iterations > 0);
        // tolerance scaled by the margin magnitude (s ~ O(100) here)
        let scale = 1.0 + rho2.abs().max(rho1.abs());
        certify(
            &k, &alpha, &alpha_bar, rho1, rho2, p.nu1, p.nu2, p.eps,
            5e-3 * scale,
        )
        .unwrap();
    }

    #[test]
    fn pg_matches_smo_objective() {
        let ds = SlabConfig::default().generate(100, 32);
        let k = Kernel::Linear.gram(&ds.x, 2);
        let pg = PgParams { tol: 1e-6, ..Default::default() };
        let (_, _, _, _, pg_stats) = solve(&k, &pg).unwrap();
        let sp = crate::solver::smo::SmoParams { tol: 1e-6, ..Default::default() };
        let (_, smo_out) =
            crate::solver::smo::train_full(&ds.x, Kernel::Linear, &sp).unwrap();
        let rel = (pg_stats.objective - smo_out.stats.objective).abs()
            / smo_out.stats.objective.abs().max(1e-9);
        assert!(
            rel < 1e-3,
            "PG {} vs SMO {}",
            pg_stats.objective,
            smo_out.stats.objective
        );
    }
}
