//! Solvers for the OCSSVM dual + baselines.
//!
//! The dual problem, in the paper's γ = α − ᾱ re-parameterization
//! (eqs. (30)–(32)):
//!
//! ```text
//!   min_γ   ½ γᵀ K γ
//!   s.t.    lo ≤ γᵢ ≤ hi        lo = −ε/(ν₂ m),  hi = 1/(ν₁ m)
//!           Σᵢ γᵢ = 1 − ε
//! ```
//!
//! Solvers (all trainable through the unified [`api::Solver`] trait /
//! [`api::Trainer`] builder, producing an [`api::FitReport`]):
//!
//! * [`smo`] — **the paper's contribution**: sequential minimal
//!   optimization with the max-|f̄| working-set heuristic;
//! * [`qp_pg`] — projected-gradient baseline (generic first-order QP);
//! * [`qp_ipm`] — primal-dual interior-point baseline (the "other QP
//!   solvers" of the paper's scaling claim);
//! * [`ocsvm_smo`] — Schölkopf one-class SVM via SMO (reference [2]),
//!   the non-slab baseline.
//!
//! [`api`] is the single entry point: [`api::SolverKind`] names the four
//! solvers for CLI/config round-tripping, [`api::Trainer`] composes
//! warm-start, cascade sharding and kernel caching as orthogonal layers
//! on top of any of them. The per-module `train` free functions are kept
//! as thin deprecated shims.
//!
//! [`validate`] certifies any returned solution: box + sum feasibility
//! and ε-KKT. Every solver's output is certified in the test suite; the
//! SMO/PG/IPM objective agreement test is the strongest correctness
//! signal (three independent algorithms, one optimum).

pub mod api;
pub mod approx;
pub mod cascade;
pub mod ocssvm;
pub mod ocsvm_smo;
pub mod qp_ipm;
pub mod qp_pg;
pub mod smo;
pub mod validate;
pub mod warmstart;

pub use api::{DualSolution, FitReport, Solver, SolverKind, Trainer};

use crate::cache::CacheStats;

/// KKT case analysis of the OCSSVM dual (paper eqs. (49)–(53), errata
/// applied — DESIGN.md §1.1). Given margin s_i = Σ_j γ_j k(x_i, x_j):
///
/// | γᵢ                | condition      |
/// |-------------------|----------------|
/// | γ = 0             | ρ1 ≤ s ≤ ρ2    |
/// | 0 < γ < hi        | s = ρ1         |
/// | γ = hi            | s ≤ ρ1         |
/// | lo < γ < 0        | s = ρ2         |
/// | γ = lo            | s ≥ ρ2         |
///
/// Returns the violation magnitude in margin units (0 when satisfied).
#[inline]
pub fn kkt_violation(
    gamma: f64,
    s: f64,
    rho1: f64,
    rho2: f64,
    lo: f64,
    hi: f64,
    tol: f64,
) -> f64 {
    if gamma.abs() <= tol {
        (rho1 - s).max(0.0) + (s - rho2).max(0.0)
    } else if gamma >= hi - tol {
        (s - rho1).max(0.0)
    } else if gamma <= lo + tol {
        (rho2 - s).max(0.0)
    } else if gamma > 0.0 {
        (s - rho1).abs()
    } else {
        (s - rho2).abs()
    }
}

/// The paper's selection score f̄(x) = min(s − ρ1, ρ2 − s) (eq. (56)).
#[inline]
pub fn fbar(s: f64, rho1: f64, rho2: f64) -> f64 {
    (s - rho1).min(rho2 - s)
}

/// Working-set selection strategy (ablation A1 in DESIGN.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Heuristic {
    /// The paper's: b = argmax |f̄(x_b)| over KKT violators, then
    /// a = argmax |f̄(x_b) − f̄(x_a)| (Schölkopf second choice).
    PaperMaxFbar,
    /// b = argmax KKT violation, a = argmax |f̄(x_b) − f̄(x_a)|.
    MaxViolation,
    /// b = uniformly random violator, a = random other index.
    RandomViolator,
    /// WSS2-style second-order rule (Fan/Chen/Lin; the "better working
    /// set selection" the paper's future work asks for): b = argmax
    /// violation, a maximizes the guaranteed decrease (s_a − s_b)²/(2κ).
    SecondOrder,
}

impl Heuristic {
    /// Every heuristic, in ablation order.
    pub const ALL: [Heuristic; 4] = [
        Heuristic::PaperMaxFbar,
        Heuristic::MaxViolation,
        Heuristic::RandomViolator,
        Heuristic::SecondOrder,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Heuristic::PaperMaxFbar => "paper-max-fbar",
            Heuristic::MaxViolation => "max-violation",
            Heuristic::RandomViolator => "random-violator",
            Heuristic::SecondOrder => "second-order",
        }
    }
}

impl std::fmt::Display for Heuristic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Heuristic {
    type Err = crate::error::Error;

    /// Inverse of [`Heuristic::name`] (a couple of short aliases kept
    /// for CLI ergonomics).
    fn from_str(s: &str) -> Result<Heuristic, Self::Err> {
        match s {
            "paper-max-fbar" | "paper" => Ok(Heuristic::PaperMaxFbar),
            "max-violation" => Ok(Heuristic::MaxViolation),
            "random-violator" | "random" => Ok(Heuristic::RandomViolator),
            "second-order" | "wss2" => Ok(Heuristic::SecondOrder),
            other => Err(crate::error::Error::config(format!(
                "unknown heuristic {other:?} (expected paper-max-fbar|\
                 max-violation|random-violator|second-order)"
            ))),
        }
    }
}

/// Convergence + effort accounting, shared by all solvers.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolveStats {
    /// outer iterations (SMO pair updates / PG steps / IPM iterations)
    pub iterations: usize,
    /// final dual objective ½ γᵀKγ
    pub objective: f64,
    /// max KKT violation at exit
    pub max_violation: f64,
    /// wall-clock seconds
    pub seconds: f64,
    /// kernel cache counters (zero when precomputed)
    pub cache: CacheStats,
    /// number of kernel evaluations if counted (0 = not tracked)
    pub kernel_evals: u64,
}

/// Shared hyper-parameter validation for the slab dual.
///
/// Requires ν₁ ∈ (0, 1], ν₂ ∈ (0, 1], ε ∈ (0, 1), and feasibility of the
/// sum constraint within the box: m·lo ≤ 1 − ε ≤ m·hi. Returns (lo, hi).
pub fn check_params(m: usize, nu1: f64, nu2: f64, eps: f64) -> crate::Result<(f64, f64)> {
    use crate::error::Error;
    if m == 0 {
        return Err(Error::config("empty training set"));
    }
    if !(0.0 < nu1 && nu1 <= 1.0) {
        return Err(Error::config(format!("nu1 must be in (0,1], got {nu1}")));
    }
    if !(0.0 < nu2 && nu2 <= 1.0) {
        return Err(Error::config(format!("nu2 must be in (0,1], got {nu2}")));
    }
    if !(0.0 < eps && eps < 1.0) {
        return Err(Error::config(format!("eps must be in (0,1), got {eps}")));
    }
    let lo = -eps / (nu2 * m as f64);
    let hi = 1.0 / (nu1 * m as f64);
    let target = 1.0 - eps;
    if target > m as f64 * hi + 1e-12 || target < m as f64 * lo - 1e-12 {
        return Err(Error::config(format!(
            "sum constraint 1-eps={target} infeasible within box [{lo},{hi}] x {m}"
        )));
    }
    Ok((lo, hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOL: f64 = 1e-9;

    #[test]
    fn kkt_interior_zero_gamma() {
        // inside slab, gamma=0 -> satisfied
        assert_eq!(kkt_violation(0.0, 0.5, 0.0, 1.0, -0.1, 0.2, TOL), 0.0);
        // below rho1 -> violation rho1 - s
        assert!((kkt_violation(0.0, -0.3, 0.0, 1.0, -0.1, 0.2, TOL) - 0.3).abs() < 1e-12);
        // above rho2
        assert!((kkt_violation(0.0, 1.4, 0.0, 1.0, -0.1, 0.2, TOL) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn kkt_free_lower_sv_on_plane() {
        // 0 < gamma < hi must sit on rho1
        assert_eq!(kkt_violation(0.1, 0.0, 0.0, 1.0, -0.1, 0.2, TOL), 0.0);
        assert!((kkt_violation(0.1, 0.25, 0.0, 1.0, -0.1, 0.2, TOL) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn kkt_free_upper_sv_on_plane() {
        // lo < gamma < 0 must sit on rho2
        assert_eq!(kkt_violation(-0.05, 1.0, 0.0, 1.0, -0.1, 0.2, TOL), 0.0);
        assert!((kkt_violation(-0.05, 0.8, 0.0, 1.0, -0.1, 0.2, TOL) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn kkt_bound_hi_needs_s_below_rho1() {
        // gamma = hi: margin violator of the LOWER plane -> s <= rho1
        assert_eq!(kkt_violation(0.2, -0.5, 0.0, 1.0, -0.1, 0.2, TOL), 0.0);
        assert!((kkt_violation(0.2, 0.3, 0.0, 1.0, -0.1, 0.2, TOL) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn kkt_bound_lo_needs_s_above_rho2() {
        // gamma = lo: margin violator of the UPPER plane -> s >= rho2
        assert_eq!(kkt_violation(-0.1, 1.5, 0.0, 1.0, -0.1, 0.2, TOL), 0.0);
        assert!((kkt_violation(-0.1, 0.7, 0.0, 1.0, -0.1, 0.2, TOL) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn fbar_is_min_distance() {
        assert_eq!(fbar(0.5, 0.0, 1.0), 0.5);
        assert!((fbar(0.9, 0.0, 1.0) - 0.1).abs() < 1e-12);
        assert!((fbar(-0.2, 0.0, 1.0) + 0.2).abs() < 1e-12);
    }

    #[test]
    fn params_validation() {
        assert!(check_params(100, 0.5, 0.01, 2.0 / 3.0).is_ok());
        assert!(check_params(0, 0.5, 0.01, 0.5).is_err());
        assert!(check_params(100, 0.0, 0.01, 0.5).is_err());
        assert!(check_params(100, 1.5, 0.01, 0.5).is_err());
        assert!(check_params(100, 0.5, 0.0, 0.5).is_err());
        assert!(check_params(100, 0.5, 0.01, 1.0).is_err());
        assert!(check_params(100, 0.5, 0.01, 0.0).is_err());
    }

    #[test]
    fn params_box_bounds() {
        let (lo, hi) = check_params(1000, 0.5, 0.01, 2.0 / 3.0).unwrap();
        assert!((hi - 1.0 / (0.5 * 1000.0)).abs() < 1e-15);
        assert!((lo + (2.0 / 3.0) / (0.01 * 1000.0)).abs() < 1e-15);
    }
}
