//! Unified estimator API: one [`Solver`] trait over every OCSSVM solver
//! and a [`Trainer`] builder that layers warm-start, cascade sharding
//! and kernel caching on top.
//!
//! Before this module each solver exposed a differently-shaped free
//! function (`smo::train → SlabModel`, `qp_pg::train → (SlabModel,
//! SolveStats)`, `ocsvm_smo::train → (OcsvmModel, SolveStats)`, plus
//! bespoke `cascade::train` / `warmstart::train`), so every bench,
//! example and the serving coordinator hand-rolled its own dispatch.
//! Now:
//!
//! * [`SolverKind`] names the four solvers, with `FromStr`/`Display`
//!   round-tripping for CLI flags and config files;
//! * [`Solver`] is the object-safe training interface — `fit` builds the
//!   Gram natively, `fit_gram` accepts a precomputed one, and
//!   `fit_provider` streams kernel rows through any
//!   [`KernelProvider`] (bounded caches included);
//! * [`FitReport`] is the uniform outcome: the trained [`SlabModel`],
//!   the full dual point ([`DualSolution`]), effort stats and an
//!   always-computed KKT [`Certificate`];
//! * [`Trainer`] composes the orthogonal layers — `warm_start(epochs)`,
//!   `cascade(shards, rounds)`, `cache_rows(capacity, policy)` — over
//!   any solver kind without bespoke entry points.
//!
//! The Schölkopf one-class SVM is served through the same interface by
//! embedding it as a slab with no upper plane: its dual is exactly the
//! OCSSVM α-block with ᾱ ≡ 0 (ε = 0), so the returned model carries
//! `rho2 =` [`NO_UPPER_PLANE`] and classifies identically to the
//! single-hyperplane decision `sgn(s − ρ)`.
//!
//! ```no_run
//! use slabsvm::data::synthetic::SlabConfig;
//! use slabsvm::kernel::Kernel;
//! use slabsvm::solver::{SolverKind, Trainer};
//!
//! let ds = SlabConfig::default().generate(1000, 42);
//! let report = Trainer::new(SolverKind::Smo)
//!     .kernel(Kernel::Linear)
//!     .nu1(0.5)
//!     .nu2(0.01)
//!     .eps(2.0 / 3.0)
//!     .fit(&ds.x)
//!     .unwrap();
//! assert!(report.model.width() > 0.0);
//! assert!(report.certificate.max_kkt_violation < 1e-2);
//! ```
//!
//! Numerical contract: for every kind, the trait path reproduces the
//! legacy free-function path bit-for-bit (same Gram build, same core
//! solve) — pinned by `rust/tests/api_parity.rs`.

use std::fmt;
use std::str::FromStr;

use super::approx::{ApproxParams, ApproxSolver};
use super::ocssvm::SlabModel;
use super::ocsvm_smo::{self, OcsvmParams};
use super::qp_ipm::{self, IpmParams};
use super::qp_pg::{self, PgParams};
use super::smo::{self, SmoParams};
use super::validate::{self, Certificate};
use super::warmstart::{self, WarmStartParams};
use super::{Heuristic, SolveStats};
use crate::cache::{CacheStats, CachedRows, KernelProvider, Policy, PrecomputedGram};
use crate::error::Error;
use crate::kernel::featmap::EngineKind;
use crate::kernel::{Kernel, Precision};
use crate::linalg::{matvec, Matrix};
use crate::Result;

/// `rho2` sentinel for models embedded from the single-plane one-class
/// SVM: far above any reachable margin, so the slab decision
/// `(s − ρ1)(ρ2 − s) ≥ 0` degenerates to the OCSVM's `s ≥ ρ`, and the
/// ranking margin `f̄ = min(s − ρ1, ρ2 − s)` degenerates to `s − ρ1`.
/// Finite (not `f64::INFINITY`) so JSON model persistence round-trips.
pub const NO_UPPER_PLANE: f64 = 1e300;

/// Margin tolerance the cascade layer uses to flag out-of-candidate KKT
/// violators when no explicit tolerance is configured.
const CASCADE_DEFAULT_TOL: f64 = 1e-5;

/// Relative KKT bound an F32-mode fit must meet on the **f64**
/// certificate to be accepted without fallback. Single-precision Gram
/// entries carry ~1e-7 relative error; after the solve that error
/// shows up in the f64-recomputed margins scaled by ‖γ‖₁ and the
/// solver's own exit tolerance, so the certification bound is set well
/// above machine-f32 noise but far below any real KKT violation.
const F32_CERT_TOL: f64 = 1e-3;

// ---------------------------------------------------------------------------
// SolverKind
// ---------------------------------------------------------------------------

/// The five trainable solvers, nameable for CLI and config files.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SolverKind {
    /// The paper's SMO on the faithful (α, ᾱ) slab dual.
    Smo,
    /// Projected-gradient (FISTA) baseline on the same dual.
    Pg,
    /// Primal-dual interior-point baseline on the same dual.
    Ipm,
    /// Schölkopf ν-one-class SVM via SMO (non-slab baseline).
    OcsvmSmo,
    /// Feature-map approximation (Nyström / RFF): trains the slab on
    /// explicitly lifted features, never forming the m×m Gram
    /// ([`super::approx`]).
    Approx,
}

impl SolverKind {
    /// Every kind, in paper-comparison order.
    pub const ALL: [SolverKind; 5] = [
        SolverKind::Smo,
        SolverKind::Pg,
        SolverKind::Ipm,
        SolverKind::OcsvmSmo,
        SolverKind::Approx,
    ];

    /// Canonical name (what [`fmt::Display`] prints and
    /// [`FromStr`] accepts).
    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Smo => "smo",
            SolverKind::Pg => "pg",
            SolverKind::Ipm => "ipm",
            SolverKind::OcsvmSmo => "ocsvm-smo",
            SolverKind::Approx => "approx",
        }
    }

    /// Construct the solver with its per-kind default hyper-parameters.
    pub fn default_solver(self) -> Box<dyn Solver + Send + Sync> {
        match self {
            SolverKind::Smo => Box::new(SmoSolver::default()),
            SolverKind::Pg => Box::new(PgSolver::default()),
            SolverKind::Ipm => Box::new(IpmSolver::default()),
            SolverKind::OcsvmSmo => Box::new(OcsvmSolver::default()),
            SolverKind::Approx => Box::new(ApproxSolver::default()),
        }
    }
}

impl fmt::Display for SolverKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for SolverKind {
    type Err = Error;

    fn from_str(s: &str) -> Result<SolverKind> {
        match s {
            "smo" => Ok(SolverKind::Smo),
            "pg" | "proj-grad" | "projected-gradient" => Ok(SolverKind::Pg),
            "ipm" | "interior-point" => Ok(SolverKind::Ipm),
            "ocsvm-smo" | "ocsvm" => Ok(SolverKind::OcsvmSmo),
            "approx" => Ok(SolverKind::Approx),
            other => Err(Error::config(format!(
                "unknown solver {other:?} (expected smo|pg|ipm|ocsvm-smo|approx)"
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// FitReport
// ---------------------------------------------------------------------------

/// Full dual point of a trained model, in the faithful (α, ᾱ)
/// parameterization, over **all** training rows (the model itself keeps
/// only the support vectors).
#[derive(Clone, Debug)]
pub struct DualSolution {
    /// lower-plane multipliers α (Σα = 1)
    pub alpha: Vec<f64>,
    /// upper-plane multipliers ᾱ (Σᾱ = ε; all-zero for the OCSVM kind)
    pub alpha_bar: Vec<f64>,
    /// γ = α − ᾱ (what the model stores for its SVs)
    pub gamma: Vec<f64>,
    /// margins s = Kγ at exit
    pub s: Vec<f64>,
    /// lower slab offset
    pub rho1: f64,
    /// upper slab offset ([`NO_UPPER_PLANE`] for the OCSVM kind)
    pub rho2: f64,
}

/// Cascade-layer accounting (present only when the cascade layer ran).
#[derive(Clone, Debug)]
pub struct CascadeTrace {
    /// candidate-set size per union round (starts at the shard-SV union)
    pub candidate_sizes: Vec<usize>,
    /// union-retrain rounds executed (0 = direct-solve fallback)
    pub rounds: usize,
}

/// Uniform training outcome for every [`Solver`].
#[derive(Clone, Debug)]
pub struct FitReport {
    /// the trained model (support vectors only)
    pub model: SlabModel,
    /// the full dual point the model was assembled from
    pub dual: DualSolution,
    /// convergence + effort accounting
    pub stats: SolveStats,
    /// feasibility / KKT report, always computed (an O(m) pass over the
    /// solver-maintained margins — never a pass/fail gate; judge it with
    /// your own tolerance, or use [`validate::certify`] independently)
    pub certificate: Certificate,
    /// cascade accounting when the [`Trainer`] cascade layer ran
    pub cascade: Option<CascadeTrace>,
    /// floating-point mode the returned model was actually computed in
    /// (`F64` after a certification fallback, even if `F32` was asked)
    pub precision: Precision,
    /// true when an F32-mode fit failed the f64 KKT certificate and the
    /// trainer redid the fit at full precision — the fallback is always
    /// visible, never silent
    pub fell_back: bool,
}

// ---------------------------------------------------------------------------
// Solver trait
// ---------------------------------------------------------------------------

/// One training interface over every solver.
///
/// Object-safe through [`Solver::fit`] / [`Solver::fit_gram`], so a
/// registry can hold heterogeneous `Box<dyn Solver>`s behind one
/// interface; [`Solver::fit_provider`] is generic (cache-backed
/// training) and therefore `where Self: Sized`.
pub trait Solver {
    /// Which [`SolverKind`] this solver implements.
    fn kind(&self) -> SolverKind;

    /// Train on a precomputed Gram matrix `k` of `x`.
    fn fit_gram(&self, x: &Matrix, kernel: Kernel, k: &Matrix) -> Result<FitReport>;

    /// Train end-to-end: build the Gram with the native engine, then
    /// [`Solver::fit_gram`].
    fn fit(&self, x: &Matrix, kernel: Kernel) -> Result<FitReport> {
        let threads = crate::util::threadpool::default_threads();
        let k = kernel.gram(x, threads);
        self.fit_gram(x, kernel, &k)
    }

    /// Train against any [`KernelProvider`] (bounded row caches, external
    /// Gram sources). The default materializes the full matrix through
    /// the provider — row-streaming solvers (SMO) override this to keep
    /// memory bounded.
    fn fit_provider<P: KernelProvider>(
        &self,
        x: &Matrix,
        kernel: Kernel,
        provider: &mut P,
    ) -> Result<FitReport>
    where
        Self: Sized,
    {
        let k = materialize_gram(provider);
        self.fit_gram(x, kernel, &k)
    }
}

/// Pull every row out of a provider into a dense Gram matrix.
fn materialize_gram<P: KernelProvider>(provider: &mut P) -> Matrix {
    let m = provider.m();
    let mut k = Matrix::zeros(m, m);
    for i in 0..m {
        provider.with_row(i, &mut |row| {
            k.row_mut(i).copy_from_slice(row);
        });
    }
    k
}

/// Read-only [`KernelProvider`] over a borrowed Gram matrix (zero-copy
/// bridge from `fit_gram` into the row-streaming SMO core).
struct BorrowedGram<'a> {
    k: &'a Matrix,
}

impl KernelProvider for BorrowedGram<'_> {
    fn m(&self) -> usize {
        self.k.rows()
    }
    fn diag(&self, i: usize) -> f64 {
        self.k.get(i, i)
    }
    fn with_row<R>(&mut self, i: usize, f: &mut dyn FnMut(&[f64]) -> R) -> R {
        f(self.k.row(i))
    }
    fn with_two_rows<R>(
        &mut self,
        a: usize,
        b: usize,
        f: &mut dyn FnMut(&[f64], &[f64]) -> R,
    ) -> R {
        f(self.k.row(a), self.k.row(b))
    }
    fn stats(&self) -> CacheStats {
        CacheStats::default()
    }
}

/// Assemble the uniform report from a solved slab dual. `eps = 0` marks
/// the degenerate ᾱ-block of the OCSVM embedding (cap_b = 0, all-zero
/// ᾱ), which the certificate handles exactly.
#[allow(clippy::too_many_arguments)]
fn assemble_slab(
    x: &Matrix,
    kernel: Kernel,
    sv_tol: f64,
    nu1: f64,
    nu2: f64,
    eps: f64,
    alpha: Vec<f64>,
    alpha_bar: Vec<f64>,
    s: Vec<f64>,
    rho1: f64,
    rho2: f64,
    stats: SolveStats,
) -> FitReport {
    let m = alpha.len() as f64;
    let gamma: Vec<f64> =
        alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
    let cap_a = 1.0 / (nu1 * m);
    let cap_b = if eps > 0.0 { eps / (nu2 * m) } else { f64::INFINITY };
    let cls_tol = cap_a.min(cap_b) * 1e-6;
    let certificate = validate::report_with_margins(
        &alpha, &alpha_bar, &s, rho1, rho2, nu1, nu2, eps, cls_tol,
    );
    let model = SlabModel::from_dual(x, &gamma, rho1, rho2, kernel, sv_tol);
    FitReport {
        model,
        dual: DualSolution { alpha, alpha_bar, gamma, s, rho1, rho2 },
        stats,
        certificate,
        cascade: None,
        precision: Precision::F64,
        fell_back: false,
    }
}

// ---------------------------------------------------------------------------
// Concrete solvers
// ---------------------------------------------------------------------------

/// The paper's SMO ([`smo::solve`]) behind the [`Solver`] interface.
#[derive(Clone, Copy, Debug, Default)]
pub struct SmoSolver {
    pub params: SmoParams,
}

impl Solver for SmoSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Smo
    }

    fn fit_gram(&self, x: &Matrix, kernel: Kernel, k: &Matrix) -> Result<FitReport> {
        let mut provider = BorrowedGram { k };
        self.fit_provider(x, kernel, &mut provider)
    }

    fn fit_provider<P: KernelProvider>(
        &self,
        x: &Matrix,
        kernel: Kernel,
        provider: &mut P,
    ) -> Result<FitReport> {
        let out = smo::solve(provider, &self.params)?;
        Ok(assemble_slab(
            x,
            kernel,
            self.params.sv_tol,
            self.params.nu1,
            self.params.nu2,
            self.params.eps,
            out.alpha,
            out.alpha_bar,
            out.s,
            out.rho1,
            out.rho2,
            out.stats,
        ))
    }
}

/// Projected-gradient baseline ([`qp_pg::solve`]) behind [`Solver`].
#[derive(Clone, Copy, Debug, Default)]
pub struct PgSolver {
    pub params: PgParams,
}

impl Solver for PgSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Pg
    }

    fn fit_gram(&self, x: &Matrix, kernel: Kernel, k: &Matrix) -> Result<FitReport> {
        let (alpha, alpha_bar, rho1, rho2, stats) = qp_pg::solve(k, &self.params)?;
        let gamma: Vec<f64> =
            alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
        let mut s = vec![0.0; gamma.len()];
        matvec(k, &gamma, &mut s);
        Ok(assemble_slab(
            x,
            kernel,
            self.params.sv_tol,
            self.params.nu1,
            self.params.nu2,
            self.params.eps,
            alpha,
            alpha_bar,
            s,
            rho1,
            rho2,
            stats,
        ))
    }
}

/// Interior-point baseline ([`qp_ipm::solve`]) behind [`Solver`].
#[derive(Clone, Copy, Debug, Default)]
pub struct IpmSolver {
    pub params: IpmParams,
}

impl Solver for IpmSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::Ipm
    }

    fn fit_gram(&self, x: &Matrix, kernel: Kernel, k: &Matrix) -> Result<FitReport> {
        let (alpha, alpha_bar, rho1, rho2, stats) = qp_ipm::solve(k, &self.params)?;
        let gamma: Vec<f64> =
            alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
        let mut s = vec![0.0; gamma.len()];
        matvec(k, &gamma, &mut s);
        Ok(assemble_slab(
            x,
            kernel,
            self.params.sv_tol,
            self.params.nu1,
            self.params.nu2,
            self.params.eps,
            alpha,
            alpha_bar,
            s,
            rho1,
            rho2,
            stats,
        ))
    }
}

/// Schölkopf one-class SVM ([`ocsvm_smo::solve`]) behind [`Solver`],
/// embedded as a slab with no upper plane (ᾱ ≡ 0, ε = 0,
/// `rho2 =` [`NO_UPPER_PLANE`]). Decision, ranking margin and objective
/// all match the single-hyperplane formulation exactly.
#[derive(Clone, Copy, Debug, Default)]
pub struct OcsvmSolver {
    pub params: OcsvmParams,
}

impl Solver for OcsvmSolver {
    fn kind(&self) -> SolverKind {
        SolverKind::OcsvmSmo
    }

    fn fit_gram(&self, x: &Matrix, kernel: Kernel, k: &Matrix) -> Result<FitReport> {
        let (alpha, rho, stats) = ocsvm_smo::solve(k, &self.params)?;
        let m = alpha.len();
        let mut s = vec![0.0; m];
        matvec(k, &alpha, &mut s);
        Ok(assemble_slab(
            x,
            kernel,
            self.params.sv_tol,
            self.params.nu,
            1.0, // unused: eps = 0 collapses the ᾱ box to {0}
            0.0,
            alpha,
            vec![0.0; m],
            s,
            rho,
            NO_UPPER_PLANE,
            stats,
        ))
    }
}

// ---------------------------------------------------------------------------
// Trainer
// ---------------------------------------------------------------------------

/// Cascade layer configuration.
#[derive(Clone, Copy, Debug)]
struct CascadeOpts {
    shards: usize,
    max_rounds: usize,
}

/// Kernel-row cache layer configuration.
#[derive(Clone, Copy, Debug)]
struct CacheOpts {
    capacity: usize,
    policy: Policy,
}

/// Builder over any [`SolverKind`], composing warm-start, cascade
/// sharding and kernel caching as orthogonal layers.
///
/// Hyper-parameters shared across solvers (ν₁, ν₂, ε, heuristic, seed)
/// have concrete defaults; `tol` and `max_iter` default to **per-solver**
/// values (an SMO tolerance makes no sense as an IPM complementarity
/// gap, and the IPM's O(m³) iterations need a budget of ~200, not
/// 500 000), so they are only overridden when set explicitly.
///
/// Layer composition rules (violations are [`Error::Config`], not
/// silent):
///
/// * `warm_start` and `cache_rows` require the row-streaming SMO solver;
/// * `cascade` composes with any solver kind (each shard / union solve
///   goes through the same [`Solver`] path, with ν rescaled so the
///   subset dual's box matches the full problem — see
///   `solver/cascade.rs` for the derivation);
/// * `cascade` + `cache_rows` together are unsupported.
#[derive(Clone, Debug)]
pub struct Trainer {
    kind: SolverKind,
    kernel: Kernel,
    nu1: f64,
    nu2: f64,
    eps: f64,
    tol: Option<f64>,
    max_iter: Option<usize>,
    heuristic: Heuristic,
    seed: u64,
    sv_tol: f64,
    shrinking: bool,
    warm_epochs: usize,
    cascade: Option<CascadeOpts>,
    cache: Option<CacheOpts>,
    precision: Precision,
    engine: EngineKind,
    features: usize,
}

impl Default for Trainer {
    fn default() -> Self {
        Trainer::new(SolverKind::Smo)
    }
}

impl Trainer {
    /// A trainer for `kind` with the paper's default constants
    /// (ν₁ = 0.5, ν₂ = 0.01, ε = 2/3, linear kernel) and the kind's own
    /// tolerance / iteration defaults.
    pub fn new(kind: SolverKind) -> Trainer {
        Trainer {
            kind,
            kernel: Kernel::Linear,
            nu1: 0.5,
            nu2: 0.01,
            eps: 2.0 / 3.0,
            tol: None,
            max_iter: None,
            heuristic: Heuristic::PaperMaxFbar,
            seed: 0,
            sv_tol: 1e-10,
            shrinking: true,
            warm_epochs: 0,
            cascade: None,
            cache: None,
            precision: Precision::F64,
            engine: EngineKind::Exact,
            features: 64,
        }
    }

    /// Import a full [`SmoParams`] (kind becomes [`SolverKind::Smo`];
    /// `tol`/`max_iter` become explicit). The one-call migration path
    /// from the legacy free functions.
    pub fn from_smo_params(p: SmoParams) -> Trainer {
        let mut t = Trainer::new(SolverKind::Smo);
        t.nu1 = p.nu1;
        t.nu2 = p.nu2;
        t.eps = p.eps;
        t.tol = Some(p.tol);
        t.max_iter = Some(p.max_iter);
        t.heuristic = p.heuristic;
        t.seed = p.seed;
        t.sv_tol = p.sv_tol;
        t.shrinking = p.shrinking;
        t
    }

    /// Switch the solver kind, keeping every other setting.
    pub fn solver(mut self, kind: SolverKind) -> Trainer {
        self.kind = kind;
        self
    }

    /// Which solver this trainer dispatches to.
    pub fn kind(&self) -> SolverKind {
        self.kind
    }

    /// Kernel to train with (default: linear, as in the paper).
    pub fn kernel(mut self, kernel: Kernel) -> Trainer {
        self.kernel = kernel;
        self
    }

    /// ν₁ — lower-plane outlier bound (OCSVM kind: its single ν).
    pub fn nu1(mut self, nu1: f64) -> Trainer {
        self.nu1 = nu1;
        self
    }

    /// ν₂ — upper-plane violator bound (ignored by the OCSVM kind).
    pub fn nu2(mut self, nu2: f64) -> Trainer {
        self.nu2 = nu2;
        self
    }

    /// ε — upper-plane mass (ignored by the OCSVM kind).
    pub fn eps(mut self, eps: f64) -> Trainer {
        self.eps = eps;
        self
    }

    /// Explicit convergence tolerance (meaning is per-solver: KKT margin
    /// units for SMO/PG, complementarity gap for IPM).
    pub fn tol(mut self, tol: f64) -> Trainer {
        self.tol = Some(tol);
        self
    }

    /// Explicit iteration budget.
    pub fn max_iter(mut self, max_iter: usize) -> Trainer {
        self.max_iter = Some(max_iter);
        self
    }

    /// SMO working-set selection rule (SMO kind only; others ignore it).
    pub fn heuristic(mut self, heuristic: Heuristic) -> Trainer {
        self.heuristic = heuristic;
        self
    }

    /// Seed for randomized selection / warm-start pair sampling.
    pub fn seed(mut self, seed: u64) -> Trainer {
        self.seed = seed;
        self
    }

    /// |γ| above which a row is kept as a support vector.
    pub fn sv_tol(mut self, sv_tol: f64) -> Trainer {
        self.sv_tol = sv_tol;
        self
    }

    /// Toggle SMO active-set shrinking.
    pub fn shrinking(mut self, shrinking: bool) -> Trainer {
        self.shrinking = shrinking;
        self
    }

    /// Layer: stochastic warm start — `epochs` random-pair epochs before
    /// the exact solve (SMO kind only). 0 disables.
    pub fn warm_start(mut self, epochs: usize) -> Trainer {
        self.warm_epochs = epochs;
        self
    }

    /// Layer: Graf-style cascade — train `shards` sub-problems in
    /// parallel, retrain on the union of their support vectors for up to
    /// `max_rounds` rounds. Composes with any solver kind.
    pub fn cascade(mut self, shards: usize, max_rounds: usize) -> Trainer {
        self.cascade = Some(CascadeOpts { shards, max_rounds });
        self
    }

    /// Layer: bounded kernel-row cache instead of the full Gram matrix
    /// (SMO kind only; memory O(capacity · m)).
    pub fn cache_rows(mut self, capacity: usize, policy: Policy) -> Trainer {
        self.cache = Some(CacheOpts { capacity, policy });
        self
    }

    /// Floating-point compute mode (default [`Precision::F64`]).
    ///
    /// [`Precision::F32`] builds the Gram at single precision and
    /// solves on it, then **re-certifies the solution in f64**: every
    /// row is re-scored through the trained model at full precision
    /// and the KKT certificate is rebuilt on those margins. If the
    /// certificate exceeds the certification bound the trainer redoes
    /// the whole fit in f64 and marks [`FitReport::fell_back`] — an
    /// f32 fit is never returned uncertified.
    pub fn precision(mut self, precision: Precision) -> Trainer {
        self.precision = precision;
        self
    }

    /// Select the training engine. `nystroem` / `rff` switch the kind
    /// to [`SolverKind::Approx`] (lifted-feature training, no m×m
    /// Gram); `exact` reverts an approx trainer to the paper's SMO.
    /// Lifted dimension comes from [`features`](Trainer::features).
    pub fn engine(mut self, engine: EngineKind) -> Trainer {
        self.engine = engine;
        match engine {
            EngineKind::Exact => {
                if self.kind == SolverKind::Approx {
                    self.kind = SolverKind::Smo;
                }
            }
            _ => self.kind = SolverKind::Approx,
        }
        self
    }

    /// Lifted dimension D for the approximate engine: landmark count
    /// for Nyström (clamped to m at fit), feature count for RFF
    /// (rounded up to even). Ignored by the exact kinds.
    pub fn features(mut self, features: usize) -> Trainer {
        self.features = features;
        self
    }

    // ---------------------------------------------------- param lowering

    /// Lower the shared fields into [`SmoParams`].
    pub fn smo_params(&self) -> SmoParams {
        let d = SmoParams::default();
        SmoParams {
            nu1: self.nu1,
            nu2: self.nu2,
            eps: self.eps,
            tol: self.tol.unwrap_or(d.tol),
            max_iter: self.max_iter.unwrap_or(d.max_iter),
            heuristic: self.heuristic,
            seed: self.seed,
            sv_tol: self.sv_tol,
            shrinking: self.shrinking,
        }
    }

    /// Lower the shared fields into [`PgParams`].
    pub fn pg_params(&self) -> PgParams {
        let d = PgParams::default();
        PgParams {
            nu1: self.nu1,
            nu2: self.nu2,
            eps: self.eps,
            tol: self.tol.unwrap_or(d.tol),
            max_iter: self.max_iter.unwrap_or(d.max_iter),
            power_iters: d.power_iters,
            sv_tol: self.sv_tol,
        }
    }

    /// Lower the shared fields into [`IpmParams`].
    pub fn ipm_params(&self) -> IpmParams {
        let d = IpmParams::default();
        IpmParams {
            nu1: self.nu1,
            nu2: self.nu2,
            eps: self.eps,
            tol: self.tol.unwrap_or(d.tol),
            max_iter: self.max_iter.unwrap_or(d.max_iter),
            tau: d.tau,
            sigma: d.sigma,
            sv_tol: self.sv_tol,
        }
    }

    /// Lower the shared fields into [`OcsvmParams`] (ν = ν₁).
    pub fn ocsvm_params(&self) -> OcsvmParams {
        let d = OcsvmParams::default();
        OcsvmParams {
            nu: self.nu1,
            tol: self.tol.unwrap_or(d.tol),
            max_iter: self.max_iter.unwrap_or(d.max_iter),
            sv_tol: self.sv_tol,
        }
    }

    /// Lower the shared fields into [`ApproxParams`]. A trainer put
    /// into approx mode without an explicit map choice defaults to
    /// Nyström (the map that works for every kernel family).
    pub fn approx_params(&self) -> ApproxParams {
        let engine = match self.engine {
            EngineKind::Exact => EngineKind::Nystroem,
            e => e,
        };
        ApproxParams { smo: self.smo_params(), engine, features: self.features }
    }

    /// Instantiate the configured base solver (no layers).
    pub fn build_solver(&self) -> Box<dyn Solver + Send + Sync> {
        match self.kind {
            SolverKind::Smo => Box::new(SmoSolver { params: self.smo_params() }),
            SolverKind::Pg => Box::new(PgSolver { params: self.pg_params() }),
            SolverKind::Ipm => Box::new(IpmSolver { params: self.ipm_params() }),
            SolverKind::OcsvmSmo => {
                Box::new(OcsvmSolver { params: self.ocsvm_params() })
            }
            SolverKind::Approx => {
                Box::new(ApproxSolver { params: self.approx_params() })
            }
        }
    }

    // ------------------------------------------------------------- fitting

    fn validate_composition(&self) -> Result<()> {
        if self.kind == SolverKind::Approx {
            if self.precision == Precision::F32 {
                return Err(Error::config(
                    "approx engine has no f32 mode: there is no Gram to \
                     build at reduced precision; lifted training is f64",
                ));
            }
            if self.cascade.is_some() {
                return Err(Error::config(
                    "cascade + approx is unsupported: the lifted engine \
                     already scales past the sizes cascade shards for",
                ));
            }
        }
        if self.warm_epochs > 0 && self.kind != SolverKind::Smo {
            return Err(Error::config(format!(
                "warm_start requires the smo solver (got {})",
                self.kind
            )));
        }
        if let Some(c) = &self.cache {
            if self.kind != SolverKind::Smo {
                return Err(Error::config(format!(
                    "cache_rows requires the row-streaming smo solver (got {}); \
                     dense solvers need the full Gram matrix",
                    self.kind
                )));
            }
            if c.capacity < 2 {
                return Err(Error::config(
                    "cache_rows capacity must be >= 2 (SMO touches row pairs)",
                ));
            }
            if self.cascade.is_some() {
                return Err(Error::config(
                    "cascade + cache_rows is unsupported; pick one layer",
                ));
            }
            if self.precision == Precision::F32 {
                return Err(Error::config(
                    "cache_rows requires f64 compute: the bounded row cache \
                     streams rows on demand, so there is no single Gram to \
                     certify against",
                ));
            }
        }
        Ok(())
    }

    /// Train on `x` with the configured solver and layers.
    ///
    /// With the recorder on ([`crate::obs`]) every fit records a
    /// Retrain span carrying the solve's iteration count — background
    /// retrains on the train queue show up in `slabsvm trace` output
    /// alongside the incremental Repair spans they escalate from.
    pub fn fit(&self, x: &Matrix) -> Result<FitReport> {
        self.validate_composition()?;
        let t_start = if crate::obs::enabled() {
            Some(crate::obs::now_us())
        } else {
            None
        };
        let report = if self.cascade.is_some() {
            self.fit_cascade(x)
        } else {
            self.fit_direct(x)
        }?;
        if let Some(start_us) = t_start {
            crate::obs::record_span(crate::obs::Span {
                trace: 0,
                stage: crate::obs::Stage::Retrain,
                start_us,
                dur_us: crate::obs::now_us().saturating_sub(start_us),
                stream: 0,
                shard: u32::MAX,
                iters: report.stats.iterations as u64,
            });
        }
        Ok(report)
    }

    /// One solve, no cascade (warm-start / cache layers still apply).
    fn fit_direct(&self, x: &Matrix) -> Result<FitReport> {
        if self.precision == Precision::F32 {
            return self.fit_f32_certified(x);
        }
        match self.kind {
            SolverKind::Smo => {
                if let Some(c) = self.cache {
                    let mut provider =
                        CachedRows::with_policy(x, self.kernel, c.capacity, c.policy);
                    self.fit_smo_with(x, &mut provider)
                } else {
                    let threads = crate::util::threadpool::default_threads();
                    let mut provider =
                        PrecomputedGram::build(x, self.kernel, threads);
                    self.fit_smo_with(x, &mut provider)
                }
            }
            _ => self.build_solver().fit(x, self.kernel),
        }
    }

    /// F32 compute mode: build the Gram at single precision (lane-
    /// blocked f32 contraction, ~2x the vector width of the f64 path),
    /// solve on it, then certify the result against **f64** margins.
    ///
    /// Certification re-scores every training row through the trained
    /// model at full precision (O(m·|SV|·d) f64 kernel evals — cheap
    /// next to the O(m²·d) Gram build) and rebuilds the KKT
    /// certificate on those margins. A pass returns the f32-computed
    /// model with the honest f64 certificate; a failure triggers a
    /// visible full-precision refit ([`FitReport::fell_back`]).
    fn fit_f32_certified(&self, x: &Matrix) -> Result<FitReport> {
        let threads = crate::util::threadpool::default_threads();
        let k32 = self.kernel.gram_in(Precision::F32, x, threads);
        let mut report = match self.kind {
            SolverKind::Smo => {
                let mut provider = BorrowedGram { k: &k32 };
                self.fit_smo_with(x, &mut provider)
            }
            _ => self.build_solver().fit_gram(x, self.kernel, &k32),
        }?;
        let m = x.rows();
        let s64: Vec<f64> =
            (0..m).map(|i| report.model.score(x.row(i))).collect();
        let eps = self.effective_eps();
        let mf = m as f64;
        let cap_a = 1.0 / (self.nu1 * mf);
        let cap_b =
            if eps > 0.0 { eps / (self.nu2 * mf) } else { f64::INFINITY };
        let cert64 = validate::report_with_margins(
            &report.dual.alpha,
            &report.dual.alpha_bar,
            &s64,
            report.dual.rho1,
            report.dual.rho2,
            self.nu1,
            self.nu2,
            eps,
            cap_a.min(cap_b) * 1e-6,
        );
        let margin_scale =
            1.0 + s64.iter().map(|v| v.abs()).sum::<f64>() / mf.max(1.0);
        if cert64.max_kkt_violation <= F32_CERT_TOL * margin_scale {
            report.dual.s = s64;
            report.certificate = cert64;
            report.precision = Precision::F32;
            report.fell_back = false;
            return Ok(report);
        }
        // The f32 Gram lost too much structure (ill-conditioned data:
        // near-duplicate rows, huge offsets) — redo at full precision.
        let mut exact = self.clone();
        exact.precision = Precision::F64;
        let mut report = exact.fit_direct(x)?;
        report.fell_back = true;
        Ok(report)
    }

    /// SMO path over any provider, with the optional warm-start layer.
    fn fit_smo_with<P: KernelProvider>(
        &self,
        x: &Matrix,
        provider: &mut P,
    ) -> Result<FitReport> {
        let p = self.smo_params();
        let warm = if self.warm_epochs > 0 {
            Some(warmstart::warm_state(
                provider,
                &WarmStartParams { smo: p, epochs: self.warm_epochs },
            ))
        } else {
            None
        };
        let out = smo::solve_from(provider, &p, warm)?;
        Ok(assemble_slab(
            x,
            self.kernel,
            p.sv_tol,
            p.nu1,
            p.nu2,
            p.eps,
            out.alpha,
            out.alpha_bar,
            out.s,
            out.rho1,
            out.rho2,
            out.stats,
        ))
    }

    /// ε used for the certificate / cascade reconstruction: the OCSVM
    /// embedding carries no ᾱ mass.
    fn effective_eps(&self) -> f64 {
        if self.kind == SolverKind::OcsvmSmo {
            0.0
        } else {
            self.eps
        }
    }

    /// Graf-style cascade over any solver kind (algorithm ported from
    /// the SMO-only `solver/cascade.rs`; see its module docs for the
    /// ν-rescaling derivation). Each shard / union solve goes through
    /// [`Trainer::fit_direct`], so warm-start composes per sub-solve.
    fn fit_cascade(&self, x: &Matrix) -> Result<FitReport> {
        let opts = self.cascade.expect("fit_cascade called without cascade opts");
        let m = x.rows();
        let shards = opts.shards.max(1);
        let mut base = self.clone();
        base.cascade = None;
        if m < shards * 16 || shards == 1 {
            let mut report = base.fit_direct(x)?;
            report.cascade =
                Some(CascadeTrace { candidate_sizes: vec![m], rounds: 0 });
            return Ok(report);
        }

        // ---- layer 1: parallel shard solves ---------------------------
        // round-robin assignment keeps shards distributionally balanced
        let mut shard_idx: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for i in 0..m {
            shard_idx[i % shards].push(i);
        }
        let shard_svs: Vec<Result<Vec<usize>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = shard_idx
                .iter()
                .map(|idx| {
                    let sub = base.clone();
                    scope.spawn(move || -> Result<Vec<usize>> {
                        let xs = x.select_rows(idx);
                        let report = sub.fit_direct(&xs)?;
                        // SVs of this shard, mapped back to global indices
                        Ok(idx
                            .iter()
                            .enumerate()
                            .filter(|(r, _)| {
                                report.dual.gamma[*r].abs() > sub.sv_tol
                            })
                            .map(|(_, &g)| g)
                            .collect())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard thread"))
                .collect()
        });
        let mut candidates: Vec<usize> = Vec::new();
        for svs in shard_svs {
            candidates.extend(svs?);
        }
        candidates.sort_unstable();
        candidates.dedup();

        // ---- layer 2+: retrain on the union until the SV set stabilizes
        let cascade_tol = self.tol.unwrap_or(CASCADE_DEFAULT_TOL);
        let mut candidate_sizes = vec![candidates.len()];
        let mut rounds = 0;
        loop {
            rounds += 1;
            // pad for ν' ≤ 1 feasibility of the rescaled subset dual.
            // Collected separately: pushing into `candidates` mid-scan
            // would unsort it and break the binary_search dedup check.
            let min_size = ((self.nu1.max(self.nu2) * m as f64).ceil() as usize
                + 1)
            .min(m);
            if candidates.len() < min_size {
                let mut pad: Vec<usize> = Vec::new();
                for i in 0..m {
                    if candidates.len() + pad.len() >= min_size {
                        break;
                    }
                    if candidates.binary_search(&i).is_err() {
                        pad.push(i);
                    }
                }
                candidates.extend(pad);
                candidates.sort_unstable();
            }
            let m_sub = candidates.len();
            let scale = m as f64 / m_sub as f64;
            let mut sub = base.clone();
            sub.nu1 = (self.nu1 * scale).min(1.0);
            sub.nu2 = (self.nu2 * scale).min(1.0);
            let xs = x.select_rows(&candidates);
            let report = sub.fit_direct(&xs)?;
            let sv_of_candidates: Vec<usize> = candidates
                .iter()
                .enumerate()
                .filter(|(r, _)| report.dual.gamma[*r].abs() > self.sv_tol)
                .map(|(_, &g)| g)
                .collect();
            // convergence check: does the model violate KKT on any point
            // OUTSIDE the candidate set? (those points have γ = 0, so
            // the check is "is the margin inside the slab")
            let mut violators: Vec<usize> = Vec::new();
            for i in 0..m {
                if candidates.binary_search(&i).is_ok() {
                    continue;
                }
                let s = report.model.score(x.row(i));
                if s < report.dual.rho1 - cascade_tol * (1.0 + s.abs())
                    || s > report.dual.rho2 + cascade_tol * (1.0 + s.abs())
                {
                    violators.push(i);
                }
            }
            if violators.is_empty() || rounds >= opts.max_rounds {
                // rebuild the dual in GLOBAL index space (γ is re-derived
                // as α − ᾱ inside assemble_slab; the sub-solve keeps them
                // exactly consistent)
                let mut alpha = vec![0.0; m];
                let mut alpha_bar = vec![0.0; m];
                for (r, &g) in candidates.iter().enumerate() {
                    alpha[g] = report.dual.alpha[r];
                    alpha_bar[g] = report.dual.alpha_bar[r];
                }
                let s: Vec<f64> =
                    (0..m).map(|i| report.model.score(x.row(i))).collect();
                let mut final_report = assemble_slab(
                    x,
                    self.kernel,
                    self.sv_tol,
                    self.nu1,
                    self.nu2,
                    self.effective_eps(),
                    alpha,
                    alpha_bar,
                    s,
                    report.dual.rho1,
                    report.dual.rho2,
                    report.stats,
                );
                final_report.cascade =
                    Some(CascadeTrace { candidate_sizes, rounds });
                // compute-mode provenance of the deciding union solve
                final_report.precision = report.precision;
                final_report.fell_back = report.fell_back;
                return Ok(final_report);
            }
            // grow the candidate set with the violators and retrain
            candidates = sv_of_candidates;
            candidates.extend(violators);
            candidates.sort_unstable();
            candidates.dedup();
            candidate_sizes.push(candidates.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    #[test]
    fn kind_roundtrip_and_rejection() {
        for kind in SolverKind::ALL {
            assert_eq!(kind.to_string().parse::<SolverKind>().unwrap(), kind);
        }
        assert!("newton".parse::<SolverKind>().is_err());
        assert_eq!("ocsvm".parse::<SolverKind>().unwrap(), SolverKind::OcsvmSmo);
    }

    #[test]
    fn all_kinds_fit_through_the_trait() {
        let ds = SlabConfig::default().generate(80, 7);
        for kind in SolverKind::ALL {
            let solver = kind.default_solver();
            assert_eq!(solver.kind(), kind);
            let report = solver.fit(&ds.x, Kernel::Linear).unwrap();
            assert_eq!(report.dual.gamma.len(), 80);
            assert!(report.stats.iterations > 0, "{kind}: no iterations");
            assert!(
                report.certificate.sum_alpha_violation < 1e-6,
                "{kind}: sum(alpha) off by {}",
                report.certificate.sum_alpha_violation
            );
        }
    }

    #[test]
    fn trainer_smo_matches_trait_smo() {
        let ds = SlabConfig::default().generate(120, 8);
        let via_trainer =
            Trainer::new(SolverKind::Smo).kernel(Kernel::Linear).fit(&ds.x).unwrap();
        let via_trait =
            SmoSolver::default().fit(&ds.x, Kernel::Linear).unwrap();
        assert!(
            (via_trainer.stats.objective - via_trait.stats.objective).abs() < 1e-12
        );
        assert_eq!(via_trainer.dual.gamma, via_trait.dual.gamma);
    }

    #[test]
    fn composition_rules_are_enforced() {
        let t = Trainer::new(SolverKind::Ipm).warm_start(2);
        assert!(t.validate_composition().is_err());
        let t = Trainer::new(SolverKind::Pg).cache_rows(64, Policy::Lru);
        assert!(t.validate_composition().is_err());
        let t = Trainer::new(SolverKind::Smo)
            .cascade(4, 3)
            .cache_rows(64, Policy::Lru);
        assert!(t.validate_composition().is_err());
        let t = Trainer::new(SolverKind::Smo).cache_rows(1, Policy::Lru);
        assert!(t.validate_composition().is_err());
        let t = Trainer::new(SolverKind::Smo).warm_start(2).cascade(4, 3);
        assert!(t.validate_composition().is_ok());
    }

    #[test]
    fn ocsvm_embedding_is_single_plane() {
        let ds = SlabConfig::default().generate(150, 9);
        let report = Trainer::new(SolverKind::OcsvmSmo)
            .kernel(Kernel::Rbf { g: 0.5 })
            .nu1(0.3)
            .fit(&ds.x)
            .unwrap();
        assert_eq!(report.dual.rho2, NO_UPPER_PLANE);
        assert!(report.dual.alpha_bar.iter().all(|&v| v == 0.0));
        // decision degenerates to sgn(s - rho1)
        for i in 0..ds.len() {
            let s = report.model.score(ds.x.row(i));
            let want = if s - report.dual.rho1 >= 0.0 { 1 } else { -1 };
            assert_eq!(report.model.classify(ds.x.row(i)), want, "row {i}");
        }
    }

    #[test]
    fn per_solver_iteration_defaults_apply() {
        // an unset max_iter must lower to each solver's own default, not
        // a shared one (an SMO budget would be catastrophic for the IPM)
        let t = Trainer::new(SolverKind::Ipm);
        assert_eq!(t.ipm_params().max_iter, IpmParams::default().max_iter);
        assert_eq!(t.smo_params().max_iter, SmoParams::default().max_iter);
        let t = t.max_iter(77);
        assert_eq!(t.ipm_params().max_iter, 77);
        assert_eq!(t.smo_params().max_iter, 77);
    }

    #[test]
    fn materialized_gram_matches_direct() {
        let ds = SlabConfig::default().generate(40, 10);
        let mut provider = PrecomputedGram::build(&ds.x, Kernel::Linear, 2);
        let k = materialize_gram(&mut provider);
        let want = Kernel::Linear.gram(&ds.x, 2);
        for i in 0..40 {
            for j in 0..40 {
                assert_eq!(k.get(i, j), want.get(i, j));
            }
        }
    }
}
