//! Baseline: primal-dual interior-point method for the OCSSVM dual.
//!
//! The "generic QP solver" of the paper's scaling claim (its refs
//! [19][21][25]): a textbook primal-dual IPM on the faithful dual in
//! z = (α, ᾱ) ∈ R^{2m}:
//!
//! ```text
//!   min ½ zᵀ Q z,  Q = [[K, −K], [−K, K]]   (PSD, rank m)
//!   s.t. Σα = 1, Σᾱ = ε,  0 ≤ α ≤ cap_a, 0 ≤ ᾱ ≤ cap_b
//! ```
//!
//! with slacks u = z − 0, v = cap − z and multipliers z₁, z₂ ≥ 0 plus a
//! 2-vector y for the equalities. Each Newton step solves the reduced
//! system (Q + D)Δz = r − AᵀΔy via **dense Cholesky on a 2m×2m matrix —
//! O(m³) per iteration with a large constant**. That cubic cost *is* the
//! point of the comparison: the IPM reaches high accuracy in a few tens
//! of iterations but falls behind SMO rapidly as m grows (qp_comparison
//! bench).

use std::time::Instant;

use super::ocssvm::SlabModel;
use super::smo::recover_rhos_blocks;
use super::{check_params, SolveStats};
use crate::error::Error;
use crate::kernel::Kernel;
use crate::linalg::{cholesky, cholesky_solve, Matrix};
use crate::Result;

/// IPM hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct IpmParams {
    pub nu1: f64,
    pub nu2: f64,
    pub eps: f64,
    /// complementarity gap tolerance
    pub tol: f64,
    pub max_iter: usize,
    /// fraction-to-boundary step damping
    pub tau: f64,
    /// centering parameter σ ∈ (0,1)
    pub sigma: f64,
    pub sv_tol: f64,
}

impl Default for IpmParams {
    fn default() -> Self {
        IpmParams {
            nu1: 0.5,
            nu2: 0.01,
            eps: 2.0 / 3.0,
            tol: 1e-10,
            max_iter: 200,
            tau: 0.995,
            sigma: 0.2,
            sv_tol: 1e-10,
        }
    }
}

/// Raw dual solve on a precomputed Gram matrix.
/// Returns (α, ᾱ, ρ₁, ρ₂, stats).
pub fn solve(
    k: &Matrix,
    p: &IpmParams,
) -> Result<(Vec<f64>, Vec<f64>, f64, f64, SolveStats)> {
    let m = k.rows();
    check_params(m, p.nu1, p.nu2, p.eps)?;
    let cap = [1.0 / (p.nu1 * m as f64), p.eps / (p.nu2 * m as f64)];
    let target = [1.0, p.eps];
    let t0 = Instant::now();
    let n = 2 * m; // extended dimension

    // strictly interior start on both blocks
    let mut z = vec![0.0; n];
    for i in 0..m {
        z[i] = (1.0 / m as f64).clamp(0.05 * cap[0], 0.95 * cap[0]);
        z[m + i] = (p.eps / m as f64).clamp(0.05 * cap[1], 0.95 * cap[1]);
    }
    for blk in 0..2 {
        let sum: f64 = z[blk * m..(blk + 1) * m].iter().sum();
        let shift = (target[blk] - sum) / m as f64;
        for i in 0..m {
            z[blk * m + i] = (z[blk * m + i] + shift)
                .clamp(0.01 * cap[blk], 0.99 * cap[blk]);
        }
    }
    let mut y = [0.0f64; 2];
    let mut z1 = vec![1.0; n]; // lower-bound multipliers
    let mut z2 = vec![1.0; n]; // upper-bound multipliers

    let cap_of = |j: usize| if j < m { cap[0] } else { cap[1] };

    // Q z without materializing Q: Qz = [K γ; −K γ], γ = α − ᾱ.
    let qz = |z: &[f64], out: &mut [f64]| {
        let mut gamma = vec![0.0; m];
        for i in 0..m {
            gamma[i] = z[i] - z[m + i];
        }
        let mut s = vec![0.0; m];
        crate::linalg::matvec(k, &gamma, &mut s);
        for i in 0..m {
            out[i] = s[i];
            out[m + i] = -s[i];
        }
    };

    let mut iterations = 0;
    let mut mu = f64::INFINITY;
    let mut qz_buf = vec![0.0; n];

    while iterations < p.max_iter {
        let u: Vec<f64> = z.to_vec();
        let v: Vec<f64> = (0..n).map(|j| cap_of(j) - z[j]).collect();
        mu = (u.iter().zip(&z1).map(|(a, b)| a * b).sum::<f64>()
            + v.iter().zip(&z2).map(|(a, b)| a * b).sum::<f64>())
            / (2 * n) as f64;

        qz(&z, &mut qz_buf);
        let r_dual: Vec<f64> = (0..n)
            .map(|j| {
                let yj = if j < m { y[0] } else { y[1] };
                -(qz_buf[j] - yj - z1[j] + z2[j])
            })
            .collect();
        let r_prim = [
            target[0] - z[..m].iter().sum::<f64>(),
            target[1] - z[m..].iter().sum::<f64>(),
        ];

        if mu < p.tol
            && r_prim[0].abs() < 1e-9
            && r_prim[1].abs() < 1e-9
            && r_dual.iter().all(|r| r.abs() < 1e-7)
        {
            break;
        }

        let mu_target = p.sigma * mu;

        // Build the 2m×2m normal matrix Q + D and factorize (the O(m³)
        // hot spot this baseline exists to demonstrate).
        let mut qd = Matrix::zeros(n, n);
        for i in 0..m {
            for j in 0..m {
                let kij = k.get(i, j);
                qd.set(i, j, kij);
                qd.set(i, m + j, -kij);
                qd.set(m + i, j, -kij);
                qd.set(m + i, m + j, kij);
            }
        }
        for j in 0..n {
            let d = z1[j] / u[j].max(1e-14) + z2[j] / v[j].max(1e-14);
            qd.set(j, j, qd.get(j, j) + d);
        }
        let l = cholesky(&qd, 1e-10).map_err(|i| {
            Error::NoConvergence(format!("IPM normal matrix not PD at pivot {i}"))
        })?;

        let rhs: Vec<f64> = (0..n)
            .map(|j| {
                r_dual[j] + (mu_target - u[j] * z1[j]) / u[j].max(1e-14)
                    - (mu_target - v[j] * z2[j]) / v[j].max(1e-14)
            })
            .collect();

        // Schur complement on the two equality constraints:
        // Δz = M⁻¹(rhs + a₁Δy₁ + a₂Δy₂) with a₁ = [1…1,0…0], a₂ mirrored.
        let minv_rhs = cholesky_solve(&l, &rhs);
        let mut a1 = vec![0.0; n];
        let mut a2 = vec![0.0; n];
        for i in 0..m {
            a1[i] = 1.0;
            a2[m + i] = 1.0;
        }
        let minv_a1 = cholesky_solve(&l, &a1);
        let minv_a2 = cholesky_solve(&l, &a2);
        // 2×2 system: Aᵀ M⁻¹ A Δy = r_prim − Aᵀ M⁻¹ rhs
        let s11: f64 = minv_a1[..m].iter().sum();
        let s12: f64 = minv_a2[..m].iter().sum();
        let s21: f64 = minv_a1[m..].iter().sum();
        let s22: f64 = minv_a2[m..].iter().sum();
        let b1 = r_prim[0] - minv_rhs[..m].iter().sum::<f64>();
        let b2 = r_prim[1] - minv_rhs[m..].iter().sum::<f64>();
        let det = s11 * s22 - s12 * s21;
        if det.abs() < 1e-300 {
            return Err(Error::NoConvergence("IPM Schur system singular".into()));
        }
        let dy1 = (b1 * s22 - b2 * s12) / det;
        let dy2 = (s11 * b2 - s21 * b1) / det;
        let dz: Vec<f64> = (0..n)
            .map(|j| minv_rhs[j] + dy1 * minv_a1[j] + dy2 * minv_a2[j])
            .collect();

        let dz1: Vec<f64> = (0..n)
            .map(|j| (mu_target - u[j] * z1[j] - z1[j] * dz[j]) / u[j].max(1e-14))
            .collect();
        let dz2: Vec<f64> = (0..n)
            .map(|j| (mu_target - v[j] * z2[j] + z2[j] * dz[j]) / v[j].max(1e-14))
            .collect();

        // fraction-to-boundary step
        let mut alpha_step: f64 = 1.0;
        for j in 0..n {
            if dz[j] < 0.0 {
                alpha_step = alpha_step.min(-p.tau * u[j] / dz[j]);
            }
            if dz[j] > 0.0 {
                alpha_step = alpha_step.min(p.tau * v[j] / dz[j]);
            }
            if dz1[j] < 0.0 {
                alpha_step = alpha_step.min(-p.tau * z1[j] / dz1[j]);
            }
            if dz2[j] < 0.0 {
                alpha_step = alpha_step.min(-p.tau * z2[j] / dz2[j]);
            }
        }
        alpha_step = alpha_step.min(1.0);

        for j in 0..n {
            z[j] += alpha_step * dz[j];
            z1[j] = (z1[j] + alpha_step * dz1[j]).max(1e-14);
            z2[j] = (z2[j] + alpha_step * dz2[j]).max(1e-14);
        }
        y[0] += alpha_step * dy1;
        y[1] += alpha_step * dy2;
        iterations += 1;
    }

    if iterations >= p.max_iter && mu > p.tol * 100.0 {
        return Err(Error::NoConvergence(format!(
            "IPM hit max_iter={} with gap {mu:.3e}",
            p.max_iter
        )));
    }

    // split + snap to bounds (interior iterates end O(μ) away)
    let mut alpha = z[..m].to_vec();
    let mut alpha_bar = z[m..].to_vec();
    for (blk, vec) in [(0usize, &mut alpha), (1, &mut alpha_bar)] {
        let snap = (p.tol.sqrt() * cap[blk]).max(1e-12);
        for g in vec.iter_mut() {
            if *g < snap {
                *g = 0.0;
            }
            if cap[blk] - *g < snap {
                *g = cap[blk];
            }
        }
        // re-normalize the block sum after snapping
        let sum: f64 = vec.iter().sum();
        let free: Vec<usize> = (0..m)
            .filter(|&i| vec[i] > 0.0 && vec[i] < cap[blk])
            .collect();
        if !free.is_empty() {
            let corr = (target[blk] - sum) / free.len() as f64;
            for &i in &free {
                vec[i] = (vec[i] + corr).clamp(0.0, cap[blk]);
            }
        }
    }

    let gamma: Vec<f64> =
        alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
    let mut s = vec![0.0; m];
    crate::linalg::matvec(k, &gamma, &mut s);
    let (mut rho1, mut rho2) = (0.0, 0.0);
    recover_rhos_blocks(
        &alpha, &alpha_bar, &s, cap[0], cap[1], 1e-9, &mut rho1, &mut rho2,
    );
    let objective = 0.5 * gamma.iter().zip(&s).map(|(g, si)| g * si).sum::<f64>();
    let stats = SolveStats {
        iterations,
        objective,
        max_violation: mu,
        seconds: t0.elapsed().as_secs_f64(),
        cache: Default::default(),
        kernel_evals: 0,
    };
    Ok((alpha, alpha_bar, rho1, rho2, stats))
}

/// Train a [`SlabModel`] with the interior-point method.
#[deprecated(
    since = "0.2.0",
    note = "use the unified API: `Trainer::new(SolverKind::Ipm).kernel(kernel).fit(x)` \
            (solver::api) — same numerics, uniform FitReport"
)]
pub fn train(x: &Matrix, kernel: Kernel, p: &IpmParams) -> Result<(SlabModel, SolveStats)> {
    let threads = crate::util::threadpool::default_threads();
    let k = kernel.gram(x, threads);
    let (alpha, alpha_bar, rho1, rho2, stats) = solve(&k, p)?;
    let gamma: Vec<f64> =
        alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
    Ok((
        SlabModel::from_dual(x, &gamma, rho1, rho2, kernel, p.sv_tol),
        stats,
    ))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // legacy shims stay covered until removal

    use super::*;
    use crate::data::synthetic::SlabConfig;

    #[test]
    fn ipm_converges_and_is_feasible() {
        let ds = SlabConfig::default().generate(80, 41);
        let k = Kernel::Linear.gram(&ds.x, 2);
        let p = IpmParams::default();
        let (alpha, alpha_bar, rho1, rho2, stats) = solve(&k, &p).unwrap();
        assert!(stats.iterations > 0 && stats.iterations < 200);
        let m = alpha.len() as f64;
        let cap_a = 1.0 / (p.nu1 * m);
        let cap_b = p.eps / (p.nu2 * m);
        for i in 0..alpha.len() {
            assert!(alpha[i] >= -1e-9 && alpha[i] <= cap_a + 1e-9);
            assert!(alpha_bar[i] >= -1e-9 && alpha_bar[i] <= cap_b + 1e-9);
        }
        let sa: f64 = alpha.iter().sum();
        let sb: f64 = alpha_bar.iter().sum();
        assert!((sa - 1.0).abs() < 1e-6, "sum(alpha)={sa}");
        assert!((sb - p.eps).abs() < 1e-6, "sum(alpha_bar)={sb}");
        assert!(rho1 <= rho2 + 1e-9);
    }

    #[test]
    fn ipm_matches_smo_objective() {
        let ds = SlabConfig::default().generate(100, 42);
        let k = Kernel::Rbf { g: 0.05 }.gram(&ds.x, 2);
        let (_, _, _, _, ipm_stats) = solve(&k, &IpmParams::default()).unwrap();
        let sp = crate::solver::smo::SmoParams { tol: 1e-7, ..Default::default() };
        let (_, smo_out) =
            crate::solver::smo::train_full(&ds.x, Kernel::Rbf { g: 0.05 }, &sp)
                .unwrap();
        let rel = (ipm_stats.objective - smo_out.stats.objective).abs()
            / smo_out.stats.objective.abs().max(1e-9);
        assert!(
            rel < 5e-3,
            "IPM {} vs SMO {}",
            ipm_stats.objective,
            smo_out.stats.objective
        );
    }

    #[test]
    fn ipm_iteration_count_is_small() {
        // the IPM signature: ~tens of iterations regardless of m
        for (seed, m) in [(1u64, 40usize), (2, 80), (3, 160)] {
            let ds = SlabConfig::default().generate(m, seed);
            let k = Kernel::Linear.gram(&ds.x, 2);
            let (_, _, _, _, stats) = solve(&k, &IpmParams::default()).unwrap();
            assert!(stats.iterations <= 120, "m={m}: {} iters", stats.iterations);
        }
    }
}
