//! Property-testing harness (proptest substitute).
//!
//! [`forall`] runs a property over `n` pseudo-random cases drawn from a
//! [`Gen`] and, on failure, re-runs a simple halving **shrink** loop on
//! the failing case's size parameters before panicking with the minimal
//! reproduction seed. Deterministic: case i of a named property always
//! sees the same RNG stream, so failures reproduce across runs.

use crate::util::rng::Rng;

/// Case generator context handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// scale knob in (0, 1]: properties use it to size their inputs so
    /// the shrink loop can reduce failing cases
    pub scale: f64,
}

impl Gen {
    /// Random dataset size in [lo, hi] scaled by the shrink knob.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + ((hi - lo) as f64 * self.scale) as usize;
        lo + self.rng.below(hi_scaled - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_range(lo, hi)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Outcome of a property: Ok(()) or a failure description.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` generated cases. Panics (with seed + shrink
/// info) on the first failure that survives shrinking.
pub fn forall(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> PropResult) {
    let base_seed = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::new(seed), scale: 1.0 };
        if let Err(msg) = prop(&mut g) {
            // shrink: halve the scale until the property passes, keep the
            // smallest failing scale
            let mut failing_scale = 1.0;
            let mut failing_msg = msg;
            let mut scale = 0.5;
            while scale > 0.01 {
                let mut g = Gen { rng: Rng::new(seed), scale };
                match prop(&mut g) {
                    Err(m) => {
                        failing_scale = scale;
                        failing_msg = m;
                        scale *= 0.5;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 minimal scale {failing_scale}): {failing_msg}"
            );
        }
    }
}

/// FNV-1a for deterministic per-name seeds.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("always-true", 25, |g| {
            let n = g.size(1, 100);
            if n >= 1 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        forall("always-false", 5, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_streams() {
        use std::sync::Mutex;
        let first: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        forall("det", 5, |g| {
            first.lock().unwrap().push(g.size(1, 1000));
            Ok(())
        });
        let second: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        forall("det", 5, |g| {
            second.lock().unwrap().push(g.size(1, 1000));
            Ok(())
        });
        assert_eq!(*first.lock().unwrap(), *second.lock().unwrap());
    }

    #[test]
    fn shrink_reduces_scale() {
        // property failing only for large sizes: shrink should find that
        // small scales pass (we only check it doesn't hang / panics with
        // the right name)
        let result = std::panic::catch_unwind(|| {
            forall("fails-large", 3, |g| {
                let n = g.size(10, 1000);
                if n > 500 {
                    Err(format!("n={n} too big"))
                } else {
                    Ok(())
                }
            });
        });
        // may or may not fail depending on draws; both fine — the point
        // is the call returns (no infinite shrink loop)
        let _ = result;
    }
}
