//! Kernel-row providers: full precompute vs bounded row caches.
//!
//! SMO touches two kernel rows per iteration; at paper scale (m <= 5000)
//! the full Gram matrix fits in memory, but the cache abstraction is what
//! makes the solver scale past that — and it reproduces the caching
//! ablation the paper's related work motivates (LFU caching for SVM
//! training, reference [37] Li/Wen/He). Three providers:
//!
//! * [`PrecomputedGram`] — O(m^2) memory, zero misses (the default for
//!   Table-1 scale);
//! * [`CachedRows`] with [`Policy::Lru`] — recency eviction;
//! * [`CachedRows`] with [`Policy::Lfu`] — frequency eviction [37].
//!
//! `rust/benches/ablation_cache.rs` sweeps policy x capacity (experiment
//! A2 in DESIGN.md).

use std::collections::HashMap;

use crate::kernel::Kernel;
use crate::linalg::Matrix;

/// Cache observability counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Source of kernel rows for the solvers.
pub trait KernelProvider {
    /// Number of training points.
    fn m(&self) -> usize;
    /// k(x_i, x_i).
    fn diag(&self, i: usize) -> f64;
    /// Run `f` with row i (k(x_i, x_j) for all j).
    fn with_row<R>(&mut self, i: usize, f: &mut dyn FnMut(&[f64]) -> R) -> R;
    /// Run `f` with rows a and b simultaneously.
    fn with_two_rows<R>(
        &mut self,
        a: usize,
        b: usize,
        f: &mut dyn FnMut(&[f64], &[f64]) -> R,
    ) -> R;
    /// Cache counters (zero for precomputed).
    fn stats(&self) -> CacheStats;
}

// ---------------------------------------------------------------- precomputed

/// Fully materialized Gram matrix.
pub struct PrecomputedGram {
    k: Matrix,
}

impl PrecomputedGram {
    /// Build with the native engine (parallel).
    pub fn build(x: &Matrix, kernel: Kernel, threads: usize) -> Self {
        PrecomputedGram { k: kernel.gram(x, threads) }
    }

    /// Wrap an externally computed Gram matrix (e.g. from the PJRT
    /// engine) — must be square.
    pub fn from_matrix(k: Matrix) -> Self {
        assert_eq!(k.rows(), k.cols(), "Gram matrix must be square");
        PrecomputedGram { k }
    }

    pub fn matrix(&self) -> &Matrix {
        &self.k
    }
}

impl KernelProvider for PrecomputedGram {
    fn m(&self) -> usize {
        self.k.rows()
    }
    fn diag(&self, i: usize) -> f64 {
        self.k.get(i, i)
    }
    fn with_row<R>(&mut self, i: usize, f: &mut dyn FnMut(&[f64]) -> R) -> R {
        f(self.k.row(i))
    }
    fn with_two_rows<R>(
        &mut self,
        a: usize,
        b: usize,
        f: &mut dyn FnMut(&[f64], &[f64]) -> R,
    ) -> R {
        f(self.k.row(a), self.k.row(b))
    }
    fn stats(&self) -> CacheStats {
        CacheStats::default()
    }
}

// --------------------------------------------------------------- cached rows

/// Eviction policy for [`CachedRows`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// evict least-recently-used row
    Lru,
    /// evict least-frequently-used row (ties by recency) — ref [37]
    Lfu,
}

struct Slot {
    row: Vec<f64>,
    key: usize,
    /// last-touch tick (LRU) / tie-break (LFU)
    touched: u64,
    /// access count since admission (LFU)
    freq: u64,
}

/// Bounded cache of kernel rows, computing misses on demand.
pub struct CachedRows {
    x: Matrix,
    kernel: Kernel,
    capacity: usize,
    policy: Policy,
    slots: Vec<Slot>,
    /// key -> slot index
    index: HashMap<usize, usize>,
    diag: Vec<f64>,
    tick: u64,
    stats: CacheStats,
}

impl CachedRows {
    /// `capacity` = max resident rows (>= 2 — SMO needs a pair).
    pub fn new(x: &Matrix, kernel: Kernel, capacity: usize) -> Self {
        Self::with_policy(x, kernel, capacity, Policy::Lru)
    }

    pub fn with_policy(
        x: &Matrix,
        kernel: Kernel,
        capacity: usize,
        policy: Policy,
    ) -> Self {
        assert!(capacity >= 2, "SMO needs at least two resident rows");
        let diag = (0..x.rows()).map(|i| kernel.eval(x.row(i), x.row(i))).collect();
        CachedRows {
            x: x.clone(),
            kernel,
            capacity,
            policy,
            slots: Vec::new(),
            index: HashMap::new(),
            diag,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    fn compute_row(&self, i: usize, out: &mut Vec<f64>) {
        out.resize(self.x.rows(), 0.0);
        self.kernel.row(&self.x, self.x.row(i), out);
    }

    /// Ensure row `key` is resident, optionally protecting one slot from
    /// eviction (the other member of an SMO pair). Returns slot index.
    fn ensure(&mut self, key: usize, protect: Option<usize>) -> usize {
        self.tick += 1;
        if let Some(&s) = self.index.get(&key) {
            self.stats.hits += 1;
            self.slots[s].touched = self.tick;
            self.slots[s].freq += 1;
            return s;
        }
        self.stats.misses += 1;
        if self.slots.len() < self.capacity {
            let mut row = Vec::new();
            self.compute_row(key, &mut row);
            self.slots.push(Slot { row, key, touched: self.tick, freq: 1 });
            let s = self.slots.len() - 1;
            self.index.insert(key, s);
            return s;
        }
        // evict
        let victim = self
            .slots
            .iter()
            .enumerate()
            .filter(|(s, _)| Some(*s) != protect)
            .min_by_key(|(_, slot)| match self.policy {
                Policy::Lru => (slot.touched, 0),
                Policy::Lfu => (slot.freq, slot.touched),
            })
            .map(|(s, _)| s)
            .expect("capacity >= 2 guarantees an evictable slot");
        self.stats.evictions += 1;
        let old_key = self.slots[victim].key;
        self.index.remove(&old_key);
        let mut row = std::mem::take(&mut self.slots[victim].row);
        self.compute_row(key, &mut row);
        self.slots[victim] =
            Slot { row, key, touched: self.tick, freq: 1 };
        self.index.insert(key, victim);
        victim
    }
}

impl KernelProvider for CachedRows {
    fn m(&self) -> usize {
        self.x.rows()
    }
    fn diag(&self, i: usize) -> f64 {
        self.diag[i]
    }
    fn with_row<R>(&mut self, i: usize, f: &mut dyn FnMut(&[f64]) -> R) -> R {
        let s = self.ensure(i, None);
        f(&self.slots[s].row)
    }
    fn with_two_rows<R>(
        &mut self,
        a: usize,
        b: usize,
        f: &mut dyn FnMut(&[f64], &[f64]) -> R,
    ) -> R {
        let sa = self.ensure(a, None);
        let sb = self.ensure(b, Some(sa));
        debug_assert_ne!(sa, sb);
        if sa < sb {
            let (lo, hi) = self.slots.split_at(sb);
            f(&lo[sa].row, &hi[0].row)
        } else {
            let (lo, hi) = self.slots.split_at(sa);
            f(&hi[0].row, &lo[sb].row)
        }
    }
    fn stats(&self) -> CacheStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn data(n: usize) -> Matrix {
        let mut rng = Rng::new(99);
        Matrix::from_vec(n, 3, (0..n * 3).map(|_| rng.normal()).collect())
    }

    #[test]
    fn precomputed_matches_kernel() {
        let x = data(20);
        let k = Kernel::Rbf { g: 0.4 };
        let mut p = PrecomputedGram::build(&x, k, 2);
        assert_eq!(p.m(), 20);
        p.with_row(3, &mut |row| {
            for j in 0..20 {
                assert!((row[j] - k.eval(x.row(3), x.row(j))).abs() < 1e-12);
            }
        });
        assert!((p.diag(5) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cached_rows_match_precomputed() {
        let x = data(30);
        let k = Kernel::Linear;
        let mut c = CachedRows::new(&x, k, 4);
        let mut p = PrecomputedGram::build(&x, k, 1);
        for i in [0, 5, 10, 5, 29, 0, 17] {
            let want: Vec<f64> = p.with_row(i, &mut |r| r.to_vec());
            c.with_row(i, &mut |got| {
                assert_eq!(got, &want[..], "row {i}");
            });
        }
    }

    #[test]
    fn two_rows_simultaneously() {
        let x = data(10);
        let k = Kernel::Rbf { g: 1.0 };
        let mut c = CachedRows::new(&x, k, 2);
        c.with_two_rows(2, 7, &mut |ra, rb| {
            assert!((ra[7] - rb[2]).abs() < 1e-12); // symmetry
            assert!((ra[2] - 1.0).abs() < 1e-12);
            assert!((rb[7] - 1.0).abs() < 1e-12);
        });
        // same pair again: both should hit
        let before = c.stats();
        c.with_two_rows(2, 7, &mut |_, _| ());
        let after = c.stats();
        assert_eq!(after.hits - before.hits, 2);
        assert_eq!(after.misses, before.misses);
    }

    #[test]
    fn pair_protection_under_min_capacity() {
        // capacity 2, alternating pairs: partner must never be evicted
        // mid-call.
        let x = data(6);
        let mut c = CachedRows::new(&x, Kernel::Linear, 2);
        for (a, b) in [(0, 1), (2, 3), (4, 5), (0, 3)] {
            c.with_two_rows(a, b, &mut |ra, rb| {
                assert_eq!(ra.len(), 6);
                assert_eq!(rb.len(), 6);
            });
        }
    }

    #[test]
    fn lru_evicts_oldest() {
        let x = data(8);
        let mut c = CachedRows::with_policy(&x, Kernel::Linear, 2, Policy::Lru);
        c.with_row(0, &mut |_| ());
        c.with_row(1, &mut |_| ());
        c.with_row(2, &mut |_| ()); // evicts 0
        assert_eq!(c.stats().evictions, 1);
        c.with_row(1, &mut |_| ()); // still resident -> hit
        assert_eq!(c.stats().hits, 1);
        c.with_row(0, &mut |_| ()); // miss again
        assert_eq!(c.stats().misses, 4);
    }

    #[test]
    fn lfu_keeps_hot_rows() {
        let x = data(8);
        let mut c = CachedRows::with_policy(&x, Kernel::Linear, 2, Policy::Lfu);
        for _ in 0..5 {
            c.with_row(0, &mut |_| ()); // freq(0) = 5
        }
        c.with_row(1, &mut |_| ()); // freq(1) = 1
        c.with_row(2, &mut |_| ()); // evicts 1 (lower freq), keeps 0
        c.with_row(0, &mut |_| ());
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        // the last access of 0 must be a hit (it was never evicted)
        assert!(s.hits >= 5);
    }

    #[test]
    fn hit_rate_math() {
        let s = CacheStats { hits: 3, misses: 1, evictions: 0 };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    #[should_panic]
    fn capacity_one_rejected() {
        CachedRows::new(&data(4), Kernel::Linear, 1);
    }
}
