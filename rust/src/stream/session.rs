//! Per-stream state machine: window + incremental solver + drift watch.
//!
//! A [`StreamSession`] is the unit the
//! [`crate::coordinator::Coordinator`] owns per live stream. It is a
//! pure state machine — [`StreamSession::absorb`] turns one arriving
//! sample into (a) a publishable [`FitReport`] once warm and (b) a drift
//! verdict — while the coordinator supplies the side effects: publishing
//! the model into the [`crate::coordinator::ModelRegistry`] (an atomic
//! hot-swap scorers never see torn) and submitting the escalated
//! cascade retrain to the background
//! [`crate::coordinator::TrainQueue`]. Keeping the session side-effect
//! free makes the whole streaming path testable without threads.

use crate::coordinator::JobId;
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::solver::api::Trainer;
use crate::solver::ocssvm::SlabModel;

use super::approx::StreamEngine;
use super::drift::{DriftConfig, DriftEvent, DriftMonitor};
use super::incremental::IncrementalConfig;

/// Everything a live stream needs configured up front.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    pub kernel: Kernel,
    /// feature dimension of arriving samples
    pub dim: usize,
    /// sliding-window capacity (the training-set size the model sees)
    pub window: usize,
    /// samples before the first model is published (and drift armed)
    pub min_train: usize,
    pub incremental: IncrementalConfig,
    pub drift: DriftConfig,
    /// cascade shards for the escalated background retrain
    pub retrain_shards: usize,
    /// cascade union-retrain rounds for the escalated retrain
    pub retrain_rounds: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            kernel: Kernel::Linear,
            dim: 2,
            window: 512,
            min_train: 64,
            incremental: IncrementalConfig::default(),
            drift: DriftConfig::default(),
            retrain_shards: 4,
            retrain_rounds: 2,
        }
    }
}

/// Outcome of absorbing one sample.
pub struct Absorbed {
    /// publishable model (None while the session is still warming up).
    /// Deliberately not the full [`crate::solver::FitReport`] — this is
    /// the per-sample hot path; call `session.solver().report()` when
    /// the dual + certificate are wanted.
    pub model: Option<SlabModel>,
    /// the absorbed sample's stable id — its 0-based arrival index on
    /// this stream, and the handle [`StreamSession::forget`] takes
    pub sample_id: u64,
    /// drift verdict for this sample (scored before absorption)
    pub drift: Option<DriftEvent>,
    /// the session wants a background retrain (drift tripped and none is
    /// already in flight) — the owner snapshots + submits
    pub retrain_wanted: bool,
}

/// Outcome of a targeted [`StreamSession::forget`].
pub struct Forgotten {
    /// refreshed model over the shrunk window (None when the removal
    /// dropped the session back below its warmup bar) — the owner
    /// hot-swaps it so the served model no longer reflects the
    /// forgotten sample
    pub model: Option<SlabModel>,
    /// resident samples remaining after the removal
    pub resident: usize,
    /// a background retrain was in flight at removal time — it was
    /// trained on a window that still contained the forgotten sample,
    /// so its completion would re-publish a model derived from deleted
    /// data. The owner must cancel it (`TrainQueue::cancel` — a
    /// cancelled job's model never reaches the registry) and submit a
    /// fresh retrain of the post-removal window, as
    /// `Coordinator::forget` does, or accept the stale publish.
    pub retrain_stale: bool,
}

/// One live stream's state.
pub struct StreamSession {
    name: String,
    cfg: StreamConfig,
    inc: StreamEngine,
    drift: DriftMonitor,
    pending_retrain: Option<JobId>,
    baselined: bool,
    updates: u64,
    retrains: u64,
    forgets: u64,
    /// adaptive publish cadence (1 = publish every absorb): stretched
    /// under mailbox pressure by [`StreamSession::set_pressure`];
    /// transient — never persisted, restored sessions start at 1
    publish_stride: u64,
}

impl StreamSession {
    /// `min_train` is clamped to the window capacity — a warmup bar the
    /// window can never reach would otherwise mean a session that
    /// absorbs forever without publishing or arming drift detection.
    pub fn new(name: impl Into<String>, mut cfg: StreamConfig) -> StreamSession {
        cfg.min_train = cfg.min_train.min(cfg.window);
        let name = name.into();
        // cold-path intern so spans/events drained later resolve this
        // stream's id back to its name (no-op while the recorder is off)
        if crate::obs::enabled() {
            crate::obs::intern_stream(&name);
        }
        StreamSession {
            name,
            inc: StreamEngine::new(
                cfg.kernel,
                cfg.window,
                cfg.dim,
                cfg.incremental,
            ),
            drift: DriftMonitor::new(cfg.drift),
            cfg,
            pending_retrain: None,
            baselined: false,
            updates: 0,
            retrains: 0,
            forgets: 0,
            publish_stride: 1,
        }
    }

    /// Registry name this session publishes under.
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn config(&self) -> &StreamConfig {
        &self.cfg
    }

    /// The streaming engine (exact windowed SMO or the lifted
    /// feature-map solver — see [`StreamEngine`]).
    pub fn solver(&self) -> &StreamEngine {
        &self.inc
    }

    pub fn drift_monitor(&self) -> &DriftMonitor {
        &self.drift
    }

    /// Samples absorbed so far.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Completed background retrains.
    pub fn retrains(&self) -> u64 {
        self.retrains
    }

    /// Samples removed by targeted unlearning.
    pub fn forgets(&self) -> u64 {
        self.forgets
    }

    /// Warm = enough samples to publish and watch for drift.
    pub fn is_warm(&self) -> bool {
        self.inc.len() >= self.cfg.min_train
    }

    /// In-flight background retrain, if any.
    pub fn pending_retrain(&self) -> Option<JobId> {
        self.pending_retrain
    }

    /// Record a submitted background retrain.
    pub fn retrain_submitted(&mut self, id: JobId) {
        self.pending_retrain = Some(id);
    }

    /// A background retrain finished: clear the in-flight marker and, on
    /// success, re-baseline drift on the retrained slab offsets.
    pub fn retrain_finished(&mut self, new_rho: Option<(f64, f64)>) {
        self.pending_retrain = None;
        if let Some((r1, r2)) = new_rho {
            self.drift.rebaseline(r1, r2);
            self.retrains += 1;
        }
    }

    /// Copy of the current window contents (background-retrain input).
    pub fn window_dataset(&self) -> Dataset {
        Dataset::unlabeled(self.inc.matrix())
    }

    /// Serialize the session's full resume state to the versioned
    /// binary snapshot format (see [`crate::stream::persist`]):
    /// window samples + ring cursor, dual `(α, ᾱ, s)`, slab offsets,
    /// drift baseline and counters, Gram checksum. Restore with
    /// [`StreamSession::restore`].
    pub fn snapshot(&self) -> Vec<u8> {
        super::persist::Snapshot::capture(self, 1, None).encode()
    }

    /// Resume a session from [`StreamSession::snapshot`] bytes: the
    /// Gram matrix is re-derived from the restored samples (verified
    /// against the stored checksum) and the dual resumes via a
    /// warm-started bounded repair sweep when it does not already
    /// certify — which it does for every snapshot this code writes, so
    /// the restore is normally bitwise exact.
    pub fn restore(bytes: &[u8]) -> crate::Result<StreamSession> {
        let (session, _) = super::persist::Snapshot::decode(bytes)?.into_session()?;
        Ok(session)
    }

    /// The drift baseline has been armed (first warm publish happened).
    pub(crate) fn is_baselined(&self) -> bool {
        self.baselined
    }

    /// Reassemble a session from persisted parts (snapshot restore).
    /// The drift monitor's *rolling* evidence window is deliberately
    /// not persisted — it restarts empty (back in its warmup guard),
    /// while the baseline slab offsets are re-armed, so a restored
    /// stream re-accumulates drift evidence before it can trip.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        name: String,
        mut cfg: StreamConfig,
        inc: StreamEngine,
        baselined: bool,
        baseline: Option<(f64, f64)>,
        updates: u64,
        retrains: u64,
        forgets: u64,
    ) -> StreamSession {
        cfg.min_train = cfg.min_train.min(cfg.window);
        // restored sessions trace like fresh ones: re-intern the name
        if crate::obs::enabled() {
            crate::obs::intern_stream(&name);
        }
        let mut drift = DriftMonitor::new(cfg.drift);
        if let Some((r1, r2)) = baseline {
            drift.rebaseline(r1, r2);
        }
        StreamSession {
            name,
            cfg,
            inc,
            drift,
            pending_retrain: None,
            baselined,
            updates,
            retrains,
            forgets,
            publish_stride: 1,
        }
    }

    /// The trainer an escalated retrain runs with: same hyper-parameters
    /// as the incremental solver, cascade-sharded for throughput, and
    /// the stream's configured compute mode (an `F32` stream runs its
    /// background retrains at certified single precision; the live
    /// absorb path stays f64 regardless).
    pub fn retrain_trainer(&self) -> Trainer {
        Trainer::from_smo_params(self.inc.config().smo)
            .kernel(self.cfg.kernel)
            .cascade(self.cfg.retrain_shards, self.cfg.retrain_rounds)
            .precision(self.inc.config().precision)
    }

    /// Adaptive load response (transient; never persisted or part of
    /// the snapshot fingerprint): `pressure` in `[0, 1]` is this
    /// stream's own mailbox backlog relative to the bound. It scales
    /// the incremental solver's repair iteration budget down (to 25%
    /// at saturation — see
    /// [`IncrementalSmo::set_repair_budget_frac`]) and stretches the
    /// publish cadence to every `1 + ⌈7·pressure⌉`-th absorb, so a hot
    /// drifting tenant trades its *own* model freshness for drain rate
    /// instead of stalling its shard-mates. Pressure `0.0` restores
    /// the configured budget and per-absorb publishing exactly, so an
    /// unloaded stream is bitwise unaffected.
    pub fn set_pressure(&mut self, pressure: f64) {
        let p = if pressure.is_finite() {
            pressure.clamp(0.0, 1.0)
        } else {
            0.0
        };
        self.inc.set_repair_budget_frac(1.0 - 0.75 * p);
        self.publish_stride = 1 + (p * 7.0).ceil() as u64;
    }

    /// Current publish cadence (1 = every absorb; see
    /// [`StreamSession::set_pressure`]).
    pub fn publish_stride(&self) -> u64 {
        self.publish_stride
    }

    /// Absorb one sample: score it against the current slab (drift
    /// evidence), update the dual incrementally, and report.
    pub fn absorb(&mut self, x: &[f64]) -> crate::Result<Absorbed> {
        // an absorb runs a bounded SMO repair — milliseconds of work
        // that must never execute with a serving-stack lock held
        crate::sync::assert_lock_free("session absorb");
        let was_warm = self.is_warm();
        let mut drift_event = None;
        if was_warm {
            let (r1, r2) = self.inc.rho();
            if !self.baselined {
                self.drift.rebaseline(r1, r2);
                self.baselined = true;
            }
            self.drift.observe(self.inc.score(x), r1, r2);
            drift_event = self.drift.check(r1, r2);
        }
        let sample_id = self.inc.push(x)?;
        self.updates += 1;
        // publish-cadence gate: the warm transition always publishes
        // (the first model must land), and pressure only *skips*
        // intermediate hot-swaps — the solver state is identical either
        // way, a skipped publish just keeps serving the last version
        let publish = self.is_warm()
            && (!was_warm
                || self.publish_stride <= 1
                || self.updates % self.publish_stride == 0);
        let model = if publish { Some(self.inc.model()) } else { None };
        Ok(Absorbed {
            model,
            sample_id,
            retrain_wanted: drift_event.is_some()
                && self.pending_retrain.is_none()
                && self.inc.supports_retrain(),
            drift: drift_event,
        })
    }

    /// Targeted unlearning: remove the resident sample with stable id
    /// `id` (the 0-based arrival index this stream assigned it — see
    /// [`Absorbed::sample_id`]), withdraw its dual mass and repair.
    /// Returns the refreshed model for the owner to hot-swap (None when
    /// the shrunk window fell back below the warmup bar — the owner
    /// keeps serving the last published model and the next absorb
    /// re-publishes). Non-resident ids are a typed
    /// [`crate::Error::Unlearning`]; the session is untouched.
    pub fn forget(&mut self, id: u64) -> crate::Result<Forgotten> {
        self.forget_many(std::slice::from_ref(&id))
    }

    /// Batch unlearning: remove every id in `ids` with a **single**
    /// repair sweep and a single refreshed model, instead of the k
    /// repairs and k intermediate hot-swaps sequential
    /// [`StreamSession::forget`] calls would publish. Validation is
    /// all-or-nothing (any non-resident or duplicated id rejects the
    /// whole batch, session untouched); each removed id still counts
    /// individually toward the stream's forget counter.
    pub fn forget_many(&mut self, ids: &[u64]) -> crate::Result<Forgotten> {
        // same repair-scale work as an absorb: no lock may be held here
        crate::sync::assert_lock_free("session forget");
        self.inc.forget_many(ids)?;
        self.forgets += ids.len() as u64;
        let model = if self.is_warm() { Some(self.inc.model()) } else { None };
        Ok(Forgotten {
            model,
            resident: self.inc.len(),
            retrain_stale: self.pending_retrain.is_some(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    fn quick_config() -> StreamConfig {
        StreamConfig {
            window: 64,
            min_train: 32,
            drift: DriftConfig {
                recent: 24,
                min_observations: 12,
                outside_frac: 0.9,
                rho_rel: 10.0, // isolate the outside-fraction signal
            },
            ..Default::default()
        }
    }

    fn feed(session: &mut StreamSession, cfg: &SlabConfig, n: usize, seed: u64) {
        let ds = cfg.generate(n, seed);
        for i in 0..n {
            session.absorb(ds.x.row(i)).unwrap();
        }
    }

    #[test]
    fn warmup_then_publishable_reports() {
        let mut s = StreamSession::new("t", quick_config());
        let ds = SlabConfig::default().generate(40, 51);
        for i in 0..40 {
            let a = s.absorb(ds.x.row(i)).unwrap();
            if i + 1 < 32 {
                assert!(a.model.is_none(), "published during warmup at {i}");
                assert!(a.drift.is_none());
            } else {
                let model = a.model.expect("warm session must publish");
                assert!(model.width() > 0.0);
                // the hot-path model matches the full report's model
                let report = s.solver().report();
                assert_eq!(model.gamma, report.model.gamma);
                assert_eq!(model.rho1, report.model.rho1);
            }
        }
        assert!(s.is_warm());
        assert_eq!(s.updates(), 40);
    }

    #[test]
    fn mean_shift_trips_drift_and_requests_one_retrain() {
        let mut s = StreamSession::new("t", quick_config());
        feed(&mut s, &SlabConfig::default(), 80, 52);
        assert!(s.drift_monitor().baseline().is_some());
        // shift the band a long way BELOW the learned slab: downward
        // shifts land under ρ1 (the ν₁ quantile), which only moves after
        // ~ν₁·window shifted samples — the rolling fraction trips first
        let shifted = SlabConfig { offset: 6.0, ..Default::default() };
        let ds = shifted.generate(60, 53);
        let mut tripped = 0;
        let mut wanted = 0;
        for i in 0..60 {
            let a = s.absorb(ds.x.row(i)).unwrap();
            if a.drift.is_some() {
                tripped += 1;
                if a.retrain_wanted {
                    wanted += 1;
                    s.retrain_submitted(JobId(7)); // owner would submit
                }
            }
        }
        assert!(tripped > 0, "mean shift never tripped the monitor");
        assert_eq!(wanted, 1, "retrain must be requested exactly once");
        assert_eq!(s.pending_retrain(), Some(JobId(7)));
        // completion re-baselines and re-arms
        s.retrain_finished(Some((0.0, 1.0)));
        assert_eq!(s.pending_retrain(), None);
        assert_eq!(s.retrains(), 1);
        assert_eq!(s.drift_monitor().baseline(), Some((0.0, 1.0)));
    }

    #[test]
    fn window_dataset_matches_window() {
        let mut s = StreamSession::new("t", quick_config());
        feed(&mut s, &SlabConfig::default(), 70, 54);
        let snap = s.window_dataset();
        assert_eq!(snap.len(), 64); // window capacity
        assert_eq!(snap.x.data(), s.solver().matrix().data());
    }

    #[test]
    fn snapshot_restore_resumes_bitwise() {
        let mut s = StreamSession::new("t", quick_config());
        feed(&mut s, &SlabConfig::default(), 70, 55);
        let bytes = s.snapshot();
        let r = StreamSession::restore(&bytes).unwrap();
        assert_eq!(r.name(), "t");
        assert_eq!(r.updates(), 70);
        assert_eq!(r.solver().alpha(), s.solver().alpha());
        assert_eq!(r.solver().alpha_bar(), s.solver().alpha_bar());
        let ((a1, a2), (b1, b2)) = (s.solver().rho(), r.solver().rho());
        assert_eq!(a1.to_bits(), b1.to_bits());
        assert_eq!(a2.to_bits(), b2.to_bits());
        assert_eq!(r.drift_monitor().baseline(), s.drift_monitor().baseline());
        // both continue identically on the same future samples
        let ds = SlabConfig::default().generate(20, 56);
        let mut s2 = s;
        let mut r2 = r;
        for i in 0..20 {
            s2.absorb(ds.x.row(i)).unwrap();
            r2.absorb(ds.x.row(i)).unwrap();
        }
        let (so, ro) = (
            s2.solver().report().stats.objective,
            r2.solver().report().stats.objective,
        );
        assert!(
            (so - ro).abs() <= 1e-9 * so.abs().max(1.0),
            "post-restore objective diverged: {so} vs {ro}"
        );
    }

    #[test]
    fn min_train_clamps_to_window_capacity() {
        // a warmup bar above capacity would never be reached — the
        // session must clamp it instead of never publishing
        let s = StreamSession::new(
            "t",
            StreamConfig { window: 32, min_train: 500, ..Default::default() },
        );
        assert_eq!(s.config().min_train, 32);
    }

    #[test]
    fn retrain_trainer_carries_session_params() {
        let s = StreamSession::new("t", quick_config());
        let t = s.retrain_trainer();
        assert_eq!(t.kind(), crate::solver::SolverKind::Smo);
    }

    #[test]
    fn absorb_reports_arrival_index_as_sample_id() {
        let mut s = StreamSession::new("t", quick_config());
        let ds = SlabConfig::default().generate(10, 57);
        for i in 0..10 {
            let a = s.absorb(ds.x.row(i)).unwrap();
            assert_eq!(a.sample_id, i as u64);
        }
    }

    #[test]
    fn forget_shrinks_window_and_republishes_when_warm() {
        let mut s = StreamSession::new("t", quick_config());
        feed(&mut s, &SlabConfig::default(), 70, 58); // window 64, warm
        let id = s.solver().id(5);
        let f = s.forget(id).unwrap();
        assert_eq!(f.resident, 63);
        assert!(f.model.is_some(), "warm session must republish");
        assert_eq!(s.forgets(), 1);
        assert_eq!(s.updates(), 70, "forget is not an update");
        assert_eq!(s.solver().slot_of_id(id), None);
        // non-resident id: typed error, counters untouched
        assert!(matches!(
            s.forget(id).unwrap_err(),
            crate::Error::Unlearning(_)
        ));
        assert_eq!(s.forgets(), 1);
    }

    #[test]
    fn forget_flags_an_in_flight_retrain_as_stale() {
        let mut s = StreamSession::new("t", quick_config());
        feed(&mut s, &SlabConfig::default(), 70, 60);
        let id = s.solver().id(3);
        let clean = s.forget(id).unwrap();
        assert!(!clean.retrain_stale, "no retrain in flight");
        // a pending retrain was trained WITH the next victim: flag it
        s.retrain_submitted(JobId(9));
        let id = s.solver().id(7);
        let stale = s.forget(id).unwrap();
        assert!(stale.retrain_stale, "in-flight retrain must be flagged");
        assert_eq!(s.pending_retrain(), Some(JobId(9)), "owner supersedes");
    }

    #[test]
    fn forget_below_warmup_bar_withholds_the_model() {
        let cfg = StreamConfig { window: 64, min_train: 6, ..quick_config() };
        let mut s = StreamSession::new("t", cfg);
        feed(&mut s, &SlabConfig::default(), 6, 59); // exactly at the bar
        let id = s.solver().id(0);
        let f = s.forget(id).unwrap();
        assert_eq!(f.resident, 5);
        assert!(f.model.is_none(), "below min_train there is no publish");
    }
}
