//! Bounded sample window with an incrementally maintained Gram matrix.
//!
//! The window is the streaming solver's working set: at most `capacity`
//! samples, FIFO eviction once full. The Gram matrix over the resident
//! samples is maintained *incrementally* — admitting a point while
//! growing appends one kernel row/column (O(m·d) kernel evaluations);
//! a steady-state admit overwrites the evicted point's slot in place
//! (same cost), never rebuilding the O(m²) matrix. The window implements
//! [`KernelProvider`], so the SMO repair sweeps of
//! [`crate::stream::incremental`] stream rows straight out of it exactly
//! like batch training streams them out of
//! [`crate::cache::PrecomputedGram`].
//!
//! Slot order is ring order, not arrival order; everything downstream
//! (dual state, margins, models) is row-permutation invariant.

use crate::cache::{CacheStats, KernelProvider};
use crate::kernel::Kernel;
use crate::linalg::Matrix;

/// Bounded FIFO sample buffer + live Gram matrix.
pub struct SlidingWindow {
    kernel: Kernel,
    capacity: usize,
    dim: usize,
    /// resident samples, flattened row-major (`len · dim`)
    points: Vec<f64>,
    /// gram[i][j] = k(x_i, x_j) over resident samples
    gram: Vec<Vec<f64>>,
    /// total samples ever admitted (ring cursor once full)
    admitted: u64,
}

impl SlidingWindow {
    /// Empty window for `dim`-dimensional samples (capacity ≥ 2: the
    /// repair sweeps are pair updates).
    pub fn new(kernel: Kernel, capacity: usize, dim: usize) -> SlidingWindow {
        assert!(capacity >= 2, "streaming window needs at least two slots");
        assert!(dim > 0, "samples must have at least one feature");
        SlidingWindow {
            kernel,
            capacity,
            dim,
            points: Vec::new(),
            gram: Vec::new(),
            admitted: 0,
        }
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Resident sample count (≤ capacity).
    pub fn len(&self) -> usize {
        self.gram.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gram.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.len() == self.capacity
    }

    /// Total samples ever admitted (≥ `len`).
    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Slot the next admit will fill: append position while growing, the
    /// oldest resident sample's slot (FIFO) once full.
    pub fn next_slot(&self) -> usize {
        if self.is_full() {
            (self.admitted % self.capacity as u64) as usize
        } else {
            self.len()
        }
    }

    /// Resident sample `i` (slot order).
    pub fn point(&self, i: usize) -> &[f64] {
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    /// Kernel row of slot `i` against every resident sample.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.gram[i]
    }

    /// Admit `x`. Returns the slot it landed in; while the window is
    /// still growing that is a fresh slot, afterwards it is the evicted
    /// oldest sample's slot (the caller handles the evicted dual mass
    /// *before* calling this — the old row is gone afterwards).
    pub fn admit(&mut self, x: &[f64]) -> usize {
        assert_eq!(x.len(), self.dim, "sample dimension mismatch");
        let slot = self.next_slot();
        if self.is_full() {
            self.points[slot * self.dim..(slot + 1) * self.dim]
                .copy_from_slice(x);
            let m = self.len();
            let mut row = std::mem::take(&mut self.gram[slot]);
            for j in 0..m {
                row[j] = self.kernel.eval(x, self.point(j));
            }
            for j in 0..m {
                if j != slot {
                    self.gram[j][slot] = row[j];
                }
            }
            self.gram[slot] = row;
        } else {
            self.points.extend_from_slice(x);
            let m = self.len() + 1;
            let mut row = Vec::with_capacity(self.capacity);
            for j in 0..m {
                row.push(self.kernel.eval(x, self.point(j)));
            }
            for j in 0..m - 1 {
                self.gram[j].push(row[j]);
            }
            self.gram.push(row);
        }
        self.admitted += 1;
        slot
    }

    /// Dense copy of the resident samples (slot order) — model assembly
    /// and retrain snapshots.
    pub fn matrix(&self) -> Matrix {
        Matrix::from_vec(self.len(), self.dim, self.points.clone())
    }

    /// Rebuild a window from persisted samples (snapshot restore): the
    /// Gram matrix is **re-derived** from the points — it is never
    /// serialized — with the same `kernel.eval` the live path uses, so
    /// the rebuild is bitwise identical to the matrix the snapshot was
    /// taken over (kernel evaluation is symmetric in its arguments at
    /// the bit level). `admitted` restores the FIFO ring cursor so the
    /// next admit overwrites the same slot it would have pre-restart.
    /// The caller (`stream::persist`) validates shapes; this asserts.
    pub(crate) fn restore(
        kernel: Kernel,
        capacity: usize,
        dim: usize,
        points: Vec<f64>,
        admitted: u64,
    ) -> SlidingWindow {
        assert!(capacity >= 2, "streaming window needs at least two slots");
        assert!(dim > 0, "samples must have at least one feature");
        assert_eq!(points.len() % dim, 0, "ragged sample block");
        let m = points.len() / dim;
        assert!(m <= capacity, "more resident samples than capacity");
        let mut w = SlidingWindow {
            kernel,
            capacity,
            dim,
            points,
            gram: Vec::with_capacity(m),
            admitted,
        };
        for i in 0..m {
            let mut row = Vec::with_capacity(m);
            for j in 0..m {
                row.push(kernel.eval(w.point(i), w.point(j)));
            }
            w.gram.push(row);
        }
        w
    }
}

impl KernelProvider for SlidingWindow {
    fn m(&self) -> usize {
        self.len()
    }
    fn diag(&self, i: usize) -> f64 {
        self.gram[i][i]
    }
    fn with_row<R>(&mut self, i: usize, f: &mut dyn FnMut(&[f64]) -> R) -> R {
        f(&self.gram[i])
    }
    fn with_two_rows<R>(
        &mut self,
        a: usize,
        b: usize,
        f: &mut dyn FnMut(&[f64], &[f64]) -> R,
    ) -> R {
        f(&self.gram[a], &self.gram[b])
    }
    fn stats(&self) -> CacheStats {
        CacheStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(w: &mut SlidingWindow, n: usize, rng: &mut Rng) {
        for _ in 0..n {
            let p: Vec<f64> = (0..w.dim()).map(|_| rng.normal()).collect();
            w.admit(&p);
        }
    }

    fn assert_gram_exact(w: &SlidingWindow) {
        let k = w.kernel();
        for i in 0..w.len() {
            assert_eq!(w.row(i).len(), w.len());
            for j in 0..w.len() {
                let want = k.eval(w.point(i), w.point(j));
                assert!(
                    (w.row(i)[j] - want).abs() < 1e-12,
                    "gram[{i}][{j}] stale: {} vs {want}",
                    w.row(i)[j]
                );
            }
        }
    }

    #[test]
    fn grows_then_rings() {
        let mut w = SlidingWindow::new(Kernel::Linear, 4, 3);
        let mut rng = Rng::new(1);
        fill(&mut w, 3, &mut rng);
        assert_eq!(w.len(), 3);
        assert!(!w.is_full());
        assert_eq!(w.next_slot(), 3);
        fill(&mut w, 1, &mut rng);
        assert!(w.is_full());
        // FIFO: next admits overwrite slots 0, 1, 2, 3, 0, ...
        for want in [0usize, 1, 2, 3, 0] {
            assert_eq!(w.next_slot(), want);
            let p: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            assert_eq!(w.admit(&p), want);
        }
        assert_eq!(w.len(), 4);
        assert_eq!(w.admitted(), 9);
    }

    #[test]
    fn gram_stays_exact_through_growth_and_replacement() {
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.3 }] {
            let mut w = SlidingWindow::new(kernel, 6, 2);
            let mut rng = Rng::new(7);
            for step in 0..20 {
                fill(&mut w, 1, &mut rng);
                if step % 3 == 0 {
                    assert_gram_exact(&w);
                }
            }
            assert_gram_exact(&w);
        }
    }

    #[test]
    fn provider_matches_gram() {
        let mut w = SlidingWindow::new(Kernel::Rbf { g: 0.5 }, 5, 2);
        let mut rng = Rng::new(3);
        fill(&mut w, 8, &mut rng); // wrapped
        assert_eq!(w.m(), 5);
        for i in 0..w.m() {
            assert!((w.diag(i) - 1.0).abs() < 1e-12); // RBF diag
        }
        let direct = w.row(1).to_vec();
        w.with_row(1, &mut |r| assert_eq!(r, &direct[..]));
        w.with_two_rows(0, 4, &mut |a, b| {
            assert!((a[4] - b[0]).abs() < 1e-12); // symmetry
        });
    }

    #[test]
    fn matrix_snapshot_matches_points() {
        let mut w = SlidingWindow::new(Kernel::Linear, 3, 2);
        let mut rng = Rng::new(11);
        fill(&mut w, 5, &mut rng);
        let m = w.matrix();
        assert_eq!(m.rows(), 3);
        for i in 0..3 {
            assert_eq!(m.row(i), w.point(i));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_capacity_one() {
        SlidingWindow::new(Kernel::Linear, 1, 2);
    }

    #[test]
    fn restore_rebuilds_gram_bitwise_and_keeps_ring_cursor() {
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.3 }] {
            let mut live = SlidingWindow::new(kernel, 5, 3);
            let mut rng = Rng::new(17);
            fill(&mut live, 13, &mut rng); // wrapped ring
            let mut points = Vec::new();
            for i in 0..live.len() {
                points.extend_from_slice(live.point(i));
            }
            let back = SlidingWindow::restore(
                kernel,
                live.capacity(),
                live.dim(),
                points,
                live.admitted(),
            );
            assert_eq!(back.len(), live.len());
            assert_eq!(back.next_slot(), live.next_slot());
            for i in 0..live.len() {
                for j in 0..live.len() {
                    assert_eq!(
                        back.row(i)[j].to_bits(),
                        live.row(i)[j].to_bits(),
                        "gram[{i}][{j}] not bitwise equal after rebuild"
                    );
                }
            }
        }
    }
}
