//! Bounded sample window with an incrementally maintained Gram matrix.
//!
//! The window is the streaming solver's working set: at most `capacity`
//! samples. The Gram matrix over the resident samples is maintained
//! *incrementally* — admitting a point while growing appends one kernel
//! row/column (O(m·d) kernel evaluations); a steady-state admit
//! overwrites the evicted victim's slot in place (same cost); a
//! targeted [`SlidingWindow::remove`] compacts by swap-remove — never
//! rebuilding the O(m²) matrix. The window implements
//! [`KernelProvider`], so the SMO repair sweeps of
//! [`crate::stream::incremental`] stream rows straight out of it exactly
//! like batch training streams them out of
//! [`crate::cache::PrecomputedGram`].
//!
//! Every admitted sample gets a **stable per-sample id** — its admit
//! sequence number — so callers can address residents by identity
//! (targeted unlearning) and eviction policies can order them by age.
//! Slot order is storage order, not arrival order; everything
//! downstream (dual state, margins, models) is row-permutation
//! invariant, and [`SlidingWindow::remove`]'s swap-remove index mapping
//! (last slot moves into the hole) is the contract the solver's dual
//! vectors mirror.
//!
//! The choice of *which* slot a steady-state admit overwrites belongs
//! to the caller (an [`crate::stream::policy::EvictionPolicy`] over the
//! dual state); [`SlidingWindow::fifo_slot`] — the oldest resident's
//! slot — reproduces the classic ring behavior bitwise: with no
//! targeted removals the smallest id always sits where the old
//! `admitted % capacity` cursor pointed.

use crate::cache::{CacheStats, KernelProvider};
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::util::threadpool;

/// Bounded sample buffer + live Gram matrix + stable per-sample ids.
pub struct SlidingWindow {
    kernel: Kernel,
    capacity: usize,
    dim: usize,
    /// resident samples, flattened row-major (`len · dim`)
    points: Vec<f64>,
    /// gram[i][j] = k(x_i, x_j) over resident samples
    gram: Vec<Vec<f64>>,
    /// per-slot stable sample id (the admit sequence number)
    ids: Vec<u64>,
    /// total samples ever admitted (also the next sample id)
    admitted: u64,
}

impl SlidingWindow {
    /// Empty window for `dim`-dimensional samples (capacity ≥ 2: the
    /// repair sweeps are pair updates).
    pub fn new(kernel: Kernel, capacity: usize, dim: usize) -> SlidingWindow {
        assert!(capacity >= 2, "streaming window needs at least two slots");
        assert!(dim > 0, "samples must have at least one feature");
        SlidingWindow {
            kernel,
            capacity,
            dim,
            points: Vec::new(),
            gram: Vec::new(),
            ids: Vec::new(),
            admitted: 0,
        }
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Resident sample count (≤ capacity).
    pub fn len(&self) -> usize {
        self.gram.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gram.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.len() == self.capacity
    }

    /// Total samples ever admitted (≥ `len`); also the id the next
    /// admitted sample will get.
    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Stable id of the sample in slot `i` (its admit sequence number).
    pub fn id(&self, i: usize) -> u64 {
        self.ids[i]
    }

    /// Per-slot ids (slot order — shares indexing with rows/points).
    pub fn ids(&self) -> &[u64] {
        &self.ids
    }

    /// Slot currently holding the sample with id `id`, if resident.
    pub fn slot_of_id(&self, id: u64) -> Option<usize> {
        self.ids.iter().position(|&v| v == id)
    }

    /// Slot of the oldest resident sample (smallest id) — the classic
    /// FIFO victim, delegated to [`crate::stream::policy::Fifo::oldest`]
    /// so the "bitwise-identical to the pre-policy ring cursor"
    /// contract has exactly one implementation. With no targeted
    /// removals this is exactly where the old `admitted % capacity`
    /// cursor pointed.
    pub fn fifo_slot(&self) -> usize {
        super::policy::Fifo::oldest(&self.ids)
    }

    /// Slot the next FIFO admit will fill: append position while
    /// growing, the oldest resident sample's slot once full.
    pub fn next_slot(&self) -> usize {
        if self.is_full() {
            self.fifo_slot()
        } else {
            self.len()
        }
    }

    /// Resident sample `i` (slot order).
    pub fn point(&self, i: usize) -> &[f64] {
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    /// Kernel row of slot `i` against every resident sample.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.gram[i]
    }

    /// Append `x` into a fresh slot (window must not be full). Returns
    /// the new slot; the sample's id is the admit sequence number.
    pub fn append(&mut self, x: &[f64]) -> usize {
        assert_eq!(x.len(), self.dim, "sample dimension mismatch");
        assert!(!self.is_full(), "append on a full window");
        self.points.extend_from_slice(x);
        let m = self.len() + 1;
        let mut row = Vec::with_capacity(self.capacity);
        for j in 0..m {
            row.push(self.kernel.eval(x, self.point(j)));
        }
        for j in 0..m - 1 {
            self.gram[j].push(row[j]);
        }
        self.gram.push(row);
        self.ids.push(self.admitted);
        self.admitted += 1;
        m - 1
    }

    /// Overwrite `slot` with `x` (the eviction path): the victim's
    /// kernel row/column is recomputed in place and the slot gets a
    /// fresh id. The caller withdraws the victim's dual mass *before*
    /// calling this — the old row is gone afterwards.
    pub fn replace(&mut self, slot: usize, x: &[f64]) {
        assert_eq!(x.len(), self.dim, "sample dimension mismatch");
        assert!(slot < self.len(), "replace of an empty slot");
        self.points[slot * self.dim..(slot + 1) * self.dim]
            .copy_from_slice(x);
        let m = self.len();
        let mut row = std::mem::take(&mut self.gram[slot]);
        for j in 0..m {
            row[j] = self.kernel.eval(x, self.point(j));
        }
        for j in 0..m {
            if j != slot {
                self.gram[j][slot] = row[j];
            }
        }
        self.gram[slot] = row;
        self.ids[slot] = self.admitted;
        self.admitted += 1;
    }

    /// Admit `x` with FIFO eviction: append while growing, overwrite
    /// the oldest resident's slot once full. Returns the slot. (The
    /// incremental solver drives [`SlidingWindow::append`] /
    /// [`SlidingWindow::replace`] directly so its eviction policy can
    /// pick the victim; this convenience keeps the classic shape.)
    pub fn admit(&mut self, x: &[f64]) -> usize {
        if self.is_full() {
            let slot = self.fifo_slot();
            self.replace(slot, x);
            slot
        } else {
            self.append(x)
        }
    }

    /// Targeted removal (unlearning): drop `slot` and compact by
    /// swap-remove — the **last** slot's sample/row/id move into
    /// `slot`, every other slot keeps its index, and the window shrinks
    /// by one. Callers maintaining parallel per-slot state must apply
    /// the same `swap_remove(slot)` mapping. `admitted` is unchanged
    /// (ids stay unique). O(m) — no Gram rebuild.
    pub fn remove(&mut self, slot: usize) {
        let m = self.len();
        assert!(slot < m, "remove of an empty slot");
        let last = m - 1;
        if slot != last {
            let (head, tail) = self.points.split_at_mut(last * self.dim);
            head[slot * self.dim..(slot + 1) * self.dim]
                .copy_from_slice(&tail[..self.dim]);
        }
        self.points.truncate(last * self.dim);
        self.ids.swap_remove(slot);
        // row `last` moves into row `slot`, then column `last` moves
        // into column `slot` of every surviving row — one consistent
        // index relabeling (old index `last` -> `slot`).
        self.gram.swap_remove(slot);
        for row in &mut self.gram {
            row.swap_remove(slot);
        }
    }

    /// Dense copy of the resident samples (slot order) — model assembly
    /// and retrain snapshots.
    pub fn matrix(&self) -> Matrix {
        Matrix::from_vec(self.len(), self.dim, self.points.clone())
    }

    /// Rebuild a window from persisted samples (snapshot restore): the
    /// Gram matrix is **re-derived** from the points — it is never
    /// serialized — through the blocked kernel-row path, which is
    /// bitwise identical per element to the live path's `kernel.eval`
    /// (same lane-blocked contraction, same transform order), so the
    /// rebuild reproduces the matrix the snapshot was taken over
    /// exactly. The O(m²·d) rebuild is parallelized across the process
    /// threadpool — full rows per worker, so the result is thread-count
    /// invariant. `ids` restore the per-slot sample identities (hence
    /// the FIFO age order) and `admitted` the id counter, so the next
    /// admit evicts the same victim and assigns the same id it would
    /// have pre-restart. The caller (`stream::persist`) validates
    /// shapes and id uniqueness; this asserts.
    pub(crate) fn restore(
        kernel: Kernel,
        capacity: usize,
        dim: usize,
        points: Vec<f64>,
        ids: Vec<u64>,
        admitted: u64,
    ) -> SlidingWindow {
        assert!(capacity >= 2, "streaming window needs at least two slots");
        assert!(dim > 0, "samples must have at least one feature");
        assert_eq!(points.len() % dim, 0, "ragged sample block");
        let m = points.len() / dim;
        assert!(m <= capacity, "more resident samples than capacity");
        assert_eq!(ids.len(), m, "one id per resident sample");
        let mut w = SlidingWindow {
            kernel,
            capacity,
            dim,
            points,
            gram: Vec::with_capacity(m),
            ids,
            admitted,
        };
        if m == 0 {
            return w;
        }
        let x = Matrix::from_vec(m, dim, w.points.clone());
        let mut flat = vec![0.0; m * m];
        let threads = threadpool::default_threads();
        threadpool::parallel_rows(&mut flat, m, threads, |start, rows| {
            for (r, out) in rows.chunks_mut(m).enumerate() {
                kernel.row(&x, x.row(start + r), out);
            }
        });
        for row in flat.chunks(m) {
            let mut grow = Vec::with_capacity(capacity);
            grow.extend_from_slice(row);
            w.gram.push(grow);
        }
        w
    }
}

impl KernelProvider for SlidingWindow {
    fn m(&self) -> usize {
        self.len()
    }
    fn diag(&self, i: usize) -> f64 {
        self.gram[i][i]
    }
    fn with_row<R>(&mut self, i: usize, f: &mut dyn FnMut(&[f64]) -> R) -> R {
        f(&self.gram[i])
    }
    fn with_two_rows<R>(
        &mut self,
        a: usize,
        b: usize,
        f: &mut dyn FnMut(&[f64], &[f64]) -> R,
    ) -> R {
        f(&self.gram[a], &self.gram[b])
    }
    fn stats(&self) -> CacheStats {
        CacheStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(w: &mut SlidingWindow, n: usize, rng: &mut Rng) {
        for _ in 0..n {
            let p: Vec<f64> = (0..w.dim()).map(|_| rng.normal()).collect();
            w.admit(&p);
        }
    }

    fn assert_gram_exact(w: &SlidingWindow) {
        let k = w.kernel();
        for i in 0..w.len() {
            assert_eq!(w.row(i).len(), w.len());
            for j in 0..w.len() {
                let want = k.eval(w.point(i), w.point(j));
                assert!(
                    (w.row(i)[j] - want).abs() < 1e-12,
                    "gram[{i}][{j}] stale: {} vs {want}",
                    w.row(i)[j]
                );
            }
        }
    }

    #[test]
    fn grows_then_rings() {
        let mut w = SlidingWindow::new(Kernel::Linear, 4, 3);
        let mut rng = Rng::new(1);
        fill(&mut w, 3, &mut rng);
        assert_eq!(w.len(), 3);
        assert!(!w.is_full());
        assert_eq!(w.next_slot(), 3);
        fill(&mut w, 1, &mut rng);
        assert!(w.is_full());
        // FIFO: next admits overwrite slots 0, 1, 2, 3, 0, ...
        for want in [0usize, 1, 2, 3, 0] {
            assert_eq!(w.next_slot(), want);
            let p: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
            assert_eq!(w.admit(&p), want);
        }
        assert_eq!(w.len(), 4);
        assert_eq!(w.admitted(), 9);
    }

    #[test]
    fn gram_stays_exact_through_growth_and_replacement() {
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.3 }] {
            let mut w = SlidingWindow::new(kernel, 6, 2);
            let mut rng = Rng::new(7);
            for step in 0..20 {
                fill(&mut w, 1, &mut rng);
                if step % 3 == 0 {
                    assert_gram_exact(&w);
                }
            }
            assert_gram_exact(&w);
        }
    }

    #[test]
    fn provider_matches_gram() {
        let mut w = SlidingWindow::new(Kernel::Rbf { g: 0.5 }, 5, 2);
        let mut rng = Rng::new(3);
        fill(&mut w, 8, &mut rng); // wrapped
        assert_eq!(w.m(), 5);
        for i in 0..w.m() {
            assert!((w.diag(i) - 1.0).abs() < 1e-12); // RBF diag
        }
        let direct = w.row(1).to_vec();
        w.with_row(1, &mut |r| assert_eq!(r, &direct[..]));
        w.with_two_rows(0, 4, &mut |a, b| {
            assert!((a[4] - b[0]).abs() < 1e-12); // symmetry
        });
    }

    #[test]
    fn matrix_snapshot_matches_points() {
        let mut w = SlidingWindow::new(Kernel::Linear, 3, 2);
        let mut rng = Rng::new(11);
        fill(&mut w, 5, &mut rng);
        let m = w.matrix();
        assert_eq!(m.rows(), 3);
        for i in 0..3 {
            assert_eq!(m.row(i), w.point(i));
        }
    }

    #[test]
    #[should_panic]
    fn rejects_capacity_one() {
        SlidingWindow::new(Kernel::Linear, 1, 2);
    }

    #[test]
    fn ids_are_admit_sequence_numbers_and_survive_eviction() {
        let mut w = SlidingWindow::new(Kernel::Linear, 3, 2);
        let mut rng = Rng::new(21);
        fill(&mut w, 3, &mut rng);
        assert_eq!(w.ids(), &[0, 1, 2]);
        fill(&mut w, 2, &mut rng); // FIFO overwrites slots 0 then 1
        assert_eq!(w.ids(), &[3, 4, 2]);
        assert_eq!(w.fifo_slot(), 2, "oldest id must be the FIFO victim");
        assert_eq!(w.slot_of_id(4), Some(1));
        assert_eq!(w.slot_of_id(0), None, "evicted id must not resolve");
        assert_eq!(w.admitted(), 5);
    }

    #[test]
    fn remove_compacts_by_swap_remove_and_keeps_gram_exact() {
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.4 }] {
            let mut w = SlidingWindow::new(kernel, 6, 3);
            let mut rng = Rng::new(33);
            fill(&mut w, 8, &mut rng); // wrapped: ids 2..=7
            let last_id = w.id(w.len() - 1);
            let victim_id = w.id(2);
            let moved_point: Vec<f64> = w.point(w.len() - 1).to_vec();
            w.remove(2);
            assert_eq!(w.len(), 5);
            // last slot moved into the hole (swap-remove contract)
            assert_eq!(w.id(2), last_id);
            assert_eq!(w.point(2), &moved_point[..]);
            assert_eq!(w.slot_of_id(victim_id), None);
            assert_gram_exact(&w);
            // a removal below capacity reopens growth: append next
            assert!(!w.is_full());
            assert_eq!(w.next_slot(), 5);
            fill(&mut w, 1, &mut rng);
            assert_eq!(w.id(5), 8);
            assert_gram_exact(&w);
            // removing the last slot is the degenerate swap
            let keep: Vec<u64> = w.ids()[..w.len() - 1].to_vec();
            w.remove(w.len() - 1);
            assert_eq!(w.ids(), &keep[..]);
            assert_gram_exact(&w);
        }
    }

    #[test]
    fn fifo_slot_matches_legacy_ring_cursor_without_removals() {
        // the bitwise-identity contract of the Fifo policy: with no
        // targeted removals, the oldest-id slot IS admitted % capacity
        let mut w = SlidingWindow::new(Kernel::Linear, 5, 2);
        let mut rng = Rng::new(55);
        fill(&mut w, 5, &mut rng);
        for _ in 0..17 {
            assert_eq!(
                w.fifo_slot() as u64,
                w.admitted() % w.capacity() as u64
            );
            fill(&mut w, 1, &mut rng);
        }
    }

    #[test]
    fn restore_rebuilds_gram_bitwise_and_keeps_ring_cursor() {
        for kernel in [Kernel::Linear, Kernel::Rbf { g: 0.3 }] {
            let mut live = SlidingWindow::new(kernel, 5, 3);
            let mut rng = Rng::new(17);
            fill(&mut live, 13, &mut rng); // wrapped ring
            let mut points = Vec::new();
            for i in 0..live.len() {
                points.extend_from_slice(live.point(i));
            }
            let back = SlidingWindow::restore(
                kernel,
                live.capacity(),
                live.dim(),
                points,
                live.ids().to_vec(),
                live.admitted(),
            );
            assert_eq!(back.len(), live.len());
            assert_eq!(back.ids(), live.ids());
            assert_eq!(back.next_slot(), live.next_slot());
            for i in 0..live.len() {
                for j in 0..live.len() {
                    assert_eq!(
                        back.row(i)[j].to_bits(),
                        live.row(i)[j].to_bits(),
                        "gram[{i}][{j}] not bitwise equal after rebuild"
                    );
                }
            }
        }
    }
}
