//! Distribution-drift detection for streamed slab models.
//!
//! Two complementary signals, both O(1) per sample:
//!
//! * **outside fraction** — the rolling fraction of arriving samples
//!   whose margin lands *outside* the current slab `[ρ1, ρ2]`, scored
//!   *before* the sample is absorbed. On in-distribution traffic this
//!   hovers near its construction value ν₁ + ν₂ (the ν-property), so the
//!   threshold is an absolute fraction comfortably above that;
//! * **ρ displacement** — how far the incrementally tracked `(ρ1, ρ2)`
//!   have wandered from the baseline snapshot taken at the last full
//!   retrain, measured in units of the baseline slab width. The
//!   incremental solver *adapts* to drift, so its offsets moving is
//!   itself evidence the data moved.
//!
//! When either signal trips, [`DriftMonitor::check`] yields a
//! [`DriftEvent`]; the owning [`crate::stream::StreamSession`] escalates
//! to a full cascade retrain on the background
//! [`crate::coordinator::TrainQueue`] and re-baselines once the new
//! model lands. With the flight recorder on, each escalation leaves a
//! `retrain_submitted` → `retrain_published` event pair (correlated by
//! job id) in the [`crate::obs`] ring, so drift trips are visible in
//! `slabsvm trace` output without any drift-specific plumbing.

/// Drift-detection thresholds.
#[derive(Clone, Copy, Debug)]
pub struct DriftConfig {
    /// samples in the rolling outside-fraction window
    pub recent: usize,
    /// minimum observations before any verdict (warmup guard)
    pub min_observations: usize,
    /// trip when the rolling outside fraction reaches this (absolute;
    /// pick it above the model's natural ν₁ + ν₂ outside rate)
    pub outside_frac: f64,
    /// trip when |ρ − ρ_baseline| exceeds this multiple of the baseline
    /// slab width, for either plane
    pub rho_rel: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            recent: 128,
            min_observations: 64,
            outside_frac: 0.9,
            rho_rel: 1.0,
        }
    }
}

/// What tripped, with the observed magnitude.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftEvent {
    /// rolling outside-the-slab fraction reached `frac`
    OutsideFraction { frac: f64 },
    /// a slab offset moved `rel` baseline-widths from its snapshot
    RhoDisplacement { rel: f64 },
}

/// Rolling drift state; owned per stream session.
pub struct DriftMonitor {
    cfg: DriftConfig,
    /// ring of outside/inside verdicts for the last `recent` samples
    ring: Vec<bool>,
    head: usize,
    filled: usize,
    outside: usize,
    observed: u64,
    baseline: Option<(f64, f64)>,
}

impl DriftMonitor {
    pub fn new(cfg: DriftConfig) -> DriftMonitor {
        assert!(cfg.recent > 0, "rolling window must be non-empty");
        DriftMonitor {
            cfg,
            ring: vec![false; cfg.recent],
            head: 0,
            filled: 0,
            outside: 0,
            observed: 0,
            baseline: None,
        }
    }

    pub fn config(&self) -> &DriftConfig {
        &self.cfg
    }

    /// Snapshot `(ρ1, ρ2)` as the new reference (call at first fit and
    /// after every completed retrain). Also clears the rolling window so
    /// pre-retrain evidence cannot immediately re-trip.
    pub fn rebaseline(&mut self, rho1: f64, rho2: f64) {
        self.baseline = Some((rho1, rho2));
        self.ring.iter_mut().for_each(|b| *b = false);
        self.head = 0;
        self.filled = 0;
        self.outside = 0;
        self.observed = 0;
    }

    pub fn baseline(&self) -> Option<(f64, f64)> {
        self.baseline
    }

    /// Record one arriving sample's margin vs the current slab.
    pub fn observe(&mut self, score: f64, rho1: f64, rho2: f64) {
        let out = score < rho1 || score > rho2;
        if self.filled == self.ring.len() {
            if self.ring[self.head] {
                self.outside -= 1;
            }
        } else {
            self.filled += 1;
        }
        self.ring[self.head] = out;
        if out {
            self.outside += 1;
        }
        self.head = (self.head + 1) % self.ring.len();
        self.observed += 1;
    }

    /// Rolling outside-the-slab fraction over the last `recent` samples.
    pub fn outside_fraction(&self) -> f64 {
        if self.filled == 0 {
            0.0
        } else {
            self.outside as f64 / self.filled as f64
        }
    }

    /// Evaluate both signals against the current `(ρ1, ρ2)`.
    pub fn check(&self, rho1: f64, rho2: f64) -> Option<DriftEvent> {
        if self.observed < self.cfg.min_observations as u64 {
            return None;
        }
        let frac = self.outside_fraction();
        if frac >= self.cfg.outside_frac {
            return Some(DriftEvent::OutsideFraction { frac });
        }
        if let Some((b1, b2)) = self.baseline {
            let width = (b2 - b1).abs().max(1e-12);
            let rel = ((rho1 - b1).abs() / width).max((rho2 - b2).abs() / width);
            if rel >= self.cfg.rho_rel {
                return Some(DriftEvent::RhoDisplacement { rel });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(recent: usize, min_obs: usize) -> DriftMonitor {
        DriftMonitor::new(DriftConfig {
            recent,
            min_observations: min_obs,
            outside_frac: 0.75,
            rho_rel: 0.5,
        })
    }

    #[test]
    fn warmup_never_trips() {
        let mut m = monitor(8, 16);
        for _ in 0..15 {
            m.observe(-10.0, 0.0, 1.0); // wildly outside
            assert_eq!(m.check(0.0, 1.0), None);
        }
        m.observe(-10.0, 0.0, 1.0);
        assert!(matches!(
            m.check(0.0, 1.0),
            Some(DriftEvent::OutsideFraction { .. })
        ));
    }

    #[test]
    fn outside_fraction_is_rolling() {
        let mut m = monitor(4, 1);
        for _ in 0..4 {
            m.observe(-1.0, 0.0, 1.0); // outside
        }
        assert!((m.outside_fraction() - 1.0).abs() < 1e-12);
        for _ in 0..4 {
            m.observe(0.5, 0.0, 1.0); // inside, evicts the old verdicts
        }
        assert_eq!(m.outside_fraction(), 0.0);
        assert_eq!(m.check(0.0, 1.0), None);
    }

    #[test]
    fn rho_displacement_trips_relative_to_width() {
        let mut m = monitor(8, 1);
        m.rebaseline(0.0, 2.0); // width 2
        for _ in 0..8 {
            m.observe(1.0, 0.0, 2.0); // inside: no outside signal
        }
        assert_eq!(m.check(0.4, 2.0), None); // 0.2 widths < 0.5
        let e = m.check(1.2, 2.0); // 0.6 widths
        assert!(
            matches!(e, Some(DriftEvent::RhoDisplacement { rel }) if rel > 0.5)
        );
    }

    #[test]
    fn rebaseline_clears_evidence() {
        let mut m = monitor(8, 4);
        for _ in 0..8 {
            m.observe(-5.0, 0.0, 1.0);
        }
        assert!(m.check(0.0, 1.0).is_some());
        m.rebaseline(0.0, 1.0);
        assert_eq!(m.outside_fraction(), 0.0);
        assert_eq!(m.check(0.0, 1.0), None); // back in warmup
    }
}
