//! Streaming feature-map engine + the exact/approx stream dispatch.
//!
//! [`ApproxIncremental`] is the lifted-space counterpart of
//! [`IncrementalSmo`]: it keeps the slab dual feasible over the
//! resident set per sample, but on explicit features
//! `φ(x) ∈ R^D` ([`crate::kernel::featmap`]) with the primal weight
//! `w = Σγᵢφᵢ` maintained directly ([`LiftedSlab`]). The costs that
//! matter on an unbounded stream change class:
//!
//! * **absorb** — O(D) structural update + a budgeted repair whose
//!   per-step cost is O(D) (sampled selection above
//!   [`SCAN_LIMIT`] residents), vs the exact engine's O(m·d) Gram row
//!   + O(m) mass transfers;
//! * **score** — one `dot_lifted`, O(d·D), **independent of m** — the
//!   exact engine's O(|SV|·d) grows with the window;
//! * **memory** — O(m·D) lifted rows (a 10⁵×64 window ≈ 51 MB) where
//!   the exact window's Gram is O(m²) (80 GB at m = 10⁵). That is the
//!   scale unlock: window sizes the exact engine cannot hold
//!   (`benches/engine.rs`, experiment KA1).
//!
//! Map lifecycle: RFF is armed at construction (frequencies depend
//! only on (d, D, g, seed)). Nyström warms up with a **growing
//! landmark set** — while m ≤ L every resident is a landmark and each
//! push rebuilds the map (cheap: m ≤ L ≪ stream length), then the
//! landmark set freezes at the first push past L and never changes, so
//! the lifted space is stable from then on. Either way there is no
//! unarmed state: the KKT certificate (in the lifted space) is
//! checkable after **every** op, which `rust/tests/stream_invariants.rs`
//! does.
//!
//! [`StreamEngine`] is the small dispatch enum [`super::session`]
//! holds: exact and approx streams share the session state machine,
//! drift detection, eviction policies, unlearning, and the persist
//! layer (format v3 snapshots carry the engine tag + lifted state).

use std::time::Instant;

use crate::error::Error;
use crate::kernel::featmap::{EngineKind, FeatMap, FeatureMap, NystroemMap};
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::solver::api::FitReport;
use crate::solver::approx::{rff_map, ApproxParams, LiftedSlab};
use crate::solver::ocssvm::SlabModel;
use crate::solver::{validate, SolveStats};
use crate::Result;

use super::incremental::{IncrementalConfig, IncrementalSmo};

/// Abort on a construction-time config bug (`assert!` is the
/// invariant-check form the hot-path lint permits). Streams are opened
/// by operators, not samples — failing at open is the contract.
fn config_abort(msg: &str) -> ! {
    assert!(msg.is_empty(), "{msg}");
    loop {
        std::hint::spin_loop();
    }
}

/// Lifted-space streaming slab: the approx counterpart of
/// [`IncrementalSmo`], same public surface, O(D) absorbs and
/// m-independent scoring.
pub struct ApproxIncremental {
    cfg: IncrementalConfig,
    kernel: Kernel,
    dim: usize,
    capacity: usize,
    map: FeatMap,
    /// Nyström landmark set is final (m grew past L); RFF is always
    /// frozen (its map never depends on the data)
    frozen: bool,
    /// raw resident samples, flat row-major m×dim (landmark warmup
    /// rebuilds, model retrain datasets, snapshots)
    points: Vec<f64>,
    /// stable admit-sequence ids, slot order (same contract as
    /// [`crate::stream::window::SlidingWindow`])
    ids: Vec<u64>,
    admitted: u64,
    core: LiftedSlab,
    stats: SolveStats,
    repair_iterations: u64,
    budget_frac: f64,
    last_admit_us: u64,
    last_repair_us: u64,
    /// reusable φ(x) buffer — the absorb path allocates nothing once
    /// warm (lint rule [[R3]])
    phi_buf: Vec<f64>,
    /// reusable kernel-row scratch for the Nyström map
    scratch: Vec<f64>,
}

impl ApproxIncremental {
    /// Empty lifted streaming solver. `cfg.engine` must be `nystroem`
    /// or `rff`; RFF additionally needs the RBF kernel (its frequency
    /// distribution is the RBF spectral measure) — both are
    /// construction-time config bugs, asserted here so a misconfigured
    /// stream fails at open, not mid-stream.
    pub fn new(
        kernel: Kernel,
        capacity: usize,
        dim: usize,
        cfg: IncrementalConfig,
    ) -> ApproxIncremental {
        assert!(
            cfg.engine != EngineKind::Exact,
            "ApproxIncremental requires a nystroem or rff engine \
             (exact streams use IncrementalSmo)"
        );
        let params = ApproxParams {
            smo: cfg.smo,
            engine: cfg.engine,
            features: cfg.features,
        };
        // RFF: the full map exists before the first sample. Nyström:
        // start from a 1-landmark placeholder at the origin — replaced
        // by the first real push (growing-landmark warmup), never used
        // to lift anything while empty.
        let (map, frozen) = match cfg.engine {
            EngineKind::Rff => match rff_map(&params, kernel, dim) {
                Ok(m) => (m, true),
                Err(e) => config_abort(&format!("rff stream: {e}")),
            },
            _ => match NystroemMap::new(kernel, Matrix::zeros(1, dim)) {
                Ok(m) => (FeatMap::Nystroem(m), false),
                Err(e) => config_abort(&format!("nystroem warmup map: {e}")),
            },
        };
        let d_out = map.d_out();
        let scratch = vec![0.0; map.scratch_len().max(1)];
        ApproxIncremental {
            core: LiftedSlab::new(d_out, &cfg.smo),
            cfg,
            kernel,
            dim,
            capacity,
            map,
            frozen,
            points: Vec::with_capacity(capacity * dim),
            ids: Vec::with_capacity(capacity),
            admitted: 0,
            stats: SolveStats::default(),
            repair_iterations: 0,
            budget_frac: 1.0,
            last_admit_us: 0,
            last_repair_us: 0,
            phi_buf: vec![0.0; d_out],
            scratch,
        }
    }

    /// Reassemble from persisted state (snapshot restore, format v3).
    /// `map` must already be rebuilt/decoded; `core` is the restored
    /// lifted dual. The caller (`stream::persist`) validates shapes.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn restore(
        kernel: Kernel,
        capacity: usize,
        dim: usize,
        cfg: IncrementalConfig,
        map: FeatMap,
        frozen: bool,
        points: Vec<f64>,
        ids: Vec<u64>,
        admitted: u64,
        core: LiftedSlab,
        repair_iterations: u64,
    ) -> ApproxIncremental {
        let d_out = map.d_out();
        let scratch = vec![0.0; map.scratch_len().max(1)];
        ApproxIncremental {
            core,
            cfg,
            kernel,
            dim,
            capacity,
            map,
            frozen,
            points,
            ids,
            admitted,
            stats: SolveStats::default(),
            repair_iterations,
            budget_frac: 1.0,
            last_admit_us: 0,
            last_repair_us: 0,
            phi_buf: vec![0.0; d_out],
            scratch,
        }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn config(&self) -> &IncrementalConfig {
        &self.cfg
    }

    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The live feature map (landmarks may still be growing while
    /// `!is_frozen`).
    pub fn featmap(&self) -> &FeatMap {
        &self.map
    }

    /// Nyström landmark set is final (always true for RFF).
    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// The lifted dual core (weights, multipliers, margins).
    pub fn core(&self) -> &LiftedSlab {
        &self.core
    }

    pub fn rho(&self) -> (f64, f64) {
        self.core.rho()
    }

    pub fn alpha(&self) -> &[f64] {
        self.core.alpha()
    }

    pub fn alpha_bar(&self) -> &[f64] {
        self.core.alpha_bar()
    }

    /// Cached lifted margins (slot order).
    pub fn margins(&self) -> &[f64] {
        self.core.margins()
    }

    /// Margins recomputed exactly from `w` (what certificates and
    /// snapshots use).
    pub fn fresh_margins(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.core.margin_of(i)).collect()
    }

    pub fn last_stats(&self) -> &SolveStats {
        &self.stats
    }

    pub fn repair_iterations(&self) -> u64 {
        self.repair_iterations
    }

    /// Wall-clock split of the most recent push, `(admit_us,
    /// repair_us)` — same contract as
    /// [`IncrementalSmo::last_stage_us`].
    pub fn last_stage_us(&self) -> (u64, u64) {
        (self.last_admit_us, self.last_repair_us)
    }

    /// Scale the per-repair iteration budget; same clamp contract as
    /// [`IncrementalSmo::set_repair_budget_frac`].
    pub fn set_repair_budget_frac(&mut self, frac: f64) {
        self.budget_frac =
            if frac.is_finite() { frac.clamp(0.25, 1.0) } else { 1.0 };
    }

    /// Stable ids in slot order.
    pub fn ids(&self) -> &[u64] {
        &self.ids
    }

    /// Stable id of slot `i`.
    pub fn id(&self, i: usize) -> u64 {
        self.ids.get(i).copied().unwrap_or(u64::MAX)
    }

    /// Slot currently holding stable id `id`.
    pub fn slot_of_id(&self, id: u64) -> Option<usize> {
        self.ids.iter().position(|&v| v == id)
    }

    /// Samples admitted over the stream's lifetime.
    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    /// Raw resident sample in slot `i`.
    pub fn point(&self, i: usize) -> &[f64] {
        let start = i * self.dim;
        self.points.get(start..start + self.dim).unwrap_or(&[])
    }

    /// Copy of the resident samples as a matrix (retrain datasets,
    /// snapshots).
    pub fn matrix(&self) -> Matrix {
        Matrix::from_vec(self.len(), self.dim, self.points.clone())
    }

    /// Score an arbitrary point under the current lifted dual:
    /// `⟨w, φ(x)⟩` — O(d·D), **independent of the resident count** (the
    /// property experiment KA1 pins).
    pub fn score(&self, x: &[f64]) -> f64 {
        self.map.dot_lifted(x, self.core.weights())
    }

    fn effective_repair_budget(&self) -> usize {
        let scaled =
            (self.cfg.repair_max_iter as f64 * self.budget_frac) as usize;
        scaled.max(1024).min(self.cfg.repair_max_iter.max(1))
    }

    /// Absorb one sample: lift, admit (evicting the configured
    /// policy's victim once full), repair, all in lifted space.
    /// Returns the absorbed sample's stable id — the same contract as
    /// [`IncrementalSmo::push`].
    pub fn push(&mut self, x: &[f64]) -> Result<u64> {
        if x.len() != self.dim {
            return Err(Error::data(format!(
                "sample dim {} != stream dim {}",
                x.len(),
                self.dim
            )));
        }
        let t0 = Instant::now();
        let id = self.admitted;
        if self.len() >= self.capacity.max(1) {
            // steady state: policy picks the victim, the newcomer takes
            // its slot AND its multipliers (exact transfer, O(D))
            let victim = self.cfg.policy.policy().victim(
                &self.ids,
                self.core.alpha(),
                self.core.alpha_bar(),
            );
            crate::obs::record(
                crate::obs::EventKind::Evict,
                0,
                0,
                u32::MAX,
                self.id(victim),
            );
            self.lift_into_buf(x);
            let row = std::mem::take(&mut self.phi_buf);
            self.core.replace_row(victim, &row);
            self.phi_buf = row;
            let start = victim * self.dim;
            if let Some(slot) = self.points.get_mut(start..start + self.dim) {
                slot.copy_from_slice(x);
            }
            if let Some(slot) = self.ids.get_mut(victim) {
                *slot = id;
            }
        } else if self.frozen {
            // growth phase, stable map: O(D) rescale-push
            self.lift_into_buf(x);
            let row = std::mem::take(&mut self.phi_buf);
            self.core.push_grown(&row);
            self.phi_buf = row;
            self.points.extend_from_slice(x);
            self.ids.push(id);
        } else {
            // Nyström warmup: the newcomer joins the landmark set and
            // the whole lifted state rebuilds in the grown space
            self.points.extend_from_slice(x);
            self.ids.push(id);
            self.grow_landmarks()?;
        }
        self.admitted += 1;
        if self.admitted % self.cfg.refresh_every.max(1) == 0 {
            self.core.refresh_margins();
        }
        self.last_admit_us = t0.elapsed().as_micros() as u64;
        let t1 = Instant::now();
        let used = self.core.repair(self.effective_repair_budget());
        self.last_repair_us = t1.elapsed().as_micros() as u64;
        self.repair_iterations += used as u64;
        self.stats = SolveStats {
            iterations: used,
            objective: self.core.objective(),
            max_violation: 0.0,
            seconds: t1.elapsed().as_secs_f64(),
            ..SolveStats::default()
        };
        Ok(id)
    }

    /// φ(x) into the reusable buffer (no allocation).
    fn lift_into_buf(&mut self, x: &[f64]) {
        self.map.map_into(x, &mut self.scratch, &mut self.phi_buf);
    }

    /// Growing-landmark warmup step: rebuild the map with landmarks =
    /// **all** residents (the newest included), re-lift every resident
    /// into the grown space, and transfer the dual by the same
    /// m/(m+1) rescale the frozen push uses — feasibility is exact,
    /// optimality is restored by the caller's repair. Freezes the
    /// landmark set once m reaches the configured budget.
    fn grow_landmarks(&mut self) -> Result<()> {
        let m = self.len();
        let x = Matrix::from_vec(m, self.dim, self.points.clone());
        let map = NystroemMap::new(self.kernel, x.clone())?;
        let d_out = map.d_out();
        self.map = FeatMap::Nystroem(map);
        self.scratch.resize(self.map.scratch_len().max(1), 0.0);
        self.phi_buf.resize(d_out, 0.0);
        let phi = self.map.map_rows(&x);
        // rescale the previous dual to the grown m and seed the
        // newcomer exactly as push_grown does — in the NEW space
        let mf = m as f64;
        let f = (m - 1) as f64 / mf;
        let mut alpha: Vec<f64> =
            self.core.alpha().iter().map(|a| a * f).collect();
        let mut alpha_bar: Vec<f64> =
            self.core.alpha_bar().iter().map(|b| b * f).collect();
        if m == 1 {
            alpha.push(1.0);
            alpha_bar.push(self.core.eps());
        } else {
            alpha.push(1.0 / mf);
            alpha_bar.push(self.core.eps() / mf);
        }
        let (rho1, rho2) = self.core.rho();
        self.core = LiftedSlab::restore(
            d_out,
            &self.cfg.smo,
            phi.data().to_vec(),
            alpha,
            alpha_bar,
            rho1,
            rho2,
        );
        if m >= self.cfg.features.max(1) {
            self.frozen = true;
        }
        Ok(())
    }

    /// Targeted unlearning by stable id — same contract and error
    /// taxonomy as [`IncrementalSmo::forget`].
    pub fn forget(&mut self, id: u64) -> Result<()> {
        self.forget_many(std::slice::from_ref(&id))
    }

    /// Batch unlearning with a single repair sweep — same
    /// all-or-nothing validation as [`IncrementalSmo::forget_many`].
    /// In the lifted space a removal is O(D): withdraw the victim's γ
    /// from `w`, swap-remove its row, redistribute its mass under the
    /// grown caps.
    pub fn forget_many(&mut self, ids: &[u64]) -> Result<()> {
        if ids.is_empty() {
            return Ok(());
        }
        let mut bad: Option<(u64, bool)> = None;
        for (k, &id) in ids.iter().enumerate() {
            if self.slot_of_id(id).is_none() {
                bad = Some((id, false));
                break;
            }
            if ids.get(..k).is_some_and(|seen| seen.contains(&id)) {
                bad = Some((id, true));
                break;
            }
        }
        if let Some((id, duplicate)) = bad {
            return Err(Error::unlearning(if duplicate {
                format!("sample id {id} appears twice in the forget batch")
            } else {
                format!(
                    "sample id {id} is not resident (never admitted, already \
                     evicted, or already forgotten)"
                )
            }));
        }
        if self.len() <= ids.len() {
            return Err(Error::unlearning(format!(
                "cannot forget all {} resident samples: an empty window has \
                 no feasible dual (close the stream instead)",
                self.len()
            )));
        }
        for &id in ids {
            // re-resolve per iteration: earlier swap-removes remap slots
            let Some(slot) = self.slot_of_id(id) else { continue };
            self.core.remove_row(slot);
            let m = self.len();
            let last = m - 1;
            if slot != last {
                let src = last * self.dim;
                self.points.copy_within(src..src + self.dim, slot * self.dim);
            }
            self.points.truncate(last * self.dim);
            self.ids.swap_remove(slot);
        }
        let used = self.core.repair(self.effective_repair_budget());
        self.repair_iterations += used as u64;
        Ok(())
    }

    /// The current model — Nyström folds to a plain kernel model over
    /// its ≤ L landmarks, RFF carries its map; either way model size
    /// and scoring cost are independent of the resident count.
    pub fn model(&self) -> SlabModel {
        crate::solver::approx::export_model(
            &self.core,
            &self.map,
            self.cfg.smo.sv_tol,
        )
    }

    /// The uniform [`FitReport`] with the KKT certificate evaluated on
    /// **fresh lifted margins** — the exact engine's checker applied in
    /// the space the slab was actually trained in.
    pub fn report(&self) -> FitReport {
        let p = &self.cfg.smo;
        let m = self.len().max(1) as f64;
        let cap_a = 1.0 / (p.nu1 * m);
        let cap_b = p.eps / (p.nu2 * m);
        let s = self.fresh_margins();
        let (rho1, rho2) = self.core.rho();
        let cls_tol = cap_a.min(cap_b) * 1e-6;
        let certificate = validate::report_with_margins(
            self.core.alpha(),
            self.core.alpha_bar(),
            &s,
            rho1,
            rho2,
            p.nu1,
            p.nu2,
            p.eps,
            cls_tol,
        );
        let alpha = self.core.alpha().to_vec();
        let alpha_bar = self.core.alpha_bar().to_vec();
        let gamma: Vec<f64> =
            alpha.iter().zip(&alpha_bar).map(|(a, b)| a - b).collect();
        let mut stats = self.stats;
        stats.objective = self.core.objective();
        stats.max_violation = certificate.max_kkt_violation;
        FitReport {
            model: self.model(),
            dual: crate::solver::api::DualSolution {
                alpha,
                alpha_bar,
                gamma,
                s,
                rho1,
                rho2,
            },
            stats,
            certificate,
            cascade: None,
            precision: crate::kernel::Precision::F64,
            fell_back: false,
        }
    }
}

// ------------------------------------------------------ StreamEngine

/// The per-stream training engine: exact windowed SMO or the lifted
/// feature-map solver, behind one dispatch so
/// [`super::session::StreamSession`] and the persist layer are
/// engine-agnostic.
pub enum StreamEngine {
    /// Exact Gram-windowed incremental SMO.
    Exact(IncrementalSmo),
    /// Lifted feature-map engine (Nyström / RFF).
    Approx(ApproxIncremental),
}

impl StreamEngine {
    /// Construct the engine `cfg.engine` names.
    pub fn new(
        kernel: Kernel,
        capacity: usize,
        dim: usize,
        cfg: IncrementalConfig,
    ) -> StreamEngine {
        match cfg.engine {
            EngineKind::Exact => StreamEngine::Exact(IncrementalSmo::new(
                kernel, capacity, dim, cfg,
            )),
            _ => StreamEngine::Approx(ApproxIncremental::new(
                kernel, capacity, dim, cfg,
            )),
        }
    }

    /// Which engine is running.
    pub fn engine_kind(&self) -> EngineKind {
        match self {
            StreamEngine::Exact(_) => EngineKind::Exact,
            StreamEngine::Approx(a) => a.config().engine,
        }
    }

    /// The exact engine, when that is what is running.
    pub fn as_exact(&self) -> Option<&IncrementalSmo> {
        match self {
            StreamEngine::Exact(e) => Some(e),
            StreamEngine::Approx(_) => None,
        }
    }

    /// The approx engine, when that is what is running.
    pub fn as_approx(&self) -> Option<&ApproxIncremental> {
        match self {
            StreamEngine::Exact(_) => None,
            StreamEngine::Approx(a) => Some(a),
        }
    }

    /// Whether drift-escalated cascade retrains make sense for this
    /// engine: the exact stream's retrain re-solves the window batch;
    /// the approx engine has no batch retrain path yet (its repair IS
    /// the optimizer), so sessions suppress retrain escalation.
    pub fn supports_retrain(&self) -> bool {
        matches!(self, StreamEngine::Exact(_))
    }

    pub fn len(&self) -> usize {
        match self {
            StreamEngine::Exact(e) => e.len(),
            StreamEngine::Approx(a) => a.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn config(&self) -> &IncrementalConfig {
        match self {
            StreamEngine::Exact(e) => e.config(),
            StreamEngine::Approx(a) => a.config(),
        }
    }

    pub fn rho(&self) -> (f64, f64) {
        match self {
            StreamEngine::Exact(e) => e.rho(),
            StreamEngine::Approx(a) => a.rho(),
        }
    }

    pub fn alpha(&self) -> &[f64] {
        match self {
            StreamEngine::Exact(e) => e.alpha(),
            StreamEngine::Approx(a) => a.alpha(),
        }
    }

    pub fn alpha_bar(&self) -> &[f64] {
        match self {
            StreamEngine::Exact(e) => e.alpha_bar(),
            StreamEngine::Approx(a) => a.alpha_bar(),
        }
    }

    pub fn margins(&self) -> &[f64] {
        match self {
            StreamEngine::Exact(e) => e.margins(),
            StreamEngine::Approx(a) => a.margins(),
        }
    }

    pub fn fresh_margins(&self) -> Vec<f64> {
        match self {
            StreamEngine::Exact(e) => e.fresh_margins(),
            StreamEngine::Approx(a) => a.fresh_margins(),
        }
    }

    pub fn last_stats(&self) -> &SolveStats {
        match self {
            StreamEngine::Exact(e) => e.last_stats(),
            StreamEngine::Approx(a) => a.last_stats(),
        }
    }

    pub fn repair_iterations(&self) -> u64 {
        match self {
            StreamEngine::Exact(e) => e.repair_iterations(),
            StreamEngine::Approx(a) => a.repair_iterations(),
        }
    }

    pub fn last_stage_us(&self) -> (u64, u64) {
        match self {
            StreamEngine::Exact(e) => e.last_stage_us(),
            StreamEngine::Approx(a) => a.last_stage_us(),
        }
    }

    pub fn set_repair_budget_frac(&mut self, frac: f64) {
        match self {
            StreamEngine::Exact(e) => e.set_repair_budget_frac(frac),
            StreamEngine::Approx(a) => a.set_repair_budget_frac(frac),
        }
    }

    pub fn score(&self, x: &[f64]) -> f64 {
        match self {
            StreamEngine::Exact(e) => e.score(x),
            StreamEngine::Approx(a) => a.score(x),
        }
    }

    pub fn push(&mut self, x: &[f64]) -> Result<u64> {
        match self {
            StreamEngine::Exact(e) => e.push(x),
            StreamEngine::Approx(a) => a.push(x),
        }
    }

    pub fn forget(&mut self, id: u64) -> Result<()> {
        match self {
            StreamEngine::Exact(e) => e.forget(id),
            StreamEngine::Approx(a) => a.forget(id),
        }
    }

    pub fn forget_many(&mut self, ids: &[u64]) -> Result<()> {
        match self {
            StreamEngine::Exact(e) => e.forget_many(ids),
            StreamEngine::Approx(a) => a.forget_many(ids),
        }
    }

    pub fn model(&self) -> SlabModel {
        match self {
            StreamEngine::Exact(e) => e.model(),
            StreamEngine::Approx(a) => a.model(),
        }
    }

    pub fn report(&self) -> FitReport {
        match self {
            StreamEngine::Exact(e) => e.report(),
            StreamEngine::Approx(a) => a.report(),
        }
    }

    /// Copy of the resident samples (retrain datasets, snapshots).
    pub fn matrix(&self) -> Matrix {
        match self {
            StreamEngine::Exact(e) => e.window().matrix(),
            StreamEngine::Approx(a) => a.matrix(),
        }
    }

    /// Stable ids in slot order.
    pub fn ids(&self) -> Vec<u64> {
        match self {
            StreamEngine::Exact(e) => e.window().ids().to_vec(),
            StreamEngine::Approx(a) => a.ids().to_vec(),
        }
    }

    /// Stable id of slot `i`.
    pub fn id(&self, i: usize) -> u64 {
        match self {
            StreamEngine::Exact(e) => e.window().id(i),
            StreamEngine::Approx(a) => a.id(i),
        }
    }

    /// Slot currently holding stable id `id`.
    pub fn slot_of_id(&self, id: u64) -> Option<usize> {
        match self {
            StreamEngine::Exact(e) => e.window().slot_of_id(id),
            StreamEngine::Approx(a) => a.slot_of_id(id),
        }
    }

    /// Samples admitted over the stream's lifetime.
    pub fn admitted(&self) -> u64 {
        match self {
            StreamEngine::Exact(e) => e.window().admitted(),
            StreamEngine::Approx(a) => a.admitted(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;
    use crate::stream::policy::PolicyKind;

    fn cfg(engine: EngineKind, features: usize) -> IncrementalConfig {
        IncrementalConfig { engine, features, ..IncrementalConfig::default() }
    }

    fn feasible(a: &ApproxIncremental, ctx: &str) {
        let m = a.len();
        if m == 0 {
            return;
        }
        let p = &a.config().smo;
        let cap_a = 1.0 / (p.nu1 * m as f64);
        let cap_b = p.eps / (p.nu2 * m as f64);
        let sa: f64 = a.alpha().iter().sum();
        let sb: f64 = a.alpha_bar().iter().sum();
        assert!((sa - 1.0).abs() < 1e-9, "{ctx}: sum alpha {sa}");
        assert!((sb - p.eps).abs() < 1e-9, "{ctx}: sum abar {sb}");
        for (&x, &y) in a.alpha().iter().zip(a.alpha_bar()) {
            assert!(x >= -1e-12 && x <= cap_a + 1e-12, "{ctx}: alpha {x}");
            assert!(y >= -1e-12 && y <= cap_b + 1e-12, "{ctx}: abar {y}");
        }
    }

    #[test]
    fn lifecycle_grow_steady_forget_both_engines() {
        let ds = SlabConfig::default().generate(60, 3);
        for engine in [EngineKind::Nystroem, EngineKind::Rff] {
            let mut a = ApproxIncremental::new(
                Kernel::Rbf { g: 0.5 },
                24,
                2,
                cfg(engine, 8),
            );
            let mut kept = Vec::new();
            for i in 0..40 {
                let id = a.push(ds.x.row(i)).unwrap();
                if i % 7 == 0 {
                    kept.push(id);
                }
                feasible(&a, &format!("{engine:?} push {i}"));
            }
            assert_eq!(a.len(), 24);
            assert_eq!(a.admitted(), 40);
            // forget still-resident ids only
            let resident: Vec<u64> = kept
                .into_iter()
                .filter(|&id| a.slot_of_id(id).is_some())
                .take(2)
                .collect();
            if !resident.is_empty() {
                a.forget_many(&resident).unwrap();
                feasible(&a, &format!("{engine:?} after forget"));
            }
            let r = a.report();
            assert!(r.certificate.sum_alpha_violation < 1e-9, "{engine:?}");
            assert!(r.certificate.max_box_violation < 1e-12, "{engine:?}");
        }
    }

    #[test]
    fn nystroem_landmarks_freeze_at_budget() {
        let ds = SlabConfig::default().generate(30, 5);
        let mut a = ApproxIncremental::new(
            Kernel::Linear,
            20,
            2,
            cfg(EngineKind::Nystroem, 6),
        );
        for i in 0..4 {
            a.push(ds.x.row(i)).unwrap();
        }
        assert!(!a.is_frozen(), "still warming: m < L");
        for i in 4..10 {
            a.push(ds.x.row(i)).unwrap();
        }
        assert!(a.is_frozen(), "past the landmark budget");
        let l = match a.featmap() {
            FeatMap::Nystroem(n) => n.landmarks().rows(),
            FeatMap::Rff(_) => unreachable!("nystroem stream"),
        };
        assert_eq!(l, 6);
        // frozen landmarks never change afterwards
        for i in 10..20 {
            a.push(ds.x.row(i)).unwrap();
        }
        let l2 = match a.featmap() {
            FeatMap::Nystroem(n) => n.landmarks().rows(),
            FeatMap::Rff(_) => unreachable!("nystroem stream"),
        };
        assert_eq!(l2, 6);
    }

    #[test]
    fn scoring_is_resident_count_independent_in_shape() {
        // the model exported at m=8 and m=64 has identical scoring
        // structure (same n_sv bound) — the structural half of KA1
        let ds = SlabConfig::default().generate(80, 9);
        let mut a = ApproxIncremental::new(
            Kernel::Rbf { g: 0.5 },
            64,
            2,
            cfg(EngineKind::Rff, 16),
        );
        for i in 0..8 {
            a.push(ds.x.row(i)).unwrap();
        }
        let small = a.model();
        for i in 8..80 {
            a.push(ds.x.row(i)).unwrap();
        }
        let large = a.model();
        assert_eq!(small.n_sv(), 1);
        assert_eq!(large.n_sv(), 1);
        assert_eq!(small.x_sv.cols(), large.x_sv.cols());
    }

    #[test]
    fn forget_rejects_bad_ids_untouched() {
        let ds = SlabConfig::default().generate(10, 7);
        let mut a = ApproxIncremental::new(
            Kernel::Rbf { g: 0.5 },
            8,
            2,
            cfg(EngineKind::Rff, 8),
        );
        for i in 0..6 {
            a.push(ds.x.row(i)).unwrap();
        }
        let before: Vec<f64> = a.alpha().to_vec();
        assert!(a.forget(999).is_err());
        assert!(a.forget_many(&[0, 0]).is_err());
        assert!(a.forget_many(&[0, 1, 2, 3, 4, 5]).is_err());
        assert_eq!(a.alpha(), &before[..], "rejected ops must not mutate");
    }

    #[test]
    fn stream_engine_dispatch_round_trip() {
        let ds = SlabConfig::default().generate(20, 11);
        let mut exact = StreamEngine::new(
            Kernel::Linear,
            16,
            2,
            IncrementalConfig::default(),
        );
        let mut approx = StreamEngine::new(
            Kernel::Rbf { g: 0.5 },
            16,
            2,
            cfg(EngineKind::Rff, 8),
        );
        assert!(exact.as_exact().is_some() && exact.as_approx().is_none());
        assert!(approx.as_approx().is_some() && approx.as_exact().is_none());
        assert!(exact.supports_retrain());
        assert!(!approx.supports_retrain());
        for i in 0..10 {
            exact.push(ds.x.row(i)).unwrap();
            approx.push(ds.x.row(i)).unwrap();
        }
        assert_eq!(exact.len(), 10);
        assert_eq!(approx.len(), 10);
        assert_eq!(exact.ids().len(), 10);
        assert_eq!(approx.admitted(), 10);
        assert_eq!(approx.matrix().rows(), 10);
        let _ = exact.model();
        let _ = approx.model();
    }

    #[test]
    fn interior_first_policy_composes_with_approx() {
        let ds = SlabConfig::default().generate(40, 13);
        let mut a = ApproxIncremental::new(
            Kernel::Rbf { g: 0.5 },
            12,
            2,
            IncrementalConfig {
                policy: PolicyKind::InteriorFirst,
                ..cfg(EngineKind::Nystroem, 8)
            },
        );
        for i in 0..40 {
            a.push(ds.x.row(i)).unwrap();
            feasible(&a, &format!("interior-first push {i}"));
        }
        assert_eq!(a.len(), 12);
    }
}
