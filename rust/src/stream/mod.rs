//! Streaming OCSSVM (the L4 online-learning layer).
//!
//! Everything below `stream::` keeps a slab model current over an
//! unbounded sample stream instead of a static batch:
//!
//! * [`window::SlidingWindow`] — bounded FIFO sample buffer with an
//!   incrementally maintained Gram matrix (admit appends a kernel
//!   row/column, steady-state eviction overwrites one slot in place),
//!   exposed to the solver core as a [`crate::cache::KernelProvider`];
//! * [`incremental::IncrementalSmo`] — per-sample **add** (the new
//!   point's dual weight is seeded at the clipped box midpoint, paid for
//!   by mass-conserving transfers from donors) and **decremental
//!   remove** (the evicted point's α/ᾱ mass is redistributed to
//!   in-window points with box headroom), each followed by a bounded
//!   number of warm-started SMO repair sweeps
//!   ([`crate::solver::smo::solve_from`]) that restore KKT within
//!   `tol`. Results surface as the same
//!   [`crate::solver::FitReport`] batch training produces, so the KKT
//!   [`certificate`](crate::solver::validate::Certificate) keeps
//!   working;
//! * [`drift::DriftMonitor`] — rolling outside-the-slab fraction and
//!   `(ρ1, ρ2)` displacement vs a baseline; trips a [`drift::DriftEvent`]
//!   when the stream no longer looks like the data the slab was fit on;
//! * [`session::StreamSession`] — the per-stream state machine the
//!   [`crate::coordinator::Coordinator`] owns: each absorbed sample
//!   atomically hot-swaps the published model version in the
//!   [`crate::coordinator::ModelRegistry`], and a tripped drift monitor
//!   escalates to a full cascade retrain on the
//!   [`crate::coordinator::TrainQueue`] (background — scoring through
//!   the [`crate::coordinator::DynamicBatcher`] never stalls);
//! * [`manager::StreamManager`] — the sharded multi-stream session
//!   manager: sessions hashed across N shard worker threads by stream
//!   name, per-stream bounded queues with blocking backpressure, and
//!   weighted-fair scheduling within a shard so one hot tenant cannot
//!   starve its shard-mates. `Coordinator::open_streams` / `push` /
//!   `close_stream` are the front door (experiment MS1,
//!   `rust/benches/streaming.rs`);
//! * [`policy`] — pluggable window eviction: [`policy::Fifo`] (oldest
//!   first — bitwise-identical to the classic ring window) and
//!   [`policy::InteriorFirst`] (evict the smallest-|α−ᾱ| resident so
//!   support vectors stay — a smaller window holds the accuracy of a
//!   larger FIFO one, experiment WP1). The same arbitrary-slot removal
//!   path powers **targeted unlearning**: [`session::StreamSession::forget`]
//!   (and `Coordinator::forget` / `slabsvm forget`) removes any
//!   resident sample by its stable id, withdraws its dual mass via the
//!   eviction path's headroom-greedy redistribution and repairs —
//!   "forget user X" at the cost of one warm-started sweep;
//! * [`persist`] — durable sessions: a versioned, self-describing
//!   binary snapshot of a session's window + dual state + drift
//!   baseline, restored via Gram re-derivation (checksum-verified) and
//!   a warm-started repair sweep. Shard workers checkpoint
//!   periodically (atomic temp-file + rename writes on a dedicated
//!   writer thread); `Coordinator::snapshot_streams` /
//!   `restore_streams` resume a whole multi-tenant fleet after a
//!   restart without cold window refills (experiment PS1).
//!
//! The whole layer is traced end to end by [`crate::obs`] (DESIGN.md
//! §8): a trace id minted at `Coordinator::push` rides the shard
//! mailbox with its sample, and the owning shard records contiguous
//! Queue→Absorb→Publish spans (with Gram/Repair sub-spans from the
//! solver's own stage split) plus typed flight-recorder events for
//! evictions, forgets, retrain hand-offs, checkpoints, backpressure
//! and worker exits. Disabled (the default), the recorder costs one
//! relaxed atomic load per would-be event.
//!
//! Why incremental works here: the slab dual decomposes per-sample (the
//! same property the SMO pair update exploits), so admitting or evicting
//! one point perturbs a *feasible* dual by O(1) coordinates. A
//! warm-started exact solve from that perturbed point needs a few dozen
//! pair updates instead of a cold solve's thousands — `benches/
//! streaming.rs` (experiment ST1 in DESIGN.md) records the ratio against
//! a full retrain per sample.
//!
//! ```no_run
//! use slabsvm::stream::{StreamConfig, StreamSession};
//!
//! let mut session = StreamSession::new("live", StreamConfig::default());
//! let absorbed = session.absorb(&[20.0, 3.0]).unwrap();
//! if let Some(model) = absorbed.model {
//!     let _w = model.width(); // publishable model after warmup
//! }
//! ```

pub mod approx;
pub mod drift;
pub mod incremental;
pub mod manager;
pub mod persist;
pub mod policy;
pub mod session;
pub(crate) mod shard;
pub mod window;

pub use approx::{ApproxIncremental, StreamEngine};
pub use drift::{DriftConfig, DriftEvent, DriftMonitor};
pub use incremental::{IncrementalConfig, IncrementalSmo};
pub use manager::{
    ForgetOutcome, RestoredStream, RestoreOutcome, SnapshotOutcome,
    StreamManager, StreamPoolConfig, StreamSpec, StreamSummary,
};
pub use persist::{CheckpointConfig, RestoreInfo, Snapshot};
pub use policy::{EvictionPolicy, Fifo, InteriorFirst, PolicyKind};
pub use session::{Absorbed, Forgotten, StreamConfig, StreamSession};
pub use window::SlidingWindow;
