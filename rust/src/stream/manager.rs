//! Sharded multi-stream session manager: one coordinator driving many
//! concurrent tenant streams.
//!
//! `Coordinator::stream_push` is single-writer — the caller owns the
//! session and pushes one sample at a time. That shape cannot serve many
//! tenants at once, so the manager applies the paper's decompose-and-
//! parallelize logic one level up: sessions are **hashed to N shards by
//! stream name**, each shard is one worker thread running an event loop
//! over its sessions, and producers just enqueue onto the owning shard's
//! mailbox, **bounded per stream** ([`StreamManager::push`] blocks under
//! backpressure rather than dropping — absorbs are never lost, and a
//! hot tenant's backlog only blocks its own producer).
//!
//! Within a shard the data plane is served **weighted-fair** (round-
//! robin over streams, at most `weight` samples per visit), so one hot
//! tenant cannot starve the others; across shards, streams proceed in
//! parallel. Per-stream semantics are exactly the single-writer path's:
//! samples of one stream absorb in push order on one thread, every
//! absorbed sample hot-swaps the published model in the
//! [`ModelRegistry`](crate::coordinator::ModelRegistry) at a
//! monotonically increasing version, and a drift trip escalates a
//! background cascade retrain on the shared
//! [`TrainQueue`](crate::coordinator::TrainQueue) whose completion is
//! handed back to the owning shard (see `stream::shard`).
//!
//! ```no_run
//! use slabsvm::coordinator::{BatcherConfig, Coordinator};
//! use slabsvm::runtime::Engine;
//! use slabsvm::stream::{StreamConfig, StreamSpec};
//!
//! let c = Coordinator::start(Engine::Native, BatcherConfig::default(), 2);
//! c.open_streams(vec![
//!     StreamSpec::new("tenant-a", StreamConfig::default()),
//!     StreamSpec::new("tenant-b", StreamConfig::default()).weight(4),
//! ]).unwrap();
//! c.push("tenant-a", &[20.0, 3.0]).unwrap();
//! c.quiesce_streams();
//! let summary = c.close_stream("tenant-a").unwrap();
//! assert_eq!(summary.updates, 1);
//! ```

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::sync::{Mutex, RwLock};

use crate::coordinator::{ModelRegistry, ServiceStats, TrainQueue};
use crate::error::Error;
use crate::Result;

use super::persist::{self, CheckpointConfig};
use super::session::StreamConfig;
use super::shard::{run_worker, CheckpointSink, Shard};

/// Sizing of the sharded session manager.
#[derive(Clone, Debug)]
pub struct StreamPoolConfig {
    /// shard worker threads; sessions are hashed across them by name
    pub shards: usize,
    /// per-STREAM queue bound in samples; a producer blocks
    /// (backpressure) while its own stream's queue is at this depth, so
    /// a hot tenant's backlog never blocks its shard-mates' producers
    pub mailbox_cap: usize,
    /// periodic durable checkpointing of every live session (None =
    /// off). Shard workers serialize at most one due session per loop
    /// tick; a dedicated writer thread does the atomic file I/O.
    pub checkpoint: Option<CheckpointConfig>,
}

impl Default for StreamPoolConfig {
    fn default() -> Self {
        StreamPoolConfig { shards: 2, mailbox_cap: 1024, checkpoint: None }
    }
}

/// Per-stream outcome of a front-door [`StreamManager::snapshot_streams`]
/// sweep (failure isolation: one stream's write error never blocks the
/// rest).
#[derive(Debug)]
pub struct SnapshotOutcome {
    pub name: String,
    pub result: Result<()>,
}

/// One stream resumed by [`StreamManager::restore_streams`].
#[derive(Clone, Debug)]
pub struct RestoredStream {
    pub name: String,
    /// samples absorbed over the stream's pre-restart lifetime
    pub updates: u64,
    /// registry version the restored model was re-published under
    /// (None while the restored session was still warming up)
    pub version: Option<u64>,
    /// a repair sweep had to run (the snapshot state did not certify)
    pub repaired: bool,
}

/// Per-file outcome of restoring a snapshot directory.
#[derive(Debug)]
pub struct RestoreOutcome {
    pub file: PathBuf,
    pub result: Result<RestoredStream>,
}

/// What a targeted [`StreamManager::forget`] /
/// [`StreamManager::forget_many`] did.
#[derive(Clone, Debug)]
pub struct ForgetOutcome {
    pub name: String,
    /// the forgotten samples' stable ids (their 0-based arrival
    /// indices) — one entry for a single forget, the whole batch for
    /// [`StreamManager::forget_many`]
    pub ids: Vec<u64>,
    /// registry version of the re-published post-removal model (None
    /// when the shrunk session is below its warmup bar — the last
    /// published model keeps serving until the next absorb)
    pub version: Option<u64>,
    /// resident samples remaining in the window
    pub resident: usize,
}

/// One tenant stream to open on the manager.
#[derive(Clone, Debug)]
pub struct StreamSpec {
    pub name: String,
    pub cfg: StreamConfig,
    /// weighted-fair service weight: samples absorbed per scheduler
    /// visit before the shard moves to the next stream (≥ 1)
    pub weight: u32,
}

impl StreamSpec {
    pub fn new(name: impl Into<String>, cfg: StreamConfig) -> StreamSpec {
        StreamSpec { name: name.into(), cfg, weight: 1 }
    }

    /// Builder: set the fair-scheduling weight.
    pub fn weight(mut self, weight: u32) -> StreamSpec {
        self.weight = weight.max(1);
        self
    }

    /// Builder: set the window-eviction policy (default FIFO).
    pub fn eviction(mut self, policy: super::policy::PolicyKind) -> StreamSpec {
        self.cfg.incremental.policy = policy;
        self
    }
}

/// Final accounting for a closed stream (everything queued at close time
/// is absorbed first — the drain is part of the close).
#[derive(Clone, Debug)]
pub struct StreamSummary {
    pub name: String,
    /// samples absorbed over the stream's lifetime
    pub updates: u64,
    /// completed background retrains
    pub retrains: u64,
    /// last registry version this stream published (None = never warm)
    pub version: Option<u64>,
    /// slab offsets (ρ1, ρ2) at close
    pub rho: (f64, f64),
    /// dual objective ½ γᵀKγ at close
    pub objective: f64,
}

/// The sharded session manager. Owned by the
/// [`Coordinator`](crate::coordinator::Coordinator), which forwards
/// `open_streams` / `push` / `close_stream` to it.
pub struct StreamManager {
    shards: Vec<Arc<Shard>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// stream name → owning shard index (the open-stream set)
    route: RwLock<HashMap<String, usize>>,
    stats: Arc<ServiceStats>,
    /// checkpoint writer thread (None when checkpointing is off); it
    /// exits once every shard worker has dropped its sender
    ckpt_writer: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl StreamManager {
    /// Spawn `pool.shards` worker threads sharing `registry` (model
    /// hot-swaps), `jobs` (escalated retrains) and `stats`. With
    /// `pool.checkpoint` set, also spawns the snapshot writer thread
    /// the shard workers hand serialized sessions to.
    pub fn start(
        pool: StreamPoolConfig,
        registry: Arc<ModelRegistry>,
        jobs: Arc<TrainQueue>,
        stats: Arc<ServiceStats>,
    ) -> StreamManager {
        let n = pool.shards.max(1);
        let shards: Vec<Arc<Shard>> = (0..n)
            .map(|i| Arc::new(Shard::new(i, pool.mailbox_cap)))
            .collect();
        let (sink, ckpt_writer) = match &pool.checkpoint {
            Some(cfg) => {
                let (tx, rx) =
                    std::sync::mpsc::channel::<(PathBuf, Vec<u8>)>();
                let wstats = Arc::clone(&stats);
                let writer = std::thread::Builder::new()
                    .name("slabsvm-ckpt-writer".into())
                    .spawn(move || {
                        // drains until every shard drops its sender;
                        // each write is temp-file + fsync + rename, so
                        // a crash mid-write never leaves a truncated
                        // snapshot visible
                        for (path, bytes) in rx {
                            let len = bytes.len() as u64;
                            match persist::write_atomic(&path, &bytes) {
                                Ok(()) => {
                                    wstats.stream_checkpoints.inc();
                                    // value = snapshot bytes on disk
                                    crate::obs::record(
                                        crate::obs::EventKind::CheckpointWritten,
                                        0,
                                        0,
                                        u32::MAX,
                                        len,
                                    );
                                }
                                Err(e) => {
                                    wstats.stream_checkpoint_errors.inc();
                                    crate::log_warn!(
                                        "stream",
                                        "checkpoint write {} failed: {e}",
                                        path.display()
                                    );
                                }
                            }
                        }
                    })
                    .expect("spawn checkpoint writer");
                (
                    Some(CheckpointSink { cfg: cfg.clone(), tx }),
                    Some(writer),
                )
            }
            None => (None, None),
        };
        let workers = shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let shard = Arc::clone(shard);
                let registry = Arc::clone(&registry);
                let jobs = Arc::clone(&jobs);
                let stats = Arc::clone(&stats);
                let sink = sink.clone();
                std::thread::Builder::new()
                    .name(format!("slabsvm-shard-{i}"))
                    .spawn(move || {
                        run_worker(shard, registry, jobs, stats, sink)
                    })
                    .expect("spawn shard worker")
            })
            .collect();
        // the workers hold the only senders now: when the last worker
        // exits, the writer's channel closes and it drains out
        drop(sink);
        StreamManager {
            shards,
            workers: Mutex::new("manager.workers", workers),
            route: RwLock::new("manager.route", HashMap::new()),
            stats,
            ckpt_writer: Mutex::new("manager.ckpt_writer", ckpt_writer),
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Routed shard lookup. Route entries only ever hold indices handed
    /// out by [`StreamManager::shard_of`], so a miss means the route map
    /// and the shard vector disagree — surfaced as a typed error
    /// instead of an index panic on the serving path.
    fn shard_at(&self, idx: usize) -> Result<&Arc<Shard>> {
        self.shards.get(idx).ok_or_else(|| {
            Error::Coordinator(
                "stream route points at a missing shard".into(),
            )
        })
    }

    /// Deterministic name → shard placement (`DefaultHasher` uses fixed
    /// keys, so placement is stable for a given build).
    fn shard_of(&self, name: &str) -> usize {
        let mut h = DefaultHasher::new();
        name.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }

    /// Open a set of tenant streams, all-or-nothing: any name already
    /// open (or duplicated within the call) rejects the whole batch.
    pub fn open_streams(&self, specs: Vec<StreamSpec>) -> Result<()> {
        let mut route = self.route.write();
        let mut seen = HashSet::new();
        for spec in &specs {
            if route.contains_key(&spec.name) || !seen.insert(spec.name.as_str())
            {
                return Err(Error::Coordinator(format!(
                    "stream '{}' already open",
                    spec.name
                )));
            }
        }
        let mut opened: Vec<String> = Vec::with_capacity(specs.len());
        for spec in specs {
            let idx = self.shard_of(&spec.name);
            let accepted = self
                .shard_at(idx)
                .map(|shard| shard.open(&spec.name, spec.cfg, spec.weight));
            if !matches!(accepted, Ok(true)) {
                // all-or-nothing also under a shutdown race: un-route
                // whatever part of the batch already opened (the draining
                // shards drop the half-opened sessions on their way out)
                for name in opened {
                    route.remove(&name);
                }
                return Err(match accepted {
                    Err(e) => e,
                    _ => Error::Coordinator(format!(
                        "stream '{}': manager is shutting down",
                        spec.name
                    )),
                });
            }
            route.insert(spec.name.clone(), idx);
            opened.push(spec.name);
        }
        Ok(())
    }

    /// Enqueue one sample onto the owning shard's mailbox. Blocks while
    /// this stream's queue is at capacity (backpressure; never drops).
    ///
    /// This is where a trace is born: with the recorder enabled a trace
    /// id is minted here and rides the mailbox with the sample, so the
    /// owning shard's absorb→repair→hot-swap chain records under the
    /// same id ([`crate::obs`]). Disabled, `mint_trace` returns 0 and
    /// the whole chain stays dark for one relaxed atomic load.
    pub fn push(&self, name: &str, x: &[f64]) -> Result<()> {
        self.push_opts(name, x, true, None)
    }

    /// Non-blocking [`StreamManager::push`]: a stream queue at capacity
    /// is a typed [`Error::Saturated`] (carrying the observed depth)
    /// instead of a producer stall — the serving layer turns it into
    /// 429 + Retry-After. Same route lookup, trace minting and mailbox
    /// implementation as the blocking path.
    pub fn try_push(&self, name: &str, x: &[f64]) -> Result<()> {
        self.push_opts(name, x, false, None)
    }

    /// Push with an externally minted trace id (the HTTP front door
    /// mints one per request so the request→queue→absorb chain records
    /// under a single trace); `None` mints here as usual.
    pub(crate) fn push_opts(
        &self,
        name: &str,
        x: &[f64],
        block: bool,
        trace: Option<u64>,
    ) -> Result<()> {
        let idx = {
            let route = self.route.read();
            *route.get(name).ok_or_else(|| {
                Error::Coordinator(format!("unknown stream '{name}'"))
            })?
        };
        let trace = trace.unwrap_or_else(crate::obs::mint_trace);
        let t_enq = if trace != 0 {
            crate::obs::record(
                crate::obs::EventKind::PushEnqueued,
                trace,
                crate::obs::stream_id(name),
                idx as u32,
                0,
            );
            crate::obs::now_us()
        } else {
            0
        };
        let shard = self.shard_at(idx)?;
        if block {
            shard.push(name, x, trace, t_enq, &self.stats)?;
        } else {
            shard.try_push(name, x, trace, t_enq, &self.stats)?;
        }
        self.stats.stream_pushes.inc();
        Ok(())
    }

    /// Targeted unlearning on a managed stream: ask the owning shard to
    /// remove the resident sample with stable id `id` (the 0-based
    /// arrival index of that stream's pushes), withdraw its dual mass,
    /// repair, and re-publish the post-removal model. Blocks until the
    /// owning shard has applied it (like a retrain completion, the
    /// reconciliation happens on the shard's own loop — never on this
    /// caller's thread). The command is control-plane: it runs at the
    /// shard's next tick, *before* samples still queued for the stream
    /// — [`StreamManager::quiesce`] first when the id to forget might
    /// still be in flight. A background retrain in flight at removal
    /// time is **cancelled** (its training set contained the forgotten
    /// sample — its model never reaches the registry) and replaced by a
    /// fresh retrain of the post-removal window. A
    /// non-resident id (never absorbed, already
    /// evicted, or already forgotten) is a typed
    /// [`crate::Error::Unlearning`]; the stream keeps running.
    pub fn forget(&self, name: &str, id: u64) -> Result<ForgetOutcome> {
        self.forget_many(name, std::slice::from_ref(&id))
    }

    /// Batch unlearning: remove every id in `ids` from `name` with a
    /// **single** repair sweep, one re-published model and at most one
    /// cancelled/replaced background retrain — not the k repairs and k
    /// intermediate hot-swapped models k [`StreamManager::forget`]
    /// calls would publish ("delete all of user X" in one shard tick).
    /// Validation is all-or-nothing: any non-resident or duplicated id
    /// rejects the whole batch with a typed
    /// [`crate::Error::Unlearning`] and the stream is untouched.
    pub fn forget_many(&self, name: &str, ids: &[u64]) -> Result<ForgetOutcome> {
        let idx = {
            let route = self.route.read();
            *route.get(name).ok_or_else(|| {
                Error::Coordinator(format!("unknown stream '{name}'"))
            })?
        };
        self.shard_at(idx)?.forget_many(name, ids)
    }

    /// Close a stream: everything already queued for it is absorbed
    /// first, then its final accounting comes back. New pushes to the
    /// name fail as soon as this is called; the name is reusable once it
    /// returns.
    pub fn close_stream(&self, name: &str) -> Result<StreamSummary> {
        let idx = {
            let mut route = self.route.write();
            route.remove(name).ok_or_else(|| {
                Error::Coordinator(format!("unknown stream '{name}'"))
            })?
        };
        self.shard_at(idx)?.close(name)
    }

    /// Block until every queued sample on every shard has been absorbed
    /// (the point where counters like `stream_absorbed` are exact).
    pub fn quiesce(&self) {
        for shard in &self.shards {
            shard.wait_idle();
        }
    }

    /// Snapshot every open stream into `dir` (created if missing), one
    /// durable `*.snap` file per stream via atomic temp-file + rename
    /// writes, with per-stream failure isolation: one stream's write
    /// error is reported in its outcome and never blocks the rest.
    ///
    /// The sweep captures each session's absorbed-so-far state; call
    /// [`StreamManager::quiesce`] first when every pushed sample must
    /// be in the snapshot.
    pub fn snapshot_streams(&self, dir: &Path) -> Result<Vec<SnapshotOutcome>> {
        std::fs::create_dir_all(dir)?;
        // group open streams by owning shard so a dead shard's streams
        // get per-stream error outcomes instead of a lost ack
        let by_shard: Vec<(usize, Vec<String>)> = {
            let route = self.route.read();
            let mut groups: HashMap<usize, Vec<String>> = HashMap::new();
            for (name, &idx) in route.iter() {
                groups.entry(idx).or_default().push(name.clone());
            }
            groups.into_iter().collect()
        };
        let mut outcomes = Vec::new();
        for (idx, names) in by_shard {
            let swept = self
                .shard_at(idx)
                .and_then(|shard| shard.snapshot_all(dir.to_path_buf()));
            match swept {
                Ok(results) => {
                    for (name, result) in results {
                        outcomes.push(SnapshotOutcome { name, result });
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    for name in names {
                        outcomes.push(SnapshotOutcome {
                            name,
                            result: Err(Error::Coordinator(msg.clone())),
                        });
                    }
                }
            }
        }
        outcomes.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(outcomes)
    }

    /// Restore every `*.snap` file in `dir` into this manager: each
    /// snapshot is decoded, its Gram matrix re-derived and checksum-
    /// verified, the dual resumed (repair sweep only when the state
    /// does not certify), the session adopted by the shard its name
    /// hashes to, and its model re-published at (or past) the
    /// pre-restart registry version. Per-file failure isolation: a
    /// corrupt or conflicting snapshot yields an `Err` outcome for that
    /// file while every other stream restores.
    pub fn restore_streams(&self, dir: &Path) -> Result<Vec<RestoreOutcome>> {
        let files = persist::list_snapshots(dir)?;
        let mut outcomes = Vec::with_capacity(files.len());
        for file in files {
            let result = self.restore_one(&file);
            outcomes.push(RestoreOutcome { file, result });
        }
        Ok(outcomes)
    }

    fn restore_one(&self, file: &Path) -> Result<RestoredStream> {
        let snap = persist::read_snapshot(file)?;
        let weight = snap.weight;
        let last_version = snap.last_version;
        let updates = snap.updates;
        let (session, info) = snap.into_session()?;
        let name = session.name().to_string();
        let idx = self.shard_of(&name);
        // Reserve the name under the route write lock, then adopt with
        // the lock RELEASED: adopt blocks on the shard worker's ack, and
        // holding the route lock across that wait would stall every
        // push/open on the manager for the whole restore (and violate
        // the no-lock-across-a-blocking-handoff rule, lint [[R2]]). The
        // reservation keeps the restore atomic against a concurrent
        // open/restore of the same name; it is rolled back on failure.
        {
            let mut route = self.route.write();
            if route.contains_key(&name) {
                return Err(Error::Coordinator(format!(
                    "stream '{name}' already open"
                )));
            }
            route.insert(name.clone(), idx);
        }
        let adopted = self.shard_at(idx).and_then(|shard| {
            shard.adopt(&name, Box::new(session), weight, last_version)
        });
        match adopted {
            Ok(version) => Ok(RestoredStream {
                name,
                updates,
                version,
                repaired: info.repaired,
            }),
            Err(e) => {
                self.route.write().remove(&name);
                Err(e)
            }
        }
    }

    /// Is a stream currently open?
    pub fn is_open(&self, name: &str) -> bool {
        self.route.read().contains_key(name)
    }

    /// Number of open streams.
    pub fn open_count(&self) -> usize {
        self.route.read().len()
    }

    /// Samples queued or in flight across all shards (diagnostics).
    pub fn backlog(&self) -> usize {
        self.shards.iter().map(|s| s.queue_depth()).sum()
    }

    /// Drain everything queued, then stop the shard workers. Safe with
    /// background retrains still in flight — they belong to the train
    /// queue and are simply no longer reconciled into (now dropped)
    /// sessions. Idempotent.
    pub fn shutdown(&self) {
        for shard in &self.shards {
            shard.begin_drain();
        }
        // take the handles under the lock, join with it released — a
        // join can block for a full drain, and a second (idempotent)
        // shutdown call must not queue behind it on the handle lock
        let handles: Vec<std::thread::JoinHandle<()>> = {
            let mut workers = self.workers.lock();
            workers.drain(..).collect()
        };
        for handle in handles {
            let _ = handle.join();
        }
        // every worker (sender) is gone: the writer drains its queue
        // and exits, so joining it guarantees all final checkpoints of
        // a graceful shutdown are durably on disk
        let writer = self.ckpt_writer.lock().take();
        if let Some(writer) = writer {
            let _ = writer.join();
        }
        self.route.write().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    fn harness(
        shards: usize,
        mailbox_cap: usize,
    ) -> (StreamManager, Arc<ModelRegistry>, Arc<TrainQueue>) {
        let registry = Arc::new(ModelRegistry::new());
        let stats = Arc::new(ServiceStats::new());
        let jobs = Arc::new(TrainQueue::start(
            Arc::clone(&registry),
            Arc::clone(&stats),
        ));
        let m = StreamManager::start(
            StreamPoolConfig { shards, mailbox_cap, checkpoint: None },
            Arc::clone(&registry),
            Arc::clone(&jobs),
            stats,
        );
        (m, registry, jobs)
    }

    fn quick_cfg() -> StreamConfig {
        StreamConfig { window: 32, min_train: 16, ..Default::default() }
    }

    #[test]
    fn open_push_quiesce_close_roundtrip() {
        let (m, registry, jobs) = harness(2, 64);
        m.open_streams(vec![StreamSpec::new("s", quick_cfg())]).unwrap();
        assert!(m.is_open("s"));
        assert_eq!(m.open_count(), 1);
        let ds = SlabConfig::default().generate(40, 301);
        for i in 0..40 {
            m.push("s", ds.x.row(i)).unwrap();
        }
        m.quiesce();
        assert_eq!(m.backlog(), 0);
        // warm stream published a model under its name
        assert!(registry.get("s").is_some());
        let summary = m.close_stream("s").unwrap();
        assert_eq!(summary.updates, 40);
        assert!(summary.version.is_some());
        assert!(summary.objective.is_finite());
        assert!(!m.is_open("s"));
        m.shutdown();
        jobs.shutdown();
    }

    #[test]
    fn duplicate_open_rejected_all_or_nothing() {
        let (m, _registry, jobs) = harness(2, 64);
        m.open_streams(vec![StreamSpec::new("a", quick_cfg())]).unwrap();
        // existing name rejects the whole batch: b must not open
        assert!(m
            .open_streams(vec![
                StreamSpec::new("b", quick_cfg()),
                StreamSpec::new("a", quick_cfg()),
            ])
            .is_err());
        assert!(!m.is_open("b"));
        // intra-call duplicate rejects too
        assert!(m
            .open_streams(vec![
                StreamSpec::new("c", quick_cfg()),
                StreamSpec::new("c", quick_cfg()),
            ])
            .is_err());
        assert!(!m.is_open("c"));
        m.shutdown();
        jobs.shutdown();
    }

    #[test]
    fn unknown_stream_errors() {
        let (m, _registry, jobs) = harness(2, 64);
        assert!(m.push("ghost", &[0.0, 0.0]).is_err());
        assert!(m.close_stream("ghost").is_err());
        m.shutdown();
        jobs.shutdown();
    }

    #[test]
    fn name_reusable_after_close() {
        let (m, _registry, jobs) = harness(1, 64);
        m.open_streams(vec![StreamSpec::new("s", quick_cfg())]).unwrap();
        let ds = SlabConfig::default().generate(5, 302);
        for i in 0..5 {
            m.push("s", ds.x.row(i)).unwrap();
        }
        let first = m.close_stream("s").unwrap();
        assert_eq!(first.updates, 5);
        assert!(m.push("s", ds.x.row(0)).is_err(), "closed stream took a push");
        m.open_streams(vec![StreamSpec::new("s", quick_cfg())]).unwrap();
        m.push("s", ds.x.row(0)).unwrap();
        m.quiesce();
        let second = m.close_stream("s").unwrap();
        assert_eq!(second.updates, 1, "session must restart fresh");
        m.shutdown();
        jobs.shutdown();
    }

    #[test]
    fn forget_routes_to_owning_shard_and_rejects_bad_ids() {
        let (m, registry, jobs) = harness(2, 64);
        m.open_streams(vec![StreamSpec::new("s", quick_cfg())]).unwrap();
        let ds = SlabConfig::default().generate(40, 303);
        for i in 0..40 {
            m.push("s", ds.x.row(i)).unwrap();
        }
        m.quiesce();
        let v_before = registry.version("s").unwrap();
        // window 32, 40 pushed: ids 8..=39 are resident
        let out = m.forget("s", 20).unwrap();
        assert_eq!(out.name, "s");
        assert_eq!(out.ids, vec![20]);
        assert_eq!(out.resident, 31);
        assert!(out.version.unwrap() > v_before, "forget must re-publish");
        // batch forget: one call, one repair, one re-publish
        let v_single = out.version.unwrap();
        let out = m.forget_many("s", &[22, 25, 30]).unwrap();
        assert_eq!(out.ids, vec![22, 25, 30]);
        assert_eq!(out.resident, 28);
        assert!(out.version.unwrap() > v_single, "batch must re-publish");
        // a batch with one bad id is rejected whole, stream untouched
        let err = m.forget_many("s", &[23, 20]).unwrap_err();
        assert!(
            matches!(err, crate::Error::Unlearning(_)),
            "want Error::Unlearning, got {err:?}"
        );
        assert!(m.forget("s", 23).is_ok(), "id 23 must still be resident");
        // id 0 was FIFO-evicted long ago: typed error, stream survives
        let err = m.forget("s", 0).unwrap_err();
        assert!(
            matches!(err, crate::Error::Unlearning(_)),
            "want Error::Unlearning, got {err:?}"
        );
        m.push("s", ds.x.row(0)).unwrap();
        m.quiesce();
        let summary = m.close_stream("s").unwrap();
        assert_eq!(summary.updates, 41, "stream must keep absorbing");
        assert!(m.forget("s", 1).is_err(), "closed stream cannot forget");
        m.shutdown();
        jobs.shutdown();
    }

    #[test]
    fn hashing_spreads_streams_across_shards() {
        let (m, _registry, jobs) = harness(4, 64);
        let mut per_shard = vec![0usize; 4];
        for i in 0..256 {
            per_shard[m.shard_of(&format!("stream-{i}"))] += 1;
        }
        for (i, &n) in per_shard.iter().enumerate() {
            assert!(n > 0, "shard {i} never assigned: {per_shard:?}");
        }
        m.shutdown();
        jobs.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_refuses_new_work() {
        let (m, _registry, jobs) = harness(2, 64);
        m.open_streams(vec![StreamSpec::new("s", quick_cfg())]).unwrap();
        m.shutdown();
        m.shutdown();
        assert!(m.push("s", &[0.0, 0.0]).is_err());
        assert!(m
            .open_streams(vec![StreamSpec::new("late", quick_cfg())])
            .is_err());
        jobs.shutdown();
    }
}
