//! Durable stream sessions: versioned binary snapshot / restore (L4
//! persistence).
//!
//! A [`Snapshot`] captures everything a [`StreamSession`] needs to
//! resume after a process restart *without* a cold window refill: the
//! sliding-window samples (slot order + ring cursor), the full dual
//! state `(α, ᾱ, s)` with the slab offsets, the drift baseline, the
//! session counters and the last published registry version. The Gram
//! matrix is deliberately **not** serialized — it is O(m²), fully
//! determined by the samples, and re-derived on restore, then verified
//! against a checksum taken over the live matrix at snapshot time (a
//! bitwise-symmetric kernel makes the rebuild exact).
//!
//! The on-disk format is self-describing and versioned:
//!
//! ```text
//! [ magic "SLABSNAP" | format version u32 | config fingerprint u64 ]
//! [ name | weight | last registry version ]
//! [ config section: kernel, dims, SMO/incremental/drift parameters,
//!   eviction policy (v2), engine + lifted feature budget (v3) ]
//! [ state: sample ids (v2), samples, α, ᾱ, s, ρ1, ρ2, drift baseline,
//!   counters (v2 adds forgets), gram checksum, approx resume block
//!   (v3, approx engines only: freeze flag + frozen Nyström landmarks) ]
//! [ payload checksum u64 over every preceding byte ]
//! ```
//!
//! This build writes **format v3** (solver-engine tag + lifted feature
//! budget in the config section, and — for `nystroem`/`rff` streams —
//! an approx resume block in the state). It still reads v2 (which
//! predates the approximate engines, so every v2 stream decodes as the
//! exact engine) and v1: a v1 snapshot decodes as the
//! [`PolicyKind::Fifo`] policy with ids synthesized from the ring
//! cursor — exactly the identities the v1 writer's FIFO window held,
//! so a restored v1 session evicts and forgets identically to one that
//! never restarted. Re-encoding a decoded v1/v2 snapshot produces its
//! canonical v3 form.
//!
//! Approx streams persist no lifted state beyond the dual: the RFF map
//! is fully reconstructible from the config (seed, bandwidth, feature
//! budget), a frozen Nyström map from its stored landmark rows, and a
//! still-warming Nyström map from the resident samples themselves (its
//! landmark set *is* the resident set until the budget is reached).
//! The `gram_checksum` slot doubles as a checksum over the re-lifted
//! feature rows, so the rebuilt map is verified exactly like the
//! rebuilt Gram.
//!
//! All integers are little-endian; floats are IEEE-754 bit patterns, so
//! a snapshot round-trips **bitwise**. The trailing payload checksum
//! (FNV-1a) means a crash-truncated or corrupted file fails with a
//! clean [`Error::Snapshot`] instead of half-loading; the config
//! fingerprint (FNV-1a over the config section alone) lets a restorer
//! that *expects* a particular [`StreamConfig`] reject a snapshot taken
//! under a different one ([`Snapshot::restore_expecting`]).
//!
//! Restore semantics: the dual state written at snapshot time is always
//! post-repair (every absorbed sample ends in a bounded KKT repair), so
//! the restored state normally certifies as-is and restore is **exact**
//! — bitwise model/dual parity with the snapshot. If the state does not
//! certify (a snapshot hand-built or taken by a future writer mid-
//! perturbation), restore self-heals with the same warm-started bounded
//! repair sweep the per-sample path uses. Either way the resumed
//! session passes a fresh-Gram KKT certificate.
//!
//! Durability: [`write_atomic`] writes to a temp file in the target
//! directory, fsyncs, then renames over the destination (and fsyncs the
//! directory), so a crash mid-write can never leave a truncated
//! `*.snap` visible to a restorer. Each durable write is mirrored as a
//! `checkpoint_written` flight-recorder event ([`crate::obs`]) — from
//! the writer thread for periodic checkpoints (value = bytes written)
//! and from the owning shard for front-door snapshot sweeps — so
//! checkpoint cadence is observable next to the absorbs it protects.

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::Error;
use crate::kernel::featmap::{
    EngineKind, FeatMap, FeatureMap, NystroemMap,
};
use crate::kernel::{Kernel, Precision};
use crate::linalg::Matrix;
use crate::solver::approx::{rff_map, ApproxParams, LiftedSlab};
use crate::solver::smo::SmoParams;
use crate::solver::{validate, Heuristic};
use crate::Result;

use super::approx::{ApproxIncremental, StreamEngine};
use super::drift::DriftConfig;
use super::incremental::{IncrementalConfig, IncrementalSmo};
use super::policy::PolicyKind;
use super::session::{StreamConfig, StreamSession};
use super::window::SlidingWindow;

/// First 8 bytes of every snapshot.
pub const MAGIC: [u8; 8] = *b"SLABSNAP";

/// Format version this build writes. Reads this and every earlier one
/// (v1 decodes as the Fifo policy with synthesized sample ids; v2
/// predates the approximate engines and decodes as the exact one).
pub const FORMAT_VERSION: u32 = 3;

/// Periodic per-shard checkpointing of live sessions.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// directory the per-stream `*.snap` files land in
    pub dir: PathBuf,
    /// minimum time between two checkpoints of the same stream; the
    /// shard worker serializes at most ONE due session per loop tick
    /// (the absorb hot path is never blocked longer than one serialize)
    /// and hands the bytes to a dedicated writer thread for the I/O
    pub every: Duration,
}

impl CheckpointConfig {
    pub fn new(dir: impl Into<PathBuf>, every: Duration) -> CheckpointConfig {
        CheckpointConfig { dir: dir.into(), every }
    }
}

// ------------------------------------------------------------------ fnv

/// FNV-1a 64-bit — the format's checksum/fingerprint hash (stable,
/// dependency-free, byte-order independent).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Checksum of a window's Gram matrix (row-major over resident slots).
/// Computed from the *live* matrix at snapshot time and from the
/// re-derived matrix at restore time; equality proves the rebuild.
fn gram_checksum(window: &SlidingWindow) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for i in 0..window.len() {
        for &v in window.row(i) {
            for &b in &v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
    }
    h
}

/// Checksum of an approx engine's lifted feature rows (row-major,
/// slot order) — the approximate engines' analogue of
/// [`gram_checksum`]: computed over the live lifted state at snapshot
/// time and over the re-lifted rows at restore time, so equality
/// proves the feature map was rebuilt exactly.
fn flat_checksum(vals: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in vals {
        for &b in &v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

// -------------------------------------------------------------- encoder

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64s(&mut self, vs: &[f64]) {
        for &v in vs {
            self.f64(v);
        }
    }
    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

// -------------------------------------------------------------- decoder

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }
    fn need(&self, n: usize) -> Result<()> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            Error::snapshot("length field overflows".to_string())
        })?;
        if end > self.buf.len() {
            return Err(Error::snapshot(format!(
                "truncated snapshot: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
    /// Take the next `n` bytes. The single bounds check every decode
    /// goes through — a truncated or corrupt file is a typed
    /// [`Error::snapshot`], never an index panic on the restore path.
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        self.need(n)?;
        let end = self.pos + n;
        let s = self.buf.get(self.pos..end).ok_or_else(|| {
            Error::snapshot("truncated snapshot".to_string())
        })?;
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        match self.take(1)? {
            &[v] => Ok(v),
            _ => Err(Error::snapshot("truncated snapshot".to_string())),
        }
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        b.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(b))
    }
    fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| {
            Error::snapshot(format!("length field {v} overflows usize"))
        })
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        self.need(n.checked_mul(8).ok_or_else(|| {
            Error::snapshot("length field overflows".to_string())
        })?)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }
    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let s = std::str::from_utf8(self.take(n)?)
            .map_err(|_| Error::snapshot("stream name is not UTF-8"))?
            .to_string();
        Ok(s)
    }
}

// ------------------------------------------------------ config section

fn kernel_tag(k: &Kernel) -> (u8, f64, f64, f64) {
    match *k {
        Kernel::Linear => (0, 0.0, 0.0, 0.0),
        Kernel::Rbf { g } => (1, g, 0.0, 0.0),
        Kernel::Poly { g, c, degree } => (2, g, c, degree),
        Kernel::Sigmoid { g, c } => (3, g, c, 0.0),
    }
}

fn kernel_from_tag(tag: u8, g: f64, c: f64, degree: f64) -> Result<Kernel> {
    match tag {
        0 => Ok(Kernel::Linear),
        1 => Ok(Kernel::Rbf { g }),
        2 => Ok(Kernel::Poly { g, c, degree }),
        3 => Ok(Kernel::Sigmoid { g, c }),
        other => Err(Error::snapshot(format!("unknown kernel tag {other}"))),
    }
}

fn heuristic_tag(h: Heuristic) -> u8 {
    match h {
        Heuristic::PaperMaxFbar => 0,
        Heuristic::MaxViolation => 1,
        Heuristic::RandomViolator => 2,
        Heuristic::SecondOrder => 3,
    }
}

fn heuristic_from_tag(tag: u8) -> Result<Heuristic> {
    match tag {
        0 => Ok(Heuristic::PaperMaxFbar),
        1 => Ok(Heuristic::MaxViolation),
        2 => Ok(Heuristic::RandomViolator),
        3 => Ok(Heuristic::SecondOrder),
        other => Err(Error::snapshot(format!("unknown heuristic tag {other}"))),
    }
}

/// Canonical (current-version) byte encoding of a [`StreamConfig`] —
/// the fingerprint is FNV-1a over exactly these bytes, so two configs
/// fingerprint equal iff every field matches bitwise. v2 appends the
/// eviction-policy tag; v3 appends the solver-engine tag and the
/// lifted feature budget.
fn config_section(cfg: &StreamConfig) -> Vec<u8> {
    let mut e = Enc::new();
    let (tag, g, c, degree) = kernel_tag(&cfg.kernel);
    e.u8(tag);
    e.f64(g);
    e.f64(c);
    e.f64(degree);
    e.u64(cfg.dim as u64);
    e.u64(cfg.window as u64);
    e.u64(cfg.min_train as u64);
    let p = &cfg.incremental.smo;
    e.f64(p.nu1);
    e.f64(p.nu2);
    e.f64(p.eps);
    e.f64(p.tol);
    e.u64(p.max_iter as u64);
    e.u8(heuristic_tag(p.heuristic));
    e.u64(p.seed);
    e.f64(p.sv_tol);
    e.u8(p.shrinking as u8);
    e.u64(cfg.incremental.repair_max_iter as u64);
    e.u64(cfg.incremental.refresh_every);
    e.u64(cfg.drift.recent as u64);
    e.u64(cfg.drift.min_observations as u64);
    e.f64(cfg.drift.outside_frac);
    e.f64(cfg.drift.rho_rel);
    e.u64(cfg.retrain_shards as u64);
    e.u64(cfg.retrain_rounds as u64);
    e.u8(cfg.incremental.policy.tag());
    e.u8(cfg.incremental.engine.tag());
    e.u64(cfg.incremental.features as u64);
    e.buf
}

fn decode_config(d: &mut Dec<'_>, version: u32) -> Result<StreamConfig> {
    let tag = d.u8()?;
    let (g, c, degree) = (d.f64()?, d.f64()?, d.f64()?);
    let kernel = kernel_from_tag(tag, g, c, degree)?;
    let dim = d.usize()?;
    let window = d.usize()?;
    let min_train = d.usize()?;
    let smo = SmoParams {
        nu1: d.f64()?,
        nu2: d.f64()?,
        eps: d.f64()?,
        tol: d.f64()?,
        max_iter: d.usize()?,
        heuristic: heuristic_from_tag(d.u8()?)?,
        seed: d.u64()?,
        sv_tol: d.f64()?,
        shrinking: d.u8()? != 0,
    };
    let mut incremental = IncrementalConfig {
        smo,
        repair_max_iter: d.usize()?,
        refresh_every: d.u64()?,
        policy: PolicyKind::Fifo,
        // compute hint, not semantic config: deliberately absent from
        // the wire format (and therefore from config fingerprints) so
        // flipping the retrain precision can't orphan old snapshots.
        // `restore_expecting` grafts the caller's precision on.
        precision: Precision::F64,
        // v2 predates the approx engines; overwritten below for v3+
        engine: EngineKind::Exact,
        features: 64,
    };
    let drift = DriftConfig {
        recent: d.usize()?,
        min_observations: d.usize()?,
        outside_frac: d.f64()?,
        rho_rel: d.f64()?,
    };
    let retrain_shards = d.usize()?;
    let retrain_rounds = d.usize()?;
    // v1 predates eviction policies; every v1 window was FIFO
    if version >= 2 {
        incremental.policy = PolicyKind::from_tag(d.u8()?)?;
    }
    // v2 predates the approximate engines; every v2 stream was exact
    if version >= 3 {
        incremental.engine = EngineKind::from_tag(d.u8()?)?;
        incremental.features = d.usize()?;
    }
    Ok(StreamConfig {
        kernel,
        dim,
        window,
        min_train,
        incremental,
        drift,
        retrain_shards,
        retrain_rounds,
    })
}

/// Reconstruct the per-slot sample ids a v1 (pre-id) snapshot's FIFO
/// window held: residents are the last `len` admits; while growing,
/// slot i holds admit i; once full, admit `a` sits at slot
/// `a % capacity` (the old ring cursor). v1 windows never shrank, so
/// any other shape is a corrupt file.
fn synthesize_v1_ids(len: usize, admitted: u64, capacity: usize) -> Result<Vec<u64>> {
    if admitted == len as u64 {
        return Ok((0..admitted).collect());
    }
    if len == capacity {
        let cap = capacity as u64;
        let base = admitted - cap;
        return Ok((0..len as u64)
            .map(|slot| base + ((slot + cap - base % cap) % cap))
            .collect());
    }
    Err(Error::snapshot(format!(
        "v1 snapshot is inconsistent: {admitted} admitted but only {len} \
         resident in a window of {capacity} (partial v1 windows never \
         evicted)"
    )))
}

// ------------------------------------------------------------ snapshot

/// What happened on restore, beyond the session itself.
#[derive(Clone, Copy, Debug)]
pub struct RestoreInfo {
    /// max KKT violation of the restored dual before any repair
    pub kkt_violation: f64,
    /// whether a warm-started repair sweep had to run (false = the
    /// snapshot state certified as-is and the restore is bitwise exact)
    pub repaired: bool,
}

/// A decoded (or about-to-be-encoded) stream-session snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// format version this snapshot was decoded from (informational:
    /// [`FORMAT_VERSION`] for fresh captures, and [`Snapshot::encode`]
    /// always writes the current format regardless)
    pub format_version: u32,
    pub name: String,
    /// manager fair-scheduling weight (1 for single-writer sessions)
    pub weight: u32,
    /// last registry version the owner published (0 = never)
    pub last_version: u64,
    pub cfg: StreamConfig,
    /// resident sample count (≤ cfg.window)
    pub len: usize,
    /// total samples ever admitted (also the next sample id)
    pub admitted: u64,
    /// stable per-sample ids, slot order (v1 files: synthesized from
    /// the ring cursor — the identities the FIFO window actually held)
    pub ids: Vec<u64>,
    /// resident samples, slot order, row-major `len · dim`
    pub points: Vec<f64>,
    pub alpha: Vec<f64>,
    pub alpha_bar: Vec<f64>,
    /// margins s = K(α − ᾱ), freshly recomputed at capture time so the
    /// restore-side recomputation from the re-derived Gram is bitwise
    /// identical
    pub s: Vec<f64>,
    pub rho1: f64,
    pub rho2: f64,
    /// the session had armed its drift baseline
    pub baselined: bool,
    /// drift baseline (ρ1, ρ2) at the last (re)baseline, if armed
    pub baseline: Option<(f64, f64)>,
    pub updates: u64,
    pub retrains: u64,
    /// samples removed by targeted unlearning (0 for v1 files)
    pub forgets: u64,
    pub repair_iterations: u64,
    /// FNV-1a over the live Gram matrix at capture time (exact
    /// engine), or over the live lifted feature rows (approx engines)
    pub gram_checksum: u64,
    /// approx engines only: the feature map had frozen (RFF is frozen
    /// from construction; Nyström freezes once the landmark budget is
    /// reached). Always false for exact streams.
    pub approx_frozen: bool,
    /// frozen-Nyström landmark rows `(rows, row-major rows·dim data)`;
    /// `None` for exact streams, RFF streams (reconstructible from the
    /// config seed) and still-warming Nyström streams (the landmark
    /// set is the resident set)
    pub landmarks: Option<(usize, Vec<f64>)>,
}

impl Snapshot {
    /// Capture a session's full resume state. `weight`/`last_version`
    /// are the manager-layer envelope (pass `1`/`None` for a
    /// single-writer session).
    pub fn capture(
        session: &StreamSession,
        weight: u32,
        last_version: Option<u64>,
    ) -> Snapshot {
        struct State {
            len: usize,
            admitted: u64,
            ids: Vec<u64>,
            points: Vec<f64>,
            alpha: Vec<f64>,
            alpha_bar: Vec<f64>,
            s: Vec<f64>,
            rho: (f64, f64),
            repair_iterations: u64,
            checksum: u64,
            frozen: bool,
            landmarks: Option<(usize, Vec<f64>)>,
        }
        let st = match session.solver() {
            StreamEngine::Exact(inc) => {
                let w = inc.window();
                let mut points = Vec::with_capacity(w.len() * w.dim());
                for i in 0..w.len() {
                    points.extend_from_slice(w.point(i));
                }
                State {
                    len: w.len(),
                    admitted: w.admitted(),
                    ids: w.ids().to_vec(),
                    points,
                    alpha: inc.alpha().to_vec(),
                    alpha_bar: inc.alpha_bar().to_vec(),
                    s: inc.fresh_margins(),
                    rho: inc.rho(),
                    repair_iterations: inc.repair_iterations(),
                    checksum: gram_checksum(w),
                    frozen: false,
                    landmarks: None,
                }
            }
            StreamEngine::Approx(a) => {
                let m = a.len();
                let mut points = Vec::with_capacity(m * a.dim());
                for i in 0..m {
                    points.extend_from_slice(a.point(i));
                }
                // only a *frozen* Nyström map carries state that the
                // residents + config can't reproduce — its landmarks
                // are a snapshot of the residents at freeze time
                let landmarks = match a.featmap() {
                    FeatMap::Nystroem(n) if a.is_frozen() => {
                        let lm = n.landmarks();
                        Some((lm.rows(), lm.data().to_vec()))
                    }
                    _ => None,
                };
                State {
                    len: m,
                    admitted: a.admitted(),
                    ids: a.ids().to_vec(),
                    points,
                    alpha: a.alpha().to_vec(),
                    alpha_bar: a.alpha_bar().to_vec(),
                    s: a.fresh_margins(),
                    rho: a.rho(),
                    repair_iterations: a.repair_iterations(),
                    checksum: flat_checksum(a.core().phi_flat()),
                    frozen: a.is_frozen(),
                    landmarks,
                }
            }
        };
        Snapshot {
            format_version: FORMAT_VERSION,
            name: session.name().to_string(),
            weight: weight.max(1),
            last_version: last_version.unwrap_or(0),
            cfg: *session.config(),
            len: st.len,
            admitted: st.admitted,
            ids: st.ids,
            points: st.points,
            alpha: st.alpha,
            alpha_bar: st.alpha_bar,
            s: st.s,
            rho1: st.rho.0,
            rho2: st.rho.1,
            baselined: session.is_baselined(),
            baseline: session.drift_monitor().baseline(),
            updates: session.updates(),
            retrains: session.retrains(),
            forgets: session.forgets(),
            repair_iterations: st.repair_iterations,
            gram_checksum: st.checksum,
            approx_frozen: st.frozen,
            landmarks: st.landmarks,
        }
    }

    /// Fingerprint of a config — what the header carries, and what
    /// [`Snapshot::restore_expecting`] compares against.
    pub fn config_fingerprint(cfg: &StreamConfig) -> u64 {
        fnv1a(&config_section(cfg))
    }

    /// Serialize to the canonical byte format (see module docs).
    /// `decode(encode(s))` round-trips bitwise.
    pub fn encode(&self) -> Vec<u8> {
        let cfg_bytes = config_section(&self.cfg);
        let mut e = Enc::new();
        e.buf.extend_from_slice(&MAGIC);
        e.u32(FORMAT_VERSION);
        e.u64(fnv1a(&cfg_bytes));
        e.str(&self.name);
        e.u32(self.weight);
        e.u64(self.last_version);
        e.buf.extend_from_slice(&cfg_bytes);
        e.u64(self.len as u64);
        e.u64(self.admitted);
        for &id in &self.ids {
            e.u64(id);
        }
        e.f64s(&self.points);
        e.f64s(&self.alpha);
        e.f64s(&self.alpha_bar);
        e.f64s(&self.s);
        e.f64(self.rho1);
        e.f64(self.rho2);
        e.u8(self.baselined as u8);
        match self.baseline {
            Some((b1, b2)) => {
                e.u8(1);
                e.f64(b1);
                e.f64(b2);
            }
            None => e.u8(0),
        }
        e.u64(self.updates);
        e.u64(self.retrains);
        e.u64(self.forgets);
        e.u64(self.repair_iterations);
        e.u64(self.gram_checksum);
        // v3: approx resume block, only for approx-engine streams
        if self.cfg.incremental.engine != EngineKind::Exact {
            e.u8(self.approx_frozen as u8);
            match &self.landmarks {
                Some((rows, data)) => {
                    e.u8(1);
                    e.u64(*rows as u64);
                    e.f64s(data);
                }
                None => e.u8(0),
            }
        }
        let check = fnv1a(&e.buf);
        e.u64(check);
        e.buf
    }

    /// Parse + integrity-check a snapshot. Magic, format version, the
    /// trailing payload checksum (truncation/corruption) and the config
    /// fingerprint are all verified; every failure is a clean
    /// [`Error::Snapshot`], never a panic.
    pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
        if bytes.len() < MAGIC.len() + 4 + 8 + 8 {
            return Err(Error::snapshot(format!(
                "file too short to be a snapshot ({} bytes)",
                bytes.len()
            )));
        }
        // the length precheck above covers every header access; each
        // one still goes through `get` so a corrupt file can only ever
        // surface as a typed error, never an index panic
        let truncated =
            || Error::snapshot("file too short to be a snapshot".to_string());
        if bytes.get(..MAGIC.len()).ok_or_else(truncated)? != MAGIC {
            return Err(Error::snapshot(
                "bad magic: not a slabsvm stream snapshot",
            ));
        }
        let version = {
            let mut b = [0u8; 4];
            b.copy_from_slice(bytes.get(8..12).ok_or_else(truncated)?);
            u32::from_le_bytes(b)
        };
        if version == 0 || version > FORMAT_VERSION {
            return Err(Error::snapshot(format!(
                "unsupported snapshot format version {version} \
                 (this build reads versions 1..={FORMAT_VERSION})"
            )));
        }
        let body_end = bytes.len() - 8;
        let body = bytes.get(..body_end).ok_or_else(truncated)?;
        let stored_check = {
            let mut b = [0u8; 8];
            b.copy_from_slice(bytes.get(body_end..).ok_or_else(truncated)?);
            u64::from_le_bytes(b)
        };
        if fnv1a(body) != stored_check {
            return Err(Error::snapshot(
                "payload checksum mismatch: snapshot is truncated or \
                 corrupted",
            ));
        }
        let mut d = Dec::new(body);
        d.pos = 8 + 4; // past magic + version
        let fingerprint = d.u64()?;
        let name = d.str()?;
        let weight = d.u32()?;
        let last_version = d.u64()?;
        let cfg_start = d.pos;
        let cfg = decode_config(&mut d, version)?;
        let cfg_section = body.get(cfg_start..d.pos).ok_or_else(|| {
            Error::snapshot("config section out of bounds".to_string())
        })?;
        if fnv1a(cfg_section) != fingerprint {
            return Err(Error::snapshot(
                "config fingerprint does not match the config section",
            ));
        }
        let len = d.usize()?;
        if cfg.dim == 0 || cfg.window < 2 {
            return Err(Error::snapshot(format!(
                "invalid config: dim={} window={}",
                cfg.dim, cfg.window
            )));
        }
        if len > cfg.window {
            return Err(Error::snapshot(format!(
                "resident count {len} exceeds window capacity {}",
                cfg.window
            )));
        }
        let admitted = d.u64()?;
        if admitted < len as u64 {
            return Err(Error::snapshot(format!(
                "ring cursor admitted={admitted} below resident count {len}"
            )));
        }
        let ids = if version >= 2 {
            // bound the allocation by the actual bytes present (the
            // same discipline f64s() applies)
            d.need(len.checked_mul(8).ok_or_else(|| {
                Error::snapshot("id block size overflows".to_string())
            })?)?;
            let mut ids = Vec::with_capacity(len);
            for _ in 0..len {
                ids.push(d.u64()?);
            }
            ids
        } else {
            synthesize_v1_ids(len, admitted, cfg.window)?
        };
        if ids.iter().any(|&id| id >= admitted) {
            return Err(Error::snapshot(format!(
                "sample id at or past the admit counter {admitted}"
            )));
        }
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        if sorted.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::snapshot(
                "duplicate sample ids in snapshot state",
            ));
        }
        let points = d.f64s(len.checked_mul(cfg.dim).ok_or_else(|| {
            Error::snapshot("sample block size overflows".to_string())
        })?)?;
        let alpha = d.f64s(len)?;
        let alpha_bar = d.f64s(len)?;
        let s = d.f64s(len)?;
        let rho1 = d.f64()?;
        let rho2 = d.f64()?;
        let baselined = d.u8()? != 0;
        let baseline = if d.u8()? != 0 {
            Some((d.f64()?, d.f64()?))
        } else {
            None
        };
        let updates = d.u64()?;
        let retrains = d.u64()?;
        let forgets = if version >= 2 { d.u64()? } else { 0 };
        let repair_iterations = d.u64()?;
        let gram_checksum = d.u64()?;
        let (approx_frozen, landmarks) = if version >= 3
            && cfg.incremental.engine != EngineKind::Exact
        {
            let frozen = d.u8()? != 0;
            let lm = if d.u8()? != 0 {
                let rows = d.usize()?;
                let data =
                    d.f64s(rows.checked_mul(cfg.dim).ok_or_else(|| {
                        Error::snapshot(
                            "landmark block size overflows".to_string(),
                        )
                    })?)?;
                Some((rows, data))
            } else {
                None
            };
            (frozen, lm)
        } else {
            (false, None)
        };
        if d.pos != body_end {
            return Err(Error::snapshot(format!(
                "{} trailing bytes after snapshot state",
                body_end - d.pos
            )));
        }
        Ok(Snapshot {
            format_version: version,
            name,
            weight,
            last_version,
            cfg,
            len,
            admitted,
            ids,
            points,
            alpha,
            alpha_bar,
            s,
            rho1,
            rho2,
            baselined,
            baseline,
            updates,
            retrains,
            forgets,
            repair_iterations,
            gram_checksum,
            approx_frozen,
            landmarks,
        })
    }

    /// Reject a snapshot taken under a different stream configuration
    /// (field-for-field, via the config fingerprint), then restore.
    pub fn restore_expecting(
        bytes: &[u8],
        expected: &StreamConfig,
    ) -> Result<(StreamSession, RestoreInfo)> {
        let mut snap = Snapshot::decode(bytes)?;
        let got = Snapshot::config_fingerprint(&snap.cfg);
        let want = Snapshot::config_fingerprint(expected);
        if got != want {
            return Err(Error::snapshot(format!(
                "config fingerprint mismatch: snapshot {got:#018x}, \
                 expected {want:#018x} — the stream '{}' was captured \
                 under a different configuration",
                snap.name
            )));
        }
        // Precision is a compute hint excluded from the wire format and
        // the fingerprint; the restored session adopts the caller's.
        snap.cfg.incremental.precision = expected.incremental.precision;
        snap.into_session()
    }

    /// One-line human description (the `slabsvm snapshot --inspect`
    /// output) — the format is self-describing, so everything here
    /// comes from the file alone.
    pub fn describe(&self) -> String {
        format!(
            "stream '{}' format v{} fingerprint {:#018x}\n\
             kernel={} dim={} window={} resident={} admitted={} \
             policy={} engine={} features={}\n\
             nu1={} nu2={} eps={} updates={} retrains={} forgets={} \
             last_version={}\n\
             rho=[{:.6}, {:.6}] baseline={:?} repair_iterations={}",
            self.name,
            self.format_version,
            Snapshot::config_fingerprint(&self.cfg),
            self.cfg.kernel.family(),
            self.cfg.dim,
            self.cfg.window,
            self.len,
            self.admitted,
            self.cfg.incremental.policy,
            self.cfg.incremental.engine,
            self.cfg.incremental.features,
            self.cfg.incremental.smo.nu1,
            self.cfg.incremental.smo.nu2,
            self.cfg.incremental.smo.eps,
            self.updates,
            self.retrains,
            self.forgets,
            self.last_version,
            self.rho1,
            self.rho2,
            self.baseline,
            self.repair_iterations,
        )
    }

    /// Validate the state, re-derive the Gram matrix from the restored
    /// samples (verified against the stored checksum) and resume the
    /// session. The restored dual is certified against the fresh Gram;
    /// a state outside tolerance gets the standard warm-started bounded
    /// repair sweep (see module docs).
    pub fn into_session(self) -> Result<(StreamSession, RestoreInfo)> {
        let m = self.len;
        if self.points.len() != m * self.cfg.dim {
            return Err(Error::snapshot(format!(
                "sample block holds {} values, want {}",
                self.points.len(),
                m * self.cfg.dim
            )));
        }
        for v in self
            .points
            .iter()
            .chain(&self.alpha)
            .chain(&self.alpha_bar)
            .chain(&self.s)
            .chain([self.rho1, self.rho2].iter())
        {
            if !v.is_finite() {
                return Err(Error::snapshot(
                    "non-finite value in snapshot state",
                ));
            }
        }
        let p = self.cfg.incremental.smo;
        if self.alpha.len() != m || self.alpha_bar.len() != m || self.s.len() != m {
            return Err(Error::snapshot(format!(
                "dual blocks hold {}/{}/{} values, want {m} each",
                self.alpha.len(),
                self.alpha_bar.len(),
                self.s.len()
            )));
        }
        if m > 0 {
            let sa: f64 = self.alpha.iter().sum();
            let sb: f64 = self.alpha_bar.iter().sum();
            if (sa - 1.0).abs() > 1e-6 || (sb - p.eps).abs() > 1e-6 {
                return Err(Error::snapshot(format!(
                    "infeasible dual state: sum(alpha)={sa}, \
                     sum(alpha_bar)={sb} (eps={})",
                    p.eps
                )));
            }
            let cap_a = 1.0 / (p.nu1 * m as f64);
            let cap_b = p.eps / (p.nu2 * m as f64);
            for (i, (a, b)) in self.alpha.iter().zip(&self.alpha_bar).enumerate() {
                let in_box = (-1e-9..=cap_a + 1e-9).contains(a)
                    && (-1e-9..=cap_b + 1e-9).contains(b);
                if !in_box {
                    return Err(Error::snapshot(format!(
                        "dual coordinate {i} outside its box",
                    )));
                }
            }
        }

        if self.cfg.incremental.engine != EngineKind::Exact {
            return self.into_approx_session();
        }

        // Re-derive the Gram matrix from the samples; the checksum over
        // the rebuilt matrix must match the one taken over the live
        // matrix at snapshot time.
        let window = SlidingWindow::restore(
            self.cfg.kernel,
            self.cfg.window,
            self.cfg.dim,
            self.points,
            self.ids,
            self.admitted,
        );
        let rebuilt = gram_checksum(&window);
        if rebuilt != self.gram_checksum {
            return Err(Error::snapshot(format!(
                "gram checksum mismatch after rebuild: stored \
                 {:#018x}, recomputed {rebuilt:#018x}",
                self.gram_checksum
            )));
        }

        let mut inc = IncrementalSmo::restore(
            window,
            self.cfg.incremental,
            self.alpha,
            self.alpha_bar,
            self.s,
            self.rho1,
            self.rho2,
            self.repair_iterations,
        );

        // Certify against the fresh Gram; repair only when the restored
        // dual is outside tolerance (never for snapshots this code
        // wrote — they are post-repair states — so the normal restore
        // is bitwise exact).
        let mut info = RestoreInfo { kkt_violation: 0.0, repaired: false };
        if m >= 2 {
            let cap_a = 1.0 / (p.nu1 * m as f64);
            let cap_b = p.eps / (p.nu2 * m as f64);
            let cert = validate::report_with_margins(
                inc.alpha(),
                inc.alpha_bar(),
                inc.margins(),
                self.rho1,
                self.rho2,
                p.nu1,
                p.nu2,
                p.eps,
                cap_a.min(cap_b) * 1e-6,
            );
            info.kkt_violation = cert.max_kkt_violation;
            let margin_scale = 1.0
                + inc.margins().iter().map(|v| v.abs()).sum::<f64>()
                    / m as f64;
            if cert.max_kkt_violation > p.tol * margin_scale {
                inc.repair_in_place()?;
                info.repaired = true;
            }
        }

        let session = StreamSession::from_parts(
            self.name,
            self.cfg,
            StreamEngine::Exact(inc),
            self.baselined,
            self.baseline,
            self.updates,
            self.retrains,
            self.forgets,
        );
        Ok((session, info))
    }

    /// Approx-engine restore: rebuild the feature map (RFF from the
    /// config seed, frozen Nyström from its stored landmark rows,
    /// warming Nyström from the residents), re-lift every resident and
    /// verify the lifted rows against the stored checksum, then resume
    /// the lifted dual — certify-or-repair, exactly like the exact
    /// path certifies against its rebuilt Gram.
    fn into_approx_session(self) -> Result<(StreamSession, RestoreInfo)> {
        let m = self.len;
        let cfg = self.cfg;
        let inc_cfg = cfg.incremental;
        let p = inc_cfg.smo;
        if let Some((rows, data)) = &self.landmarks {
            if *rows == 0
                || data.len()
                    != rows.checked_mul(cfg.dim).unwrap_or(usize::MAX)
            {
                return Err(Error::snapshot(format!(
                    "landmark block holds {} values, want {}·{}",
                    data.len(),
                    rows,
                    cfg.dim
                )));
            }
            if data.iter().any(|v| !v.is_finite()) {
                return Err(Error::snapshot(
                    "non-finite value in landmark block",
                ));
            }
        }
        let params = ApproxParams {
            smo: p,
            engine: inc_cfg.engine,
            features: inc_cfg.features,
        };
        let map = match inc_cfg.engine {
            EngineKind::Rff => rff_map(&params, cfg.kernel, cfg.dim)
                .map_err(|e| {
                    Error::snapshot(format!("rff map rebuild failed: {e}"))
                })?,
            EngineKind::Nystroem => {
                if self.approx_frozen && self.landmarks.is_none() {
                    return Err(Error::snapshot(
                        "frozen nystroem snapshot is missing its \
                         landmark block",
                    ));
                }
                let lm = match &self.landmarks {
                    Some((rows, data)) => {
                        Matrix::from_vec(*rows, cfg.dim, data.clone())
                    }
                    // still warming: the landmark set IS the resident
                    // set (grow_landmarks rebuilds over all residents
                    // every admit), so it needs no separate storage
                    None if m > 0 => {
                        Matrix::from_vec(m, cfg.dim, self.points.clone())
                    }
                    // empty stream: the same placeholder the fresh
                    // constructor starts from
                    None => Matrix::zeros(1, cfg.dim),
                };
                FeatMap::Nystroem(
                    NystroemMap::new(cfg.kernel, lm).map_err(|e| {
                        Error::snapshot(format!(
                            "nystroem map rebuild failed: {e}"
                        ))
                    })?,
                )
            }
            EngineKind::Exact => {
                return Err(Error::snapshot(
                    "exact engine reached the approx restore path",
                ))
            }
        };

        // Re-lift the residents through the rebuilt map; the checksum
        // over the lifted rows must match the one taken over the live
        // lifted state at snapshot time.
        let d_out = map.d_out();
        let mut scratch = vec![0.0; map.scratch_len().max(1)];
        let mut phi = vec![0.0; m * d_out];
        for i in 0..m {
            let x = self
                .points
                .get(i * cfg.dim..(i + 1) * cfg.dim)
                .ok_or_else(|| {
                    Error::snapshot("sample block out of bounds".to_string())
                })?;
            let out = phi
                .get_mut(i * d_out..(i + 1) * d_out)
                .ok_or_else(|| {
                    Error::snapshot("lifted block out of bounds".to_string())
                })?;
            map.map_into(x, &mut scratch, out);
        }
        let rebuilt = flat_checksum(&phi);
        if rebuilt != self.gram_checksum {
            return Err(Error::snapshot(format!(
                "lifted-feature checksum mismatch after map rebuild: \
                 stored {:#018x}, recomputed {rebuilt:#018x}",
                self.gram_checksum
            )));
        }

        let mut core = LiftedSlab::restore(
            d_out,
            &p,
            phi,
            self.alpha,
            self.alpha_bar,
            self.rho1,
            self.rho2,
        );
        let mut info = RestoreInfo { kkt_violation: 0.0, repaired: false };
        if m >= 2 {
            let cert = core.certify();
            info.kkt_violation = cert.max_kkt_violation;
            let margin_scale = 1.0
                + core.margins().iter().map(|v| v.abs()).sum::<f64>()
                    / m as f64;
            if cert.max_kkt_violation > p.tol * margin_scale {
                core.repair(inc_cfg.repair_max_iter.max(1));
                info.repaired = true;
            }
        }

        let inc = ApproxIncremental::restore(
            cfg.kernel,
            cfg.window,
            cfg.dim,
            inc_cfg,
            map,
            self.approx_frozen,
            self.points,
            self.ids,
            self.admitted,
            core,
            self.repair_iterations,
        );
        let session = StreamSession::from_parts(
            self.name,
            cfg,
            StreamEngine::Approx(inc),
            self.baselined,
            self.baseline,
            self.updates,
            self.retrains,
            self.forgets,
        );
        Ok((session, info))
    }
}

// ------------------------------------------------------------ file I/O

/// Deterministic snapshot filename for a stream: sanitized name plus an
/// FNV hash of the raw name (distinct names never collide on disk even
/// when sanitization makes them look alike).
pub fn snapshot_filename(name: &str) -> String {
    let safe: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .take(64)
        .collect();
    format!("{safe}-{:08x}.snap", fnv1a(name.as_bytes()) as u32)
}

/// `dir/<snapshot_filename(name)>`.
pub fn snapshot_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(snapshot_filename(name))
}

/// Crash-safe file replacement: write to a temp file in the same
/// directory, fsync it, rename over the destination, fsync the
/// directory. A reader can only ever observe the old file or the
/// complete new one — never a truncation (and a truncated leftover
/// would fail the payload checksum anyway). The temp name carries the
/// pid and a process-wide nonce so concurrent writers targeting the
/// same snapshot (e.g. a front-door sweep racing the periodic
/// checkpoint writer) never share a temp file — last rename wins with
/// a complete file either way.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    use std::sync::atomic::{AtomicU64, Ordering};
    static TMP_NONCE: AtomicU64 = AtomicU64::new(0);
    let nonce = TMP_NONCE.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!(
        "snap.{}-{nonce}.tmp",
        std::process::id()
    ));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Read + decode one snapshot file.
pub fn read_snapshot(path: &Path) -> Result<Snapshot> {
    let bytes = std::fs::read(path)?;
    Snapshot::decode(&bytes)
}

/// All `*.snap` files in a directory, sorted by filename (deterministic
/// restore order).
pub fn list_snapshots(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some("snap") {
            out.push(path);
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SlabConfig;

    fn warm_session(n: usize, seed: u64) -> StreamSession {
        let cfg = StreamConfig {
            window: 32,
            min_train: 16,
            ..Default::default()
        };
        let mut s = StreamSession::new("t", cfg);
        let ds = SlabConfig::default().generate(n, seed);
        for i in 0..n {
            s.absorb(ds.x.row(i)).unwrap();
        }
        s
    }

    #[test]
    fn encode_decode_roundtrips_bitwise() {
        let session = warm_session(40, 401);
        let snap = Snapshot::capture(&session, 3, Some(25));
        let bytes = snap.encode();
        let back = Snapshot::decode(&bytes).unwrap();
        assert_eq!(back.name, "t");
        assert_eq!(back.weight, 3);
        assert_eq!(back.last_version, 25);
        assert_eq!(back.len, 32);
        assert_eq!(back.admitted, 40);
        assert_eq!(back.ids, snap.ids);
        assert_eq!(back.forgets, 0);
        assert_eq!(back.points, snap.points);
        assert_eq!(back.alpha, snap.alpha);
        assert_eq!(back.alpha_bar, snap.alpha_bar);
        assert_eq!(back.s, snap.s);
        assert_eq!(back.rho1.to_bits(), snap.rho1.to_bits());
        assert_eq!(back.rho2.to_bits(), snap.rho2.to_bits());
        assert_eq!(back.baseline, snap.baseline);
        assert_eq!(back.updates, 40);
        assert_eq!(back.gram_checksum, snap.gram_checksum);
        // canonical: re-encoding the decoded snapshot is byte-identical
        assert_eq!(back.encode(), bytes);
    }

    #[test]
    fn fingerprint_changes_with_any_config_field() {
        let base = StreamConfig::default();
        let f0 = Snapshot::config_fingerprint(&base);
        let mut w = base;
        w.window += 1;
        assert_ne!(f0, Snapshot::config_fingerprint(&w));
        let mut k = base;
        k.kernel = Kernel::Rbf { g: 0.5 };
        assert_ne!(f0, Snapshot::config_fingerprint(&k));
        let mut n = base;
        n.incremental.smo.nu1 += 1e-12;
        assert_ne!(f0, Snapshot::config_fingerprint(&n));
        let mut p = base;
        p.incremental.policy = PolicyKind::InteriorFirst;
        assert_ne!(f0, Snapshot::config_fingerprint(&p));
        let mut e = base;
        e.incremental.engine = EngineKind::Rff;
        assert_ne!(f0, Snapshot::config_fingerprint(&e));
        let mut d = base;
        d.incremental.features = 128;
        assert_ne!(f0, Snapshot::config_fingerprint(&d));
        assert_eq!(f0, Snapshot::config_fingerprint(&base));
    }

    #[test]
    fn empty_and_warming_sessions_snapshot_too() {
        let cfg = StreamConfig { window: 8, min_train: 4, ..Default::default() };
        // empty
        let s0 = StreamSession::new("empty", cfg);
        let (r0, _) =
            Snapshot::decode(&Snapshot::capture(&s0, 1, None).encode())
                .unwrap()
                .into_session()
                .unwrap();
        assert_eq!(r0.updates(), 0);
        assert!(r0.solver().is_empty());
        // one sample (no repairable pair yet)
        let mut s1 = StreamSession::new("one", cfg);
        s1.absorb(&[20.0, 3.0]).unwrap();
        let (r1, info) =
            Snapshot::decode(&Snapshot::capture(&s1, 1, None).encode())
                .unwrap()
                .into_session()
                .unwrap();
        assert_eq!(r1.solver().len(), 1);
        assert!(!info.repaired);
        assert_eq!(r1.solver().alpha(), &[1.0]);
    }

    #[test]
    fn filenames_are_sanitized_and_collision_free() {
        let a = snapshot_filename("tenant/alpha");
        let b = snapshot_filename("tenant_alpha");
        assert!(a.ends_with(".snap"));
        assert!(!a.contains('/'));
        assert_ne!(a, b, "sanitized collisions must differ via the hash");
        assert_eq!(a, snapshot_filename("tenant/alpha"), "deterministic");
    }

    #[test]
    fn describe_is_self_contained() {
        let session = warm_session(20, 402);
        let snap = Snapshot::capture(&session, 1, None);
        let text = snap.describe();
        assert!(text.contains("stream 't'"), "{text}");
        assert!(text.contains("format v3"), "{text}");
        assert!(text.contains("window=32"), "{text}");
        assert!(text.contains("policy=fifo"), "{text}");
        assert!(text.contains("engine=exact"), "{text}");
    }

    fn approx_cfg(engine: EngineKind, features: usize) -> StreamConfig {
        let mut cfg = StreamConfig {
            kernel: Kernel::Rbf { g: 0.5 },
            window: 24,
            min_train: 8,
            ..Default::default()
        };
        cfg.incremental.engine = engine;
        cfg.incremental.features = features;
        cfg
    }

    #[test]
    fn approx_sessions_snapshot_restore_and_continue_bitwise() {
        for engine in [EngineKind::Nystroem, EngineKind::Rff] {
            let cfg = approx_cfg(engine, 8);
            let mut live = StreamSession::new("ap", cfg);
            let ds = SlabConfig::default().generate(48, 907);
            for i in 0..40 {
                live.absorb(ds.x.row(i)).unwrap();
            }
            let snap = Snapshot::capture(&live, 1, None);
            let bytes = snap.encode();
            let back = Snapshot::decode(&bytes).unwrap();
            assert_eq!(back.encode(), bytes, "canonical re-encode");
            let (mut restored, info) = back.into_session().unwrap();
            assert!(
                !info.repaired,
                "{engine:?}: post-repair approx state must certify as-is"
            );
            assert_eq!(restored.solver().alpha(), live.solver().alpha());
            assert_eq!(restored.solver().ids(), live.solver().ids());
            let (l1, l2) = live.solver().rho();
            let (r1, r2) = restored.solver().rho();
            assert_eq!(l1.to_bits(), r1.to_bits());
            assert_eq!(l2.to_bits(), r2.to_bits());
            // continue in lockstep: the restored session must absorb
            // new samples bitwise-identically to one that never paused
            for i in 40..48 {
                live.absorb(ds.x.row(i)).unwrap();
                restored.absorb(ds.x.row(i)).unwrap();
            }
            assert_eq!(
                restored.solver().alpha(),
                live.solver().alpha(),
                "{engine:?}: restored session diverged after resume"
            );
            assert_eq!(
                restored.solver().margins(),
                live.solver().margins()
            );
        }
    }

    #[test]
    fn warming_nystroem_snapshots_without_a_landmark_block() {
        // below the feature budget the map is derived from the
        // residents themselves: nothing extra on the wire
        let cfg = approx_cfg(EngineKind::Nystroem, 16);
        let mut live = StreamSession::new("warm", cfg);
        let ds = SlabConfig::default().generate(6, 908);
        for i in 0..6 {
            live.absorb(ds.x.row(i)).unwrap();
        }
        let snap = Snapshot::capture(&live, 1, None);
        assert!(!snap.approx_frozen);
        assert!(snap.landmarks.is_none());
        let (restored, info) =
            Snapshot::decode(&snap.encode()).unwrap().into_session().unwrap();
        assert!(!info.repaired);
        assert_eq!(restored.solver().alpha(), live.solver().alpha());
        // frozen sessions DO carry landmarks
        let mut frozen = StreamSession::new("froze", cfg);
        let ds2 = SlabConfig::default().generate(20, 909);
        for i in 0..20 {
            frozen.absorb(ds2.x.row(i)).unwrap();
        }
        let fsnap = Snapshot::capture(&frozen, 1, None);
        assert!(fsnap.approx_frozen);
        let (rows, _) = fsnap.landmarks.as_ref().unwrap();
        assert_eq!(*rows, 16);
    }

    #[test]
    fn approx_snapshot_rejects_tampered_landmarks() {
        let cfg = approx_cfg(EngineKind::Nystroem, 4);
        let mut live = StreamSession::new("tamper", cfg);
        let ds = SlabConfig::default().generate(12, 910);
        for i in 0..12 {
            live.absorb(ds.x.row(i)).unwrap();
        }
        let mut snap = Snapshot::capture(&live, 1, None);
        if let Some((_, data)) = snap.landmarks.as_mut() {
            data[0] += 1.0;
        }
        // decode succeeds (the payload checksum covers the bytes we
        // re-encode), but the lifted rebuild no longer matches
        match Snapshot::decode(&snap.encode()).unwrap().into_session() {
            Ok(_) => panic!("tampered landmarks must not restore"),
            Err(err) => assert!(
                err.to_string().contains("checksum"),
                "want a lifted-checksum failure, got: {err}"
            ),
        }
    }

    #[test]
    fn forgotten_sessions_snapshot_and_restore_their_state() {
        let mut s = warm_session(40, 403);
        let id = s.solver().id(3);
        s.forget(id).unwrap();
        let snap = Snapshot::capture(&s, 1, None);
        assert_eq!(snap.forgets, 1);
        assert_eq!(snap.len, 31);
        assert!(!snap.ids.contains(&id));
        let (back, info) =
            Snapshot::decode(&snap.encode()).unwrap().into_session().unwrap();
        assert!(!info.repaired, "post-repair forget state must certify");
        assert_eq!(back.forgets(), 1);
        assert_eq!(back.solver().ids(), s.solver().ids());
        assert_eq!(back.solver().alpha(), s.solver().alpha());
    }
}
