//! Pluggable window-eviction policies.
//!
//! Once a [`super::window::SlidingWindow`] is full, every further
//! absorb must evict one resident sample. Which one is a policy
//! decision, made over the *dual* state — the same decomposition
//! argument that makes the per-sample SMO update cheap says the dual
//! weight γ = α − ᾱ is exactly how much a resident point carries the
//! slab: interior points (γ ≈ 0) can leave without moving the model,
//! support vectors cannot.
//!
//! * [`Fifo`] — evict the oldest resident sample (smallest per-sample
//!   id). Bitwise-identical to the pre-policy eviction path: with no
//!   targeted removals the oldest id always sits in the slot the old
//!   ring cursor (`admitted % capacity`) pointed at.
//! * [`InteriorFirst`] — evict the resident point with the smallest
//!   margin-slack score |α − ᾱ|, i.e. interior non-support points
//!   before support vectors; ties break toward the oldest id (so a
//!   window of all-interior points degrades to FIFO, deterministically).
//!   Keeping the support set resident is what lets a smaller window
//!   hold the accuracy of a larger FIFO one (experiment WP1,
//!   `rust/benches/streaming.rs`).
//!
//! The trait is object-safe and stateless; configs carry the
//! serializable [`PolicyKind`] tag (snapshot format v2, CLI `--evict`)
//! and resolve it to a `&'static dyn EvictionPolicy` at use sites.
//! Every eviction the chosen policy makes is recorded as an `evict`
//! flight-recorder event carrying the victim's stable id
//! ([`crate::obs`]), so policy behavior is auditable on a live stream.

use crate::error::Error;

/// Selects the eviction victim among the resident samples.
///
/// `ids[i]` is slot `i`'s stable per-sample id (admit sequence number —
/// older samples have smaller ids); `alpha`/`alpha_bar` are the slot's
/// dual multipliers. All three slices share the slot indexing and are
/// non-empty when this is called. Returns the victim slot index.
pub trait EvictionPolicy: Send + Sync {
    /// The serializable tag of this policy.
    fn kind(&self) -> PolicyKind;

    /// Pick the slot to evict. Must be a valid index into `ids`.
    fn victim(&self, ids: &[u64], alpha: &[f64], alpha_bar: &[f64]) -> usize;
}

/// Evict the oldest resident sample (smallest id) — the classic
/// sliding window, bitwise-identical to the pre-policy ring cursor.
pub struct Fifo;

impl Fifo {
    /// Slot of the smallest id — THE min-id scan. Shared by the trait
    /// impl and by callers with no dual state in hand
    /// (`SlidingWindow::fifo_slot`), so the "bitwise-identical to the
    /// old ring cursor" contract has exactly one implementation.
    pub fn oldest(ids: &[u64]) -> usize {
        let mut best = 0;
        for (i, &id) in ids.iter().enumerate() {
            if id < ids[best] {
                best = i;
            }
        }
        best
    }
}

impl EvictionPolicy for Fifo {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Fifo
    }

    fn victim(&self, ids: &[u64], _alpha: &[f64], _alpha_bar: &[f64]) -> usize {
        Fifo::oldest(ids)
    }
}

/// Evict the resident point with the smallest |α − ᾱ| (interior
/// non-support points before support vectors); ties go to the oldest.
pub struct InteriorFirst;

impl EvictionPolicy for InteriorFirst {
    fn kind(&self) -> PolicyKind {
        PolicyKind::InteriorFirst
    }

    fn victim(&self, ids: &[u64], alpha: &[f64], alpha_bar: &[f64]) -> usize {
        let mut best = 0;
        let mut best_score = f64::INFINITY;
        let mut best_id = u64::MAX;
        for i in 0..ids.len() {
            let score = (alpha[i] - alpha_bar[i]).abs();
            if score < best_score || (score == best_score && ids[i] < best_id)
            {
                best = i;
                best_score = score;
                best_id = ids[i];
            }
        }
        best
    }
}

/// Serializable policy tag: what configs, snapshots (format v2) and the
/// CLI (`--evict`) carry; resolves to the trait object via
/// [`PolicyKind::policy`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PolicyKind {
    /// evict the oldest resident sample
    #[default]
    Fifo,
    /// evict the smallest-|α − ᾱ| resident (interior points first)
    InteriorFirst,
}

static FIFO: Fifo = Fifo;
static INTERIOR_FIRST: InteriorFirst = InteriorFirst;

impl PolicyKind {
    /// Every kind, for sweeps and benches.
    pub const ALL: [PolicyKind; 2] = [PolicyKind::Fifo, PolicyKind::InteriorFirst];

    /// The policy implementation behind this tag.
    pub fn policy(self) -> &'static dyn EvictionPolicy {
        match self {
            PolicyKind::Fifo => &FIFO,
            PolicyKind::InteriorFirst => &INTERIOR_FIRST,
        }
    }

    /// Stable one-byte tag for the snapshot format (v2).
    pub fn tag(self) -> u8 {
        match self {
            PolicyKind::Fifo => 0,
            PolicyKind::InteriorFirst => 1,
        }
    }

    /// Inverse of [`PolicyKind::tag`]; unknown tags are a typed error
    /// (a snapshot written by a future build, never a panic).
    pub fn from_tag(tag: u8) -> crate::Result<PolicyKind> {
        match tag {
            0 => Ok(PolicyKind::Fifo),
            1 => Ok(PolicyKind::InteriorFirst),
            other => Err(Error::snapshot(format!(
                "unknown eviction policy tag {other}"
            ))),
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::InteriorFirst => "interior-first",
        })
    }
}

impl std::str::FromStr for PolicyKind {
    type Err = Error;

    fn from_str(s: &str) -> crate::Result<PolicyKind> {
        match s {
            "fifo" => Ok(PolicyKind::Fifo),
            "interior-first" => Ok(PolicyKind::InteriorFirst),
            other => Err(Error::config(format!(
                "unknown eviction policy {other:?} (expected fifo|interior-first)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_picks_smallest_id_regardless_of_mass() {
        let ids = [7u64, 3, 11, 5];
        let a = [0.0, 0.9, 0.1, 0.2];
        let b = [0.0, 0.0, 0.0, 0.0];
        assert_eq!(Fifo.victim(&ids, &a, &b), 1);
    }

    #[test]
    fn interior_first_picks_smallest_margin_slack() {
        let ids = [0u64, 1, 2, 3];
        let a = [0.30, 0.25, 0.25, 0.20];
        let b = [0.00, 0.25, 0.10, 0.05];
        // |gamma| = [0.30, 0.00, 0.15, 0.15] -> slot 1 is interior
        assert_eq!(InteriorFirst.victim(&ids, &a, &b), 1);
    }

    #[test]
    fn interior_first_breaks_ties_toward_oldest() {
        let ids = [9u64, 2, 5];
        let a = [0.5, 0.25, 0.25];
        let b = [0.0, 0.25, 0.25]; // slots 1 and 2 tie at |gamma| = 0
        assert_eq!(InteriorFirst.victim(&ids, &a, &b), 1);
    }

    #[test]
    fn kind_round_trips_through_tag_and_str() {
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::from_tag(kind.tag()).unwrap(), kind);
            assert_eq!(kind.to_string().parse::<PolicyKind>().unwrap(), kind);
            assert_eq!(kind.policy().kind(), kind);
        }
        assert!(PolicyKind::from_tag(9).is_err());
        assert!("lru".parse::<PolicyKind>().is_err());
    }
}
